"""MoE: sort-based dispatch vs a per-token oracle; shard_map expert
parallelism vs the single-shard path; load-balance loss properties."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.layers.moe import _dispatch_tables, _moe_local, apply_moe, init_moe
from repro.layers.mlp import activation_fn


def _cfg(e=4, k=2, ff=16, d=8, cap=100.0):
    return ModelConfig(
        arch_id="t", family="moe", num_layers=1, d_model=d, num_heads=2,
        num_kv_heads=2, d_ff=ff, vocab_size=16,
        moe=MoEConfig(num_experts=e, experts_per_token=k, expert_d_ff=ff,
                      capacity_factor=cap),
        dtype="float32", param_dtype="float32",
    )


def _oracle(params, x, cfg):
    """Per-token dense mixture (no capacity drops)."""
    moe = cfg.moe
    logits = x @ params["router"]["kernel"]
    probs = jax.nn.softmax(logits, -1)
    top_g, top_i = jax.lax.top_k(probs, moe.experts_per_token)
    top_g = top_g / top_g.sum(-1, keepdims=True)
    act = activation_fn(cfg.activation)
    out = jnp.zeros_like(x)
    for t in range(x.shape[0]):
        acc = jnp.zeros((x.shape[1],))
        for j in range(moe.experts_per_token):
            e = int(top_i[t, j])
            h = act(x[t] @ params["w_gate"][e]) * (x[t] @ params["w_in"][e])
            acc += top_g[t, j] * (h @ params["w_out"][e])
        out = out.at[t].set(acc)
    return out


def test_dispatch_tables_invariants():
    t, k, e, cap = 16, 2, 4, 8
    idx = jax.random.randint(jax.random.key(0), (t, k), 0, e)
    gate = jax.nn.softmax(jax.random.normal(jax.random.key(1), (t, k)))
    table, gates, frac = _dispatch_tables(idx, gate, e, cap)
    assert table.shape == (e, cap) and gates.shape == (e, cap)
    # every real slot points to a token that chose this expert
    tbl = np.asarray(table)
    for ei in range(e):
        for ci in range(cap):
            tok = tbl[ei, ci]
            if tok < t:
                assert ei in np.asarray(idx)[tok]
    # fractions sum to 1 over experts
    np.testing.assert_allclose(np.asarray(frac).sum(), 1.0, rtol=1e-6)


def test_local_matches_oracle_no_drops():
    cfg = _cfg()
    params = init_moe(jax.random.key(0), cfg.d_model, cfg.moe, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (12, cfg.d_model), jnp.float32)
    got, aux = _moe_local(
        x, params, moe=cfg.moe, activation=cfg.activation, dtype=jnp.float32,
        expert_shards=1, expert_rank=0,
    )
    want = _oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    assert float(aux) > 0


def test_capacity_drops_tokens():
    """With capacity_factor << 1 outputs lose tokens but stay finite."""
    cfg = _cfg(cap=0.3)
    params = init_moe(jax.random.key(0), cfg.d_model, cfg.moe, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (32, cfg.d_model), jnp.float32)
    got, _ = _moe_local(
        x, params, moe=cfg.moe, activation=cfg.activation, dtype=jnp.float32,
        expert_shards=1, expert_rank=0,
    )
    assert np.isfinite(np.asarray(got)).all()
    dropped_rows = np.where(np.abs(np.asarray(got)).sum(-1) == 0)[0]
    assert len(dropped_rows) > 0  # some tokens exceeded capacity


def test_aux_loss_uniform_router_is_one_x_weight():
    """Perfectly uniform routing gives the Switch loss's minimum E * (1/E)
    * (1/E) * E = 1 (x weight)."""
    cfg = _cfg()
    params = init_moe(jax.random.key(0), cfg.d_model, cfg.moe, jnp.float32)
    params = dict(params)
    params["router"] = {"kernel": jnp.zeros_like(params["router"]["kernel"])}
    x = jax.random.normal(jax.random.key(1), (64, cfg.d_model), jnp.float32)
    _, aux = _moe_local(
        x, params, moe=cfg.moe, activation=cfg.activation, dtype=jnp.float32,
        expert_shards=1, expert_rank=0,
    )
    assert np.isclose(float(aux), cfg.moe.load_balance_loss_weight, rtol=1e-5)
