"""Eq. 1 workload-share invariants (hypothesis property tests)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.partitioner import (
    allocate_kernels,
    predicted_conv_time,
    speedup,
    workload_shares,
)

times_strategy = st.lists(
    st.floats(min_value=1e-3, max_value=1e3, allow_nan=False, allow_infinity=False),
    min_size=1,
    max_size=32,
)


@given(times_strategy)
def test_shares_sum_to_one(times):
    s = workload_shares(times)
    assert np.isclose(s.sum(), 1.0)
    assert np.all(s > 0)


@given(times_strategy)
def test_shares_inverse_monotonic(times):
    """Faster device (smaller time) never gets a smaller share."""
    s = workload_shares(times)
    t = np.asarray(times)
    order = np.argsort(t)
    assert np.all(np.diff(s[order]) <= 1e-12)


@given(times_strategy, st.integers(min_value=0, max_value=5000))
def test_allocation_preserves_total(times, num_kernels):
    k = allocate_kernels(num_kernels, times)
    assert k.sum() == num_kernels
    assert np.all(k >= 0)


@given(times_strategy, st.integers(min_value=64, max_value=5000))
@settings(max_examples=50)
def test_allocation_close_to_ideal(times, num_kernels):
    """Integer allocation is within 1 kernel of the fractional ideal."""
    s = workload_shares(times)
    k = allocate_kernels(num_kernels, times)
    assert np.all(np.abs(k - s * num_kernels) <= 1.0 + 1e-9)


def test_paper_example():
    """§4.1.1: devices at 10 s and 20 s -> shares (2/3, 1/3), both finish
    in 6.67 s, speedup 1.5x vs device 1."""
    times = [10.0, 20.0]
    s = workload_shares(times)
    assert np.allclose(s, [2 / 3, 1 / 3])
    k = allocate_kernels(300, times)
    assert list(k) == [200, 100]
    t = predicted_conv_time(times, k, 300)
    assert np.isclose(t, 20 / 3, rtol=1e-6)
    assert np.isclose(speedup(times, k, 300), 1.5, rtol=1e-6)


@given(times_strategy)
@settings(max_examples=50)
def test_balanced_finish_times(times):
    """Under fractional Eq. 1 shares every device finishes simultaneously
    in the harmonic-aggregate time."""
    t = np.asarray(times)
    s = workload_shares(times)
    finish = t * s
    assert np.allclose(finish, finish[0], rtol=1e-9)
    assert np.allclose(finish[0], 1.0 / np.sum(1.0 / t), rtol=1e-9)


def test_homogeneous_fixed_point():
    """Homogeneous devices -> uniform shares (the TPU-mesh degenerate
    case noted in DESIGN.md)."""
    s = workload_shares([3.7] * 8)
    assert np.allclose(s, 1 / 8)


def test_invalid_inputs():
    with pytest.raises(ValueError):
        workload_shares([])
    with pytest.raises(ValueError):
        workload_shares([1.0, -2.0])
    with pytest.raises(ValueError):
        allocate_kernels(-1, [1.0])
