"""Eq. 1 workload-share invariants (hypothesis property tests) and the
comm-extended Eq. 1 (compute + wire time per device)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.partitioner import (
    DeviceProfile,
    allocate_kernels,
    comm_aware_allocate,
    link_aware_times,
    predicted_conv_time,
    profiles_to_shares,
    speedup,
    workload_shares,
)

times_strategy = st.lists(
    st.floats(min_value=1e-3, max_value=1e3, allow_nan=False, allow_infinity=False),
    min_size=1,
    max_size=32,
)


@given(times_strategy)
def test_shares_sum_to_one(times):
    s = workload_shares(times)
    assert np.isclose(s.sum(), 1.0)
    assert np.all(s > 0)


@given(times_strategy)
def test_shares_inverse_monotonic(times):
    """Faster device (smaller time) never gets a smaller share."""
    s = workload_shares(times)
    t = np.asarray(times)
    order = np.argsort(t)
    assert np.all(np.diff(s[order]) <= 1e-12)


@given(times_strategy, st.integers(min_value=0, max_value=5000))
def test_allocation_preserves_total(times, num_kernels):
    k = allocate_kernels(num_kernels, times)
    assert k.sum() == num_kernels
    assert np.all(k >= 0)


@given(times_strategy, st.integers(min_value=64, max_value=5000))
@settings(max_examples=50)
def test_allocation_close_to_ideal(times, num_kernels):
    """Integer allocation is within 1 kernel of the fractional ideal."""
    s = workload_shares(times)
    k = allocate_kernels(num_kernels, times)
    assert np.all(np.abs(k - s * num_kernels) <= 1.0 + 1e-9)


def test_paper_example():
    """§4.1.1: devices at 10 s and 20 s -> shares (2/3, 1/3), both finish
    in 6.67 s, speedup 1.5x vs device 1."""
    times = [10.0, 20.0]
    s = workload_shares(times)
    assert np.allclose(s, [2 / 3, 1 / 3])
    k = allocate_kernels(300, times)
    assert list(k) == [200, 100]
    t = predicted_conv_time(times, k, 300)
    assert np.isclose(t, 20 / 3, rtol=1e-6)
    assert np.isclose(speedup(times, k, 300), 1.5, rtol=1e-6)


@given(times_strategy)
@settings(max_examples=50)
def test_balanced_finish_times(times):
    """Under fractional Eq. 1 shares every device finishes simultaneously
    in the harmonic-aggregate time."""
    t = np.asarray(times)
    s = workload_shares(times)
    finish = t * s
    assert np.allclose(finish, finish[0], rtol=1e-9)
    assert np.allclose(finish[0], 1.0 / np.sum(1.0 / t), rtol=1e-9)


def test_homogeneous_fixed_point():
    """Homogeneous devices -> uniform shares (the TPU-mesh degenerate
    case noted in DESIGN.md)."""
    s = workload_shares([3.7] * 8)
    assert np.allclose(s, 1 / 8)


def test_invalid_inputs():
    with pytest.raises(ValueError):
        workload_shares([])
    with pytest.raises(ValueError):
        workload_shares([1.0, -2.0])
    with pytest.raises(ValueError):
        allocate_kernels(-1, [1.0])


# ---------------------------------------------------------------------------
# the comm-extended Eq. 1: compute + wire time per device
# ---------------------------------------------------------------------------


def test_link_aware_times_adds_wire_seconds():
    """1 MB over an 8 Mbps link is exactly 1 second; None/inf links (the
    master, or unemulated sockets) add nothing."""
    t = link_aware_times([1.0, 1.0, 1.0], [1e6, 1e6, 1e6],
                         [None, 8.0, np.inf])
    assert t[0] == pytest.approx(1.0)
    assert t[1] == pytest.approx(2.0)
    assert t[2] == pytest.approx(1.0)
    with pytest.raises(ValueError):
        link_aware_times([1.0], [1e6], [-5.0])
    with pytest.raises(ValueError):
        link_aware_times([1.0, 1.0], [1e6], [None, 8.0])


def test_comm_aware_allocate_penalizes_slow_links():
    """Equal compute, one slow link: the comm-extended Eq. 1 hands the
    slow-linked device fewer units than the plain compute split."""
    plain = allocate_kernels(30, [1.0, 1.0, 1.0])
    comm = comm_aware_allocate(30, [1.0, 1.0, 1.0], [0.0, 1e6, 1e6],
                               [None, 100.0, 5.0])
    assert plain.tolist() == [10, 10, 10]
    assert comm.sum() == 30
    assert comm[2] < comm[1] <= comm[0]


def test_profiles_to_shares_weighs_measured_links():
    """With wire_bytes the probed shares include each profile's link —
    the device behind the paper's ~5 Mbps Wi-Fi loses share to the
    wired one even at identical compute."""
    profs = [
        DeviceProfile("master", 1.0),
        DeviceProfile("wired", 1.0, bandwidth_mbps=1000.0),
        DeviceProfile("wifi", 1.0, bandwidth_mbps=5.0),
    ]
    plain = profiles_to_shares(profs)
    comm = profiles_to_shares(profs, wire_bytes=[0.0, 1e6, 1e6])
    assert np.allclose(plain, 1 / 3)
    assert comm[2] < comm[1] <= comm[0]
    assert np.isclose(comm.sum(), 1.0)
