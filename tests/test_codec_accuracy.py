"""Accuracy-vs-bytes for the lossy codec stages, on CIFAR-shaped work.

The int8 stage must cut the wire ~4x while keeping a train step's
gradients within 1e-2 norm-relative of the fp32 wire; top-k sparsified
gradients ship a fraction of the bytes and — thanks to the master-side
error feedback — multi-step training still converges like fp32 (the
SINGLE-step gradient is deliberately wrong by construction: top-k drops
most of the mass each step and repays it later).
"""
import numpy as np

from repro.core.master_slave import HeteroCluster

_CIFAR = (8, 32, 32, 3)


def _data(rng):
    """Uniform(-1, 1) keeps every tensor well inside one int8 absmax
    step of its neighbours — gaussian outliers stretch the scale.  The
    kernels get a 0.3 init scale so the SGD runs sit in a stable
    regime."""
    x = rng.uniform(-1.0, 1.0, size=_CIFAR).astype(np.float32)
    w1 = (0.3 * rng.uniform(-1.0, 1.0, size=(3, 3, 3, 8))).astype(np.float32)
    w2 = (0.3 * rng.uniform(-1.0, 1.0, size=(3, 3, 8, 12))).astype(np.float32)
    return x, w1, w2


def _relu():
    def between(y):
        mask = (y > 0).astype(np.float32)
        return np.maximum(y, 0.0), lambda gz: gz * mask

    return between


def _train_step(c, x, w1, w2):
    """One fwd+bwd of the 2-layer chain under loss 0.5*||y||^2 (head
    gradient = the output itself); returns (res, comm_bytes)."""
    c.reset_stats()
    res = c.conv_train_chain(
        x, [w1, w2], [_relu(), None], lambda z, i: (None, z)
    )
    return res, c.comm_bytes


def _make(wire_codec=None):
    c = HeteroCluster([1.0, 1.0], wire_codec=wire_codec)
    c.probe_times = [1.0, 1.0]
    return c


def _rel(a, b):
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-12)


def test_int8_train_step_grads_within_1e2_at_4x_fewer_bytes():
    rng = np.random.default_rng(0)
    x, w1, w2 = _data(rng)
    c32, c8 = _make(), _make("int8")
    try:
        ref, bytes32 = _train_step(c32, x, w1, w2)
        got, bytes8 = _train_step(c8, x, w1, w2)
        # the ACCEPTANCE bound: weight gradients within 1e-2 of fp32
        assert _rel(got.dw[0], ref.dw[0]) <= 1e-2
        assert _rel(got.dw[1], ref.dw[1]) <= 1e-2
        # dx crosses two quantized hops (g down, dx up): looser bound
        assert _rel(got.dx, ref.dx) <= 5e-2
        assert bytes32 / bytes8 > 3.5  # ~4x: arrays at 1 B + one scale each
    finally:
        c32.shutdown()
        c8.shutdown()


def _sgd_losses(c, x, w1, w2, steps=8, lr=2.0):
    """Train the 2-layer chain against the MEAN quadratic loss
    0.5*mean(y^2) (head gradient y/size) and record the loss
    trajectory — computed master-side in fp32: only the WIRE is lossy,
    the comparison metric must not be."""
    losses, total_bytes = [], 0
    for _ in range(steps):
        got = {}

        def head(z, i):
            z = np.asarray(z, np.float32)
            got.setdefault("y", []).append(z)
            return None, z / z.size

        c.reset_stats()
        res = c.conv_train_chain(x, [w1, w2], [_relu(), None], head)
        total_bytes += c.comm_bytes
        y = np.concatenate(got["y"], axis=0)
        losses.append(0.5 * float(np.mean(y * y)))
        w1 = w1 - lr * res.dw[0]
        w2 = w2 - lr * res.dw[1]
    return losses, total_bytes


def test_topk_grads_converge_like_fp32_with_fewer_bytes():
    rng = np.random.default_rng(1)
    x, w1, w2 = _data(rng)

    c32 = _make()
    ck = _make("grads=topk:0.05")
    try:
        ref_losses, ref_bytes = _sgd_losses(c32, x, w1, w2)
        tk_losses, tk_bytes = _sgd_losses(ck, x, w1, w2)
    finally:
        c32.shutdown()
        ck.shutdown()

    # training moves: both trajectories decrease
    assert ref_losses[-1] < ref_losses[0]
    assert tk_losses[-1] < tk_losses[0]
    # and error feedback keeps the sparsified run tracking fp32: the
    # total loss reduction stays close to the dense wire's
    ref_drop = ref_losses[0] - ref_losses[-1]
    tk_drop = tk_losses[0] - tk_losses[-1]
    assert tk_drop > 0.7 * ref_drop
    # the sparsified wire is strictly cheaper
    assert tk_bytes < ref_bytes
