"""The asynchronous pipelined protocol must be bit-compatible with the
barrier protocol (and the local reference) — double-buffered microbatch
scatter/gather, the layer chain, bandwidth-limited links, and the FIFO
ordering contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.master_slave import HeteroCluster, make_distributed_conv
from repro.models.cnn import cnn_loss, init_cnn, make_cnn_config


def _ref_conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


@pytest.fixture(scope="module")
def pipelined():
    """Pipelined hetero cluster; batch 5 over 3 microbatches exercises
    uneven microbatch sizes on top of uneven kernel shards."""
    c = HeteroCluster([1.0, 1.5, 2.0], pipeline=True, microbatches=3)
    c.probe(image_size=8, in_channels=3, kernel_size=5, num_kernels=8, batch=2)
    yield c
    c.shutdown()


def _data(b=5, s=8, cin=3, cout=21, k=5, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, s, s, cin)).astype(np.float32)
    w = rng.normal(size=(k, k, cin, cout)).astype(np.float32)
    g = rng.normal(size=(b, s, s, cout)).astype(np.float32)
    return x, w, g


def test_pipelined_forward_matches_reference(pipelined):
    x, w, _ = _data()
    got = pipelined.conv_forward(x, w)
    np.testing.assert_allclose(got, np.asarray(_ref_conv(x, w)), atol=1e-4)


def test_pipelined_backward_matches_reference(pipelined):
    x, w, g = _data(seed=1)
    _, pullback = jax.vjp(_ref_conv, jnp.asarray(x), jnp.asarray(w))
    dx_want, dw_want = pullback(jnp.asarray(g))
    dx, dw = pipelined.conv_backward(x, w, g)
    np.testing.assert_allclose(dx, np.asarray(dx_want), atol=1e-4)
    np.testing.assert_allclose(dw, np.asarray(dw_want), atol=1e-4)


def test_single_image_degenerates_to_barrier(pipelined):
    """batch < microbatches: no empty microbatches, same numerics."""
    x, w, _ = _data(b=1, seed=2)
    got = pipelined.conv_forward(x, w)
    np.testing.assert_allclose(got, np.asarray(_ref_conv(x, w)), atol=1e-4)


def test_forward_chain_matches_sequential(pipelined):
    """2-layer conv chain with master-only between stages == running the
    layers sequentially on the reference."""
    x, w1, _ = _data(cout=6, seed=3)
    rng = np.random.default_rng(4)
    w2 = rng.normal(size=(5, 5, 6, 9)).astype(np.float32)

    def between(y):
        return np.maximum(y, 0.0)[:, ::2, ::2, :]

    got = pipelined.conv_forward_chain(x, [w1, w2], [between, None])
    ref1 = between(np.asarray(_ref_conv(x, w1)))
    want = np.asarray(_ref_conv(ref1, w2))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_overlap_is_accounted(pipelined):
    pipelined.reset_stats()
    x, w, _ = _data(seed=5)
    pipelined.conv_forward(x, w)
    t = pipelined.timing
    assert t.overlap_s > 0.0          # scatters were in flight during gathers
    assert t.gather_wait_s >= 0.0
    assert t.comm_s > 0.0


def test_gather_order_is_enforced(pipelined):
    """The FIFO sockets make out-of-order gathers a protocol violation."""
    x, w, _ = _data(b=2, seed=6)
    p1 = pipelined.scatter_conv(x, w)
    p2 = pipelined.scatter_conv(x, w)
    with pytest.raises(RuntimeError):
        pipelined.gather_conv(p2)
    # the failed gather read nothing: draining in order still works
    pipelined.gather_conv(p1)
    pipelined.gather_conv(p2)


def test_bandwidth_limited_links_preserve_numerics():
    """Finite emulated links delay delivery, never corrupt it."""
    c = HeteroCluster([1.0, 1.0], pipeline=True, microbatches=2,
                      bandwidth_mbps=2000.0)
    try:
        c.probe_times = [1.0, 1.0]
        x, w, g = _data(b=4, seed=7)
        np.testing.assert_allclose(
            c.conv_forward(x, w), np.asarray(_ref_conv(x, w)), atol=1e-4
        )
        _, pullback = jax.vjp(_ref_conv, jnp.asarray(x), jnp.asarray(w))
        dx_want, dw_want = pullback(jnp.asarray(g))
        dx, dw = c.conv_backward(x, w, g)
        np.testing.assert_allclose(dx, np.asarray(dx_want), atol=1e-4)
        np.testing.assert_allclose(dw, np.asarray(dw_want), atol=1e-4)
        assert c.comm_bytes > 0
    finally:
        c.shutdown()


def test_pipelined_weight_traffic_sent_once():
    """Pipelined microbatches send each layer's kernel shard ONCE; later
    microbatches carry w=None and the slave reuses its cached shard."""
    c = HeteroCluster([1.0, 1.0], pipeline=True, microbatches=4)
    try:
        c.probe_times = [1.0, 1.0]
        x, w, _ = _data(b=8, seed=8)
        c.reset_stats()
        got = c.conv_forward(x, w)
        np.testing.assert_allclose(got, np.asarray(_ref_conv(x, w)), atol=1e-4)
        shard_bytes = c._split(w, c.shares_for(w.shape[-1]))[1].nbytes
        to_slave = c.sockets[0].bytes_to_slave
        # all 4 microbatch inputs + ONE shard (+ a few 8-byte flags);
        # resending the shard per microbatch would add 3*shard_bytes
        assert to_slave < x.nbytes + 2 * shard_bytes
        assert to_slave >= x.nbytes + shard_bytes
    finally:
        c.shutdown()


def test_pipelined_end_to_end_cnn_gradients(pipelined):
    """Full CNN through the pipelined cluster via jax callbacks == local."""
    cfg = make_cnn_config(6, 10)
    params = init_cnn(jax.random.key(0), cfg)
    imgs = jax.random.normal(jax.random.key(1), (4, 32, 32, 3))
    labels = jnp.array([0, 1, 2, 3])
    dist_conv = make_distributed_conv(pipelined)

    loss_ref, _ = cnn_loss(params, imgs, labels, cfg=cfg)
    loss_dist, _ = cnn_loss(params, imgs, labels, cfg=cfg, conv_fn=dist_conv)
    assert np.isclose(float(loss_ref), float(loss_dist), atol=1e-5)

    g_ref = jax.grad(lambda p: cnn_loss(p, imgs, labels, cfg=cfg)[0])(params)
    g_dist = jax.grad(
        lambda p: cnn_loss(p, imgs, labels, cfg=cfg, conv_fn=dist_conv)[0]
    )(params)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_dist)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-4)
