"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (the kernel bodies execute in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.conv2d import conv2d_pallas
from repro.kernels.flash_attn import flash_attention_pallas
from repro.kernels.ssd import ssd_pallas

ATOL = {jnp.float32: 2e-4, jnp.bfloat16: 5e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,w,cin,cout,k",
    [
        (1, 8, 8, 3, 16, 3),
        (2, 16, 16, 8, 24, 5),   # odd cout vs tile
        (2, 32, 32, 3, 50, 5),   # the paper's C1 layer (reduced batch)
        (1, 16, 16, 50, 40, 5),
    ],
)
def test_conv2d_sweep(b, h, w, cin, cout, k, dtype):
    x = jax.random.normal(jax.random.key(0), (b, h, w, cin), jnp.float32).astype(dtype)
    wk = (jax.random.normal(jax.random.key(1), (k, k, cin, cout), jnp.float32) * 0.1).astype(dtype)
    got = conv2d_pallas(x, wk, cout_tile=16, interpret=True)
    want = ref.conv2d_ref(x.astype(jnp.float32), wk.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), atol=ATOL[dtype], rtol=0.05
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("s,t,d", [(32, 32, 16), (48, 80, 32), (17, 33, 8)])
def test_flash_attention_sweep(s, t, d, causal, window, dtype):
    if t < s:
        pytest.skip("kv shorter than q not in the contract")
    q = jax.random.normal(jax.random.key(0), (2, 2, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.key(1), (2, 2, t, d), jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.key(2), (2, 2, t, d), jnp.float32).astype(dtype)
    got = flash_attention_pallas(
        q, k, v, causal=causal, window=window, block_q=16, block_k=16,
        interpret=True,
    )
    want = ref.flash_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        causal=causal, window=window,
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), atol=ATOL[dtype], rtol=0.05
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,h,p,n,chunk", [(32, 2, 8, 4, 8), (48, 3, 16, 8, 16), (25, 1, 4, 4, 8)])
def test_ssd_sweep(s, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (2, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (2, s, h, n), jnp.float32)
    cm = jax.random.normal(ks[4], (2, s, h, n), jnp.float32)
    got = ssd_pallas(
        x.astype(dtype), dt, a, bm.astype(dtype), cm.astype(dtype),
        chunk=chunk, interpret=True,
    )
    want, _ = ref.ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want),
        atol=10 * ATOL[dtype], rtol=0.05,
    )


def test_flash_matches_model_attention_path():
    """The kernel and the model's blockwise path implement the same
    contract (right-aligned decode positions)."""
    from repro.layers.attention import blockwise_attention

    b, s, t, h, d = 1, 8, 24, 2, 16
    q = jax.random.normal(jax.random.key(0), (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (b, t, h, d), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (b, t, h, d), jnp.float32)
    q_pos = jnp.broadcast_to(jnp.arange(t - s, t)[None], (b, s))
    kv_pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    want = blockwise_attention(q, k, v, q_pos, kv_pos, causal=True, window=None, block_k=8)
    got = flash_attention_pallas(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, block_q=8, block_k=8, interpret=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
