"""Algorithms 1 & 2: the distributed convolution must be bit-compatible
with the local reference, forward AND backward, including heterogeneous
(uneven) kernel allocations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.master_slave import HeteroCluster, make_distributed_conv
from repro.models.cnn import cnn_loss, init_cnn, make_cnn_config


@pytest.fixture(scope="module")
def cluster():
    c = HeteroCluster([1.0, 1.5, 2.0])  # master + 2 slaves, heterogeneous
    c.probe(image_size=16, in_channels=3, kernel_size=5, num_kernels=16, batch=4)
    yield c
    c.shutdown()


def test_probe_reports_slowdowns(cluster):
    t = cluster.probe_times
    assert len(t) == 3 and all(x > 0 for x in t)
    # NOTE: wall-clock ordering between emulated devices is not asserted:
    # on a contended single-core CI host the base measurement under the
    # slowdown multiplier can exceed an uncontended one.  The slowdown
    # MECHANISM (measured x factor) is deterministic and covered below.
    from repro.core.master_slave import _np_probe

    base = _np_probe(image_size=8, in_channels=3, kernel_size=3,
                     num_kernels=4, batch=2, repeats=1, slowdown=1.0)
    assert base > 0


def test_forward_matches_reference(cluster):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2, 16, 16, 3)).astype(np.float32)
    w = rng.normal(size=(5, 5, 3, 21)).astype(np.float32)  # odd count: uneven shards
    got = cluster.conv_forward(x, w)
    want = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    np.testing.assert_allclose(got, np.asarray(want), atol=1e-5)


def test_backward_matches_reference(cluster):
    rng = np.random.default_rng(1)
    x = rng.normal(size=(2, 16, 16, 3)).astype(np.float32)
    w = rng.normal(size=(5, 5, 3, 21)).astype(np.float32)
    g = rng.normal(size=(2, 16, 16, 21)).astype(np.float32)

    def f(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )

    _, vjp = jax.vjp(f, jnp.asarray(x), jnp.asarray(w))
    dx_want, dw_want = vjp(jnp.asarray(g))
    dx, dw = cluster.conv_backward(x, w, g)
    np.testing.assert_allclose(dx, np.asarray(dx_want), atol=1e-4)
    np.testing.assert_allclose(dw, np.asarray(dw_want), atol=1e-4)


def test_end_to_end_cnn_gradients(cluster):
    """Full CNN loss + grads through the distributed conv == local."""
    cfg = make_cnn_config(6, 10)
    params = init_cnn(jax.random.key(0), cfg)
    imgs = jax.random.normal(jax.random.key(1), (4, 32, 32, 3))
    labels = jnp.array([0, 1, 2, 3])
    dist_conv = make_distributed_conv(cluster)

    loss_ref, acc_ref = cnn_loss(params, imgs, labels, cfg=cfg)
    loss_dist, acc_dist = cnn_loss(params, imgs, labels, cfg=cfg, conv_fn=dist_conv)
    assert np.isclose(float(loss_ref), float(loss_dist), atol=1e-5)

    g_ref = jax.grad(lambda p: cnn_loss(p, imgs, labels, cfg=cfg)[0])(params)
    g_dist = jax.grad(
        lambda p: cnn_loss(p, imgs, labels, cfg=cfg, conv_fn=dist_conv)[0]
    )(params)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_dist)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_uneven_allocation_used(cluster):
    """Heterogeneous probe times must produce non-uniform kernel shares
    (deterministic: shares computed from pinned times, not wall-clock)."""
    saved = cluster.probe_times
    try:
        cluster.probe_times = [1.0, 1.5, 2.0]
        counts = cluster.shares_for(100)
        assert counts.sum() == 100
        assert counts[0] > counts[1] > counts[2]
    finally:
        cluster.probe_times = saved
