"""Calibration + scalability simulator vs the paper's reported numbers."""
import numpy as np
import pytest

from repro.core.costmodel import paper_network
from repro.core.simulator import (
    ClusterSpec,
    PAPER_TABLE4_CPU,
    PAPER_TABLE5_GPU,
    amdahl_ceiling,
    fit_paper_row,
    gaussian_cluster,
    simulate,
    speedup_curve,
)


@pytest.mark.parametrize("key", list(PAPER_TABLE4_CPU))
def test_cpu_table4_fit(key):
    """Eq.1+Eq.2 model reproduces Table 4 within 6% per entry."""
    r = fit_paper_row(*key, PAPER_TABLE4_CPU[key], device="cpu")
    assert r["max_rel_err"] < 0.06, r


@pytest.mark.parametrize("key", list(PAPER_TABLE5_GPU))
def test_gpu_table5_fit(key):
    """GPU rows fit within 12% (their 2-GPU smallest-net entry exceeds
    the conv-only bound for any fixed speed ratio — noted in the bench)."""
    r = fit_paper_row(*key, PAPER_TABLE5_GPU[key], device="gpu")
    assert r["max_rel_err"] < 0.12, r


def test_gpu_trend_decreasing_cpu_increasing():
    """§5.3.3's qualitative claim: CPU speedups grow with network size,
    GPU speedups shrink."""
    cpu2 = [PAPER_TABLE4_CPU[k][0] for k in sorted(PAPER_TABLE4_CPU)]
    gpu2 = [PAPER_TABLE5_GPU[k][0] for k in sorted(PAPER_TABLE5_GPU)]
    # fitted model must reproduce the direction of both trends at n=2
    fits_cpu = [
        fit_paper_row(*k, PAPER_TABLE4_CPU[k], device="cpu")["predicted"][0]
        for k in sorted(PAPER_TABLE4_CPU)
    ]
    fits_gpu = [
        fit_paper_row(*k, PAPER_TABLE5_GPU[k], device="gpu")["predicted"][0]
        for k in sorted(PAPER_TABLE5_GPU)
    ]
    assert fits_cpu[-1] > fits_cpu[0]  # grows with size
    assert fits_gpu[-1] < fits_gpu[0]  # shrinks with size


def _spec(n=32, bw=5.0, seed=0):
    return gaussian_cluster(
        n_nodes=n, base_conv_time=100.0, rel_speed_low=0.8, rel_speed_high=2.0,
        master_comp_time=15.0, bandwidth_mbps=bw,
        layers=paper_network(500, 1500), batch=1024, seed=seed,
    )


def test_scalability_saturates():
    """Figs 9/10: speedup grows then stabilises; adding nodes never makes
    the balanced schedule slower (comm here is input-broadcast bound)."""
    curve = speedup_curve(_spec(bw=1e4))
    assert curve[0] == pytest.approx(1.0)
    assert curve[3] > 2.0
    # saturation: the last doublings gain little
    assert curve[-1] / curve[15] < 1.35


def test_amdahl_ceiling_respected():
    spec = _spec(bw=1e9)
    curve = speedup_curve(spec)
    assert np.all(curve <= amdahl_ceiling(spec) + 1e-9)


def test_slow_bandwidth_can_hurt():
    """§5.4: at low enough bandwidth distribution is SLOWER than one
    device (speedup < 1) — the GPU simulation's observed regime."""
    slow = _spec(bw=0.05)
    curve = speedup_curve(slow)
    assert curve.min() < 1.0


def test_comm_grows_with_nodes():
    spec = _spec()
    t8 = simulate(spec, 8)
    t32 = simulate(spec, 32)
    assert t32.comm_time > t8.comm_time  # more slaves -> more input broadcast
