"""SSD layer: chunked scan vs the sequential oracle; decode-step
consistency with the full-sequence pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ModelConfig, SSMConfig
from repro.kernels.ref import ssd_ref
from repro.layers.mamba2 import (
    _ssd_chunked,
    apply_mamba2,
    decode_mamba2,
    init_mamba2,
    init_mamba2_state,
)
from repro.models.registry import rules_for_mode

RULES = rules_for_mode("megatron")


def _inputs(key, b, s, h, p, n):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, s, h, n), jnp.float32)
    cm = jax.random.normal(ks[4], (b, s, h, n), jnp.float32)
    return x, dt, a, bm, cm


@pytest.mark.parametrize("chunk", [1, 4, 16, 64])
def test_chunked_matches_sequential(chunk):
    x, dt, a, bm, cm = _inputs(jax.random.key(0), 2, 48, 3, 8, 4)
    y, final = _ssd_chunked(x, dt, a, bm, cm, chunk)
    y_ref, final_ref = ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(final), np.asarray(final_ref), atol=1e-3, rtol=1e-3)


@given(
    st.integers(min_value=1, max_value=3),
    st.integers(min_value=1, max_value=40),
    st.integers(min_value=1, max_value=16),
    st.integers(min_value=0, max_value=2 ** 31),
)
@settings(max_examples=15, deadline=None)
def test_chunked_property(b, s, chunk, seed):
    """Any (batch, seq, chunk) combination matches the recurrence."""
    x, dt, a, bm, cm = _inputs(jax.random.key(seed), b, s, 2, 4, 4)
    y, _ = _ssd_chunked(x, dt, a, bm, cm, min(chunk, s))
    y_ref, _ = ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-3, rtol=2e-3)


def test_initial_state_carries():
    x, dt, a, bm, cm = _inputs(jax.random.key(1), 1, 32, 2, 4, 4)
    # full pass == two half passes chained via the state
    y_full, final_full = _ssd_chunked(x, dt, a, bm, cm, 8)
    y1, s1 = _ssd_chunked(x[:, :16], dt[:, :16], a, bm[:, :16], cm[:, :16], 8)
    y2, s2 = _ssd_chunked(
        x[:, 16:], dt[:, 16:], a, bm[:, 16:], cm[:, 16:], 8, initial_state=s1
    )
    np.testing.assert_allclose(np.asarray(y_full[:, 16:]), np.asarray(y2), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(final_full), np.asarray(s2), atol=1e-3, rtol=1e-3)


def _tiny_cfg():
    return ModelConfig(
        arch_id="t", family="ssm", num_layers=1, d_model=32, num_heads=0,
        num_kv_heads=0, d_ff=0, vocab_size=16, head_dim=8,
        ssm=SSMConfig(d_state=4, d_conv=3, expand=2, head_dim=8, chunk_size=4),
        dtype="float32", param_dtype="float32",
    )


def test_decode_matches_full_sequence():
    """Stepwise decode through (conv state, ssm state) must reproduce the
    full-sequence forward token by token."""
    cfg = _tiny_cfg()
    params = init_mamba2(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 12, cfg.d_model), jnp.float32)

    full = apply_mamba2(params, x, cfg=cfg, rules=RULES)

    state = init_mamba2_state(cfg, 2, jnp.float32)
    outs = []
    for t in range(12):
        y, state = decode_mamba2(params, x[:, t : t + 1], state, cfg=cfg, rules=RULES)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step), atol=2e-3, rtol=2e-3)
