"""Expert-parallel MoE on a real (simulated) multi-device mesh must equal
the single-shard path — run in a subprocess so the 8-device XLA flag
never leaks into the main test process.  Fast-lane: ~15s now that the
mesh-context compat shim (repro/compat.py) fixed the seed failure."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import mesh_context
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.layers.moe import apply_moe, init_moe, moe_axes

    def run(num_experts, d_ff, label, dispatch="psum"):
        cfg = ModelConfig(
            arch_id="t", family="moe", num_layers=1, d_model=16, num_heads=2,
            num_kv_heads=2, d_ff=d_ff, vocab_size=16,
            moe=MoEConfig(num_experts=num_experts, experts_per_token=2,
                          expert_d_ff=d_ff, capacity_factor=100.0,
                          dispatch=dispatch),
            dtype="float32", param_dtype="float32",
        )
        params = init_moe(jax.random.key(0), cfg.d_model, cfg.moe, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (8, 6, cfg.d_model), jnp.float32)

        ref, aux_ref = apply_moe(params, x, cfg=cfg, mesh=None)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh_context(mesh):
            got, aux = jax.jit(
                lambda p, xx: apply_moe(p, xx, cfg=cfg, mesh=mesh,
                                        token_axes=("data",))
            )(params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)
        # sharded paths compute load-balance stats per token shard
        # (mean of per-shard f_e*p_e != the global statistic); the drift
        # grows with shard count — require same order of magnitude only
        assert 0.5 * float(aux_ref) < float(aux) < 2.0 * float(aux_ref)
        print(label, "OK")

    # experts divisible by model axis (4): expert-parallel path
    run(num_experts=8, d_ff=8, label="expert-parallel")
    # experts NOT divisible (mixtral case): per-expert d_ff TP path
    run(num_experts=3, d_ff=8, label="dff-parallel")
    # beyond-paper all-to-all dispatch (tokens sharded over model too)
    run(num_experts=8, d_ff=8, label="alltoall", dispatch="alltoall")
    """
)


def test_moe_sharded_equals_local():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, cwd=ROOT,
        capture_output=True, text=True, timeout=300,  # runs in ~15-30s;
        # a short timeout keeps a regression from eating the fast lane
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "expert-parallel OK" in r.stdout
    assert "dff-parallel OK" in r.stdout
    assert "alltoall OK" in r.stdout
