"""The docstring-coverage gate (tools/check_docstrings.py) must hold:
every public symbol in core/cluster/ and serve/ stays documented, and
the checker itself keeps flagging undocumented code."""
import os
import subprocess
import sys
import tempfile
import textwrap

REPO = os.path.join(os.path.dirname(__file__), "..")
CHECKER = os.path.join(REPO, "tools", "check_docstrings.py")


def test_public_cluster_and_serve_api_fully_documented():
    r = subprocess.run(
        [sys.executable, CHECKER], cwd=REPO,
        capture_output=True, text=True, timeout=60,
    )
    assert r.returncode == 0, f"undocumented public API:\n{r.stdout}{r.stderr}"


def test_checker_flags_undocumented_code():
    """The gate must actually bite: a file with undocumented public
    symbols fails, and a documented one passes."""
    with tempfile.TemporaryDirectory() as d:
        bad = os.path.join(d, "bad")
        os.makedirs(bad)
        with open(os.path.join(bad, "mod.py"), "w") as f:
            f.write(textwrap.dedent('''\
                """Module doc."""
                def documented():
                    """Has one."""
                def naked():
                    pass
                class Klass:
                    """Has one."""
                    def method(self):
                        pass
                    def _private(self):
                        pass
            '''))
        r = subprocess.run(
            [sys.executable, CHECKER, bad], cwd=REPO,
            capture_output=True, text=True, timeout=60,
        )
        assert r.returncode == 1
        flagged = [line.rsplit(": ", 1)[-1]
                   for line in r.stdout.splitlines() if ": " in line]
        assert "naked" in flagged and "Klass.method" in flagged
        assert "_private" not in flagged and "Klass._private" not in flagged
        assert "documented" not in flagged
