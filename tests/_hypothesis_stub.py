"""Deterministic stand-in for `hypothesis`, used ONLY when the real
package is absent (conftest.py installs it into sys.modules then).

CI pins the real hypothesis (requirements-dev.txt); this stub keeps the
property tests collectable AND meaningfully running on minimal hosts by
drawing a fixed number of pseudo-random examples from a seed derived
from the test's qualified name — same examples every run, no shrinking,
no database.  Only the strategy surface this repo uses is implemented:
``lists``, ``floats``, ``integers``, ``sampled_from``.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def floats(min_value=None, max_value=None, allow_nan=False,
           allow_infinity=False, **_kw):
    lo = -1e6 if min_value is None else float(min_value)
    hi = 1e6 if max_value is None else float(max_value)

    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return rng.uniform(lo, hi)

    return _Strategy(draw)


def integers(min_value=None, max_value=None, **_kw):
    lo = -(2 ** 31) if min_value is None else int(min_value)
    hi = 2 ** 31 if max_value is None else int(max_value)

    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return rng.randint(lo, hi)

    return _Strategy(draw)


def lists(elements: _Strategy, min_size=0, max_size=10, **_kw):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]

    return _Strategy(draw)


def sampled_from(seq):
    choices = list(seq)

    def draw(rng):
        return rng.choice(choices)

    return _Strategy(draw)


def settings(max_examples=None, deadline=None, **_kw):
    def deco(f):
        f._stub_max_examples = max_examples
        return f

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(f):
        @functools.wraps(f)
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", None) or DEFAULT_MAX_EXAMPLES
            seed = zlib.crc32(f.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n):
                args = [s.draw(rng) for s in arg_strategies]
                kwargs = {k: s.draw(rng) for k, s in kw_strategies.items()}
                f(*args, **kwargs)

        # pytest introspects signatures (and follows __wrapped__) to bind
        # fixtures; the strategy-bound params must not look like fixtures
        del wrapper.__dict__["__wrapped__"]
        wrapper.__signature__ = inspect.Signature(parameters=[])
        return wrapper

    return deco


def build_module() -> types.ModuleType:
    """Assemble importable `hypothesis` + `hypothesis.strategies`."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.__version__ = "0.0.0-stub"
    st = types.ModuleType("hypothesis.strategies")
    st.floats = floats
    st.integers = integers
    st.lists = lists
    st.sampled_from = sampled_from
    hyp.strategies = st
    return hyp
