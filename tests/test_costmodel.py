"""Eq. 2 cost model: closed form vs the ACTUAL bytes moved through the
emulated sockets by Algorithms 1 & 2."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.costmodel import (
    ConvLayerSpec,
    comm_time_s,
    paper_network,
    predict_step_time,
    upload_bytes,
    upload_elements,
    upload_elements_nodes,
)


def test_eq2_paper_network_counts():
    layers = paper_network(500, 1500)
    batch = 1024
    want = (
        32 ** 2 * 3 * batch + 5 ** 2 * 500 * 3 + 32 ** 2 * 500 * batch
        + 16 ** 2 * 500 * batch + 5 ** 2 * 1500 * 500 + 16 ** 2 * 1500 * batch
    )
    assert upload_elements(layers, batch) == want
    assert upload_bytes(layers, batch) == want * 8


@given(
    st.integers(min_value=1, max_value=64),   # in_size
    st.integers(min_value=1, max_value=16),   # in_channels
    st.integers(min_value=1, max_value=7),    # kernel
    st.integers(min_value=1, max_value=256),  # num kernels
    st.integers(min_value=1, max_value=128),  # batch
)
@settings(max_examples=30)
def test_eq2_positive_and_monotone_in_batch(in_size, in_ch, k, nk, batch):
    layer = [ConvLayerSpec(in_size, in_ch, k, nk)]
    a = upload_elements(layer, batch)
    b = upload_elements(layer, batch + 1)
    assert 0 < a < b


def test_comm_time_at_paper_bandwidth():
    layers = paper_network(50, 500)
    secs = comm_time_s(layers, 64, bandwidth_mbps=5.0)
    # volume x 8 bytes x 8 bits / 5e6 — just pin the closed form
    want = upload_elements(layers, 64) * 64 / 5e6
    assert np.isclose(secs, want)


def test_eq2_matches_measured_socket_traffic():
    """The node-aware Eq. 2 must predict the REAL bytes the master/slave
    protocol moves (within the integer-allocation rounding)."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.core.master_slave import HeteroCluster

    cluster = HeteroCluster([1.0, 1.0, 1.0])
    try:
        cluster.probe(image_size=8, in_channels=3, kernel_size=5,
                      num_kernels=8, batch=2)
        # force equal shares for a deterministic comparison
        cluster.probe_times = [1.0, 1.0, 1.0]
        rng = np.random.default_rng(0)
        batch = 4
        x = rng.normal(size=(batch, 8, 8, 3)).astype(np.float32)
        w = rng.normal(size=(5, 5, 3, 30)).astype(np.float32)
        cluster.reset_stats()
        out = cluster.conv_forward(x, w)
        assert out.shape == (batch, 8, 8, 30)
        measured_elems = cluster.comm_bytes / 4  # float32 payloads
        layer = [ConvLayerSpec(8, 3, 5, 30)]
        shares = np.array([1 / 3, 1 / 3])  # the two slaves
        predicted = upload_elements_nodes(
            layer, batch, shares, broadcast_inputs=True
        )  # the real protocol writes the inputs to every slave socket
        # acks/flags add a few extra 8-byte tokens — allow 2% slack
        assert abs(measured_elems - predicted) / predicted < 0.02
    finally:
        cluster.shutdown()


def test_predict_step_time_single_device_no_comm():
    p = predict_step_time(
        layers=paper_network(50, 500), batch=64,
        device_conv_times=[2.0], master_comp_time=0.5, bandwidth_mbps=5.0,
    )
    assert p.comm_time == 0.0 and p.total == 2.5


def test_predict_step_time_balanced():
    p = predict_step_time(
        layers=paper_network(50, 500), batch=64,
        device_conv_times=[10.0, 20.0], master_comp_time=1.0,
        bandwidth_mbps=1e9,  # comm ~ 0
    )
    assert np.isclose(p.conv_time, 20 / 3)
    assert p.total < 10.0 + 1.0  # distributed beats master-alone
