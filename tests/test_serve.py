"""Serving: prefill+decode must be consistent with the full forward pass
for every decode-capable family (dense, SWA, GQA, ssm, hybrid, encdec)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config, reduced_for_smoke
from repro.configs.base import ModelConfig, SSMConfig
from repro.models.registry import build_model, rules_for_mode
from repro.serve.engine import ServeEngine

RULES = rules_for_mode("megatron")


def _cfg(**kw):
    base = dict(
        arch_id="t", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=97, dtype="float32",
        param_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


CASES = {
    "dense-gqa": _cfg(),
    "swa": _cfg(sliding_window=8),
    "ssm": _cfg(family="ssm", num_heads=0, num_kv_heads=0, d_ff=0, head_dim=8,
                ssm=SSMConfig(d_state=4, d_conv=3, expand=2, head_dim=8, chunk_size=4)),
    "hybrid": _cfg(family="hybrid", head_dim=16,
                   ssm=SSMConfig(d_state=4, d_conv=3, expand=2, head_dim=16, chunk_size=4)),
}


@pytest.mark.parametrize("name", list(CASES))
def test_prefill_then_decode_matches_forward(name):
    """logits(prefill at t) and logits(decode at t+1..) must equal the
    teacher-forced forward logits on the same token stream."""
    cfg = CASES[name]
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)

    full_logits, _ = api.forward(params, {"tokens": toks}, rules=RULES)

    n_prefill = 10
    logits_p, cache = api.prefill(
        params, {"tokens": toks[:, :n_prefill]}, rules=RULES, cache_len=16
    )
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, n_prefill - 1]),
        atol=2e-3, rtol=2e-3,
    )
    for t in range(n_prefill, 16):
        logits_d, cache = api.decode_step(
            params, cache, toks[:, t : t + 1], rules=RULES
        )
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, t]),
            atol=2e-3, rtol=2e-3, err_msg=f"{name} step {t}",
        )


def test_swa_ring_buffer_matches_window_semantics():
    """With a window-sized ring cache, decode must equal the full forward
    (which masks by the same window) even past the wrap point."""
    cfg = CASES["swa"]
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 24), 0, cfg.vocab_size)
    full_logits, _ = api.forward(params, {"tokens": toks}, rules=RULES)

    logits_p, cache = api.prefill(params, {"tokens": toks[:, :8]}, rules=RULES)
    assert cache["k"].shape[2] == cfg.sliding_window  # ring, not full
    for t in range(8, 24):  # runs well past one wrap of the 8-slot ring
        logits_d, cache = api.decode_step(params, cache, toks[:, t : t + 1], rules=RULES)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, t]),
            atol=2e-3, rtol=2e-3, err_msg=f"step {t}",
        )


def test_encdec_prefill_decode_consistency():
    cfg = reduced_for_smoke(get_config("whisper-medium"))
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    frames = jax.random.normal(jax.random.key(2), (2, cfg.audio.num_frames, cfg.d_model))
    batch = {"tokens": toks, "frames": frames}
    full_logits, _ = api.forward(params, batch, rules=RULES)

    logits_p, cache = api.prefill(
        params, {"tokens": toks[:, :6], "frames": frames}, rules=RULES, cache_len=12
    )
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, 5]), atol=2e-3, rtol=2e-3
    )
    for t in range(6, 12):
        logits_d, cache = api.decode_step(params, cache, toks[:, t : t + 1], rules=RULES)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, t]),
            atol=2e-3, rtol=2e-3, err_msg=f"step {t}",
        )


def test_engine_generate_deterministic_greedy():
    cfg = CASES["dense-gqa"]
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    eng = ServeEngine(api=api, run=RunConfig(), params=params)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)}
    a = eng.generate(batch, max_new_tokens=6)
    b = eng.generate(batch, max_new_tokens=6)
    assert a.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
