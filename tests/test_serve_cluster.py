"""The continuous-batching serving lane (serve/server.py + ServeChain):

- ServeChain's cross-batch pipeline must match the per-batch forward
  chain exactly (FIFO holds across batch boundaries),
- deadlines expire queued requests instead of computing them,
- admission control rejects beyond max_queue,
- requests joining a partially-filled batch between decode steps keep
  solo-run numerics,
- a SlaveLost mid-request completes on the survivors and surfaces as a
  retry count, not an error,
- the autoscaler admits/evicts at its thresholds (fake clock, no
  sleeps).
"""
import threading
import time

import numpy as np
import pytest

from repro.core.backends import get_backend
from repro.core.cluster.scheduler import ServeChain
from repro.core.master_slave import HeteroCluster
from repro.serve.server import (
    AutoScaler,
    ClusterServer,
    RequestQueue,
    ServeFuture,
)
from repro.serve.server import _Request


def _relu(y):
    return np.maximum(y, 0.0)


def _ref_chain(x, weights, between):
    """Single-host reference: numpy conv + the between stages.  Accepts
    one (H, W, Cin) image or a (B, H, W, Cin) batch."""
    nb = get_backend("numpy")
    y = np.asarray(x, np.float32)
    single = y.ndim == 3
    if single:
        y = y[None]
    for w, f in zip(weights, between):
        y = nb.conv(y, w)
        if f is not None:
            y = f(y)
    return y[0] if single else y


def _weights(rng, chans):
    return [rng.standard_normal((3, 3, cin, cout)).astype(np.float32) * 0.1
            for cin, cout in zip(chans, chans[1:])]


class FakeClock:
    """Deterministic monotonic clock for queue/deadline/scaler tests."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _req(rid, clock, deadline_s=None, steps=1):
    x = np.zeros((4, 4, 3), np.float32)
    deadline = None if deadline_s is None else clock() + deadline_s
    return _Request(rid, x, deadline, steps, 0, ServeFuture(), clock())


# ---------------------------------------------------------------- chain


def test_serve_chain_matches_forward_chain():
    """Pushing a stream of differently-sized batches through the
    cross-batch pipeline must reproduce conv_forward_chain exactly —
    outputs come back one push late, in order."""
    rng = np.random.default_rng(0)
    weights = _weights(rng, [3, 8, 8])
    between = [_relu, _relu]
    batches = [rng.standard_normal((b, 8, 8, 3)).astype(np.float32)
               for b in (3, 1, 4, 2)]
    c = HeteroCluster([1.0, 1.0, 1.5], pipeline=True, microbatches=2)
    try:
        c.probe_times = [1.0, 1.0, 1.5]
        chain = ServeChain(c, weights, between)
        outs = []
        for x in batches:
            y = chain.push(x)
            if y is not None:
                outs.append(y)
        assert chain.in_flight
        outs.append(chain.flush())
        assert not chain.in_flight and chain.flush() is None
        assert len(outs) == len(batches)
        for x, y in zip(batches, outs):
            np.testing.assert_allclose(
                y, _ref_chain(x, weights, between), rtol=1e-5, atol=1e-5
            )
    finally:
        c.shutdown()


# ------------------------------------------------- queue and admission


def test_request_queue_expires_stale_heads_fake_clock():
    clock = FakeClock()
    q = RequestQueue(max_depth=8, clock=clock)
    assert q.offer(_req(0, clock, deadline_s=1.0))
    assert q.offer(_req(1, clock, deadline_s=None))
    assert q.offer(_req(2, clock, deadline_s=5.0))
    clock.advance(2.0)  # request 0 is now past deadline
    ready, expired = q.take(max_n=2)
    assert [r.request_id for r in expired] == [0]
    # the stale head never blocks live traffic and costs no slot
    assert [r.request_id for r in ready] == [1, 2]
    assert len(q) == 0


def test_request_queue_culls_expired_behind_live_window():
    """Expired entries are culled wherever they sit in the queue — not
    just ahead of the first max_n live requests (the take docstring's
    contract)."""
    clock = FakeClock()
    q = RequestQueue(max_depth=8, clock=clock)
    assert q.offer(_req(0, clock))
    assert q.offer(_req(1, clock))
    assert q.offer(_req(2, clock, deadline_s=1.0))  # behind the window
    assert q.offer(_req(3, clock))
    clock.advance(2.0)  # request 2 is now past deadline
    ready, expired = q.take(max_n=2)
    assert [r.request_id for r in ready] == [0, 1]
    assert [r.request_id for r in expired] == [2]
    assert len(q) == 1  # request 3 kept its place
    ready, expired = q.take(max_n=2)
    assert [r.request_id for r in ready] == [3] and not expired


def test_request_queue_close_refuses_late_offers():
    clock = FakeClock()
    q = RequestQueue(max_depth=8, clock=clock)
    assert q.offer(_req(0, clock))
    leftovers = q.close()
    assert [r.request_id for r in leftovers] == [0]
    assert q.closed and not q.offer(_req(1, clock))


def test_request_queue_admission_control():
    clock = FakeClock()
    q = RequestQueue(max_depth=2, clock=clock)
    assert q.offer(_req(0, clock))
    assert q.offer(_req(1, clock))
    assert not q.offer(_req(2, clock))  # full: admission-control reject
    ready, _ = q.take(max_n=10)
    assert len(ready) == 2 and q.offer(_req(3, clock))


def test_server_rejects_when_queue_full_and_expires_dead_requests():
    """End-to-end admission control + deadline expiry: requests beyond
    max_queue resolve 'rejected' immediately; a request whose deadline
    already passed resolves 'expired' without being computed."""
    rng = np.random.default_rng(1)
    weights = _weights(rng, [3, 8])
    c = HeteroCluster([1.0, 1.0], pipeline=True, microbatches=2)
    try:
        c.probe_times = [1.0, 1.0]
        server = ClusterServer(c, weights, max_batch=2, max_queue=2)
        x = rng.standard_normal((6, 6, 3)).astype(np.float32)
        # not started yet: the queue fills and the third submit bounces
        f1 = server.submit(x)
        f2 = server.submit(x, deadline_s=-1.0)  # already past deadline
        f3 = server.submit(x)
        r3 = f3.result(timeout=1.0)
        assert r3.status == "rejected" and "queue full" in r3.detail
        with server:
            assert f1.result(timeout=30.0).status == "ok"
            r2 = f2.result(timeout=30.0)
        assert r2.status == "expired" and r2.output is None
        s = server.stats()
        assert (s["completed"], s["rejected"], s["expired"]) == (1, 1, 1)
    finally:
        c.shutdown()


def test_submit_validates_input():
    rng = np.random.default_rng(2)
    c = HeteroCluster([1.0, 1.0], pipeline=True, microbatches=2)
    try:
        c.probe_times = [1.0, 1.0]
        server = ClusterServer(c, _weights(rng, [3, 8]), max_batch=2)
        with pytest.raises(ValueError, match="H, W, Cin"):
            server.submit(np.zeros((2, 6, 6, 3), np.float32))
        with pytest.raises(ValueError, match="step_fn"):
            server.submit(np.zeros((6, 6, 3), np.float32), steps=3)
    finally:
        c.shutdown()


# --------------------------------------------------- continuous batching


def test_batch_join_between_steps_preserves_solo_numerics():
    """Multi-step requests re-enter the ready set between decode steps
    and join whatever partially-filled batch forms next; every
    request's outputs must match a solo (one-at-a-time) run."""
    rng = np.random.default_rng(3)
    weights = _weights(rng, [8, 8])  # cin == cout: outputs feed back
    between = [_relu]

    def step_fn(x, y, step):
        return 0.5 * y + 0.25 * x  # next decode input mixes state + output

    reqs = [(rng.standard_normal((6, 6, 8)).astype(np.float32), steps)
            for steps in (3, 1, 2, 3, 2)]

    def solo(x, steps):
        y = None
        for s in range(steps):
            y = _ref_chain(x, weights, between)
            if s + 1 < steps:
                x = step_fn(x, y, s + 1)
        return y

    c = HeteroCluster([1.0, 1.0, 1.5], pipeline=True, microbatches=2)
    try:
        c.probe_times = [1.0, 1.0, 1.5]
        server = ClusterServer(
            c, weights, between=between, step_fn=step_fn, max_batch=3,
        )
        with server:
            futs = [server.submit(x, steps=s) for x, s in reqs]
            resps = [f.result(timeout=60.0) for f in futs]
        assert [r.status for r in resps] == ["ok"] * len(reqs)
        assert [r.steps for r in resps] == [s for _, s in reqs]
        for (x, s), r in zip(reqs, resps):
            np.testing.assert_allclose(
                r.output, solo(x, s), rtol=1e-4, atol=1e-5,
                err_msg=f"request with {s} steps diverged from solo run",
            )
    finally:
        c.shutdown()


def test_head_applied_per_finished_request():
    rng = np.random.default_rng(4)
    weights = _weights(rng, [3, 8])
    fc = rng.standard_normal((6 * 6 * 8, 5)).astype(np.float32)

    def head(z):
        return z.reshape(z.shape[0], -1) @ fc

    c = HeteroCluster([1.0, 1.0], pipeline=True, microbatches=2)
    try:
        c.probe_times = [1.0, 1.0]
        xs = [rng.standard_normal((6, 6, 3)).astype(np.float32)
              for _ in range(3)]
        with ClusterServer(c, weights, head=head, max_batch=2) as server:
            resps = [f.result(timeout=30.0)
                     for f in [server.submit(x) for x in xs]]
        for x, r in zip(xs, resps):
            want = head(_ref_chain(x, weights, [None])[None])[0]
            np.testing.assert_allclose(r.output, want, rtol=1e-4, atol=1e-5)
    finally:
        c.shutdown()


def test_mixed_shape_requests_form_separate_slabs():
    """submit only checks rank, so requests of different spatial sizes
    can coexist; the server must group a slab by shape (one np.stack)
    instead of crashing the loop, and every request still completes."""
    rng = np.random.default_rng(7)
    weights = _weights(rng, [3, 8])
    c = HeteroCluster([1.0, 1.0], pipeline=True, microbatches=2)
    try:
        c.probe_times = [1.0, 1.0]
        xs = [rng.standard_normal(shape).astype(np.float32)
              for shape in ((6, 6, 3), (8, 8, 3), (6, 6, 3), (8, 8, 3))]
        server = ClusterServer(c, weights, max_batch=4)
        futs = [server.submit(x) for x in xs]  # one queue, two shapes
        with server:
            resps = [f.result(timeout=60.0) for f in futs]
        assert [r.status for r in resps] == ["ok"] * len(xs)
        for x, r in zip(xs, resps):
            np.testing.assert_allclose(
                r.output, _ref_chain(x, weights, [None]), rtol=1e-4, atol=1e-5
            )
    finally:
        c.shutdown()


def test_submit_after_stop_is_rejected_not_stranded():
    """A submit that lands after stop() must resolve 'rejected'
    immediately — never enqueue into a queue no thread will read."""
    rng = np.random.default_rng(8)
    c = HeteroCluster([1.0, 1.0], pipeline=True, microbatches=2)
    try:
        c.probe_times = [1.0, 1.0]
        server = ClusterServer(c, _weights(rng, [3, 8]), max_batch=2)
        x = rng.standard_normal((6, 6, 3)).astype(np.float32)
        with server:
            assert server.submit(x).result(timeout=30.0).status == "ok"
        late = server.submit(x).result(timeout=1.0)  # must not hang
        assert late.status == "rejected" and late.detail == "server stopped"
    finally:
        c.shutdown()


# ------------------------------------------------------- fault handling


def test_slave_lost_mid_request_completes_on_survivors():
    """SIGKILL a TCP slave while requests are in flight: the affected
    batches drain on the survivors, every response is 'ok' with the
    loss surfaced as a retry count, and numerics still match."""
    rng = np.random.default_rng(5)
    weights = _weights(rng, [3, 8, 8])
    killed = threading.Event()
    c = HeteroCluster(
        [1.0, 1.0, 2.0], transport="tcp", pipeline=True, microbatches=2,
        heartbeat_s=2.0,  # a SIGKILL EOF lands far sooner
    )
    try:
        c.probe_times = [1.0, 1.0, 2.0]
        victim = c.procs[-1]

        def kill_after_layer0(y):
            if not killed.is_set():
                killed.set()
                victim.kill()
            return _relu(y)

        between = [kill_after_layer0, _relu]
        xs = [rng.standard_normal((6, 6, 3)).astype(np.float32)
              for _ in range(6)]
        with ClusterServer(c, weights, between=between,
                           max_batch=2) as server:
            resps = [f.result(timeout=120.0)
                     for f in [server.submit(x) for x in xs]]
        assert [r.status for r in resps] == ["ok"] * len(xs)
        assert len(c.failures) == 1 and victim.returncode is not None
        assert sum(r.retries for r in resps) >= 1  # surfaced, not raised
        for x, r in zip(xs, resps):
            np.testing.assert_allclose(
                r.output, _ref_chain(x, weights, [_relu, _relu]),
                rtol=1e-4, atol=1e-5,
            )
    finally:
        c.shutdown()


def test_head_exception_fails_inflight_and_poisons_server():
    """An exception out of a user head must not strand any future: the
    in-flight slabs resolve 'error', the still-queued requests resolve
    'rejected', the loop thread exits, and later submits bounce with
    'server stopped on error'."""
    rng = np.random.default_rng(9)
    weights = _weights(rng, [3, 8])

    def bad_head(z):
        raise RuntimeError("head blew up")

    c = HeteroCluster([1.0, 1.0], pipeline=True, microbatches=2)
    try:
        c.probe_times = [1.0, 1.0]
        server = ClusterServer(c, weights, head=bad_head, max_batch=1)
        x = rng.standard_normal((6, 6, 3)).astype(np.float32)
        futs = [server.submit(x) for _ in range(4)]
        with server:
            resps = [f.result(timeout=30.0) for f in futs]  # none may hang
        statuses = [r.status for r in resps]
        assert "error" in statuses and set(statuses) <= {"error", "rejected"}
        errs = [r for r in resps if r.status == "error"]
        assert all("RuntimeError" in r.detail for r in errs)
        late = server.submit(x).result(timeout=1.0)
        assert late.status == "rejected"
        assert late.detail == "server stopped on error"
    finally:
        c.shutdown()


# ------------------------------------------------------------ autoscaler


class FakeCluster:
    """Membership-only cluster stand-in for scaler unit tests."""

    def __init__(self, n=1):
        self.slave_ids = list(range(1, n + 1))
        self.calls = []
        self._next = n + 1

    @property
    def n_slaves(self):
        return len(self.slave_ids)

    def admit(self, **kw):
        dev = self._next
        self._next += 1
        self.slave_ids.append(dev)
        self.calls.append(("admit", dev))
        return dev

    def evict(self, device):
        self.slave_ids.remove(device)
        self.calls.append(("evict", device))


def test_autoscaler_thresholds_and_cooldown_fake_clock():
    clock = FakeClock()
    fc = FakeCluster(n=1)
    scaler = AutoScaler(
        fc, scale_up_depth=4, scale_down_depth=0, min_slaves=1,
        max_slaves=3, cooldown_s=2.0, clock=clock,
    )
    assert scaler.observe(3) is None          # below threshold: no-op
    assert scaler.observe(4) == "admit"       # at threshold: admit
    assert scaler.observe(9) is None          # cooling down
    clock.advance(2.0)
    assert scaler.observe(9) == "admit"       # cooldown over: admit again
    clock.advance(2.0)
    assert scaler.observe(9) is None          # at max_slaves: bounded
    assert fc.n_slaves == 3
    assert scaler.observe(0) == "evict"       # youngest goes first
    assert scaler.observe(0) is None          # evicts share the cooldown
    clock.advance(2.0)
    assert scaler.observe(0) == "evict"
    clock.advance(2.0)
    assert scaler.observe(0) is None          # at min_slaves: bounded
    assert fc.calls == [("admit", 2), ("admit", 3), ("evict", 3),
                        ("evict", 2)]
    assert [e[1] for e in scaler.events] == ["admit", "admit", "evict",
                                             "evict"]


def test_autoscaler_drives_real_admit_evict_from_load():
    """Integration: a burst queued before start() makes the serve loop
    admit a slave; the drained queue then evicts back to min."""
    rng = np.random.default_rng(6)
    weights = _weights(rng, [3, 8])
    c = HeteroCluster([1.0, 1.0], pipeline=True, microbatches=2)
    try:
        c.probe_times = [1.0, 1.0]
        scaler = AutoScaler(
            c, scale_up_depth=6, scale_down_depth=0, min_slaves=1,
            max_slaves=2, cooldown_s=0.0,
        )
        server = ClusterServer(
            c, weights, max_batch=2, max_queue=16, autoscaler=scaler,
        )
        futs = [server.submit(rng.standard_normal((6, 6, 3))
                              .astype(np.float32)) for _ in range(8)]
        with server:
            resps = [f.result(timeout=60.0) for f in futs]
            deadline = time.monotonic() + 30.0
            while c.n_slaves > 1 and time.monotonic() < deadline:
                time.sleep(0.01)  # idle loop iterations evict to min
        assert [r.status for r in resps] == ["ok"] * len(futs)
        actions = [e[1] for e in scaler.events]
        assert "admit" in actions and "evict" in actions
        assert c.n_slaves == 1
    finally:
        c.shutdown()
