"""Re-partition conformance: evict/admit on a LIVE cluster must keep
plans coherent and numerics exact on every partition axis, over both
transports.

After each membership change the next plan must re-run the comm-aware
Eq. 1 over exactly the current device set (counts re-sum to the layer's
units, spatial strips re-tile the image with fresh halos), and a full
pipelined fwd+bwd train chain must keep matching the single-device VJP.
Also: the membership bookkeeping itself (stable ids, aligned lists,
validation of the elastic constructor knobs).
"""
import os
import subprocess
import time
import sys

import numpy as np
import pytest

from repro.core.cluster.plans import check_plan, strip_plan
from repro.core.master_slave import HeteroCluster

TRANSPORTS = ("inproc", "tcp")
AXES = ("kernel", "spatial", "auto")


def _data(seed=4):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(5, 8, 8, 3)).astype(np.float32)
    w1 = rng.normal(size=(3, 3, 3, 6)).astype(np.float32)
    w2 = rng.normal(size=(3, 3, 6, 9)).astype(np.float32)
    g = rng.normal(size=(5, 8, 8, 9)).astype(np.float32)
    return x, w1, w2, g


def _single_device_grads(x, w1, w2, g):
    import jax
    import jax.numpy as jnp

    def f(x_, w1_, w2_):
        y = jax.nn.relu(jax.lax.conv_general_dilated(
            x_, w1_, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ))
        y2 = jax.lax.conv_general_dilated(
            y, w2_, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        return jnp.sum(y2 * g)

    return tuple(
        np.asarray(a)
        for a in jax.grad(f, argnums=(0, 1, 2))(
            jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2)
        )
    )


def _train_step(c, x, w1, w2, g, evict_mid_step=None):
    fired = {}

    def between(y):
        if evict_mid_step is not None and not fired:
            fired["done"] = True
            c.evict(evict_mid_step)
        mask = (y > 0).astype(np.float32)
        return np.maximum(y, 0.0), lambda gz: gz * mask

    slices = c.microbatch_slices(x.shape[0])

    def head(z, i):
        return None, g[slices[i]]

    return c.conv_train_chain(x, [w1, w2], [between, None], head)


def _assert_matches(res, want, atol=1e-3):
    dx_want, dw1_want, dw2_want = want
    np.testing.assert_allclose(res.dx, dx_want, rtol=1e-4, atol=atol)
    np.testing.assert_allclose(res.dw[0], dw1_want, rtol=1e-4, atol=atol)
    np.testing.assert_allclose(res.dw[1], dw2_want, rtol=1e-4, atol=atol)


def _check_all_plans(c, x, w):
    """Fresh plans on both axes satisfy the invariants for the CURRENT
    membership."""
    n_dev = c.n_slaves + 1
    kp = c.plan_conv(x.shape, w, "train", partition="kernel")
    check_plan(kp, w.shape[-1], n_dev)
    sp = c.plan_conv(x.shape, w, "train", partition="spatial")
    check_plan(sp, x.shape[1], n_dev)
    # halos recomputed for the current counts, not inherited
    rows, halos = strip_plan(x.shape[1], w.shape[0], sp.counts)
    assert sp.rows == rows and sp.halos == halos


@pytest.mark.parametrize("kind", TRANSPORTS)
@pytest.mark.parametrize("partition", AXES)
def test_evict_admit_train_chain_matches_vjp(kind, partition):
    """The conformance bar: train-chain numerics vs the single-device
    VJP before, after an evict, and after an admit — every axis, both
    wires.  Finite planning bandwidth exercises the comm-aware Eq. 1
    re-run on each membership."""
    x, w1, w2, g = _data()
    want = _single_device_grads(x, w1, w2, g)
    c = HeteroCluster(
        [1.0, 1.0, 1.0], transport=kind, partition=partition,
        pipeline=True, microbatches=3, bandwidth_mbps=50.0,
    )
    try:
        c.probe_times = [1.0, 1.0, 1.0]
        _assert_matches(_train_step(c, x, w1, w2, g), want)
        c.evict(c.slave_ids[-1])
        assert c.n_slaves == 1
        _check_all_plans(c, x, w1)
        _assert_matches(_train_step(c, x, w1, w2, g), want)
        dev = c.admit(slowdown=1.0, backend="numpy", bandwidth_mbps=50.0,
                      probe_time=1.0)
        assert dev not in (None, c.slave_ids[0]) and c.n_slaves == 2
        _check_all_plans(c, x, w1)
        _assert_matches(_train_step(c, x, w1, w2, g), want)
    finally:
        c.shutdown()


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_graceful_evict_mid_step_drains_on_survivors(kind):
    """evict() while ops are in flight: the live plans keep naming the
    retiree, the master absorbs its shards, the step's numerics hold,
    and the NEXT plans cover only the survivors."""
    x, w1, w2, g = _data(seed=6)
    want = _single_device_grads(x, w1, w2, g)
    c = HeteroCluster([1.0, 1.0, 1.0], transport=kind, pipeline=True,
                      microbatches=3)
    try:
        c.probe_times = [1.0, 1.0, 1.0]
        res = _train_step(c, x, w1, w2, g, evict_mid_step=c.slave_ids[0])
        _assert_matches(res, want)
        assert c.n_slaves == 1
        assert c.timing.recompute_s > 0.0  # the master really absorbed work
        assert not c.failures  # graceful: an evict is not a failure
        _check_all_plans(c, x, w1)
    finally:
        c.shutdown()


def test_membership_bookkeeping_stays_aligned():
    """Stable ids never recycle; every per-slot list tracks membership
    through an evict/admit churn."""
    c = HeteroCluster([1.0, 1.0, 1.5], bandwidth_mbps=[25.0, 50.0])
    try:
        c.probe_times = [1.0, 1.0, 1.5]
        assert c.slave_ids == [1, 2]
        c.evict(1)
        assert c.slave_ids == [2]
        assert c.slowdowns == [1.0, 1.5]
        assert c.bandwidths == [50.0]
        assert c.probe_times == [1.0, 1.5]
        dev = c.admit(slowdown=2.0, backend="numpy", bandwidth_mbps=10.0,
                      probe_time=2.0)
        assert dev == 3  # id 1 is never reused
        assert c.slave_ids == [2, 3]
        assert c.slowdowns == [1.0, 1.5, 2.0]
        assert c.bandwidths == [50.0, 10.0]
        assert c.probe_times == [1.0, 1.5, 2.0]
        # Eq. 1 over the new membership: every unit lands somewhere
        counts = c.shares_for(16)
        assert counts.sum() == 16 and len(counts) == 3
        # the 2.0x slave gets the smallest share (largest probe time)
        assert counts[2] == counts.min()
    finally:
        c.shutdown()


def test_evict_unknown_device_raises():
    c = HeteroCluster([1.0, 1.0])
    try:
        with pytest.raises(KeyError, match="no live slave"):
            c.evict(99)
        c.evict(1)
        with pytest.raises(KeyError, match="no live slave"):
            c.evict(1)  # already gone
    finally:
        c.shutdown()


def test_elastic_constructor_validation():
    with pytest.raises(ValueError, match="transport='tcp'"):
        HeteroCluster([1.0], expected_slaves=1)  # inproc can't join
    with pytest.raises(ValueError, match="ONLY the master"):
        HeteroCluster([1.0, 1.5], transport="tcp", expected_slaves=1)
    with pytest.raises(ValueError, match="heartbeat_s"):
        HeteroCluster([1.0, 1.0], heartbeat_s=0.0)
    with pytest.raises(ValueError, match="spawn=False"):
        c = HeteroCluster([1.0, 1.0])
        try:
            c.admit(spawn=False)
        finally:
            c.shutdown()


def test_expected_slaves_requires_auth_token():
    """An unauthenticated waiting listener would hand any process that
    can reach it pickle-powered code execution: refuse to start."""
    env_had = os.environ.pop("REPRO_CLUSTER_AUTH", None)
    try:
        with pytest.raises(RuntimeError, match="REPRO_CLUSTER_AUTH"):
            HeteroCluster([1.0], transport="tcp", expected_slaves=1)
    finally:
        if env_had is not None:
            os.environ["REPRO_CLUSTER_AUTH"] = env_had


def test_stray_connections_do_not_abort_join():
    """A port scanner hitting the listener — connect-and-slam, wrong
    token — is rejected and SKIPPED; the real joiner behind it in the
    backlog still gets in.  One bad peer must never abort membership."""
    import socket as socket_mod

    c = HeteroCluster([1.0, 1.0], transport="tcp")
    slave = None
    try:
        c.probe_times = [1.0, 1.0]
        host, port = c.listen_address
        junk1 = socket_mod.create_connection((host, port))
        junk1.close()  # EOF before any auth bytes
        junk2 = socket_mod.create_connection((host, port))
        junk2.sendall(b"\x00" * 32)  # wrong token
        env = os.environ.copy()
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = os.path.abspath(src) + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        env["REPRO_CLUSTER_AUTH"] = c.auth_token_hex
        slave = subprocess.Popen(
            [sys.executable, "-m", "repro.core.cluster.protocol",
             "--host", host, "--port", str(port), "--backend", "numpy"],
            env=env,
        )
        dev = c.admit(spawn=False, timeout_s=60.0, probe_time=1.0)
        junk2.close()
        assert c.n_slaves == 2 and dev in c.slave_ids
    finally:
        c.shutdown()
        if slave is not None:
            assert slave.wait(timeout=10) == 0


def test_admit_timeout_raises_not_hangs():
    """admit(spawn=False) with nobody joining fails loudly and promptly."""
    c = HeteroCluster([1.0, 1.0], transport="tcp")
    try:
        t0 = time.monotonic()
        with pytest.raises((TimeoutError, OSError)):
            c.admit(spawn=False, timeout_s=1.0)
        assert time.monotonic() - t0 < 10.0
        assert c.n_slaves == 1  # membership untouched
    finally:
        c.shutdown()


def test_admit_external_join_into_spawned_cluster():
    """admit(spawn=False): a hand-launched slave joins a RUNNING
    spawn-mode cluster mid-life, using the cluster's own join secret
    (auth_token_hex) — grow-while-training, the ISSUE's join path."""
    c = HeteroCluster([1.0, 1.0], transport="tcp")
    slave = None
    try:
        c.probe_times = [1.0, 1.0]
        host, port = c.listen_address
        env = os.environ.copy()
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env["PYTHONPATH"] = os.path.abspath(src) + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        env["REPRO_CLUSTER_AUTH"] = c.auth_token_hex
        slave = subprocess.Popen(
            [sys.executable, "-m", "repro.core.cluster.protocol",
             "--host", host, "--port", str(port),
             "--backend", "numpy", "--slowdown", "1.0"],
            env=env,
        )
        dev = c.admit(spawn=False, timeout_s=60.0, probe_time=1.0)
        assert dev == 2 and c.n_slaves == 2
        assert c.backends == ["numpy", "numpy", "numpy"]
        # the joiner serves real ops
        x = np.random.default_rng(0).normal(size=(2, 8, 8, 3)).astype(np.float32)
        w = np.random.default_rng(1).normal(size=(3, 3, 3, 9)).astype(np.float32)
        y = c.conv_forward(x, w)
        assert y.shape == (2, 8, 8, 9)
    finally:
        c.shutdown()
        if slave is not None:
            assert slave.wait(timeout=10) == 0
