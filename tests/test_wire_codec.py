"""Unit tests for the pluggable wire-compressor stack (codec.py).

Spec parsing and its canonical round-trip, the int8 absmax stage, the
top-k gradient sparsifier with master-side error feedback, per-class
stage routing by the op grammar, and the canonical ``wire_nbytes``
accounting of every marker class.
"""
import numpy as np
import pytest

from repro.core.cluster import codec
from repro.core.cluster.codec import (
    QuantArray,
    SparseGrad,
    WeightRef,
    WireCodec,
    resolve_wire_dtype,
    wire_nbytes,
)


# ---------------------------------------------------------------------------
# spec parsing
# ---------------------------------------------------------------------------


def test_single_stage_spec_applies_to_all_classes():
    c = WireCodec.from_spec("int8")
    assert c.weights == "int8" and c.acts == "int8" and c.grads == "int8"
    assert c.spec == "int8"


def test_per_class_spec_and_canonical_roundtrip():
    c = WireCodec.from_spec("weights=fp16,acts=int8,grads=topk:0.05")
    assert c.weights == np.dtype(np.float16)
    assert c.acts == "int8"
    assert c.grad_topk == pytest.approx(0.05)
    spec = c.spec
    assert spec == "weights=fp16,acts=int8,grads=topk:0.05"
    c2 = WireCodec.from_spec(spec)
    assert c2.spec == spec


def test_empty_spec_falls_back_to_wire_dtype():
    assert WireCodec.from_spec(None, "fp16").acts == np.dtype(np.float16)
    assert WireCodec.from_spec("", None).spec is None


@pytest.mark.parametrize("bad", [
    "float8",                   # unknown stage
    "voltage=fp16",             # unknown message class
    "acts=fp16,acts=int8",      # duplicate class
    "acts=topk:0.1",            # topk only valid for grads
    "grads=topk:1.5",           # fraction out of (0, 1)
    "fp16 int8",                # missing class=stage shape
])
def test_bad_specs_raise(bad):
    with pytest.raises(ValueError):
        WireCodec.from_spec(bad)


def test_int8_is_a_codec_stage_not_a_wire_dtype():
    """The legacy single-dtype knob stays dtype-only: int8 needs the
    marker-based stack (scales ride along), so ``wire_dtype='int8'``
    must fail loudly instead of half-working."""
    with pytest.raises(ValueError):
        resolve_wire_dtype("int8")


# ---------------------------------------------------------------------------
# int8 absmax stage
# ---------------------------------------------------------------------------


def test_int8_roundtrip_error_bounded_by_half_step():
    rng = np.random.default_rng(0)
    a = rng.uniform(-3.0, 3.0, size=(64, 33)).astype(np.float32)
    qa = codec._quant_int8(a)
    assert qa.q.dtype == np.int8
    back = codec._dequant_int8(qa)
    step = float(np.max(np.abs(a))) / 127.0
    assert np.max(np.abs(back - a)) <= step / 2 + 1e-7


def test_int8_degenerate_tensors():
    z = codec._dequant_int8(codec._quant_int8(np.zeros(5, np.float32)))
    np.testing.assert_array_equal(z, np.zeros(5, np.float32))
    e = codec._quant_int8(np.zeros((0, 3), np.float32))
    assert e.q.shape == (0, 3)


# ---------------------------------------------------------------------------
# top-k sparsification + error feedback
# ---------------------------------------------------------------------------


def test_topk_keeps_largest_and_densifies_back():
    g = np.array([[0.1, -5.0, 0.2], [4.0, -0.3, 0.05]], np.float32)
    sp = codec._sparsify_topk(g, 1 / 3)
    dense = codec._densify(sp)
    assert dense.shape == g.shape
    # the two largest-|.| entries survive, everything else is zero
    np.testing.assert_array_equal(
        dense, [[0, -5.0, 0], [4.0, 0, 0]]
    )


def test_topk_too_small_ships_dense():
    assert codec._sparsify_topk(np.ones(3, np.float32), 0.5) is None


def test_error_feedback_reinjects_dropped_mass():
    """With a CONSTANT gradient, the shipped top-k stream must average
    to the true gradient: the residual is re-added every step, so after
    N steps total shipped = N*g - residual_N with residual bounded."""
    rng = np.random.default_rng(1)
    g = rng.normal(size=(6, 40)).astype(np.float32)
    c = WireCodec.from_spec("grads=topk:0.1")
    shipped = np.zeros_like(g)
    n = 30
    for _ in range(n):
        enc = c._grad_down(g, "layer0")
        assert isinstance(enc, SparseGrad)
        shipped += codec._densify(enc)
    resid = n * g - shipped
    # the EF identity: the leftover is EXACTLY the stored residual
    np.testing.assert_allclose(
        resid, c._ef[("layer0", g.shape)], rtol=1e-4, atol=1e-4
    )
    # and it is bounded: the average shipped gradient converges to g
    assert np.linalg.norm(shipped / n - g) / np.linalg.norm(g) < 0.15


def test_topk_dense_fallback_pops_residual():
    c = WireCodec.from_spec("grads=topk:0.4")
    big = np.arange(100, dtype=np.float32)
    assert isinstance(c._grad_down(big, "k"), SparseGrad)
    assert ("k", big.shape) in c._ef
    tiny = np.ones(2, np.float32)
    out = c._grad_down(tiny, "t")
    assert isinstance(out, np.ndarray)  # dense: indices would not pay
    assert ("t", tiny.shape) not in c._ef


# ---------------------------------------------------------------------------
# grammar routing and accounting
# ---------------------------------------------------------------------------


def test_down_grammar_routes_classes_independently():
    c = WireCodec.from_spec("weights=int8,acts=fp16,grads=topk:0.05")
    x = np.random.default_rng(2).normal(size=(2, 8, 8, 3)).astype(np.float32)
    w = np.ones((3, 3, 3, 4), np.float32)
    g = np.random.default_rng(3).normal(size=(2, 8, 8, 4)).astype(np.float32)
    op, (ex, ew, eg) = c.encode_down(("bwd", (x, w, g)))
    assert op == "bwd"
    assert ex.dtype == np.float16
    assert isinstance(ew, QuantArray)
    assert isinstance(eg, SparseGrad)


def test_ping_passes_through_uncompressed():
    """Bandwidth probes must measure the raw wire, whatever the codec."""
    c = WireCodec.from_spec("int8")
    blob = np.ones(256, np.float32)
    op, payload = c.encode_down(("ping", blob))
    assert op == "ping"
    assert payload is blob


def test_up_pair_is_grads_everything_else_acts():
    c = WireCodec.from_spec("acts=fp16,grads=int8")
    dx, dw = c.encode_up((np.ones(4, np.float32), np.ones(3, np.float32)))
    assert isinstance(dx, QuantArray) and isinstance(dw, QuantArray)
    y = c.encode_up(np.ones(4, np.float32))
    assert y.dtype == np.float16


def test_decode_restores_float32_for_every_marker():
    c = WireCodec.from_spec("int8")
    a = np.random.default_rng(4).uniform(-1, 1, 50).astype(np.float32)
    dec = c.decode(c.encode_down({"a": a})["a"])
    assert dec.dtype == np.float32
    np.testing.assert_allclose(dec, a, atol=1.0 / 127.0)
    sp = codec._sparsify_topk(a, 0.1)
    np.testing.assert_array_equal(c.decode(sp), codec._densify(sp))


def test_wire_nbytes_of_marker_classes():
    qa = QuantArray(np.zeros(10, np.int8), 0.5)
    assert wire_nbytes(qa) == 10 + 8
    sp = SparseGrad(np.zeros(3, np.int32), np.zeros(3, np.float32), (30,))
    assert wire_nbytes(sp) == 3 * 4 + 3 * 4 + 8
    assert wire_nbytes(WeightRef("layer", 7, None)) == 8 + 8
    assert wire_nbytes(WeightRef("layer", 7, np.zeros(4, np.float32))) == 32


def test_itemsize_feeds_the_planner():
    assert WireCodec.from_spec(None).itemsize("acts") == 4.0
    assert WireCodec.from_spec("fp16").itemsize("weights") == 2.0
    assert WireCodec.from_spec("int8").itemsize("acts") == 1.0
    c = WireCodec.from_spec("grads=topk:0.05")
    assert c.itemsize("grads") == pytest.approx(8.0 * 0.05)
    assert c.itemsize("acts") == 4.0
