"""Per-architecture smoke tests (REQUIRED): instantiate the REDUCED
same-family variant of every assigned config (2 layers, d_model<=512,
<=4 experts) and run one forward + one train step on CPU, asserting
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, RunConfig, get_config, reduced_for_smoke
from repro.models.registry import build_model
from repro.train.step import init_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.vision is not None:
        v = cfg.vision
        batch["patches"] = jax.random.normal(
            ks[2], (B, v.num_image_tokens, v.vision_dim), jnp.float32
        )
    if cfg.audio is not None:
        a = cfg.audio
        batch["frames"] = jax.random.normal(
            ks[2], (B, a.num_frames, a.frame_dim), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_smoke_forward_and_train_step(arch):
    cfg = reduced_for_smoke(get_config(arch))
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    api = build_model(cfg)
    run = RunConfig(
        optimizer="adam", learning_rate=1e-3, remat="none", tp_mode="megatron",
        max_grad_norm=1.0,
    )
    state = init_train_state(jax.random.key(0), api, run)
    batch = _batch(cfg, jax.random.key(1))

    logits, aux = api.forward(
        state.params, batch, rules=__import__("repro.models.registry",
        fromlist=["rules_for_mode"]).rules_for_mode("megatron")
    )
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, dtype=np.float32)).any()

    step = jax.jit(make_train_step(api, run))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state.step) == 1
    # params actually changed
    changed = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params, new_state.params,
    )
    assert max(jax.tree.leaves(changed)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_pool_spec(arch):
    """The FULL configs carry the exact pool numbers (cited source in
    brackets) — guard against accidental edits."""
    cfg = get_config(arch)
    spec = {
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, (got, spec)
    assert cfg.source  # citation present
    if arch == "qwen3-moe-235b-a22b":
        assert cfg.moe.num_experts == 128 and cfg.moe.experts_per_token == 8
    if arch == "mixtral-8x22b":
        assert cfg.moe.num_experts == 8 and cfg.moe.experts_per_token == 2
    if arch == "moonshot-v1-16b-a3b":
        assert cfg.moe.num_experts == 64 and cfg.moe.experts_per_token == 6
    if arch == "mamba2-370m":
        assert cfg.ssm.d_state == 128
    if arch == "hymba-1.5b":
        assert cfg.ssm.d_state == 16
    if arch == "nemotron-4-340b":
        assert cfg.activation == "squared_relu"
    if arch == "whisper-medium":
        assert cfg.num_encoder_layers == 24
