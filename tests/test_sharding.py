"""Sharding rule system: shape-aware spec construction properties."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.sharding.axes import (
    AxisRules,
    LOGICAL_RULES_GATHER,
    LOGICAL_RULES_MEGATRON,
)
from repro.sharding.partitioning import spec_for_shape

SIZES = {"pod": 2, "data": 16, "model": 16}


def test_megatron_basic_specs():
    spec = spec_for_shape(
        LOGICAL_RULES_MEGATRON, (4096, 14336), ("fsdp_embed", "mlp"), SIZES
    )
    assert spec == P(("pod", "data"), "model")


def test_gather_mode_keeps_weights_sharded_but_acts_replicated():
    w = spec_for_shape(LOGICAL_RULES_GATHER, (4096, 14336), ("embed", "mlp"), SIZES)
    assert w == P(None, "model")
    act = spec_for_shape(
        LOGICAL_RULES_GATHER, (256, 4096, 14336), ("batch", None, "act_mlp"), SIZES
    )
    assert act == P(("pod", "data"))  # hidden gathered
    act_col = spec_for_shape(
        LOGICAL_RULES_GATHER, (256, 4096, 14336), ("batch", None, "act_mlp_col"), SIZES
    )
    assert act_col == P(("pod", "data"), None, "model")


def test_non_divisible_axis_dropped():
    # hymba: 25 heads on a 16-way model axis -> replicated
    spec = spec_for_shape(
        LOGICAL_RULES_MEGATRON, (4096, 25, 64), ("fsdp_embed", "heads", "head_dim"), SIZES
    )
    assert spec == P(("pod", "data"))
    # mixtral: 8 experts on 16-way -> dropped on experts
    spec = spec_for_shape(
        LOGICAL_RULES_MEGATRON, (8, 6144, 16384),
        ("experts", "fsdp_embed", "expert_mlp"), SIZES,
    )
    assert spec[0] is None


def test_mesh_axis_used_once():
    """A mesh axis consumed by an earlier dim cannot repeat."""
    spec = spec_for_shape(
        LOGICAL_RULES_MEGATRON, (64, 128), ("heads", "mlp"), SIZES
    )
    # both map to "model"; only the first keeps it
    assert spec == P("model")


def test_partial_divisibility_of_compound_axis():
    """batch maps to (pod, data): a batch of 2 shards only over pod."""
    spec = spec_for_shape(LOGICAL_RULES_MEGATRON, (2, 128), ("batch", None), SIZES)
    assert spec == P("pod")
    spec = spec_for_shape(LOGICAL_RULES_MEGATRON, (1, 128), ("batch", None), SIZES)
    assert spec == P()


@given(
    st.integers(min_value=1, max_value=4096),
    st.sampled_from(["batch", "mlp", "heads", "embed", "vocab", "experts"]),
)
@settings(max_examples=100, deadline=None)
def test_spec_always_divides(dim, axis):
    """PROPERTY: every mesh axis kept in a spec divides its dim."""
    spec = spec_for_shape(LOGICAL_RULES_MEGATRON, (dim,), (axis,), SIZES)
    entry = spec[0] if len(spec) else None
    if entry is None:
        return
    axes = entry if isinstance(entry, tuple) else (entry,)
    prod = int(np.prod([SIZES[a] for a in axes]))
    assert dim % prod == 0


def test_rules_spec_trailing_nones_trimmed():
    spec = LOGICAL_RULES_MEGATRON.spec("batch", None, None)
    assert spec == P(("pod", "data"))


def test_fsdp_mode_folds_model_into_batch():
    from repro.sharding.axes import LOGICAL_RULES_FSDP

    # batch 512 divides pod*data*model = 512; 256 would keep (pod, data)
    act = spec_for_shape(
        LOGICAL_RULES_FSDP, (512, 4096, 4096), ("batch", None, "act_embed"), SIZES
    )
    assert act == P(("pod", "data", "model"))
    act256 = spec_for_shape(
        LOGICAL_RULES_FSDP, (256, 4096, 4096), ("batch", None, "act_embed"), SIZES
    )
    assert act256 == P(("pod", "data"))  # divisibility-safe prefix
    w = spec_for_shape(
        LOGICAL_RULES_FSDP, (4096, 11008), ("fsdp_embed", "mlp"), SIZES
    )
    assert w == P(("pod", "data", "model"))  # ZeRO-3; no TP on the out axis
    # experts keep the model axis for expert parallelism
    e = spec_for_shape(
        LOGICAL_RULES_FSDP, (128, 4096, 1536),
        ("experts", "fsdp_embed", "expert_mlp"), SIZES,
    )
    assert e[0] == "model"


def test_zero1_params_replicated_opt_sharded():
    from repro.sharding.axes import LOGICAL_RULES_ZERO1

    w = spec_for_shape(
        LOGICAL_RULES_ZERO1, (4096, 11008), ("fsdp_embed", "mlp"), SIZES
    )
    assert w == P()  # params replicated
    m = spec_for_shape(
        LOGICAL_RULES_ZERO1, (4096, 11008), ("opt_embed", "mlp"), SIZES
    )
    assert m == P(("pod", "data", "model"))  # moments sharded everywhere
