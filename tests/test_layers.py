"""Layer-level unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ModelConfig
from repro.layers.attention import blockwise_attention, naive_attention
from repro.layers.mlp import activation_fn
from repro.layers.norm import apply_layernorm, apply_rmsnorm, init_layernorm, init_rmsnorm, local_response_norm
from repro.layers.embedding import apply_rope


def _qkv(key, b, s, t, h, kv, d):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kv, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("h,kv", [(4, 4), (8, 2)])
def test_blockwise_matches_naive(h, kv, window):
    """The flash-style blockwise path must equal the naive path (GQA and
    sliding-window included)."""
    b, s, t, d = 2, 24, 40, 16
    q, k, v = _qkv(jax.random.key(0), b, s, t, h, kv, d)
    q_pos = jnp.broadcast_to(jnp.arange(16, 16 + s)[None], (b, s))
    kv_pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    a = naive_attention(q, k, v, q_pos, kv_pos, causal=True, window=window)
    bw = blockwise_attention(
        q, k, v, q_pos, kv_pos, causal=True, window=window, block_k=8
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(bw), atol=2e-5)


def test_attention_invalid_slots_ignored():
    """kv slots with position -1 (empty cache) must not contribute."""
    b, s, t, h, d = 1, 1, 8, 2, 8
    q, k, v = _qkv(jax.random.key(1), b, s, t, h, h, d)
    q_pos = jnp.full((b, s), 100)
    kv_pos = jnp.concatenate(
        [jnp.arange(4)[None], jnp.full((1, 4), -1)], axis=1
    )
    full = naive_attention(q, k, v, q_pos, kv_pos, causal=True, window=None)
    trunc = naive_attention(
        q, k[:, :4], v[:, :4], q_pos, kv_pos[:, :4], causal=True, window=None
    )
    np.testing.assert_allclose(np.asarray(full), np.asarray(trunc), atol=1e-6)


def test_rope_preserves_norm_and_relative_property():
    x = jax.random.normal(jax.random.key(2), (1, 6, 2, 16))
    pos = jnp.arange(6)[None]
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.key(3), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.key(4), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]), 10000.0)
        kj = apply_rope(k, jnp.array([[j]]), 10000.0)
        return float(jnp.sum(qi * kj))
    assert np.isclose(dot_at(3, 1), dot_at(10, 8), atol=1e-4)


@given(st.integers(min_value=1, max_value=64), st.integers(min_value=0, max_value=2 ** 31))
@settings(max_examples=20, deadline=None)
def test_rmsnorm_unit_rms(d, seed):
    x = jax.random.normal(jax.random.key(seed), (3, d)) * 7.0
    p = init_rmsnorm(d)
    y = np.asarray(apply_rmsnorm(p, x))
    rms = np.sqrt(np.mean(y ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-2)


def test_layernorm_zero_mean_unit_var():
    x = jax.random.normal(jax.random.key(5), (4, 32)) * 3 + 5
    p = init_layernorm(32)
    y = np.asarray(apply_layernorm(p, x))
    np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.var(-1), 1.0, rtol=1e-3)


def test_lrn_matches_direct_window_sum():
    """cuda-convnet LRN: y = x / (k + a * windowed sum of squares)^b."""
    x = jax.random.normal(jax.random.key(6), (2, 4, 4, 10))
    y = np.asarray(local_response_norm(x, size=5, alpha=1e-4, beta=0.75, k=2.0))
    xn = np.asarray(x)
    for c in range(10):
        lo, hi = max(0, c - 2), min(10, c + 3)
        denom = (2.0 + 1e-4 * (xn[..., lo:hi] ** 2).sum(-1)) ** 0.75
        np.testing.assert_allclose(y[..., c], xn[..., c] / denom, rtol=1e-5)


def test_squared_relu():
    f = activation_fn("squared_relu")
    x = jnp.array([-2.0, 0.0, 3.0])
    np.testing.assert_allclose(np.asarray(f(x)), [0.0, 0.0, 9.0])
