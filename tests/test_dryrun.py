"""Dry-run integration: the production-mesh lowering path runs in a
subprocess (it needs its own XLA device-count flag, which must never leak
into this test process — smoke tests see 1 device)."""
import json
import os
import subprocess
import sys

import jax
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_this_process_has_one_device():
    assert len(jax.devices()) == 1


@pytest.mark.slow
def test_dryrun_subprocess_single_pair(tmp_path):
    """One cheap (arch x shape) through the REAL 16x16 dry-run."""
    out = tmp_path / "rec.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-370m",
         "--shape", "train_4k", "--mesh", "single", "--out", str(out)],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=1500,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["chips"] == 256
    assert rec["flops_per_device"] > 0
    assert rec["dominant"] in ("compute", "memory", "collective")
    assert rec["hbm_bytes_per_device"] < 16 * 2 ** 30  # fits v5e HBM


@pytest.mark.slow
def test_dryrun_subprocess_multipod(tmp_path):
    """The 2x16x16 multi-pod mesh lowers (the 'pod' axis shards)."""
    out = tmp_path / "rec.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-370m",
         "--shape", "decode_32k", "--mesh", "multi", "--out", str(out)],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=1500,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["chips"] == 512
    assert rec["mesh"] == "2x16x16"
