"""The reprolint suite (tools/lint/) must hold on the repo AND bite:
every checker passes the live tree, every checker fails its negative
fixture, the waiver grammar works, and the lock-order sanitizer
detects a seeded AB/BA inversion.  Mirrors test_docstring_gate.py's
positive/negative structure."""
import os
import subprocess
import sys
import tempfile
import textwrap
import threading
from pathlib import Path

REPO = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.abspath(REPO))

from tools.lint.checkers import (  # noqa: E402
    auth_unpickle,
    blocking_lock,
    clock_injection,
    future_resolution,
    import_graph,
    resource_hygiene,
    thread_hygiene,
)
from tools.lint.core import Violation, apply_waivers, parse_waivers  # noqa: E402
from tools.lint import lockorder  # noqa: E402


def _names(violations):
    return sorted({v.checker for v in violations})


def _write_tree(root, files):
    for relpath, src in files.items():
        p = Path(root, relpath)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return Path(root)


# ---- positive: the live repo passes the whole suite -------------------

def test_repo_passes_reprolint():
    r = subprocess.run(
        [sys.executable, "-m", "tools.lint"], cwd=REPO,
        capture_output=True, text=True, timeout=120,
    )
    assert r.returncode == 0, (
        f"reprolint violations in the repo:\n{r.stdout}{r.stderr}"
    )


def test_cli_flags():
    for flags, rc in [(["--list"], 0), (["--explain"], 0),
                      (["--only", "no-such-checker"], 2)]:
        r = subprocess.run(
            [sys.executable, "-m", "tools.lint", *flags], cwd=REPO,
            capture_output=True, text=True, timeout=120,
        )
        assert r.returncode == rc, f"{flags}: {r.stdout}{r.stderr}"
    r = subprocess.run(
        [sys.executable, "-m", "tools.lint", "--list"], cwd=REPO,
        capture_output=True, text=True, timeout=120,
    )
    for name in ("import-graph", "auth-before-unpickle", "clock-injection",
                 "blocking-under-lock", "future-resolution",
                 "thread-hygiene", "docstrings"):
        assert name in r.stdout


# ---- negative fixtures: one per checker -------------------------------

def test_import_graph_catches_eager_jax():
    """An `import jax` anywhere on the entry's module-level import
    chain must fail, with the chain in the message; a lazy
    (function-level) import must pass."""
    with tempfile.TemporaryDirectory() as d:
        src = _write_tree(d, {
            "src/pkg/__init__.py": "",
            "src/pkg/entry.py": "import pkg.helper\n",
            "src/pkg/helper.py": "import jax\n",
        }) / "src"
        bad = import_graph.check(src, "pkg.entry", ("jax",), Path(d))
        assert len(bad) == 1 and "pkg.entry -> pkg.helper" in bad[0].message
    with tempfile.TemporaryDirectory() as d:
        src = _write_tree(d, {
            "src/pkg/__init__.py": "",
            "src/pkg/entry.py": "import pkg.helper\n",
            "src/pkg/helper.py": "def f():\n    import jax\n",
        }) / "src"
        assert import_graph.check(src, "pkg.entry", ("jax",), Path(d)) == []


def test_auth_unpickle_catches_unauthenticated_read():
    bad_src = '''\
        import hmac, pickle
        def handshake(listener, token):
            conn = listener.accept()
            hello = pickle.loads(conn.recv(4096))
            return hello
    '''
    good_src = '''\
        import hmac, pickle
        def handshake(listener, token):
            conn = listener.accept()
            presented = conn.recv(32)
            if not hmac.compare_digest(presented, token):
                raise RuntimeError("bad token")
            return pickle.loads(conn.recv(4096))
    '''
    p = Path("fixture.py")
    bad = auth_unpickle.check_source(p, textwrap.dedent(bad_src), Path("."))
    assert bad and all(v.checker == "auth-before-unpickle" for v in bad)
    assert auth_unpickle.check_source(p, textwrap.dedent(good_src), Path(".")) == []


def test_clock_injection_catches_direct_calls():
    bad_src = '''\
        import time
        def wait_for(deadline_s):
            deadline = time.monotonic() + deadline_s
            while time.monotonic() < deadline:
                time.sleep(0.1)
    '''
    good_src = '''\
        import time
        def wait_for(deadline_s, clock=time.monotonic):
            deadline = clock() + deadline_s
            while clock() < deadline:
                pass
    '''
    aliased = '''\
        from time import monotonic as now
        def f():
            return now()
    '''
    p = Path("fixture.py")
    bad = clock_injection.check_source(p, textwrap.dedent(bad_src), Path("."))
    assert len(bad) == 3
    assert clock_injection.check_source(p, textwrap.dedent(good_src), Path(".")) == []
    assert clock_injection.check_source(p, textwrap.dedent(aliased), Path("."))


def test_blocking_lock_catches_blocking_calls_under_lock():
    bad_src = '''\
        import threading
        class C:
            def __init__(self):
                self._lock = threading.Lock()
            def f(self, sock, q):
                with self._lock:
                    data = sock.recv(4096)
                    item = q.get()
                return data, item
    '''
    good_src = '''\
        import threading
        class C:
            def __init__(self):
                self._lock = threading.Lock()
            def f(self, sock, q):
                with self._lock:
                    n = self.count = getattr(self, "count", 0) + 1
                data = sock.recv(4096)
                return n, data
    '''
    p = Path("fixture.py")
    bad = blocking_lock.check_source(p, textwrap.dedent(bad_src), Path("."))
    assert len(bad) == 2
    assert blocking_lock.check_source(p, textwrap.dedent(good_src), Path(".")) == []


def test_future_resolution_catches_loop_without_catchall():
    bad_src = '''\
        import threading
        class Server:
            def start(self):
                self._thread = threading.Thread(target=self._loop)
                self._thread.start()
            def _loop(self):
                while True:
                    fut = self.inflight.pop()
                    fut._resolve(self.step())
    '''
    good_src = '''\
        import threading
        class Server:
            def start(self):
                self._thread = threading.Thread(target=self._loop)
                self._thread.start()
            def _loop(self):
                try:
                    while True:
                        fut = self.inflight.pop()
                        fut._resolve(self.step())
                except BaseException as e:
                    self._fatal = e
                finally:
                    self._fail_inflight()
    '''
    p = Path("fixture.py")
    bad = future_resolution.check_source(p, textwrap.dedent(bad_src), Path("."))
    assert bad and "catch-all" in bad[0].message
    assert future_resolution.check_source(p, textwrap.dedent(good_src), Path(".")) == []


def test_future_resolution_catches_orphaned_future():
    bad_src = '''\
        def submit(self, x):
            fut = ServeFuture()
            self.log(x)
    '''
    good_src = '''\
        def submit(self, x):
            fut = ServeFuture()
            self.log(x)
            return fut
    '''
    p = Path("fixture.py")
    bad = future_resolution.check_source(p, textwrap.dedent(bad_src), Path("."))
    assert bad and "ServeFuture" in bad[0].message
    assert future_resolution.check_source(p, textwrap.dedent(good_src), Path(".")) == []


def test_thread_hygiene_catches_leaks_and_swallows():
    bad_src = '''\
        import threading
        def go():
            t = threading.Thread(target=work)
            t.start()
            try:
                risky()
            except Exception:
                pass
    '''
    good_src = '''\
        import threading
        def go():
            t = threading.Thread(target=work, daemon=True)
            t.start()
            try:
                risky()
            except OSError:
                pass
    '''
    joined_src = '''\
        import threading
        def go():
            t = threading.Thread(target=work)
            t.start()
            t.join()
    '''
    p = Path("fixture.py")
    bad = thread_hygiene.check_source(p, textwrap.dedent(bad_src), Path("."))
    assert len(bad) == 2  # non-daemon unjoined thread + silent swallow
    assert thread_hygiene.check_source(p, textwrap.dedent(good_src), Path(".")) == []
    assert thread_hygiene.check_source(p, textwrap.dedent(joined_src), Path(".")) == []


def test_resource_hygiene_catches_unreleased_segments():
    bad_create = '''\
        from multiprocessing import shared_memory
        def ring():
            shm = shared_memory.SharedMemory(create=True, size=1024)
            return shm
    '''
    bad_attach = '''\
        from multiprocessing.shared_memory import SharedMemory
        def attach(name):
            return SharedMemory(name=name)
    '''
    good_src = '''\
        from multiprocessing import shared_memory
        class Ring:
            def __init__(self):
                self._shm = shared_memory.SharedMemory(create=True, size=1024)
            def close(self):
                self._shm.close()
                self._shm.unlink()
    '''
    p = Path("fixture.py")
    bad = resource_hygiene.check_source(p, textwrap.dedent(bad_create), Path("."))
    assert len(bad) == 2  # no close path AND no unlink path
    assert "unlink" in bad[1].message
    attach = resource_hygiene.check_source(
        p, textwrap.dedent(bad_attach), Path("."))
    assert len(attach) == 1  # attachers need close(), not unlink()
    assert "close" in attach[0].message
    assert resource_hygiene.check_source(
        p, textwrap.dedent(good_src), Path(".")) == []


# ---- waivers ----------------------------------------------------------

def test_waiver_needs_reason_and_matching_checker(tmp_path):
    src = textwrap.dedent('''\
        x = 1  # reprolint: allow=clock-injection -- fixture reason
        pad = 0
        pad = 0
        y = 2  # reprolint: allow=clock-injection
    ''')
    f = tmp_path / "w.py"
    f.write_text(src)
    waivers = parse_waivers(src)
    assert 1 in waivers
    assert 4 not in waivers  # no `-- reason` => not a waiver at all
    vs = [
        Violation("clock-injection", "w.py", 1, "waived (has reason)"),
        Violation("clock-injection", "w.py", 4, "NOT waived (no reason)"),
        Violation("thread-hygiene", "w.py", 1, "NOT waived (other checker)"),
    ]
    kept, waived = apply_waivers(vs, tmp_path)
    assert waived == 1
    assert sorted(v.message for v in kept) == [
        "NOT waived (no reason)", "NOT waived (other checker)",
    ]


def test_waiver_covers_next_line(tmp_path):
    src = textwrap.dedent('''\
        # reprolint: allow=clock-injection -- next-line fixture
        x = 1
    ''')
    (tmp_path / "w.py").write_text(src)
    kept, waived = apply_waivers(
        [Violation("clock-injection", "w.py", 2, "m")], tmp_path)
    assert kept == [] and waived == 1


# ---- lock-order sanitizer ---------------------------------------------

def test_lockorder_detects_seeded_ab_ba_cycle():
    monitor = lockorder.LockOrderMonitor()
    a = lockorder._SanitizedLock(monitor, "site:A")
    b = lockorder._SanitizedLock(monitor, "site:B")

    def ab():
        with a:
            with b:
                pass

    def ba():
        with b:
            with a:
                pass

    t1 = threading.Thread(target=ab)
    t2 = threading.Thread(target=ba)
    t1.start(); t1.join()  # sequential: the ORDER is the bug, not the timing
    t2.start(); t2.join()
    cycles = monitor.cycles()
    assert cycles == [["site:A", "site:B"]]


def test_lockorder_consistent_order_is_clean():
    monitor = lockorder.LockOrderMonitor()
    a = lockorder._SanitizedLock(monitor, "site:A")
    b = lockorder._SanitizedLock(monitor, "site:B")
    for _ in range(3):
        with a:
            with b:
                pass
    assert monitor.cycles() == []
    rep = monitor.report()
    assert ("site:A", "site:B") in [tuple(e) for e in rep["ordered_edges"]]


def test_lockorder_condition_wait_releases_held_stack():
    """Condition.wait over a sanitized RLock must pop the lock from the
    monitor's held stack (it really is released while waiting) — else
    every wait-then-acquire would fabricate false edges."""
    monitor = lockorder.LockOrderMonitor()
    rl = lockorder._SanitizedRLock(monitor, "site:R")
    other = lockorder._SanitizedLock(monitor, "site:O")
    cond = threading.Condition(rl)
    woke = []

    def waiter():
        with cond:
            woke.append(cond.wait(timeout=5))

    t = threading.Thread(target=waiter)
    t.start()
    # while the waiter sleeps inside wait(), this thread takes the
    # OTHER lock then the rlock: if wait() had not released site:R
    # from the waiter's stack, notify could never be delivered at all
    import time as _time
    _time.sleep(0.05)
    with other:
        with cond:
            cond.notify()
    t.join(5)
    assert woke == [True]
    assert monitor.cycles() == []


def test_lockorder_install_uninstall_roundtrip():
    real_lock = threading.Lock
    monitor = lockorder.install()
    try:
        assert lockorder.install() is monitor  # idempotent
        lk = threading.Lock()
        assert isinstance(lk, lockorder._SanitizedLock)
        with lk:
            pass
    finally:
        lockorder.uninstall()
    assert threading.Lock is real_lock
