"""HLO analyzer: trip-count weighting and dot-flop counting verified
against modules with known costs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_parse import analyze_hlo, parse_computations


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_single_dot_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    comp = _compile(lambda x, y: x @ y, a, b)
    c = analyze_hlo(comp.as_text())
    assert c.flops == 2 * 128 * 64 * 256
    # memory: lhs + rhs + result + args + out
    min_bytes = (128 * 256 + 256 * 64 + 128 * 64) * 4
    assert c.memory_bytes >= min_bytes


def test_while_trip_count_multiplies():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((17, 64, 64), jnp.float32)

    def f(x, ws):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    comp = _compile(f, a, w)
    c = analyze_hlo(comp.as_text())
    per_iter = 2 * 64 * 64 * 64
    assert c.flops == pytest.approx(17 * per_iter, rel=0.01), c.flops


def test_nested_scan_multiplies_twice():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 32, 32), jnp.float32)

    def f(x, ws):
        def outer(c, _):
            def inner(ci, wi):
                return ci @ wi, None
            y, _ = jax.lax.scan(inner, c, ws)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    comp = _compile(f, a, w)
    c = analyze_hlo(comp.as_text())
    per_iter = 2 * 32 * 32 * 32
    assert c.flops == pytest.approx(5 * 3 * per_iter, rel=0.01), c.flops


def test_backward_dots_counted():
    """grad adds backward dots on top of the forward ones (the
    useful-flops-ratio denominator behaviour we rely on)."""
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def loss(x, wi):
        f = jax.checkpoint(lambda x: jnp.sum(jnp.tanh(x @ wi) @ wi))
        return f(x)

    comp = _compile(lambda x, wi: jax.grad(loss)(x, wi), a, w)
    c = analyze_hlo(comp.as_text())
    fwd = 2 * 2 * 64 * 64 * 64
    assert c.flops >= 1.5 * fwd  # fwd + bwd dots present


def test_parse_computations_structure():
    comp = _compile(lambda x: x @ x.T, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    comps = parse_computations(comp.as_text())
    assert any(c.is_entry for c in comps.values())
