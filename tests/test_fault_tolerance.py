"""Chaos lane: fault injection against the elastic cluster runtime.

The acceptance bar, exercised for real: a TCP slave SIGKILLed in the
middle of a pipelined train step is DETECTED within the configured
heartbeat timeout, auto-evicted, its in-flight shards recomputed by the
master, and the step completes on the survivors with gradients matching
the single-device VJP — then the next step re-partitions via the
comm-aware Eq. 1 over the survivors.  A wedged (SIGSTOPped) slave —
socket open, nothing flowing — trips the heartbeat deadline instead of
the EOF fast path.  And a slave launched BY HAND via
``python -m repro.core.cluster.protocol --host H --port P`` joins a
waiting cluster (the remote-host path, over loopback here).
"""
import os
import signal
import socket
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.cluster.transport import (
    SlaveLost,
    TCPListener,
    TCPSlaveEndpoint,
    TCPTransport,
)
from repro.core.master_slave import HeteroCluster


def _data(seed=4):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(5, 8, 8, 3)).astype(np.float32)
    w1 = rng.normal(size=(3, 3, 3, 6)).astype(np.float32)
    w2 = rng.normal(size=(3, 3, 6, 9)).astype(np.float32)
    g = rng.normal(size=(5, 8, 8, 9)).astype(np.float32)
    return x, w1, w2, g


def _single_device_grads(x, w1, w2, g):
    import jax
    import jax.numpy as jnp

    def f(x_, w1_, w2_):
        y = jax.nn.relu(jax.lax.conv_general_dilated(
            x_, w1_, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ))
        y2 = jax.lax.conv_general_dilated(
            y, w2_, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        return jnp.sum(y2 * g)

    return tuple(
        np.asarray(a)
        for a in jax.grad(f, argnums=(0, 1, 2))(
            jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2)
        )
    )


def _run_step(c, x, w1, w2, g, fault=None):
    """One pipelined fwd+bwd train chain; ``fault()`` (if given) fires
    from the first between-stage callback — i.e. MID-STEP, with conv
    and bwd ops still in flight on every link."""
    fired = {}

    def between(y):
        if fault is not None and not fired:
            fired["t"] = time.monotonic()
            fault()
        mask = (y > 0).astype(np.float32)
        return np.maximum(y, 0.0), lambda gz: gz * mask

    slices = c.microbatch_slices(x.shape[0])

    def head(z, i):
        return None, g[slices[i]]

    res = c.conv_train_chain(x, [w1, w2], [between, None], head)
    return res, fired.get("t")


def _assert_matches(res, want, atol=1e-3):
    dx_want, dw1_want, dw2_want = want
    np.testing.assert_allclose(res.dx, dx_want, rtol=1e-4, atol=atol)
    np.testing.assert_allclose(res.dw[0], dw1_want, rtol=1e-4, atol=atol)
    np.testing.assert_allclose(res.dw[1], dw2_want, rtol=1e-4, atol=atol)


def test_sigkill_mid_step_recovers_on_survivors():
    """SIGKILL one TCP slave while a pipelined train step has ops in
    flight: the loss is detected within the heartbeat timeout, the
    victim is auto-evicted, the master absorbs its shards, and the
    step's gradients still match the single-device VJP.  The NEXT step
    re-partitions over the survivors and matches too."""
    x, w1, w2, g = _data()
    want = _single_device_grads(x, w1, w2, g)
    c = HeteroCluster(
        [1.0, 1.0, 1.0], transport="tcp", pipeline=True, microbatches=3,
        heartbeat_s=2.0,  # timeout 6s; a SIGKILL EOF lands far sooner
    )
    try:
        c.probe_times = [1.0, 1.0, 1.0]
        victim_proc = c.procs[0]
        victim_dev = c.slave_ids[0]
        res, t_kill = _run_step(c, x, w1, w2, g, fault=victim_proc.kill)
        _assert_matches(res, want)
        # detection: recorded, attributed, and within the deadline
        assert len(c.failures) == 1
        assert c.failures[0]["device"] == victim_dev
        assert t_kill is not None
        assert c.failures[0]["t_detected"] - t_kill < c.heartbeat_timeout_s
        # survivor-only membership, victim reaped, recovery work logged
        assert c.slave_ids == [2] and c.n_slaves == 1
        assert victim_proc.returncode is not None
        assert c.timing.recompute_s > 0.0
        # the next step re-partitions on the survivors: plans cover
        # exactly master + 1 slave and numerics hold
        plan = c.plan_conv(x.shape, w2, "train")
        assert len(plan.counts) == 2 and int(plan.counts.sum()) == w2.shape[-1]
        res2, _ = _run_step(c, x, w1, w2, g)
        _assert_matches(res2, want)
    finally:
        c.shutdown()


def test_sigstop_wedged_slave_trips_heartbeat_deadline():
    """A SIGSTOPped slave keeps its socket open — only the heartbeat
    deadline can unmask it.  The step must still complete correctly,
    within the timeout + the step's own work."""
    x, w1, w2, g = _data(seed=7)
    want = _single_device_grads(x, w1, w2, g)
    c = HeteroCluster(
        [1.0, 1.0, 1.0], transport="tcp", pipeline=True, microbatches=3,
        heartbeat_s=0.25,  # timeout 0.75s: keep the blocked wait short
    )
    try:
        c.probe_times = [1.0, 1.0, 1.0]
        victim = c.procs[0]
        res, t_stop = _run_step(
            c, x, w1, w2, g,
            fault=lambda: os.kill(victim.pid, signal.SIGSTOP),
        )
        _assert_matches(res, want)
        assert len(c.failures) == 1
        assert "deadline" in c.failures[0]["error"]
        # detected via the heartbeat clock, not EOF — and within it
        # (plus scheduling slack: the master only reads at gathers)
        assert c.failures[0]["t_detected"] - t_stop < c.heartbeat_timeout_s + 2.0
        assert c.slave_ids == [2]
    finally:
        c.shutdown()
        # _remove_slot SIGKILLed and reaped the stopped process
        assert victim.returncode is not None


def test_wedged_link_raises_slave_lost_within_deadline():
    """Transport-level deadline: a link whose peer never beats raises
    SlaveLost from read_on_master within the configured timeout."""
    listener = TCPListener()
    box = {}

    def _connect():
        box["ep"] = TCPSlaveEndpoint(listener.host, listener.port)

    t = threading.Thread(target=_connect)
    t.start()
    chan = TCPTransport(listener.accept(timeout_s=10), heartbeat_timeout_s=0.6)
    t.join(timeout=10)
    try:
        t0 = time.monotonic()
        with pytest.raises(SlaveLost, match="deadline"):
            chan.read_on_master()
        elapsed = time.monotonic() - t0
        assert 0.5 <= elapsed < 5.0, elapsed
    finally:
        chan.close()
        box["ep"].close()
        listener.close()


def test_mid_frame_stall_trips_deadline():
    """select() only promises the FIRST byte of a frame: a peer that
    stalls MID-frame (e.g. SIGSTOPped between chunks of a multi-MB
    result) must still trip the armed deadline instead of hanging a
    timeout-less recv forever."""
    import struct

    listener = TCPListener()
    box = {}

    def _connect():
        box["s"] = socket.create_connection((listener.host, listener.port))

    t = threading.Thread(target=_connect)
    t.start()
    chan = TCPTransport(listener.accept(timeout_s=10), heartbeat_timeout_s=0.6)
    t.join(timeout=10)
    peer = box["s"]
    try:
        # header promises 1 MB; only 1 KB ever arrives
        peer.sendall(struct.pack(">Q", 1 << 20) + b"x" * 1024)
        t0 = time.monotonic()
        with pytest.raises(SlaveLost, match="mid-frame"):
            chan.read_on_master()
        assert 0.5 <= time.monotonic() - t0 < 5.0
    finally:
        chan.close()
        peer.close()
        listener.close()


def test_heartbeats_keep_slow_link_alive():
    """The inverse: a peer that beats but answers slowly must NOT be
    declared lost — heartbeats refresh the deadline."""
    listener = TCPListener()
    box = {}

    def _connect():
        box["ep"] = TCPSlaveEndpoint(listener.host, listener.port)

    t = threading.Thread(target=_connect)
    t.start()
    chan = TCPTransport(listener.accept(timeout_s=10), heartbeat_timeout_s=0.6)
    t.join(timeout=10)
    ep = box["ep"]
    try:
        ep.start_heartbeat(0.15)

        def _slow_reply():
            time.sleep(1.5)  # >2x the deadline, bridged by heartbeats
            ep.send(("done", np.arange(3, dtype=np.float32)))

        threading.Thread(target=_slow_reply, daemon=True).start()
        tag, arr = chan.read_on_master()
        assert tag == "done"
        np.testing.assert_array_equal(arr, np.arange(3, dtype=np.float32))
        # heartbeats are liveness, not protocol traffic: only the real
        # reply may be accounted
        assert chan.bytes_to_master == arr.nbytes + 8
    finally:
        chan.close()
        ep.close()
        listener.close()


def test_slave_killed_between_steps_recovers():
    """A slave dead BEFORE the step starts (no in-flight ops): the
    first scatter/gather of the next step discovers it, recovery kicks
    in, and the step completes correctly on the survivors."""
    x, w1, w2, g = _data(seed=9)
    want = _single_device_grads(x, w1, w2, g)
    c = HeteroCluster([1.0, 1.0, 1.0], transport="tcp", pipeline=True,
                      microbatches=3)
    try:
        c.probe_times = [1.0, 1.0, 1.0]
        c.procs[1].kill()
        c.procs[1].wait(timeout=10)
        res, _ = _run_step(c, x, w1, w2, g)
        _assert_matches(res, want)
        assert c.slave_ids == [1]
        assert len(c.failures) == 1 and c.failures[0]["device"] == 2
    finally:
        c.shutdown()


def test_hand_launched_slave_joins_waiting_cluster():
    """The remote-host path over loopback: a slave started by hand via
    ``python -m repro.core.cluster.protocol --host H --port P`` (no
    --device: the master assigns one) joins a cluster waiting with
    expected_slaves=1, probes, and serves a real train step."""
    x, w1, w2, g = _data(seed=11)
    want = _single_device_grads(x, w1, w2, g)
    # rendezvous port: bind-and-release (the race window is negligible
    # on a CI loopback)
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    token = "ab" * 32
    env = os.environ.copy()
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env["REPRO_CLUSTER_AUTH"] = token
    # the slave starts FIRST and retries the connect until the master
    # binds — the two-terminal ordering an operator would actually hit
    slave = subprocess.Popen(
        [sys.executable, "-m", "repro.core.cluster.protocol",
         "--host", "127.0.0.1", "--port", str(port),
         "--backend", "numpy", "--heartbeat-s", "0.25",
         "--connect-timeout-s", "30"],
        env=env,
    )
    os.environ["REPRO_CLUSTER_AUTH"] = token
    try:
        c = HeteroCluster(
            [1.0], transport="tcp", expected_slaves=1,
            listen_port=port, heartbeat_s=0.25, pipeline=True,
            microbatches=3,
        )
        try:
            assert c.n_slaves == 1 and c.backends == ["numpy", "numpy"]
            probe = c.probe(image_size=8, in_channels=3, kernel_size=3,
                            num_kernels=4, batch=2, repeats=1)
            assert len(probe) == 2 and all(t > 0 for t in probe)
            assert c.measured_bandwidths[0] is not None
            res, _ = _run_step(c, x, w1, w2, g)
            _assert_matches(res, want)
        finally:
            c.shutdown()
        assert slave.wait(timeout=10) == 0
    finally:
        os.environ.pop("REPRO_CLUSTER_AUTH", None)
        if slave.poll() is None:
            slave.kill()
