"""The pipelined FULL training step (conv_train_chain / conv_train_step):
numerics must match the single-device VJP — including mixed compute
backends — the FIFO contract must hold when conv and bwd ops interleave
on the wire, comm bytes must be accounted under emulated bandwidth, and
the documented callback deadlocks must fail fast instead of hanging."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.master_slave import HeteroCluster, make_distributed_conv
from repro.core.partitioner import DeviceProfile, comp_aware_times, profiles_to_shares
from repro.models.cnn import (
    cnn_loss,
    init_cnn,
    make_cluster_train_step,
    make_cnn_config,
)


def _ref_conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _data(b=5, s=8, cin=3, cout=21, k=5, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, s, s, cin)).astype(np.float32)
    w = rng.normal(size=(k, k, cin, cout)).astype(np.float32)
    g = rng.normal(size=(b, s, s, cout)).astype(np.float32)
    return x, w, g


def _train_chain_refs(x, w1, w2):
    """Single-device forward + VJP of conv -> relu -> conv -> sum(y*g)."""
    _, _, g = _data(b=x.shape[0], s=x.shape[1], cin=x.shape[3],
                    cout=w2.shape[3], seed=9)

    def f(x, w1, w2):
        y = jax.nn.relu(_ref_conv(x, w1))
        return jnp.sum(_ref_conv(y, w2) * g)

    grads = jax.grad(f, argnums=(0, 1, 2))(
        jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2)
    )
    return g, tuple(np.asarray(a) for a in grads)


def _run_train_chain(cluster, x, w1, w2, g):
    """Drive conv_train_chain with a relu between stage and a fixed-g head."""

    def between(y):
        mask = (y > 0).astype(np.float32)
        return np.maximum(y, 0.0), lambda gz: gz * mask

    slices = cluster.microbatch_slices(x.shape[0])

    def head(z, i):
        return None, g[slices[i]]

    return cluster.conv_train_chain(x, [w1, w2], [between, None], head)


@pytest.mark.parametrize("backends", [None, ["numpy", "xla", "numpy"]])
def test_train_chain_matches_single_device_vjp(backends):
    """Pipelined fwd+bwd over the cluster == jax.grad on one device, for
    all-numpy and mixed numpy/xla clusters (uneven shards, microbatches)."""
    x, w1, _ = _data(cout=6, seed=3)
    rng = np.random.default_rng(4)
    w2 = rng.normal(size=(5, 5, 6, 9)).astype(np.float32)
    g, (dx_want, dw1_want, dw2_want) = _train_chain_refs(x, w1, w2)

    c = HeteroCluster([1.0, 1.5, 2.0], backends, pipeline=True, microbatches=3)
    try:
        c.probe_times = [1.0, 1.5, 2.0]
        res = _run_train_chain(c, x, w1, w2, g)
        np.testing.assert_allclose(res.dx, dx_want, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(res.dw[0], dw1_want, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(res.dw[1], dw2_want, rtol=1e-4, atol=1e-3)
    finally:
        c.shutdown()


def test_cluster_train_step_matches_sgd():
    """The models/cnn.py driver: one distributed step == loss/grads/SGD of
    the single-device reference, end to end (conv, bias, LRN, pool, fc)."""
    cfg = make_cnn_config(6, 10)
    params = init_cnn(jax.random.key(0), cfg)
    imgs = jax.random.normal(jax.random.key(1), (5, 32, 32, 3))
    labels = jnp.array([0, 1, 2, 3, 4])
    lr = 0.05

    (loss_ref, _), grads = jax.value_and_grad(
        lambda p: cnn_loss(p, imgs, labels, cfg=cfg), has_aux=True
    )(params)
    ref_new = jax.tree.map(lambda p, g: p - lr * g, params, grads)

    c = HeteroCluster([1.0, 1.5, 2.0], pipeline=True, microbatches=3)
    try:
        c.probe(image_size=8, in_channels=3, kernel_size=5, num_kernels=8, batch=2)
        step = make_cluster_train_step(c, cfg, lr=lr)
        new_params, loss, _acc = step(params, imgs, labels)
        assert np.isclose(float(loss_ref), loss, atol=1e-5)
        flat_ref, _ = jax.tree_util.tree_flatten_with_path(ref_new)
        flat_new, _ = jax.tree_util.tree_flatten_with_path(new_params)
        for (pa, a), (_pb, b) in zip(
            sorted(flat_ref, key=lambda kv: str(kv[0])),
            sorted(flat_new, key=lambda kv: str(kv[0])),
        ):
            np.testing.assert_allclose(
                np.asarray(b), np.asarray(a), atol=1e-4, err_msg=str(pa)
            )
        # the chain measured the master's non-conv duty for Eq. 1
        assert 0.0 < c.comp_duty <= 1.0
    finally:
        c.shutdown()


def test_fifo_when_conv_and_bwd_ops_interleave():
    """Interleaved conv/bwd scatters must gather in exact issue order —
    the wire order of a train step — and out-of-order gathers raise."""
    c = HeteroCluster([1.0, 1.5], pipeline=True, microbatches=2)
    try:
        c.probe_times = [1.0, 1.5]
        x, w, g = _data(b=2, seed=6)
        want_y = np.asarray(_ref_conv(x, w))
        _, pullback = jax.vjp(_ref_conv, jnp.asarray(x), jnp.asarray(w))
        dx_want, dw_want = (np.asarray(a) for a in pullback(jnp.asarray(g)))

        p1 = c.scatter_conv(x, w)
        p2 = c.scatter_bwd(x, w, g)
        p3 = c.scatter_conv(x, w)
        # FIFO violations: wrong seq, and wrong op for the right seq
        with pytest.raises(RuntimeError):
            c.gather_bwd(p2)
        with pytest.raises(RuntimeError):
            c.gather_bwd(p1)  # seq 1 is a conv, gathered as bwd
        # draining in issue order still works and stays bit-correct
        np.testing.assert_allclose(c.gather_conv(p1), want_y, atol=1e-4)
        dx, dw = c.gather_bwd(p2)
        np.testing.assert_allclose(dx, dx_want, atol=1e-4)
        np.testing.assert_allclose(dw, dw_want, atol=1e-4)
        np.testing.assert_allclose(c.gather_conv(p3), want_y, atol=1e-4)
    finally:
        c.shutdown()


def test_train_chain_comm_bytes_under_bandwidth():
    """Over finite links the train step's traffic is fully accounted and
    each phase's kernel shard crosses the wire ONCE (microbatches after
    the first ride the slave's cached copy); numerics are unharmed.
    The versioned weight-broadcast cache is disabled so the per-phase
    accounting stays exact (with it on, the bwd phases re-ship their
    unchanged shards as ~24-byte tokens — test_weight_cache.py pins
    that side)."""
    x, w1, _ = _data(b=4, cout=6, seed=3)
    rng = np.random.default_rng(4)
    w2 = rng.normal(size=(5, 5, 6, 9)).astype(np.float32)
    g, (dx_want, dw1_want, dw2_want) = _train_chain_refs(x, w1, w2)

    c = HeteroCluster([1.0, 1.0], pipeline=True, microbatches=4,
                      bandwidth_mbps=2000.0, weight_cache=False)
    try:
        c.probe_times = [1.0, 1.0]
        c.reset_stats()
        # the counts the chain will use: compute BEFORE the run — the
        # chain's measured comp_duty re-balances shares for LATER steps
        counts = [c.shares_for(w.shape[-1]) for w in (w1, w2)]
        res = _run_train_chain(c, x, w1, w2, g)
        np.testing.assert_allclose(res.dx, dx_want, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(res.dw[1], dw2_want, rtol=1e-4, atol=1e-3)
        shard_b = [c._split(w, ct)[1].nbytes for w, ct in ((w1, counts[0]), (w2, counts[1]))]
        y1 = np.maximum(np.asarray(_ref_conv(x, w1)), 0.0)
        # master -> slave, per phase: fwd k sends x_k per microbatch + its
        # shard once; bwd k sends (x_k, g_k-slice) per microbatch + the
        # shard once.  Everything else is 8-byte flags/None markers.
        g2_slave = g.nbytes // g.shape[-1] * int(counts[1][1])
        g1_slave = y1.nbytes // y1.shape[-1] * int(counts[0][1])
        payload = (
            x.nbytes + shard_b[0]                 # fwd conv1
            + y1.nbytes + shard_b[1]              # fwd conv2
            + y1.nbytes + shard_b[1] + g2_slave   # bwd conv2
            + x.nbytes + shard_b[0] + g1_slave    # bwd conv1
        )
        to_slave = c.sockets[0].bytes_to_slave
        assert payload <= to_slave <= payload + 1024, (payload, to_slave)
        assert c.comm_bytes == sum(s.total_bytes for s in c.sockets)
        assert c.sockets[0].bytes_to_master > 0
    finally:
        c.shutdown()


def test_callback_deadlocks_fail_fast():
    """The two documented make_distributed_conv deadlocks raise a clear
    error at construction instead of hanging at 0% CPU."""
    c = HeteroCluster([1.0, 1.0], ["xla", "numpy"])
    try:
        with pytest.raises(RuntimeError, match="master.*numpy"):
            make_distributed_conv(c)
    finally:
        c.shutdown()

    c = HeteroCluster([1.0, 1.0], ["numpy", "pallas"])
    try:
        from repro.core.backends import get_backend

        if getattr(get_backend("pallas"), "interpret", False):
            with pytest.raises(RuntimeError, match="interpret"):
                make_distributed_conv(c)
    finally:
        c.shutdown()

    # the parameterized registry name must not slip past the check
    c = HeteroCluster([1.0, 1.0], ["numpy", "pallas:interpret"])
    try:
        with pytest.raises(RuntimeError, match="interpret"):
            make_distributed_conv(c)
    finally:
        c.shutdown()


def test_comp_aware_shares_discount_master():
    """A busy master (non-conv duty) loses conv kernels to the slaves;
    comp_aware=False restores the seed behaviour."""
    c = HeteroCluster([1.0, 1.0, 1.0])
    try:
        c.probe_times = [1.0, 1.0, 1.0]
        base = c.shares_for(30).tolist()
        c.comp_duty = 0.5
        discounted = c.shares_for(30).tolist()
        assert discounted[0] < base[0]
        assert sum(discounted) == 30
        c.comp_aware = False
        assert c.shares_for(30).tolist() == base
    finally:
        c.shutdown()

    t = comp_aware_times([1.0, 2.0], 0.5)
    assert t[0] == pytest.approx(2.0) and t[1] == pytest.approx(2.0)
    # duty >= 1 clamps instead of dividing by zero
    assert np.isfinite(comp_aware_times([1.0], 1.0)[0])

    profs = [DeviceProfile("m", 1.0, comp_duty=0.5), DeviceProfile("s", 1.0)]
    shares = profiles_to_shares(profs)
    assert shares[0] == pytest.approx(1.0 / 3.0)
    assert profs[0].with_comp_duty(0.0).effective_conv_time == pytest.approx(1.0)


def test_zero_kernel_shard_runs_on_every_backend():
    """Comp-aware shares may allocate 0 kernels to a device; the protocol
    must tolerate that on any backend (pallas grid math divides by cout),
    both directions — instead of killing the slave and hanging."""
    x, w, g = _data(b=2, s=4, cout=4, k=3, seed=10)
    # pallas-interpret slave deliberately given ~no share via probe times
    c = HeteroCluster([1.0, 1e6], ["numpy", "pallas"])
    try:
        c.probe_times = [1.0, 1e6]
        assert c.shares_for(4).tolist() == [4, 0]
        want = np.asarray(_ref_conv(x, w))
        np.testing.assert_allclose(c.conv_forward(x, w), want, atol=1e-4)
        _, pullback = jax.vjp(_ref_conv, jnp.asarray(x), jnp.asarray(w))
        dx_want, dw_want = pullback(jnp.asarray(g))
        dx, dw = c.conv_backward(x, w, g)
        np.testing.assert_allclose(dx, np.asarray(dx_want), atol=1e-4)
        np.testing.assert_allclose(dw, np.asarray(dw_want), atol=1e-4)
    finally:
        c.shutdown()


def test_slave_exception_raises_at_gather():
    """A slave whose backend blows up ships the traceback to the master,
    which raises at the matching gather — no 0%-CPU hang."""
    from repro.core.cluster.plans import LayerPlan

    x, w, _ = _data(b=2, s=4, cout=4, k=3, seed=11)
    c = HeteroCluster([1.0, 1.0])
    try:
        c.probe_times = [1.0, 1.0]
        plan = LayerPlan(
            "kernel", np.array([2, 2]),
            shards=[w[..., :2], "not-an-array"],
            member_ids=tuple(c.slave_ids),
        )
        p = c._scatter_conv_shards(x, plan, send_weights=True)
        with pytest.raises(RuntimeError, match="slave device 1 failed"):
            c.gather_conv(p)
    finally:
        c.shutdown()


def test_mesh_context_compat():
    """The version-compat mesh shim activates a mesh visible to the
    sharding constraints on every pinned jax (the seed-failure bugfix)."""
    from repro.compat import get_active_mesh, mesh_context

    assert get_active_mesh() is None
    mesh = jax.make_mesh((1,), ("model",))
    with mesh_context(mesh):
        active = get_active_mesh()
        assert active is not None
        assert "model" in active.axis_names
    assert get_active_mesh() is None
