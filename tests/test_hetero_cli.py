"""The launch.hetero CLI must EXIT in every configuration.

The ROADMAP pre-existing bug: with an ``xla`` slave the CLI completed
its steps and printed results but then hung at interpreter exit (XLA
runtime threads vs CPython finalization).  The CLI now always leaves
through a flushed ``os._exit`` (``_clean_exit``), so a subprocess run
with a timeout is the regression test: if the hang comes back, the
timeout fires.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run_hetero(tmp_path, *args, timeout=600):
    out = tmp_path / "rec.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.hetero",
         "--steps", "1", "--batch", "2", "--c1", "4", "--c2", "4",
         "--out", str(out), *args],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=timeout,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text().splitlines()[0])
    assert len(rec["losses"]) == 1
    return rec, r.stdout


@pytest.mark.slow
def test_cli_exits_cleanly_with_xla_slave(tmp_path):
    """The exact ROADMAP hang configuration: callback-driven training
    with an xla SLAVE.  Completing within the timeout IS the test."""
    rec, stdout = _run_hetero(
        tmp_path, "--slowdowns", "1.0,1.0", "--backends", "numpy,xla",
    )
    assert rec["backends"] == ["numpy", "xla"]
    assert "steps in" in stdout


@pytest.mark.slow
def test_cli_exits_cleanly_with_tcp_transport(tmp_path):
    """The full-lane e2e shape: one real train step over subprocess TCP
    slaves, train-pipeline schedule."""
    rec, _ = _run_hetero(
        tmp_path, "--slowdowns", "1.0,1.5", "--transport", "tcp",
        "--train-pipeline",
    )
    assert rec["transport"] == "tcp"
    assert all(b and b > 0 for b in rec["measured_bandwidth_mbps"])


def test_cli_exits_cleanly_all_numpy_fast(tmp_path):
    """Fast-lane guard on the exit path itself (no xla slave, tiny)."""
    rec, _ = _run_hetero(
        tmp_path, "--slowdowns", "1.0,1.0", "--train-pipeline", timeout=300,
    )
    assert rec["protocol"] == "trainstep-pipelined"
