"""Transport conformance: the in-proc queue emulation, the real TCP
wire and the shared-memory rings must be interchangeable behind the
same contract.

One suite runs against ALL THREE transports: payload roundtrip fidelity
and FIFO order, canonical nbytes accounting (identical numbers on every
wire, with and without each codec stage), slave-error propagation, and
— subprocess wires — measured link bandwidth feeding the comm-aware
partitioner, subprocess slave numerics vs the single-device VJP on
every partition axis, and orderly subprocess shutdown on cluster close
and after a master-side protocol exception.  Shm additionally proves
segment hygiene (nothing leaks into /dev/shm) and the inline fallback
for arrays larger than the ring.
"""
import threading

import numpy as np
import pytest

from repro.core.cluster.codec import WireCodec, resolve_wire_dtype
from repro.core.cluster.transport import (
    InProcTransport,
    ShmSlaveEndpoint,
    ShmTransport,
    TCPListener,
    TCPSlaveEndpoint,
    TCPTransport,
)
from repro.core.master_slave import HeteroCluster

TRANSPORTS = ("inproc", "tcp", "shm")


def _make_link(kind: str, wire_dtype=None, wire_codec=None, **chan_kw):
    """(master_channel, slave_endpoint, close) for any transport; the
    TCP/shm pairs cross a REAL localhost socket.  Each side gets its
    own codec instance, like the cluster builds per link."""
    dtype = resolve_wire_dtype(wire_dtype)

    def _codec():
        return WireCodec.from_spec(wire_codec, wire_dtype)

    if kind == "inproc":
        link = InProcTransport(None, dtype, wire_codec=_codec())
        return link, link.slave_endpoint(), link.close
    chan_cls, ep_cls = (
        (ShmTransport, ShmSlaveEndpoint) if kind == "shm"
        else (TCPTransport, TCPSlaveEndpoint)
    )
    listener = TCPListener()
    slave_box = {}

    def _connect():
        slave_box["ep"] = ep_cls(
            listener.host, listener.port, dtype, wire_codec=_codec()
        )

    t = threading.Thread(target=_connect)
    t.start()
    chan = chan_cls(
        listener.accept(timeout_s=10), dtype, wire_codec=_codec(), **chan_kw
    )
    t.join(timeout=10)
    slave = slave_box["ep"]

    def _close():
        chan.close()
        slave.close()
        listener.close()

    return chan, slave, _close


def _payload(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "x": rng.normal(size=(2, 4, 4, 3)).astype(np.float32),
        "nested": (np.arange(5, dtype=np.float64), [np.ones(3, np.float32)]),
        "ints": np.arange(4, dtype=np.int32),
        "flag": "keep-me",
    }


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_roundtrip_fifo_both_directions(kind):
    """Messages cross intact (nested containers, dtypes, strings) and in
    FIFO order, in both directions."""
    chan, slave, close = _make_link(kind)
    try:
        msgs = [_payload(s) for s in range(3)]
        for m in msgs:
            chan.write_to_slave(m)
        for m in msgs:
            got = slave.recv()
            assert got["flag"] == "keep-me"
            np.testing.assert_array_equal(got["x"], m["x"])
            np.testing.assert_array_equal(got["nested"][0], m["nested"][0])
            assert got["ints"].dtype == np.int32
            slave.send(("echo", got["ints"]))
        for m in msgs:
            tag, ints = chan.read_on_master()
            assert tag == "echo"
            np.testing.assert_array_equal(ints, m["ints"])
    finally:
        close()


# canonical bytes of _payload() under each wire setting — the GOLDEN
# accounting numbers every transport must report identically.  96 float
# elements (x), 5 float64 (normalized to the codec dtype — float32 even
# on the uncompressed wire), 3 float32 (ones), 4 int32 (never encoded),
# one string flag and FOUR dict keys at the 8-byte scalar rate.
_GOLDEN_BYTES = {
    (None, None): 96 * 4 + 5 * 4 + 3 * 4 + 16 + 8 + 4 * 8,      # 472
    ("fp16", None): 96 * 2 + 5 * 2 + 3 * 2 + 16 + 8 + 4 * 8,    # 264
    ("bf16", None): 96 * 2 + 5 * 2 + 3 * 2 + 16 + 8 + 4 * 8,    # 264
    # int8: each float tensor ships q.nbytes + one 8-byte scale
    (None, "int8"): (96 + 8) + (5 + 8) + (3 + 8) + 16 + 8 + 4 * 8,  # 184
}


@pytest.mark.parametrize("wire_dtype,wire_codec", sorted(
    _GOLDEN_BYTES, key=str
))
def test_nbytes_accounting_identical_across_transports(wire_dtype, wire_codec):
    """The canonical byte counters report the SAME golden number on the
    queue emulation, the real TCP wire and the shm rings — comm_bytes
    is transport-independent — for every codec stage."""
    counted = {}
    for kind in TRANSPORTS:
        chan, slave, close = _make_link(kind, wire_dtype, wire_codec)
        try:
            chan.write_to_slave(_payload())
            slave.recv()
            counted[kind] = chan.bytes_to_slave
        finally:
            close()
    want = _GOLDEN_BYTES[(wire_dtype, wire_codec)]
    assert counted == {kind: want for kind in TRANSPORTS}


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_float64_normalized_to_float32_on_uncompressed_wire(kind):
    """The fp32 (no-codec) wire must not ship 8-byte doubles: float64
    arrays normalize to float32 on write, so ``comm_bytes`` is
    comparable across codec settings (PR 8 accounting-asymmetry fix)."""
    chan, slave, close = _make_link(kind)
    try:
        chan.write_to_slave(np.arange(6, dtype=np.float64))
        got = slave.recv()
        assert got.dtype == np.float32
        assert chan.bytes_to_slave == 6 * 4
        slave.send(np.arange(6, dtype=np.float64))
        back = chan.read_on_master()
        assert back.dtype == np.float32
    finally:
        close()


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_codec_decodes_to_float32_on_read(kind):
    chan, slave, close = _make_link(kind, "fp16")
    try:
        chan.write_to_slave(np.arange(8, dtype=np.float32))
        got = slave.recv()
        assert got.dtype == np.float32
        np.testing.assert_array_equal(got, np.arange(8, dtype=np.float32))
        slave.send(got)
        back = chan.read_on_master()
        assert back.dtype == np.float32
    finally:
        close()


def test_tcp_frame_bytes_track_real_wire():
    """TCP additionally accounts what ACTUALLY crossed the socket —
    framing + pickle overhead on top of the canonical payload bytes."""
    chan, slave, close = _make_link("tcp")
    try:
        chan.write_to_slave(_payload())
        slave.recv()
        assert chan.frame_bytes_to_slave > chan.bytes_to_slave > 0
    finally:
        close()


# ---------------------------------------------------------------------------
# shm-specific: segment hygiene and the inline-overflow fallback
# ---------------------------------------------------------------------------


def _shm_segments():
    import os

    try:
        return set(os.listdir("/dev/shm"))
    except (FileNotFoundError, NotADirectoryError):  # pragma: no cover
        pytest.skip("no /dev/shm on this platform")


def test_shm_close_unlinks_every_segment():
    """The shm link creates its rings on open and must leave NOTHING in
    /dev/shm after close — the master owns unlink, the slave only
    detaches."""
    before = _shm_segments()
    chan, slave, close = _make_link("shm")
    try:
        chan.write_to_slave(_payload())
        slave.recv()
        assert _shm_segments() - before  # the rings are real OS segments
    finally:
        close()
    assert _shm_segments() - before == set()


def test_shm_array_larger_than_ring_falls_back_inline():
    """An array that cannot fit the ring ships inline on the control
    socket instead of deadlocking the ring writer — and the canonical
    accounting is unchanged either way."""
    big = np.arange(4096, dtype=np.float32)  # 16 KiB > the 4 KiB ring
    small = np.ones((8, 8), np.float32)
    chan, slave, close = _make_link("shm", ring_bytes=4096)
    try:
        chan.write_to_slave({"big": big, "small": small})
        got = slave.recv()
        np.testing.assert_array_equal(got["big"], big)
        np.testing.assert_array_equal(got["small"], small)
        assert chan.bytes_to_slave == big.nbytes + small.nbytes + 2 * 8
        slave.send(big * 2.0)
        np.testing.assert_array_equal(chan.read_on_master(), big * 2.0)
    finally:
        close()


def test_shm_sustains_many_frames_through_small_ring():
    """Ring reuse under wraparound: far more traffic than the ring's
    capacity crosses intact and in order once the consumer releases."""
    chan, slave, close = _make_link("shm", ring_bytes=1 << 14)
    try:
        msgs = [
            np.full((32, 16), float(i), np.float32)  # 2 KiB each, 64 total
            for i in range(64)
        ]
        def _pump():
            for m in msgs:
                chan.write_to_slave(m)

        t = threading.Thread(target=_pump)
        t.start()
        for m in msgs:
            np.testing.assert_array_equal(slave.recv(), m)
        t.join(timeout=10)
        assert not t.is_alive()
    finally:
        close()


# ---------------------------------------------------------------------------
# cluster-level conformance: the same protocol over either wire
# ---------------------------------------------------------------------------


def _ref_conv(x, w):
    import jax

    return np.asarray(jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ))


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_cluster_forward_matches_reference(kind):
    x = np.random.default_rng(0).normal(size=(2, 8, 8, 3)).astype(np.float32)
    w = np.random.default_rng(1).normal(size=(3, 3, 3, 9)).astype(np.float32)
    c = HeteroCluster([1.0, 1.0], transport=kind)
    try:
        c.probe_times = [1.0, 1.0]
        np.testing.assert_allclose(c.conv_forward(x, w), _ref_conv(x, w), atol=1e-4)
    finally:
        c.shutdown()


@pytest.mark.parametrize("kind", TRANSPORTS)
def test_slave_error_propagates_not_hangs(kind):
    """A slave-side exception ships back as a SlaveError and re-raises
    on the master instead of hanging the gather — on either wire.
    (w=None with no cached shard is a guaranteed slave-side KeyError.)"""
    c = HeteroCluster([1.0, 1.0], transport=kind)
    try:
        x = np.zeros((1, 4, 4, 2), np.float32)
        c.sockets[0].write_to_slave(("conv", (x, None)))
        out = c.sockets[0].read_on_master()
        with pytest.raises(RuntimeError, match="slave device 1 failed"):
            c._check_result(out)
        # the link survives the error: the next op still works
        w = np.ones((1, 1, 2, 3), np.float32)
        c.sockets[0].write_to_slave(("conv", (x, w)))
        assert c._check_result(c.sockets[0].read_on_master()).shape == (1, 4, 4, 3)
    finally:
        c.shutdown()


@pytest.mark.parametrize("kind", ["tcp", "shm"])
def test_subprocess_probe_measures_link_bandwidth(kind):
    """probe() on a subprocess transport fills the planning bandwidths
    from a real echo round-trip — the measured link replaces the knob.
    On shm the probe times the RING, so Eq. 1 sees the speed the plans
    will actually get."""
    c = HeteroCluster([1.0, 1.0], transport=kind)
    try:
        c.probe(image_size=8, in_channels=3, kernel_size=3, num_kernels=4,
                batch=2, repeats=1)
        assert all(b is not None and b > 0 for b in c.measured_bandwidths)
        assert c.bandwidths == c.measured_bandwidths
        # the echo probes are not protocol traffic: neither counter family
        # may retain their megabytes
        assert all(s.total_bytes < 1 << 20 for s in c.sockets)
        assert all(
            s.frame_bytes_to_slave + s.frame_bytes_to_master < 1 << 20
            for s in c.sockets
        )
        # RE-probing refreshes the measurement instead of mistaking the
        # first one for a user override
        c.probe(image_size=8, in_channels=3, kernel_size=3, num_kernels=4,
                batch=2, repeats=1)
        assert c.bandwidths == c.measured_bandwidths
        # the comm-aware Eq. 1 consumes it without blowing up
        counts = c.shares_for(16, unit_bytes=1024.0, layer_flops=1e6)
        assert counts.sum() == 16
    finally:
        c.shutdown()


def test_tcp_explicit_bandwidth_overrides_measurement():
    c = HeteroCluster([1.0, 1.0], transport="tcp", bandwidth_mbps=25.0)
    try:
        c.probe(image_size=8, in_channels=3, kernel_size=3, num_kernels=4,
                batch=2, repeats=1)
        assert c.bandwidths == [25.0]
    finally:
        c.shutdown()


@pytest.mark.parametrize("kind", ["tcp", "shm"])
@pytest.mark.parametrize("partition", ["kernel", "spatial", "auto"])
def test_subprocess_train_chain_matches_single_device_vjp(partition, kind):
    """The acceptance bar: the pipelined fwd+bwd train chain over REAL
    subprocess slaves == jax.grad on one device, on every axis and on
    both subprocess wires (tcp sockets and shm rings)."""
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(4)
    x = rng.normal(size=(5, 8, 8, 3)).astype(np.float32)
    w1 = rng.normal(size=(3, 3, 3, 6)).astype(np.float32)
    w2 = rng.normal(size=(3, 3, 6, 9)).astype(np.float32)
    g = rng.normal(size=(5, 8, 8, 9)).astype(np.float32)

    def f(x_, w1_, w2_):
        y = jax.nn.relu(jax.lax.conv_general_dilated(
            x_, w1_, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ))
        y2 = jax.lax.conv_general_dilated(
            y, w2_, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        return jnp.sum(y2 * g)

    dx_want, dw1_want, dw2_want = (
        np.asarray(a)
        for a in jax.grad(f, argnums=(0, 1, 2))(
            jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2)
        )
    )

    c = HeteroCluster(
        [1.0, 1.0, 1.0], transport=kind, partition=partition,
        pipeline=True, microbatches=3,
        # finite links exercise auto's comm-extended prediction; tcp
        # never delays anything, this only feeds the planner
        bandwidth_mbps=50.0,
    )
    try:
        c.probe_times = [1.0, 1.0, 1.0]

        def between(y):
            mask = (y > 0).astype(np.float32)
            return np.maximum(y, 0.0), lambda gz: gz * mask

        slices = c.microbatch_slices(x.shape[0])

        def head(z, i):
            return None, g[slices[i]]

        res = c.conv_train_chain(x, [w1, w2], [between, None], head)
        np.testing.assert_allclose(res.dx, dx_want, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(res.dw[0], dw1_want, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(res.dw[1], dw2_want, rtol=1e-4, atol=1e-3)
    finally:
        c.shutdown()


@pytest.mark.parametrize("kind", ["tcp", "shm"])
def test_subprocess_orderly_shutdown_reaps_subprocesses(kind):
    c = HeteroCluster([1.0, 1.0, 1.0], transport=kind)
    c.probe_times = [1.0, 1.0, 1.0]
    x = np.zeros((2, 6, 6, 2), np.float32)
    w = np.ones((3, 3, 2, 4), np.float32)
    c.conv_forward(x, w)
    c.shutdown()
    assert [p.returncode for p in c.procs] == [0, 0]
    c.shutdown()  # idempotent


@pytest.mark.parametrize("kind", ["tcp", "shm"])
def test_subprocess_shutdown_after_master_exception_reaps(kind):
    """A protocol error on the master must not leak slave processes:
    shutdown() after the exception still ends them cleanly."""
    c = HeteroCluster([1.0, 1.0], transport=kind)
    try:
        x = np.zeros((1, 4, 4, 2), np.float32)
        c.sockets[0].write_to_slave(("conv", (x, None)))  # slave KeyError
        with pytest.raises(RuntimeError, match="failed"):
            c._check_result(c.sockets[0].read_on_master())
    finally:
        c.shutdown()
    assert [p.returncode for p in c.procs] == [0]
