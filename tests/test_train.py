"""Training substrate: optimizers converge, grad accumulation is
equivalent to the large batch, clipping bounds the update."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import synthetic_token_batches
from repro.models.registry import build_model
from repro.optim.schedule import make_schedule
from repro.train.loss import softmax_cross_entropy
from repro.train.step import init_train_state, make_train_step


def _cfg():
    return ModelConfig(
        arch_id="t", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32",
    )


@pytest.mark.parametrize("opt,lr", [("sgd", 0.1), ("adam", 1e-3), ("adafactor", 1e-2)])
def test_loss_decreases(opt, lr):
    cfg = _cfg()
    api = build_model(cfg)
    run = RunConfig(optimizer=opt, learning_rate=lr, warmup_steps=5,
                    total_steps=60, remat="none")
    state = init_train_state(jax.random.key(0), api, run)
    step = jax.jit(make_train_step(api, run))
    it = synthetic_token_batches(8, 16, cfg.vocab_size)
    losses = []
    for _ in range(60):
        b = next(it)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5


def test_grad_accum_equivalent_to_full_batch():
    cfg = _cfg()
    api = build_model(cfg)
    base = dict(optimizer="sgd", learning_rate=0.1, max_grad_norm=None,
                schedule="constant", warmup_steps=0)
    run1 = RunConfig(grad_accum=1, **base)
    run4 = RunConfig(grad_accum=4, **base)
    s1 = init_train_state(jax.random.key(0), api, run1)
    s4 = init_train_state(jax.random.key(0), api, run4)
    it = synthetic_token_batches(8, 16, cfg.vocab_size)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    s1, m1 = jax.jit(make_train_step(api, run1))(s1, batch)
    s4, m4 = jax.jit(make_train_step(api, run4))(s4, batch)
    assert np.isclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_grad_clipping_bounds_norm():
    cfg = _cfg()
    api = build_model(cfg)
    run = RunConfig(optimizer="sgd", learning_rate=1.0, max_grad_norm=1e-8)
    state = init_train_state(jax.random.key(0), api, run)
    it = synthetic_token_batches(4, 8, cfg.vocab_size)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    new_state, m = jax.jit(make_train_step(api, run))(state, batch)
    # with a tiny clip threshold the params barely move
    deltas = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), state.params, new_state.params
    )
    assert max(jax.tree.leaves(deltas)) < 1e-6


def test_cross_entropy_gather_equals_one_hot():
    logits = jax.random.normal(jax.random.key(0), (2, 5, 11))
    labels = jax.random.randint(jax.random.key(1), (2, 5), 0, 11)
    got = softmax_cross_entropy(logits, labels)
    one_hot = jax.nn.one_hot(labels, 11)
    want = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * one_hot, axis=-1))
    assert np.isclose(float(got), float(want), rtol=1e-6)


def test_schedules():
    for kind in ("constant", "cosine", "wsd"):
        f = make_schedule(kind, learning_rate=1.0, warmup_steps=10, total_steps=100)
        lrs = np.array([float(f(jnp.array(s))) for s in range(100)])
        assert lrs[0] < lrs[9] <= 1.0  # warmup
        assert lrs.max() <= 1.0 + 1e-6
        if kind == "cosine":
            assert lrs[-1] < 0.2
        if kind == "wsd":
            # stable plateau then sharp decay
            assert np.allclose(lrs[15:85], lrs[20], rtol=1e-6)
            assert lrs[-1] < 0.15


@pytest.mark.parametrize("mode", ["gather", "megatron", "fsdp", "zero1"])
def test_train_step_runs_in_every_tp_mode(mode):
    """All four sharding modes trace and step on one device (constraints
    become no-ops but the full code path runs)."""
    cfg = _cfg()
    api = build_model(cfg)
    run = RunConfig(optimizer="adam", learning_rate=1e-3, tp_mode=mode)
    state = init_train_state(jax.random.key(0), api, run)
    it = synthetic_token_batches(4, 8, cfg.vocab_size)
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    state, m = jax.jit(make_train_step(api, run))(state, batch)
    assert np.isfinite(float(m["loss"]))
