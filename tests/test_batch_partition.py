"""The third partition axis: batch data parallelism with dW all-reduce.

``partition="batch"`` replicates the kernel, splits the batch's N axis
by the Eq. 1 shares, and the master SUMS the per-slave dW — an exact
all-reduce, since each dW is the gradient over a disjoint set of batch
rows.  These tests pin the axis end to end: forward/backward numerics
against the single-device VJP (even and odd splits, zero-row devices,
all three transports), the hybrid ``auto`` chooser's per-regime picks
(batch on fat links and large batches; kernel/spatial keep thin links
and parameter-heavy layers), survivor recovery after a mid-step
SIGKILL on the batch axis, admit/evict re-planning batch rows, and the
bounded decision caches that keep serve-lane dynamic batching (a new
shape key per slab size) from flapping or growing without bound.
"""
import time

import numpy as np
import pytest

from repro.core.backends import get_backend
from repro.core.cluster import plans
from repro.core.master_slave import HeteroCluster


def _data(batch, seed=7):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(batch, 8, 8, 3)).astype(np.float32)
    w1 = rng.normal(size=(3, 3, 3, 6)).astype(np.float32)
    w2 = rng.normal(size=(3, 3, 6, 9)).astype(np.float32)
    g = rng.normal(size=(batch, 8, 8, 9)).astype(np.float32)
    return x, w1, w2, g


def _single_device_grads(x, w1, w2, g):
    import jax
    import jax.numpy as jnp

    def f(x_, w1_, w2_):
        y = jax.nn.relu(jax.lax.conv_general_dilated(
            x_, w1_, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ))
        y2 = jax.lax.conv_general_dilated(
            y, w2_, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        return jnp.sum(y2 * g)

    return tuple(
        np.asarray(a)
        for a in jax.grad(f, argnums=(0, 1, 2))(
            jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2)
        )
    )


def _train_chain(c, x, w1, w2, g):
    def between(y):
        mask = (y > 0).astype(np.float32)
        return np.maximum(y, 0.0), lambda gz: gz * mask

    slices = c.microbatch_slices(x.shape[0])

    def head(z, i):
        return None, g[slices[i]]

    return c.conv_train_chain(x, [w1, w2], [between, None], head)


def _assert_grads(res, want, atol=1e-3):
    dx_want, dw1_want, dw2_want = want
    np.testing.assert_allclose(res.dx, dx_want, rtol=1e-4, atol=atol)
    np.testing.assert_allclose(res.dw[0], dw1_want, rtol=1e-4, atol=atol)
    np.testing.assert_allclose(res.dw[1], dw2_want, rtol=1e-4, atol=atol)


# ---------------------------------------------------------------------------
# plan geometry


def test_batch_ranges_recut_even_odd_and_exact():
    """batch_ranges re-cuts a plan's proportions to any slab size:
    b == sum(counts) reproduces the counts, odd slabs tile exactly,
    zero-share devices keep empty ranges."""
    counts = [3, 3, 2]
    assert plans.batch_ranges(counts, 8) == [(0, 3), (3, 6), (6, 8)]
    for b in (1, 2, 5, 7, 16):
        rng = plans.batch_ranges(counts, b)
        assert rng[0][0] == 0 and rng[-1][1] == b
        assert all(r0 <= r1 for r0, r1 in rng)
        assert [r0 for (r0, _), (_, p1) in zip(rng[1:], rng)] == [
            p1 for (_, p1) in rng[:-1]
        ]
    assert plans.batch_ranges([4, 0, 2], 3) == [(0, 2), (2, 2), (2, 3)]


def test_check_plan_accepts_batch_plan():
    c = HeteroCluster([1.0, 1.0, 1.0], partition="batch")
    try:
        c.probe_times = [1.0, 1.0, 1.0]
        w = np.zeros((3, 3, 3, 6), np.float32)
        plan = c.plan_conv((6, 8, 8, 3), w, "train")
        assert plan.mode == "batch"
        assert plan.w is not None and plan.shards is None
        plans.check_plan(plan, n_units=6, n_devices=3)
    finally:
        c.shutdown()


def test_unit_bytes_batch_counts_sample_traffic():
    """One batch unit is one sample: x + y out/back forward; the bwd
    adds the sample's g out and dX back.  The full-kernel ship and the
    full-dW return are fixed per-slave costs, excluded here (they live
    in the mode predictor)."""
    x_shape, w_shape = (8, 4, 4, 3), (3, 3, 3, 5)
    smp_x, smp_y = 4 * 4 * 3, 4 * 4 * 5
    conv = plans.unit_bytes(x_shape, w_shape, "batch", "conv", 4.0)
    assert conv == pytest.approx((smp_x + smp_y) * 4.0)
    train = plans.unit_bytes(
        x_shape, w_shape, "batch", "train", 4.0, g_itemsize=2.0
    )
    assert train == pytest.approx(
        conv + smp_x * 4.0 + (smp_x + smp_y) * 2.0
    )


# ---------------------------------------------------------------------------
# numerics: batch axis vs single-device reference


@pytest.mark.parametrize("batch", [6, 5])  # even and odd splits over 3 devices
def test_batch_forward_backward_match_reference(batch):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(batch, 8, 8, 3)).astype(np.float32)
    w = rng.normal(size=(3, 3, 3, 8)).astype(np.float32)
    g = rng.normal(size=(batch, 8, 8, 8)).astype(np.float32)
    ref = get_backend("numpy")
    c = HeteroCluster([1.0, 1.5, 2.0], partition="batch")
    try:
        c.probe_times = [1.0, 1.5, 2.0]
        y = c.conv_forward(x, w)
        np.testing.assert_allclose(y, ref.conv(x, w), rtol=1e-5, atol=1e-5)
        dx, dw = c.conv_backward(x, w, g)
        rdx, rdw = ref.conv_vjp(x, w, g)
        np.testing.assert_allclose(dx, rdx, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(dw, rdw, rtol=1e-4, atol=1e-3)
    finally:
        c.shutdown()


def test_batch_zero_row_device_is_exact():
    """A device too slow to earn a single batch row legally ships zero
    rows (its dW contribution is a zero array) and the result is still
    exact — the batch-axis analogue of the 0-kernel share."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(4, 8, 8, 3)).astype(np.float32)
    w = rng.normal(size=(3, 3, 3, 8)).astype(np.float32)
    g = rng.normal(size=(4, 8, 8, 8)).astype(np.float32)
    ref = get_backend("numpy")
    c = HeteroCluster([1.0, 1.0, 1000.0], partition="batch")
    try:
        c.probe_times = [1.0, 1.0, 1000.0]
        plan = c.plan_conv(x.shape, w, "train")
        assert int(plan.counts[-1]) == 0  # the slow device got no rows
        np.testing.assert_allclose(
            c.conv_forward(x, w), ref.conv(x, w), rtol=1e-5, atol=1e-5
        )
        dx, dw = c.conv_backward(x, w, g)
        rdx, rdw = ref.conv_vjp(x, w, g)
        np.testing.assert_allclose(dx, rdx, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(dw, rdw, rtol=1e-4, atol=1e-3)
    finally:
        c.shutdown()


def test_batch_train_chain_matches_vjp_inproc():
    """The pipelined fwd+bwd train chain on the batch axis: microbatch
    slices are re-cut per slab, dW sums across members AND microbatches,
    and the result matches the single-device VJP at fp32 tolerance."""
    x, w1, w2, g = _data(batch=7)  # 7 rows: odd per-microbatch re-cuts
    want = _single_device_grads(x, w1, w2, g)
    c = HeteroCluster(
        [1.0, 1.5, 2.0], partition="batch", pipeline=True, microbatches=3
    )
    try:
        c.probe_times = [1.0, 1.5, 2.0]
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
    finally:
        c.shutdown()


@pytest.mark.parametrize("transport", ["tcp", "shm"])
def test_batch_train_chain_matches_vjp_subprocess(transport):
    """Batch-axis train-step gradients over real OS-subprocess slaves
    (framed TCP sockets / zero-copy shm rings) match the single-device
    VJP — the wire carries row slices and full-dW returns correctly."""
    x, w1, w2, g = _data(batch=6)
    want = _single_device_grads(x, w1, w2, g)
    c = HeteroCluster(
        [1.0, 1.0, 1.0], transport=transport, partition="batch",
        pipeline=True, microbatches=2,
    )
    try:
        c.probe_times = [1.0, 1.0, 1.0]
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
    finally:
        c.shutdown()


# ---------------------------------------------------------------------------
# hybrid auto: per-regime picks


def _auto_cluster(bandwidth_mbps):
    c = HeteroCluster(
        [1.0, 1.0, 1.0], partition="auto", bandwidth_mbps=bandwidth_mbps
    )
    c.probe_times = [1e-4, 1e-4, 1e-4]  # fast devices: the wire decides
    c.probe_flops = 2.0 * 4 * 8 * 8 * 9 * 3 * 4
    return c


def test_auto_picks_batch_on_fat_link_for_train():
    """Activation-heavy layer, big batch, >= 1 Gbps: splitting rows
    moves ~1/n of the activation traffic per member and the full-dW
    all-reduce is cheap relative to the link — batch must beat both
    kernel (full-x broadcast per slave) and spatial (halo overhead),
    for the op the plan governs (train: fwd + bwd wire)."""
    x_shape, w_shape = (32, 32, 32, 16), (3, 3, 16, 16)
    c = _auto_cluster(1000.0)
    try:
        pred = c.predict_partition_seconds(x_shape, w_shape, "train")
        assert pred["batch"] < pred["kernel"]
        assert pred["batch"] < pred["spatial"]
        assert c._resolve_mode(x_shape, w_shape, None, "train") == "batch"
        assert c.partition_choices[(x_shape, w_shape)] == "batch"
    finally:
        c.shutdown()


def test_auto_keeps_kernel_or_spatial_on_thin_link():
    """The 25 Mbps acceptance regime: on a parameter-heavy layer the
    per-slave full-dW return sinks batch (it is constant in the batch
    share), so auto must keep the paper's kernel axis or spatial —
    data parallelism does NOT take over thin links."""
    x_shape, w_shape = (4, 8, 8, 4), (5, 5, 4, 256)
    c = _auto_cluster(25.0)
    try:
        pred = c.predict_partition_seconds(x_shape, w_shape, "train")
        assert pred["kernel"] < pred["batch"]
        mode = c._resolve_mode(x_shape, w_shape, None, "train")
        assert mode in ("kernel", "spatial")
    finally:
        c.shutdown()


def test_auto_small_batch_granularity_prefers_intra_image_axes():
    """Batch's allocation unit is one SAMPLE: at a tiny batch the
    quantum is coarse (b=2 over 3 devices puts half the batch on one
    member) while spatial splits the same activation into H=32 row
    units — the chooser must see the difference and keep an
    intra-image axis.  Devices slow enough that no single member can
    absorb the whole slab, so the 2-row quantum really hurts."""
    x_shape, w_shape = (2, 32, 32, 16), (3, 3, 16, 16)
    c = _auto_cluster(25.0)
    c.probe_times = [3e-3, 3e-3, 3e-3]
    try:
        pred = c.predict_partition_seconds(x_shape, w_shape, "conv")
        assert pred["batch"] > min(pred["kernel"], pred["spatial"])
        mode = c._resolve_mode(x_shape, w_shape, None, "conv")
        assert mode in ("kernel", "spatial")
    finally:
        c.shutdown()


def test_batch_beats_kernel_wall_clock_on_fat_emulated_link():
    """End-to-end acceptance: on an emulated 1 Gbps link at an
    activation-heavy shape, forcing batch beats forcing kernel in real
    wall-clock (deterministic sim compute + byte-accounted bandwidth
    emulation), and auto agrees with the measurement."""
    rng = np.random.default_rng(8)
    x = rng.normal(size=(16, 32, 32, 16)).astype(np.float32)
    w = rng.normal(size=(3, 3, 16, 16)).astype(np.float32)
    probe_flops = 2.0 * 16 * 32 * 32 * 9 * 16 * 16
    walls = {}
    for mode in ("kernel", "batch", "auto"):
        c = HeteroCluster(
            [1.0, 1.0, 1.0], ["sim:1e12"] * 3, partition=mode,
            bandwidth_mbps=1000.0,
        )
        try:
            c.probe_times = [probe_flops / 1e12] * 3
            c.probe_flops = probe_flops
            c.conv_forward(x, w)  # warm (plans, caches)
            t0 = time.perf_counter()
            c.conv_forward(x, w)
            walls[mode] = time.perf_counter() - t0
            if mode == "auto":
                assert set(c.partition_choices.values()) == {"batch"}
        finally:
            c.shutdown()
    assert walls["batch"] < walls["kernel"], walls


# ---------------------------------------------------------------------------
# decision caches: bounded, memoized, invalidated on membership change


def test_mode_cache_memoizes_repeated_slab_sizes(monkeypatch):
    """Serve-lane dynamic batching re-resolves auto per slab batch
    size; repeated sizes must hit the memo instead of re-running the
    predictor every slab."""
    c = _auto_cluster(50.0)
    calls = {"n": 0}
    real = plans.predict_partition_seconds

    def counting(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    monkeypatch.setattr(plans, "predict_partition_seconds", counting)
    try:
        w_shape = (3, 3, 16, 16)
        for slab in (1, 3, 4, 3, 1, 4, 3, 1):  # 3 distinct sizes
            c._resolve_mode((slab, 16, 16, 16), w_shape, None, "conv")
        assert calls["n"] == 3
        # picks recorded per (x_shape, w_shape), batch dim included
        assert len(c.partition_choices) == 3
    finally:
        c.shutdown()


def test_partition_caches_are_bounded_under_mixed_slabs():
    """A serve lane cycling through many distinct slab sizes must not
    grow the planner's caches without bound."""
    c = _auto_cluster(50.0)
    try:
        w_shape = (3, 3, 8, 8)
        for slab in range(1, 400):
            c._resolve_mode((slab, 16, 16, 8), w_shape, None, "conv")
        bound = c.partition_choices.maxsize
        assert len(c.partition_choices) <= bound
        assert len(c._mode_cache) <= c._mode_cache.maxsize
        # the most recent slab's pick is still present (FIFO evicts old)
        assert ((399, 16, 16, 8), w_shape) in c.partition_choices
    finally:
        c.shutdown()


def test_mode_cache_invalidated_on_membership_change():
    """admit()/evict() change the Eq. 1 inputs, so memoized auto picks
    must be dropped with partition_choices."""
    c = _auto_cluster(50.0)
    try:
        c._resolve_mode((8, 16, 16, 8), (3, 3, 8, 8), None, "conv")
        assert len(c._mode_cache) == 1
        dev = c.admit(slowdown=1.0, backend="numpy", probe_time=1e-4)
        assert len(c._mode_cache) == 0 and len(c.partition_choices) == 0
        c._resolve_mode((8, 16, 16, 8), (3, 3, 8, 8), None, "conv")
        c.evict(dev)
        assert len(c._mode_cache) == 0 and len(c.partition_choices) == 0
    finally:
        c.shutdown()


# ---------------------------------------------------------------------------
# elasticity + chaos on the batch axis


def test_admit_evict_replan_moves_batch_rows():
    """Membership changes re-run the comm-aware Eq. 1 over the batch
    axis: an admitted member takes rows (zero halo logic to rebuild),
    an evicted member's rows fold back, and numerics stay exact
    throughout."""
    rng = np.random.default_rng(11)
    x = rng.normal(size=(9, 8, 8, 3)).astype(np.float32)
    w = rng.normal(size=(3, 3, 3, 8)).astype(np.float32)
    ref = get_backend("numpy").conv(x, w)
    c = HeteroCluster([1.0, 1.0], partition="batch")
    try:
        c.probe_times = [1.0, 1.0]
        plan0 = c.plan_conv(x.shape, w, "conv")
        assert len(plan0.counts) == 2
        np.testing.assert_allclose(c.conv_forward(x, w), ref, rtol=1e-5, atol=1e-5)

        dev = c.admit(slowdown=1.0, backend="numpy", probe_time=1.0)
        plan1 = c.plan_conv(x.shape, w, "conv")
        plans.check_plan(plan1, n_units=9, n_devices=3)
        assert int(plan1.counts[-1]) > 0  # the newcomer took batch rows
        np.testing.assert_allclose(c.conv_forward(x, w), ref, rtol=1e-5, atol=1e-5)

        c.evict(dev)
        plan2 = c.plan_conv(x.shape, w, "conv")
        plans.check_plan(plan2, n_units=9, n_devices=2)
        np.testing.assert_allclose(c.conv_forward(x, w), ref, rtol=1e-5, atol=1e-5)
    finally:
        c.shutdown()


def test_sigkill_mid_step_batch_axis_recovers_on_survivors():
    """Chaos acceptance on the batch axis: SIGKILL a TCP slave while a
    pipelined batch-partition train step has row slices in flight — the
    master recomputes the dead member's ROWS (from the per-slab re-cut
    ranges the op actually shipped), the dW all-reduce still sums every
    row exactly once, and the gradients match the single-device VJP.
    The next step re-plans the batch rows over the survivors."""
    x, w1, w2, g = _data(batch=6)
    want = _single_device_grads(x, w1, w2, g)
    c = HeteroCluster(
        [1.0, 1.0, 1.0], transport="tcp", partition="batch",
        pipeline=True, microbatches=3, heartbeat_s=2.0,
    )
    try:
        c.probe_times = [1.0, 1.0, 1.0]
        victim_proc = c.procs[0]
        victim_dev = c.slave_ids[0]
        fired = {}

        def between(y):
            if not fired:
                fired["t"] = True
                victim_proc.kill()
            mask = (y > 0).astype(np.float32)
            return np.maximum(y, 0.0), lambda gz: gz * mask

        slices = c.microbatch_slices(x.shape[0])

        def head(z, i):
            return None, g[slices[i]]

        res = c.conv_train_chain(x, [w1, w2], [between, None], head)
        _assert_grads(res, want)
        assert len(c.failures) == 1
        assert c.failures[0]["device"] == victim_dev
        assert c.slave_ids == [2] and c.n_slaves == 1
        assert c.timing.recompute_s > 0.0
        # next step: re-planned batch rows over the survivors, still exact
        plan = c.plan_conv(x.shape, w1, "train")
        plans.check_plan(plan, n_units=6, n_devices=2)
        res2 = _train_chain(c, x, w1, w2, g)
        _assert_grads(res2, want)
    finally:
        c.shutdown()
