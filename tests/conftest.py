import os
import sys

# src/ layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis (pinned in requirements-dev.txt, installed
# in CI).  On minimal hosts without it, install the deterministic stub so
# every test module still collects and the properties still run.
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only on minimal hosts
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    _mod = _hypothesis_stub.build_module()
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
