"""The versioned weight-broadcast cache: master-side version store and
per-link shipped-token bookkeeping, slave-side (key, version) cache
resolution, and the end-to-end byte collapse on repeated train steps
and serve pushes with static weights.
"""
import numpy as np
import pytest

from repro.core.cluster.codec import WeightRef
from repro.core.cluster.scheduler import ServeChain
from repro.core.master_slave import HeteroCluster


def _weights(rng):
    w1 = rng.normal(size=(3, 3, 3, 6)).astype(np.float32)
    w2 = rng.normal(size=(3, 3, 6, 8)).astype(np.float32)
    return w1, w2


def _cluster(n=2, **kw):
    c = HeteroCluster([1.0] * n, **kw)
    c.probe_times = [1.0] * n
    return c


# ---------------------------------------------------------------------------
# master-side version store
# ---------------------------------------------------------------------------


def test_weight_version_bumps_only_on_new_array_object():
    c = _cluster()
    try:
        w = np.ones((3, 3, 3, 4), np.float32)
        assert c._weight_version("k", w) == (0, False)
        assert c._weight_version("k", w) == (0, True)  # same object: cached
        assert c._weight_version("k", w + 0.0) == (1, False)  # new object
        assert c._weight_version("other", w) == (0, False)  # per-key spaces
    finally:
        c.shutdown()


# ---------------------------------------------------------------------------
# end-to-end: repeated train steps collapse the weight broadcast
# ---------------------------------------------------------------------------


def _train_bytes(c, x, ws, steps):
    """comm_bytes of each of ``steps`` identical train-chain calls."""
    out = []
    for _ in range(steps):
        c.reset_stats()
        c.conv_train_chain(x, list(ws), [None, None], lambda z, i: (None, z))
        out.append(c.comm_bytes)
    return out


def test_train_chain_second_step_ships_tokens_not_kernels():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(4, 8, 8, 3)).astype(np.float32)
    ws = _weights(rng)
    c = _cluster()
    try:
        b1, b2, b3 = _train_bytes(c, x, ws, 3)
        wire_kernel_bytes = sum(w.nbytes for w in ws)
        assert b2 < b1
        assert b1 - b2 > 0.25 * wire_kernel_bytes  # shards became tokens
        assert b3 == b2  # steady state
    finally:
        c.shutdown()


def test_weight_cache_off_reships_every_step():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(4, 8, 8, 3)).astype(np.float32)
    ws = _weights(rng)
    c = _cluster(weight_cache=False)
    try:
        b1, b2 = _train_bytes(c, x, ws, 2)
        assert b1 == b2
    finally:
        c.shutdown()


def test_new_weight_object_and_new_geometry_invalidate_token():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(4, 8, 8, 3)).astype(np.float32)
    w1, w2 = _weights(rng)
    c = _cluster()
    try:
        _, steady = _train_bytes(c, x, (w1, w2), 2)
        # an optimizer step produces NEW arrays: the version bumps and
        # the fresh kernels ship again
        c.reset_stats()
        c.conv_train_chain(
            x, [w1 * 0.9, w2 * 0.9], [None, None], lambda z, i: (None, z)
        )
        assert c.comm_bytes > steady
        # same weights, different batch geometry: counts change, so the
        # shard boundaries may move — the token must not match
        _train_bytes(c, x, (w1, w2), 1)  # re-prime with the originals
        c.reset_stats()
        x2 = rng.normal(size=(6, 8, 8, 3)).astype(np.float32)
        c.conv_train_chain(
            x2, [w1, w2], [None, None], lambda z, i: (None, z)
        )
        assert c.comm_bytes > steady
    finally:
        c.shutdown()


def test_evict_drops_per_link_shipped_state():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(4, 8, 8, 3)).astype(np.float32)
    ws = _weights(rng)
    c = _cluster(3)
    try:
        _train_bytes(c, x, ws, 1)
        assert len(c._wshipped) == 2  # one token map per live slave link
        c.evict(c.slave_ids[0])
        assert len(c._wshipped) == 1
    finally:
        c.shutdown()


# ---------------------------------------------------------------------------
# slave-side cache resolution
# ---------------------------------------------------------------------------


def test_weight_ref_miss_raises_slave_error_not_garbage():
    """A token for a (key, version) the slave never cached is a master
    bug: it must surface as a loud SlaveError, not a silent wrong
    answer."""
    c = _cluster()
    try:
        x = np.zeros((1, 4, 4, 2), np.float32)
        c.sockets[0].write_to_slave(
            ("conv", (x, WeightRef("never-shipped", 0, None)))
        )
        with pytest.raises(RuntimeError, match="slave device 1 failed"):
            c._check_result(c.sockets[0].read_on_master())
    finally:
        c.shutdown()


def test_weight_ref_version_mismatch_raises():
    c = _cluster()
    try:
        x = np.zeros((1, 4, 4, 2), np.float32)
        w = np.ones((1, 1, 2, 3), np.float32)
        c.sockets[0].write_to_slave(("conv", (x, WeightRef("k", 0, w))))
        out = c._check_result(c.sockets[0].read_on_master())
        assert out.shape == (1, 4, 4, 3)
        # cached hit: the token alone reproduces the same result
        c.sockets[0].write_to_slave(("conv", (x, WeightRef("k", 0, None))))
        np.testing.assert_array_equal(
            c._check_result(c.sockets[0].read_on_master()), out
        )
        # stale version: the slave must refuse, not silently reuse
        c.sockets[0].write_to_slave(("conv", (x, WeightRef("k", 1, None))))
        with pytest.raises(RuntimeError, match="slave device 1 failed"):
            c._check_result(c.sockets[0].read_on_master())
    finally:
        c.shutdown()


# ---------------------------------------------------------------------------
# the serve lane: push-to-push weight bytes collapse
# ---------------------------------------------------------------------------


def _steady_push_bytes(c, chain, x, rng):
    """Wire bytes of one STEADY-STATE push: the pipeline keeps a batch
    in flight, so push N's window includes push N-1's tail gather —
    warm two pushes first, then measure the third."""
    chain.push(x)
    chain.push(x)
    c.reset_stats()
    chain.push(x)
    return c.comm_bytes


def test_serve_push_weight_bytes_collapse_to_tokens():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(3, 8, 8, 3)).astype(np.float32)
    ws = _weights(rng)

    c_on = _cluster()
    c_off = _cluster(weight_cache=False)
    try:
        on = _steady_push_bytes(c_on, ServeChain(c_on, list(ws)), x, rng)
        off = _steady_push_bytes(c_off, ServeChain(c_off, list(ws)), x, rng)
        assert on < off  # static serve weights ride as ~24-byte tokens
    finally:
        c_on.shutdown()
        c_off.shutdown()
