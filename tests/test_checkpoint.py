"""Checkpoint roundtrip + resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import latest_step, restore_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2,), jnp.int32), "d": jnp.array(3.5, jnp.bfloat16)},
    }
    save_checkpoint(str(tmp_path), 7, tree)
    got = restore_checkpoint(str(tmp_path))
    np.testing.assert_array_equal(got["a"], np.asarray(tree["a"]))
    np.testing.assert_array_equal(got["b"]["c"], np.asarray(tree["b"]["c"]))
    assert got["b"]["d"].dtype == np.asarray(tree["b"]["d"]).dtype


def test_latest_step_selection(tmp_path):
    for s in (3, 11, 5):
        save_checkpoint(str(tmp_path), s, {"x": jnp.zeros(1)})
    assert latest_step(str(tmp_path)) == 11
    got = restore_checkpoint(str(tmp_path), step=5)
    assert got["x"].shape == (1,)


def test_training_resume_equivalence(tmp_path):
    """Save at step k, restore, continue: identical params to an
    uninterrupted run (pure-functional update + deterministic data)."""
    from repro.configs.base import ModelConfig, RunConfig
    from repro.data.pipeline import synthetic_token_batches
    from repro.models.registry import build_model
    from repro.train.step import init_train_state, make_train_step

    cfg = ModelConfig(arch_id="t", family="dense", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=32,
                      dtype="float32", param_dtype="float32")
    api = build_model(cfg)
    run = RunConfig(optimizer="sgd", learning_rate=0.1, max_grad_norm=None,
                    schedule="constant", warmup_steps=0)
    step = jax.jit(make_train_step(api, run))

    def batches():
        return synthetic_token_batches(4, 8, cfg.vocab_size, seed=0)

    # uninterrupted: 4 steps
    s = init_train_state(jax.random.key(0), api, run)
    it = batches()
    for _ in range(4):
        s, _ = step(s, {k: jnp.asarray(v) for k, v in next(it).items()})

    # interrupted at 2
    s2 = init_train_state(jax.random.key(0), api, run)
    it = batches()
    for _ in range(2):
        s2, _ = step(s2, {k: jnp.asarray(v) for k, v in next(it).items()})
    save_checkpoint(str(tmp_path), 2, {"params": s2.params, "opt": s2.opt_state})
    restored = restore_checkpoint(str(tmp_path))
    s3 = s2.__class__(step=jnp.array(2), params=restored["params"],
                      opt_state=restored["opt"])
    for _ in range(2):
        s3, _ = step(s3, {k: jnp.asarray(v) for k, v in next(it).items()})

    for a, b in zip(jax.tree.leaves(s.params), jax.tree.leaves(s3.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
