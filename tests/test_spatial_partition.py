"""Hybrid spatial x kernel partitioning + the compact wire codec.

Spatial (height-strip) mode must be numerically identical to the
single-device reference — forward and VJP, even/odd heights, kernel
sizes 1/3/5, uneven Eq. 1 strips, zero-row devices — because the halo
exchange and the master's dX seam overlap-add reconstruct exactly the
SAME convolution.  The codec must halve the accounted wire bytes while
master-side accumulation stays float32.  ``partition="auto"`` must pick
the cheaper axis from the comm-extended Eq. 1 prediction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backends import get_backend, strip_conv, strip_conv_vjp
from repro.core.master_slave import (
    HeteroCluster,
    _strip_plan,
    resolve_wire_dtype,
)


def _ref_conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _vjp_ref(x, w, g):
    _, pullback = jax.vjp(_ref_conv, jnp.asarray(x), jnp.asarray(w))
    dx, dw = pullback(jnp.asarray(g))
    return np.asarray(dx), np.asarray(dw)


def _data(b=2, h=8, wd=6, cin=3, cout=5, k=3, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, h, wd, cin)).astype(np.float32)
    w = rng.normal(size=(k, k, cin, cout)).astype(np.float32)
    g = rng.normal(size=(b, h, wd, cout)).astype(np.float32)
    return x, w, g


# ---------------------------------------------------------------------------
# the strip helpers themselves (backends.py), outside the protocol
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h", [7, 8])
@pytest.mark.parametrize("k", [1, 3, 5])
def test_strip_conv_tiles_reconstruct_reference(h, k):
    """Any strip tiling of H — including clipped halos at both borders —
    concatenates back to the exact SAME conv, fwd and bwd."""
    x, w, g = _data(h=h, k=k, seed=1)
    want_y = np.asarray(_ref_conv(x, w))
    dx_want, dw_want = _vjp_ref(x, w, g)
    backend = get_backend("numpy")
    counts = [h // 3, h - h // 3 - 1, 1]
    rows, halos = _strip_plan(h, k, counts)
    ys, dx, dw = [], np.zeros_like(x), np.zeros_like(w)
    for (r0, r1), (lo, hi, pt, pb) in zip(rows, halos):
        ys.append(strip_conv(backend, x[:, lo:hi], w, pt, pb))
        dxh, dwp = strip_conv_vjp(backend, x[:, lo:hi], w, g[:, r0:r1], pt, pb)
        dx[:, lo:hi] += dxh  # the halo seams overlap-add
        dw += dwp
    np.testing.assert_allclose(np.concatenate(ys, axis=1), want_y, atol=1e-4)
    np.testing.assert_allclose(dx, dx_want, atol=1e-4)
    np.testing.assert_allclose(dw, dw_want, atol=1e-4)


def test_strip_plan_covers_height_with_clipped_halos():
    rows, halos = _strip_plan(10, 5, [4, 0, 6])
    assert rows == [(0, 4), (4, 4), (4, 10)]
    # first strip: top halo clipped at the border -> 2 pad rows restore it
    assert halos[0] == (0, 6, 2, 0)
    assert halos[1] == (4, 4, 0, 0)  # empty strip, empty window
    assert halos[2] == (2, 10, 0, 2)
    with pytest.raises(AssertionError):
        _strip_plan(10, 3, [4, 4])  # counts must sum to H


# ---------------------------------------------------------------------------
# the protocol in spatial mode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h", [7, 8])
@pytest.mark.parametrize("k", [1, 3, 5])
def test_spatial_cluster_matches_reference(h, k):
    """Spatial-mode conv_forward/conv_backward over uneven Eq. 1 strips
    == the single-device reference, for even/odd H and kh in {1,3,5}."""
    x, w, g = _data(h=h, k=k, cout=5, seed=2)
    c = HeteroCluster([1.0, 1.5, 2.0], partition="spatial")
    try:
        c.probe_times = [1.0, 1.5, 2.0]
        np.testing.assert_allclose(
            c.conv_forward(x, w), np.asarray(_ref_conv(x, w)), atol=1e-4
        )
        dx_want, dw_want = _vjp_ref(x, w, g)
        dx, dw = c.conv_backward(x, w, g)
        np.testing.assert_allclose(dx, dx_want, atol=1e-3)
        np.testing.assert_allclose(dw, dw_want, atol=1e-3)
    finally:
        c.shutdown()


def test_spatial_mode_with_zero_row_device():
    """A device whose Eq. 1 share rounds to 0 rows must not break the
    strip reassembly (it ships an empty window and returns empty rows)."""
    x, w, g = _data(h=6, k=3, seed=3)
    c = HeteroCluster([1.0, 1e6], partition="spatial")
    try:
        c.probe_times = [1.0, 1e6]
        assert c.shares_for(6).tolist() == [6, 0]
        np.testing.assert_allclose(
            c.conv_forward(x, w), np.asarray(_ref_conv(x, w)), atol=1e-4
        )
        dx_want, dw_want = _vjp_ref(x, w, g)
        dx, dw = c.conv_backward(x, w, g)
        np.testing.assert_allclose(dx, dx_want, atol=1e-3)
        np.testing.assert_allclose(dw, dw_want, atol=1e-3)
    finally:
        c.shutdown()


def test_spatial_train_chain_matches_single_device_vjp():
    """The pipelined fwd+bwd train chain in spatial mode == jax.grad on
    one device (same tolerance as the kernel-mode test in
    test_train_pipeline.py), microbatched and with a relu between."""
    x, w1, _ = _data(b=5, h=8, wd=8, cout=6, k=5, seed=4)
    rng = np.random.default_rng(5)
    w2 = rng.normal(size=(5, 5, 6, 9)).astype(np.float32)
    g = rng.normal(size=(5, 8, 8, 9)).astype(np.float32)

    def f(x_, w1_, w2_):
        y = jax.nn.relu(_ref_conv(x_, w1_))
        return jnp.sum(_ref_conv(y, w2_) * g)

    dx_want, dw1_want, dw2_want = (
        np.asarray(a)
        for a in jax.grad(f, argnums=(0, 1, 2))(
            jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2)
        )
    )

    c = HeteroCluster(
        [1.0, 1.5, 2.0], partition="spatial", pipeline=True, microbatches=3
    )
    try:
        c.probe_times = [1.0, 1.5, 2.0]

        def between(y):
            mask = (y > 0).astype(np.float32)
            return np.maximum(y, 0.0), lambda gz: gz * mask

        slices = c.microbatch_slices(x.shape[0])

        def head(z, i):
            return None, g[slices[i]]

        res = c.conv_train_chain(x, [w1, w2], [between, None], head)
        np.testing.assert_allclose(res.dx, dx_want, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(res.dw[0], dw1_want, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(res.dw[1], dw2_want, rtol=1e-4, atol=1e-3)
    finally:
        c.shutdown()


def test_spatial_mode_cuts_scatter_gather_bytes():
    """The point of the exercise: at 3 slaves, one fwd+bwd layer moves
    >= 2x fewer bytes in spatial mode than in kernel mode (each slave
    gets its rows + halo instead of the full activation, and returns a
    halo'd dX strip instead of a full dX)."""
    x, w, g = _data(b=4, h=16, wd=16, cin=8, cout=8, k=3, seed=6)
    bytes_by_mode = {}
    for mode in ("kernel", "spatial"):
        c = HeteroCluster([1.0, 1.0, 1.0, 1.0], partition=mode)
        try:
            c.probe_times = [1.0, 1.0, 1.0, 1.0]
            c.conv_forward(x, w)
            c.conv_backward(x, w, g)
            bytes_by_mode[mode] = c.comm_bytes
        finally:
            c.shutdown()
    assert bytes_by_mode["kernel"] >= 2 * bytes_by_mode["spatial"], bytes_by_mode


# ---------------------------------------------------------------------------
# the compact wire codec
# ---------------------------------------------------------------------------


def test_resolve_wire_dtype():
    assert resolve_wire_dtype(None) is None
    assert resolve_wire_dtype("fp32") is None
    assert resolve_wire_dtype("fp16") == np.dtype(np.float16)
    assert resolve_wire_dtype("bf16").itemsize == 2
    with pytest.raises(ValueError):
        resolve_wire_dtype("int8")


@pytest.mark.parametrize("dtype", ["fp16", "bf16"])
def test_codec_halves_accounted_bytes_and_roundtrips(dtype):
    """The encoded wire: byte counters see the 2-byte arrays (≈2x fewer
    bytes than fp32, exactly 2x on the float payload), results come back
    float32, and the numerics stay within the codec's precision."""
    x, w, g = _data(b=2, h=8, wd=8, cin=4, cout=6, k=3, seed=7)
    got = {}
    for wd_ in (None, dtype):
        c = HeteroCluster([1.0, 1.0], wire_dtype=wd_)
        try:
            c.probe_times = [1.0, 1.0]
            y = c.conv_forward(x, w)
            dx, dw = c.conv_backward(x, w, g)
            got[wd_ or "fp32"] = (y, dx, dw, c.comm_bytes)
        finally:
            c.shutdown()
    y32, dx32, dw32, b32 = got["fp32"]
    y16, dx16, dw16, b16 = got[dtype]
    assert y16.dtype == np.float32 and dx16.dtype == np.float32
    # flags/None markers keep the ratio just under 2
    assert 1.8 < b32 / b16 <= 2.0, (b32, b16)
    np.testing.assert_allclose(y16, y32, rtol=0.05, atol=0.15)
    np.testing.assert_allclose(dx16, dx32, rtol=0.05, atol=0.2)
    np.testing.assert_allclose(dw16, dw32, rtol=0.05, atol=0.6)


def test_codec_socket_roundtrip_is_lossless_for_fp16_representable():
    """fp16-representable payloads cross the codec bit-exactly, nested
    structures included, and the counters see the ENCODED size."""
    from repro.core.master_slave import _Socket

    s = _Socket(wire_dtype=np.dtype(np.float16))
    payload = {
        "a": np.arange(8, dtype=np.float32),
        "b": (np.ones((2, 2), np.float32), [np.zeros(3, np.float64)]),
        "flag": "keep-me",
        "i": np.arange(4, dtype=np.int32),  # non-float: untouched
    }
    s.write_to_slave(payload)
    got = s.read_on_slave()
    assert got["flag"] == "keep-me"
    assert got["a"].dtype == np.float32
    np.testing.assert_array_equal(got["a"], payload["a"])
    np.testing.assert_array_equal(got["b"][0], payload["b"][0])
    assert got["i"].dtype == np.int32
    # 8 + 4 + 3 floats at 2B encoded + 4 int32 at 4B + 8B for the string
    # + 4 dict keys at the 8B scalar rate
    assert s.bytes_to_slave == (8 + 4 + 3) * 2 + 4 * 4 + 8 + 4 * 8


# ---------------------------------------------------------------------------
# partition="auto": the comm-extended Eq. 1 chooses the axis
# ---------------------------------------------------------------------------


def _auto_pick(bandwidth, x_shape, w_shape, probe_flops=None):
    c = HeteroCluster(
        [1.0, 1.0, 1.0], partition="auto", bandwidth_mbps=bandwidth
    )
    try:
        c.probe_times = [1.0, 1.0, 1.0]
        c.probe_flops = probe_flops
        mode = c._resolve_mode(x_shape, w_shape, None)
        pred = (
            c.predict_partition_seconds(x_shape, w_shape)
            if bandwidth is not None
            else None
        )
        return mode, pred, dict(c.partition_choices)
    finally:
        c.shutdown()


def test_auto_picks_spatial_on_slow_link_for_activation_heavy_layer():
    """Activation-dominated layer (big H, cin == cout, small kernel) on a
    slow link: spatial's row-strip scatter beats re-broadcasting the full
    input, and auto must say so — and record its pick."""
    x_shape, w_shape = (8, 32, 32, 16), (3, 3, 16, 16)
    mode, pred, choices = _auto_pick(10.0, x_shape, w_shape)
    assert mode == "spatial"
    assert pred["spatial"] < pred["kernel"]
    assert choices[(x_shape, w_shape)] == "spatial"


def test_predictor_weighs_backward_wire():
    """op="bwd"/"train" predictions include the backward's wire (kernel
    mode re-broadcasts x and returns a full dX; spatial ships strips) —
    strictly more traffic, so never a smaller predicted time."""
    c = HeteroCluster([1.0, 1.0, 1.0], partition="auto", bandwidth_mbps=10.0)
    try:
        c.probe_times = [1.0, 1.0, 1.0]
        shapes = ((8, 32, 32, 16), (3, 3, 16, 16))
        pred = {
            op: c.predict_partition_seconds(*shapes, op)
            for op in ("conv", "bwd", "train")
        }
        for mode in ("kernel", "spatial"):
            assert pred["bwd"][mode] > pred["conv"][mode]
            assert pred["train"][mode] > pred["bwd"][mode]
        # kernel mode's backward pays the full-x re-broadcast + full-dX
        # return, so the backward penalizes it MORE than spatial
        assert (pred["train"]["kernel"] / pred["conv"]["kernel"]
                > pred["train"]["spatial"] / pred["conv"]["spatial"])
    finally:
        c.shutdown()


def test_cluster_rejects_sub_one_slowdowns():
    """The op-level emulation can only sleep, never speed up — a sub-1
    slowdown would probe fast but compute at host speed, so the
    constructor rejects it and points at parameterized sim backends."""
    with pytest.raises(ValueError, match="sim:5e9"):
        HeteroCluster([1.0, 0.5])


def test_auto_picks_kernel_on_free_links():
    """Infinitely fast links: the wire is free, the halo isn't — auto
    keeps the paper's kernel axis."""
    mode, _, _ = _auto_pick(None, (8, 32, 32, 16), (3, 3, 16, 16))
    assert mode == "kernel"


def test_auto_picks_kernel_when_gather_dominates():
    """cout >> cin: the y gather dwarfs the x scatter, spatial saves
    little and pays the halo + full-kernel broadcast — kernel wins."""
    mode, pred, _ = _auto_pick(10.0, (4, 8, 8, 4), (5, 5, 4, 256))
    assert mode == "kernel"
    assert pred["kernel"] <= pred["spatial"]


def test_auto_end_to_end_improves_wall_clock_under_slow_link():
    """conv_forward with auto on a slow emulated link at an
    activation-heavy shape is faster than forcing kernel mode (the
    acceptance wall-clock check, deterministic sim compute)."""
    import time

    rng = np.random.default_rng(8)
    x = rng.normal(size=(4, 32, 32, 16)).astype(np.float32)
    w = rng.normal(size=(3, 3, 16, 16)).astype(np.float32)
    probe_flops = 2.0 * 4 * 32 * 32 * 9 * 16 * 16
    walls = {}
    for mode in ("kernel", "auto"):
        c = HeteroCluster(
            [1.0, 1.0, 1.0], ["sim"] * 3, partition=mode,
            bandwidth_mbps=25.0,
        )
        try:
            c.probe_times = [probe_flops / 1e9] * 3
            c.probe_flops = probe_flops
            c.conv_forward(x, w)  # warm (plans, caches)
            t0 = time.perf_counter()
            c.conv_forward(x, w)
            walls[mode] = time.perf_counter() - t0
            if mode == "auto":
                assert set(c.partition_choices.values()) == {"spatial"}
        finally:
            c.shutdown()
    assert walls["auto"] < walls["kernel"], walls
