"""The two-tier hierarchy: sub-masters over slave groups.

``HierarchicalCluster`` puts a batch-axis root over whole groups, each
group a full ``HeteroCluster`` behind a sub-master that speaks the
ordinary slave wire upward.  These tests pin the composition end to
end: group-aggregate Eq. 1 capacity math (rates sum, bandwidth
bottleneck folds), topology parsing, the SharedNIC master-ingress
emulation, two-tier numerics against the single-device VJP on inproc
AND tcp roots, degenerate topologies (one group, one-device groups,
zero-row groups) planning without division hazards, elasticity at both
tiers (``admit_group``/``evict`` at the root, ``admit``/``evict``
inside a group with ``refresh_capacity`` re-pricing), and the composed
failure domains — a SIGKILLed LEAF recovered entirely inside its group
(invisible to the root), a SIGKILLed SUB-MASTER recovered at the root
as one dead batch member, both VJP-exact for the survivors.
"""
import time

import numpy as np
import pytest

from repro.core.cluster import plans
from repro.core.cluster.hierarchy import (
    GroupSpec,
    HierarchicalCluster,
    group_hello_meta,
    parse_groups,
)
from repro.core.cluster.transport import SharedNIC


def _data(batch, seed=7):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(batch, 8, 8, 3)).astype(np.float32)
    w1 = rng.normal(size=(3, 3, 3, 6)).astype(np.float32)
    w2 = rng.normal(size=(3, 3, 6, 9)).astype(np.float32)
    g = rng.normal(size=(batch, 8, 8, 9)).astype(np.float32)
    return x, w1, w2, g


def _single_device_grads(x, w1, w2, g):
    import jax
    import jax.numpy as jnp

    def f(x_, w1_, w2_):
        y = jax.nn.relu(jax.lax.conv_general_dilated(
            x_, w1_, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ))
        y2 = jax.lax.conv_general_dilated(
            y, w2_, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        return jnp.sum(y2 * g)

    return tuple(
        np.asarray(a)
        for a in jax.grad(f, argnums=(0, 1, 2))(
            jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2)
        )
    )


def _train_chain(c, x, w1, w2, g):
    def between(y):
        mask = (y > 0).astype(np.float32)
        return np.maximum(y, 0.0), lambda gz: gz * mask

    slices = c.microbatch_slices(x.shape[0])

    def head(z, i):
        return None, g[slices[i]]

    return c.conv_train_chain(x, [w1, w2], [between, None], head)


def _assert_grads(res, want, atol=1e-3):
    dx_want, dw1_want, dw2_want = want
    np.testing.assert_allclose(res.dx, dx_want, rtol=1e-4, atol=atol)
    np.testing.assert_allclose(res.dw[0], dw1_want, rtol=1e-4, atol=atol)
    np.testing.assert_allclose(res.dw[1], dw2_want, rtol=1e-4, atol=atol)


# ---------------------------------------------------------------- units


def test_group_aggregate_time_harmonic():
    # rates SUM: two devices at 2s each == one device at 1s
    assert plans.group_aggregate_time([2.0, 2.0]) == pytest.approx(1.0)
    # a fast member dominates but never hurts
    agg = plans.group_aggregate_time([1.0, 10.0])
    assert agg < 1.0
    assert agg == pytest.approx(1.0 / (1.0 + 0.1))
    # singleton: aggregate is the member
    assert plans.group_aggregate_time([3.0]) == pytest.approx(3.0)


def test_group_aggregate_time_rejects_bad_input():
    with pytest.raises(ValueError):
        plans.group_aggregate_time([])
    with pytest.raises(ValueError):
        plans.group_aggregate_time([1.0, 0.0])
    with pytest.raises(ValueError):
        plans.group_aggregate_time([-1.0])


def test_group_capacity_bandwidth_bottleneck():
    t, bw = plans.group_capacity([2.0, 2.0], [100.0, 50.0, None])
    assert t == pytest.approx(1.0)
    assert bw == 50.0
    _, bw_none = plans.group_capacity([1.0], [None, None])
    assert bw_none is None


def test_parse_groups():
    specs = parse_groups("2x3")
    assert [s.size for s in specs] == [3, 3]
    assert all(s.slowdowns == [1.0, 1.0, 1.0] for s in specs)
    # explicit per-device values chunk M per group, in order
    specs = parse_groups("2x2", slowdowns=[1.0, 2.0, 3.0, 4.0],
                         backends=["numpy", "sim", "numpy", "sim"])
    assert specs[0].slowdowns == [1.0, 2.0]
    assert specs[1].slowdowns == [3.0, 4.0]
    assert specs[1].backends == ["numpy", "sim"]
    for bad in ("2", "0x3", "2x0", "axb", "2x3x4"):
        with pytest.raises(ValueError):
            parse_groups(bad)
    with pytest.raises(ValueError):
        parse_groups("2x3", slowdowns=[1.0])  # needs 6


def test_shared_nic_serializes_per_direction():
    nic = SharedNIC(bandwidth_mbps=8.0)  # 1e6 bytes/s
    t0 = time.perf_counter()
    a = nic.reserve("down", 100_000)  # 0.1s transit
    b = nic.reserve("down", 100_000)  # queued behind a
    # same direction serializes: b's window starts where a's ends
    assert b >= a + 0.099
    # directions are independent ports: up is not queued behind down
    c = nic.reserve("up", 100_000)
    assert c < b
    assert a >= t0  # windows are in the future, not the past
    with pytest.raises(ValueError):
        SharedNIC(0.0)


# ----------------------------------------------------- two-tier numerics


def test_hierarchy_inproc_matches_single_device():
    """ISSUE acceptance: a 2x3 two-tier cluster trains with gradients
    matching single-device jax — the root's sum of per-group full dW
    over disjoint rows is the exact all-reduce, one tier up from PR 9.
    Second step rides the WeightRef token path at BOTH tiers."""
    x, w1, w2, g = _data(batch=12)
    want = _single_device_grads(x, w1, w2, g)
    c = HierarchicalCluster("2x3", microbatches=3)
    try:
        assert c.n_slaves == 2  # two sub-masters
        assert [g_.n_slaves for g_ in c.group_clusters] == [2, 2]
        c.probe(image_size=8, in_channels=3, kernel_size=3,
                num_kernels=4, batch=4, repeats=1)
        # every root member is a group: hello meta says so
        for dev in c.slave_ids:
            assert c.hello_meta[dev]["group"]["size"] == 3
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
    finally:
        c.shutdown()


def test_hierarchy_tcp_matches_single_device():
    """Same acceptance over the real wire: each sub-master is an OS
    subprocess (spawned with ``--group-*`` flags) mastering its own
    in-proc group, and the grammar round-trips through real sockets."""
    x, w1, w2, g = _data(batch=8)
    want = _single_device_grads(x, w1, w2, g)
    c = HierarchicalCluster("2x2", transport="tcp", microbatches=2)
    try:
        assert c.n_slaves == 2
        assert c.group_clusters == []  # groups live in the subprocesses
        for dev in c.slave_ids:
            assert c.hello_meta[dev]["group"]["size"] == 2
        c.probe(image_size=8, in_channels=3, kernel_size=3,
                num_kernels=4, batch=4, repeats=1)
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
    finally:
        c.shutdown()


# ------------------------------------------- degenerate topologies plan


def test_single_group_plans_and_trains():
    """G=1 degenerates to 'master + one group': batch_ranges over two
    members (root compute + the aggregate group) must tile, not 0-div."""
    x, w1, w2, g = _data(batch=6)
    want = _single_device_grads(x, w1, w2, g)
    c = HierarchicalCluster("1x3", microbatches=3)
    try:
        c.probe_times = [1.0, 0.5]  # pinned: group aggregates faster
        plan = c.plan_conv(x.shape, w1, "train")
        plans.check_plan(plan, n_units=6, n_devices=2)
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
    finally:
        c.shutdown()


def test_one_device_groups_plan_and_train():
    """M=1 groups: each inner cluster is MASTER-ONLY (zero slaves) —
    the sub-master computes its rows itself; aggregate Eq. 1 over one
    member is that member.  No empty-list or 0-div hazards anywhere."""
    x, w1, w2, g = _data(batch=6)
    want = _single_device_grads(x, w1, w2, g)
    c = HierarchicalCluster("2x1", microbatches=3)
    try:
        assert [g_.n_slaves for g_ in c.group_clusters] == [0, 0]
        times = c.probe(image_size=8, in_channels=3, kernel_size=3,
                        num_kernels=4, batch=4, repeats=1)
        assert len(times) == 3 and all(t > 0 for t in times)
        plan = c.plan_conv(x.shape, w1, "train")
        plans.check_plan(plan, n_units=6, n_devices=3)
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
    finally:
        c.shutdown()


def test_zero_row_group_short_circuits():
    """A group priced so slow it draws ZERO batch rows must neither
    divide by zero at the root nor crash the sub-master: its zero-row
    conv/bwd short-circuit (``scheduler.group_forward``) and the other
    members carry the exact gradient."""
    x, w1, w2, g = _data(batch=6)
    want = _single_device_grads(x, w1, w2, g)
    c = HierarchicalCluster("2x2", microbatches=2)
    try:
        c.probe_times = [1.0, 1.0, 1e9]  # group 2: ~0 of the Eq. 1 share
        plan = c.plan_conv(x.shape, w1, "train")
        plans.check_plan(plan, n_units=6, n_devices=3)
        assert any(n == 0 for n in plan.counts)  # the starved group
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
    finally:
        c.shutdown()


def test_group_bandwidth_folds_into_root_planning():
    """A group's internal bottleneck (min member link) rides the hello
    meta and CAPS the root's planning bandwidth for that slot — rows
    must not be priced faster than the group can redistribute them."""
    c = HierarchicalCluster(
        [GroupSpec(slowdowns=[1.0, 1.0], bandwidth_mbps=50.0),
         GroupSpec(slowdowns=[1.0, 1.0])],
        bandwidth_mbps=1000.0,
    )
    try:
        metas = [c.hello_meta[d]["group"] for d in c.slave_ids]
        assert metas[0]["bandwidth_mbps"] == 50.0
        assert metas[1]["bandwidth_mbps"] is None
        assert c.bandwidths[0] == 50.0  # min(1000, 50)
        assert c.bandwidths[1] == 1000.0  # unmetered group: uplink rules
    finally:
        c.shutdown()


# --------------------------------------------- elasticity at both tiers


def test_admit_group_and_evict_roundtrip():
    """Root-tier elasticity over WHOLE groups: admit_group grows the
    root by one sub-master (numerics stay exact over the wider plan),
    evict of that sub-master drains its group; the inner clusters ride
    along.  Exercised on inproc where the inner handles are visible."""
    x, w1, w2, g = _data(batch=6)
    want = _single_device_grads(x, w1, w2, g)
    c = HierarchicalCluster("1x2", microbatches=3)
    try:
        c.probe(image_size=8, in_channels=3, kernel_size=3,
                num_kernels=4, batch=4, repeats=1)
        _assert_grads(_train_chain(c, x, w1, w2, g), want)

        dev = c.admit_group(GroupSpec(slowdowns=[1.0, 1.0]))
        assert c.n_slaves == 2
        assert c.hello_meta[dev]["group"]["size"] == 2
        assert len(c.group_clusters) == 2
        plan = c.plan_conv(x.shape, w1, "train")
        plans.check_plan(plan, n_units=6, n_devices=3)
        _assert_grads(_train_chain(c, x, w1, w2, g), want)

        c.evict(dev)
        assert c.n_slaves == 1
        plan = c.plan_conv(x.shape, w1, "train")
        plans.check_plan(plan, n_units=6, n_devices=2)
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
    finally:
        c.shutdown()


def test_inner_tier_admit_evict_reprices_group():
    """Leaf churn INSIDE a group is invisible to the root's membership:
    evicting a leaf only changes the group's aggregate capacity, which
    ``refresh_capacity`` re-prices (slower group, longer aggregate
    time) — and numerics stay exact throughout."""
    x, w1, w2, g = _data(batch=6)
    want = _single_device_grads(x, w1, w2, g)
    c = HierarchicalCluster("2x2", microbatches=3)
    try:
        t_before = c.probe(image_size=8, in_channels=3, kernel_size=3,
                           num_kernels=4, batch=4, repeats=1)
        inner = c.group_clusters[0]
        root_ids_before = list(c.slave_ids)
        _assert_grads(_train_chain(c, x, w1, w2, g), want)

        inner.evict(inner.slave_ids[0])  # a leaf leaves its group
        assert inner.n_slaves == 0
        t_after = c.refresh_capacity()
        assert list(c.slave_ids) == root_ids_before  # root membership: same
        # the shrunk group aggregates SLOWER than with both members
        assert t_after[1] > t_before[1] * 1.2
        _assert_grads(_train_chain(c, x, w1, w2, g), want)

        dev = inner.admit(1.0, "numpy")  # and a leaf joins back
        assert inner.n_slaves == 1 and dev in inner.slave_ids
        c.refresh_capacity()
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
    finally:
        c.shutdown()


# ------------------------------------------------ composed chaos domains


def test_leaf_sigkill_recovers_inside_group_invisible_to_root():
    """ISSUE chaos acceptance 1: SIGKILL a LEAF slave mid-step.  Its
    group's sub-master evicts it and recomputes its in-flight rows; the
    step's gradients stay VJP-exact, and the ROOT sees no failure at
    all — only the capacity drop the next refresh_capacity re-plans
    on.  Root inproc (the sub-master is a thread we can reach), group
    on tcp (leaves are real processes a SIGKILL can take)."""
    x, w1, w2, g = _data(batch=8)
    want = _single_device_grads(x, w1, w2, g)
    c = HierarchicalCluster(
        [GroupSpec(slowdowns=[1.0, 1.0, 1.0], transport="tcp",
                   heartbeat_s=2.0, microbatches=2),
         GroupSpec(slowdowns=[1.0, 1.0, 1.0], transport="tcp",
                   heartbeat_s=2.0, microbatches=2)],
        microbatches=2,
    )
    try:
        c.probe(image_size=8, in_channels=3, kernel_size=3,
                num_kernels=4, batch=4, repeats=1)
        inner = c.group_clusters[0]
        victim_proc = inner.procs[0]
        victim_dev = inner.slave_ids[0]
        fired = {}

        def between(y):
            if not fired:
                fired["t"] = True
                victim_proc.kill()
            mask = (y > 0).astype(np.float32)
            return np.maximum(y, 0.0), lambda gz: gz * mask

        slices = c.microbatch_slices(x.shape[0])

        def head(z, i):
            return None, g[slices[i]]

        res = c.conv_train_chain(x, [w1, w2], [between, None], head)
        _assert_grads(res, want)
        # the failure lives one tier DOWN: group evicted its leaf...
        assert len(inner.failures) == 1
        assert inner.failures[0]["device"] == victim_dev
        assert inner.n_slaves == 1
        # ...and the root never saw a topology event
        assert c.failures == []
        assert c.n_slaves == 2
        # re-price the shrunk group; the next step is still exact
        c.refresh_capacity()
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
    finally:
        c.shutdown()


def test_submaster_sigkill_root_recovers_whole_group():
    """ISSUE chaos acceptance 2: SIGKILL a whole SUB-MASTER mid-step.
    To the root that is ONE dead batch member; the stock batch-axis
    recovery recomputes the group's rows on the root, the dW all-reduce
    still sums every row exactly once, and the next step re-plans over
    the surviving group.  Root on tcp — sub-masters are real processes."""
    x, w1, w2, g = _data(batch=6)
    want = _single_device_grads(x, w1, w2, g)
    c = HierarchicalCluster(
        "2x2", transport="tcp", microbatches=3, heartbeat_s=2.0,
    )
    try:
        c.probe_times = [1.0, 1.0, 1.0]
        victim_proc = c.procs[0]
        victim_dev = c.slave_ids[0]
        fired = {}

        def between(y):
            if not fired:
                fired["t"] = True
                victim_proc.kill()
            mask = (y > 0).astype(np.float32)
            return np.maximum(y, 0.0), lambda gz: gz * mask

        slices = c.microbatch_slices(x.shape[0])

        def head(z, i):
            return None, g[slices[i]]

        res = c.conv_train_chain(x, [w1, w2], [between, None], head)
        _assert_grads(res, want)
        assert len(c.failures) == 1
        assert c.failures[0]["device"] == victim_dev
        assert c.n_slaves == 1
        assert c.timing.recompute_s > 0.0
        plan = c.plan_conv(x.shape, w1, "train")
        plans.check_plan(plan, n_units=6, n_devices=2)
        _assert_grads(_train_chain(c, x, w1, w2, g), want)
    finally:
        c.shutdown()


def test_group_hello_meta_shape():
    """The upward-facing group summary: size counts the sub-master's
    own compute, bandwidth is the min FINITE member link (None when
    every inner link is unmetered)."""
    from repro.core.cluster.hierarchy import build_group_cluster

    inner = build_group_cluster(GroupSpec(slowdowns=[1.0, 1.0, 1.0]))
    try:
        meta = group_hello_meta(inner)
        assert meta == {"size": 3, "bandwidth_mbps": None}
    finally:
        inner.shutdown()
    inner = build_group_cluster(
        GroupSpec(slowdowns=[1.0, 1.0], bandwidth_mbps=25.0)
    )
    try:
        assert group_hello_meta(inner)["bandwidth_mbps"] == 25.0
    finally:
        inner.shutdown()
