"""The backend contract: every registered compute backend must produce
the same conv / conv_vjp results (numpy ≡ xla ≡ pallas-interpret), and a
mixed-backend HeteroCluster must match the single-device reference model
end to end — the probe, the slaves, and the master time the same code
they run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backends import (
    available_backends,
    get_backend,
    make_conv_fn,
    probe_conv_time,
)
from repro.core.master_slave import HeteroCluster, make_distributed_conv
from repro.models.cnn import cnn_loss, init_cnn, make_cnn_config

PARITY_BACKENDS = ["numpy", "xla", "pallas"]


def _ref_conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _data(b=2, s=8, cin=3, cout=7, k=5, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, s, s, cin)).astype(np.float32)
    w = rng.normal(size=(k, k, cin, cout)).astype(np.float32)
    g = rng.normal(size=(b, s, s, cout)).astype(np.float32)
    return x, w, g


def test_registry_exposes_the_contract():
    assert {"numpy", "xla", "pallas", "sim"} <= set(available_backends())
    with pytest.raises(KeyError):
        get_backend("no-such-backend")


def test_registry_parameterized_instances():
    """"sim:5e9" is a 5 GFLOP/s sim device, cached per parameterized
    name: a cluster can mix sim speeds without the slowdown workaround."""
    fast = get_backend("sim:5e9")
    slow = get_backend("sim:1e9")
    assert fast is not slow
    assert fast.flops_per_s == pytest.approx(5e9)
    assert slow.flops_per_s == pytest.approx(1e9)
    assert get_backend("sim:5e9") is fast  # each name caches its own
    assert get_backend("sim") is not fast
    with pytest.raises(ValueError, match="rejected parameter"):
        get_backend("sim:not-a-number")
    with pytest.raises(ValueError, match="rejected parameter"):
        get_backend("sim:-1e9")
    with pytest.raises(KeyError):
        get_backend("no-such-backend:5e9")


def test_parameterized_sim_cluster_shares():
    """Two sim devices at different registry-parameter speeds probe at
    ~the speed ratio, so Eq. 1 splits accordingly — no slowdown needed."""
    c = HeteroCluster([1.0, 1.0], ["sim:4e9", "sim:1e9"])
    try:
        # sleeps of ~2.5/10 ms: far above the host's timer slack
        t = c.probe(image_size=16, in_channels=3, kernel_size=5,
                    num_kernels=32, batch=8, repeats=1)
        assert t[1] > 2.0 * t[0]  # 4x nominal; sleep jitter-safe margin
        counts = c.shares_for(20)
        assert counts[0] > counts[1]
    finally:
        c.shutdown()


@pytest.mark.parametrize("name", PARITY_BACKENDS)
def test_conv_parity(name):
    x, w, _ = _data()
    got = get_backend(name).conv(x, w)
    want = np.asarray(_ref_conv(x, w))
    np.testing.assert_allclose(got, want, atol=1e-4)


@pytest.mark.parametrize("name", PARITY_BACKENDS)
def test_conv_vjp_parity(name):
    x, w, g = _data(seed=1)
    _, pullback = jax.vjp(_ref_conv, jnp.asarray(x), jnp.asarray(w))
    dx_want, dw_want = pullback(jnp.asarray(g))
    dx, dw = get_backend(name).conv_vjp(x, w, g)
    np.testing.assert_allclose(dx, np.asarray(dx_want), atol=1e-4)
    np.testing.assert_allclose(dw, np.asarray(dw_want), atol=1e-4)


def test_even_kernel_backends_self_consistent():
    """Even kernels: numpy and pallas share the repo's k//2-low SAME pad
    (XLA's differs), so they must agree with each other."""
    x, w, g = _data(cout=6, k=4, seed=2)
    np_b, pl_b = get_backend("numpy"), get_backend("pallas")
    np.testing.assert_allclose(pl_b.conv(x, w), np_b.conv(x, w), atol=1e-4)
    dx_n, dw_n = np_b.conv_vjp(x, w, g)
    dx_p, dw_p = pl_b.conv_vjp(x, w, g)
    np.testing.assert_allclose(dx_p, dx_n, atol=1e-4)
    np.testing.assert_allclose(dw_p, dw_n, atol=1e-4)


@pytest.mark.parametrize("name", ["numpy", "xla", "sim"])
def test_probe_times_every_backend(name):
    t = probe_conv_time(name, image_size=8, in_channels=3, kernel_size=3,
                        num_kernels=4, batch=2, repeats=1)
    assert t > 0


def test_probe_slowdown_scales_measurement():
    """The emulated slowdown multiplies the measured median — in BOTH
    directions: a slowdown < 1 emulates a FASTER device and must shrink
    the probe time too (it used to be silently dropped, handing emulated
    fast devices an unscaled time and the wrong Eq. 1 share).  200x
    factors dwarf scheduler noise on a loaded CI host, so the ordering
    is safe to assert (per-backend ordering at small factors is not)."""
    kw = dict(image_size=8, in_channels=3, kernel_size=3,
              num_kernels=4, batch=2, repeats=1)
    base = probe_conv_time("numpy", **kw)
    slowed = probe_conv_time("numpy", slowdown=200.0, **kw)
    assert slowed > base
    sped = probe_conv_time("numpy", slowdown=1.0 / 200.0, **kw)
    assert sped < base
    with pytest.raises(ValueError, match="positive"):
        probe_conv_time("numpy", slowdown=0.0, **kw)


def test_sim_probe_slowdown_below_one_exact():
    """On the deterministic sim backend the scaling is exact: the probe
    at slowdown s is ~s x the unscaled probe (the Eq. 1 input an
    emulated faster device must present)."""
    kw = dict(image_size=16, in_channels=3, kernel_size=5,
              num_kernels=16, batch=8, repeats=1)  # ~5 ms sleeps
    base = probe_conv_time("sim", **kw)
    fast = probe_conv_time("sim", slowdown=0.25, **kw)
    assert fast == pytest.approx(0.25 * base, rel=0.2)


def test_sim_backend_shapes_only():
    x, w, g = _data()
    sim = get_backend("sim")
    assert sim.conv(x, w).shape == (2, 8, 8, 7)
    dx, dw = sim.conv_vjp(x, w, g)
    assert dx.shape == x.shape and dw.shape == w.shape


@pytest.mark.parametrize("name", PARITY_BACKENDS)
def test_make_conv_fn_grads_match_reference(name):
    """The jax-level conv_fn of each backend is differentiable and
    matches lax end to end (forward + grads, bias included)."""
    rng = np.random.default_rng(3)
    params = {
        "kernel": jnp.asarray(rng.normal(size=(3, 3, 2, 5)).astype(np.float32)),
        "bias": jnp.asarray(rng.normal(size=(5,)).astype(np.float32)),
    }
    x = jnp.asarray(rng.normal(size=(2, 6, 6, 2)).astype(np.float32))
    from repro.layers.conv import apply_conv

    conv_fn = make_conv_fn(name)

    def loss(fn, p, xx):
        return jnp.sum(fn(p, xx) ** 2)

    ref = loss(apply_conv, params, x)
    got = loss(conv_fn, params, x)
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-4)
    g_ref = jax.grad(lambda p: loss(apply_conv, p, x))(params)
    g_got = jax.grad(lambda p: loss(conv_fn, p, x))(params)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_got)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-3)


@pytest.fixture(scope="module")
def mixed_cluster():
    """Heterogeneous cluster where every device runs a DIFFERENT backend:
    numpy master (callback-safe), xla + pallas-interpret slaves."""
    c = HeteroCluster([1.0, 1.5, 2.0], ["numpy", "xla", "pallas"])
    c.probe(image_size=8, in_channels=3, kernel_size=5, num_kernels=8, batch=2)
    yield c
    c.shutdown()


def test_mixed_cluster_forward_matches_reference(mixed_cluster):
    x, w, _ = _data(s=16, cout=21, seed=4)  # odd count: uneven shards
    got = mixed_cluster.conv_forward(x, w)
    np.testing.assert_allclose(got, np.asarray(_ref_conv(x, w)), atol=1e-4)


def test_mixed_cluster_backward_matches_reference(mixed_cluster):
    x, w, g = _data(s=16, cout=21, seed=5)
    _, pullback = jax.vjp(_ref_conv, jnp.asarray(x), jnp.asarray(w))
    dx_want, dw_want = pullback(jnp.asarray(g))
    dx, dw = mixed_cluster.conv_backward(x, w, g)
    np.testing.assert_allclose(dx, np.asarray(dx_want), atol=1e-4)
    np.testing.assert_allclose(dw, np.asarray(dw_want), atol=1e-4)


def test_mixed_cluster_end_to_end_cnn():
    """Full CNN loss + grads through a mixed-backend distributed conv
    must equal the local single-device model.  numpy master + xla slaves:
    pallas-INTERPRET slaves can deadlock when compiling inside the window
    where the master blocks in a jax host callback (interpret mode
    re-enters jax); the direct-call protocol tests above cover pallas."""
    cluster = HeteroCluster([1.0, 1.5, 2.0], ["numpy", "xla", "xla"])
    cluster.probe(image_size=8, in_channels=3, kernel_size=5,
                  num_kernels=8, batch=2)
    try:
        _check_cnn_end_to_end(cluster)
    finally:
        cluster.shutdown()


def _check_cnn_end_to_end(cluster):
    cfg = make_cnn_config(6, 10)
    params = init_cnn(jax.random.key(0), cfg)
    imgs = jax.random.normal(jax.random.key(1), (4, 32, 32, 3))
    labels = jnp.array([0, 1, 2, 3])
    dist_conv = make_distributed_conv(cluster)

    loss_ref, _ = cnn_loss(params, imgs, labels, cfg=cfg)
    loss_dist, _ = cnn_loss(params, imgs, labels, cfg=cfg, conv_fn=dist_conv)
    assert np.isclose(float(loss_ref), float(loss_dist), atol=1e-5)

    g_ref = jax.grad(lambda p: cnn_loss(p, imgs, labels, cfg=cfg)[0])(params)
    g_dist = jax.grad(
        lambda p: cnn_loss(p, imgs, labels, cfg=cfg, conv_fn=dist_conv)[0]
    )(params)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_dist)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-4)


def test_probe_reflects_backend_not_just_device(mixed_cluster):
    """Eq. 1 input: every entry positive, one per device."""
    assert len(mixed_cluster.probe_times) == 3
    assert all(t > 0 for t in mixed_cluster.probe_times)
    counts = mixed_cluster.shares_for(64)
    assert counts.sum() == 64 and (counts >= 0).all()
