"""Scan-unroll context for the dry-run.

XLA's ``cost_analysis()`` counts a ``while`` body ONCE, not times its
trip count, so a scanned-over-layers model under-reports FLOPs and
collective bytes by ~num_layers.  The dry-run therefore lowers with the
layer scans fully unrolled (trace-time switch); training/serving keep the
rolled scan (fast compiles, the production layout).
"""
from __future__ import annotations

import contextlib
import contextvars

_UNROLL = contextvars.ContextVar("repro_scan_unroll", default=False)


def scan_unroll_enabled() -> bool:
    return _UNROLL.get()


def scan_unroll_amount(num_layers: int) -> int:
    return num_layers if _UNROLL.get() else 1


@contextlib.contextmanager
def scan_unroll(enabled: bool = True):
    token = _UNROLL.set(enabled)
    try:
        yield
    finally:
        _UNROLL.reset(token)
