"""Model registry: a uniform ``ModelApi`` over every architecture family.

``build_model(cfg)`` returns closures for init / forward / prefill /
decode plus the logical-axis trees the launcher needs to shard params and
caches.  The encoder-decoder family (whisper) has its own implementation;
all decoder-only families (dense, moe, ssm, hybrid, vlm) share
``models/transformer.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as encdec_lib
from repro.models import transformer as tf_lib
from repro.sharding.axes import (
    AxisRules,
    LOGICAL_RULES_FSDP,
    LOGICAL_RULES_GATHER,
    LOGICAL_RULES_MEGATRON,
    LOGICAL_RULES_ZERO1,
)


def rules_for_mode(tp_mode: str) -> AxisRules:
    if tp_mode == "gather":
        return LOGICAL_RULES_GATHER
    if tp_mode == "megatron":
        return LOGICAL_RULES_MEGATRON
    if tp_mode == "fsdp":
        return LOGICAL_RULES_FSDP
    if tp_mode == "zero1":
        return LOGICAL_RULES_ZERO1
    raise ValueError(f"unknown tp_mode {tp_mode!r}")


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    init: Callable[..., Any]
    param_axes: Callable[[], Any]
    # forward(params, batch, *, rules, mesh, remat) -> (logits, aux)
    forward: Callable[..., Any]
    # prefill(params, batch, *, rules, mesh, remat, cache_len) -> (logits, cache)
    prefill: Callable[..., Any]
    # decode_step(params, cache, tokens, *, rules, mesh) -> (logits, cache)
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]
    cache_axes: Callable[[], Any]


def _lm_batch_forward(params, batch, *, cfg, rules, mesh=None, remat="none"):
    return tf_lib.lm_forward(
        params,
        batch["tokens"],
        cfg=cfg,
        rules=rules,
        mesh=mesh,
        patches=batch.get("patches"),
        remat=remat,
    )


def _lm_batch_prefill(params, batch, *, cfg, rules, mesh=None, remat="none",
                      cache_len=None):
    return tf_lib.lm_prefill(
        params,
        batch["tokens"],
        cfg=cfg,
        rules=rules,
        mesh=mesh,
        patches=batch.get("patches"),
        remat=remat,
        cache_len=cache_len,
    )


def build_model(cfg: ModelConfig) -> ModelApi:
    if cfg.num_encoder_layers > 0:
        return ModelApi(
            cfg=cfg,
            init=functools.partial(encdec_lib.init_encdec, cfg=cfg),
            param_axes=functools.partial(encdec_lib.encdec_axes, cfg),
            forward=functools.partial(encdec_lib.encdec_forward, cfg=cfg),
            prefill=functools.partial(encdec_lib.encdec_prefill, cfg=cfg),
            decode_step=functools.partial(encdec_lib.encdec_decode_step, cfg=cfg),
            init_cache=functools.partial(encdec_lib.init_encdec_cache, cfg),
            cache_axes=encdec_lib.encdec_cache_axes,
        )
    return ModelApi(
        cfg=cfg,
        init=functools.partial(tf_lib.init_lm, cfg=cfg),
        param_axes=functools.partial(tf_lib.lm_axes, cfg),
        forward=functools.partial(_lm_batch_forward, cfg=cfg),
        prefill=functools.partial(_lm_batch_prefill, cfg=cfg),
        decode_step=functools.partial(tf_lib.lm_decode_step, cfg=cfg),
        init_cache=functools.partial(tf_lib.init_cache, cfg),
        cache_axes=functools.partial(tf_lib.cache_axes, cfg),
    )
