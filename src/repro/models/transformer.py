"""Decoder-only transformer with scan-over-layers, covering the dense /
moe / ssm / hybrid / vlm families of the assigned architecture pool.

Layer params are stacked on a leading ``layers`` dim and consumed with
``lax.scan`` (compile time stays flat in depth — required for the
94-layer qwen3 MoE dry-run).  Each block family maps the paper's
"distribute the compute-dominant kernels, gather the outputs" scheme onto
its own hot spot: attention/MLP feature shards (dense), expert shards
(moe), SSD head shards (ssm/hybrid) — see sharding/axes.py.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.unroll import scan_unroll_amount
from repro.layers import attention as attn_lib
from repro.layers import mamba2 as mamba_lib
from repro.layers import moe as moe_lib
from repro.layers.embedding import (
    embed_tokens,
    embedding_axes,
    init_embedding,
    logits_from_embedding,
)
from repro.layers.linear import apply_dense, dense_axes, init_dense
from repro.layers.mlp import apply_mlp, init_mlp, mlp_axes
from repro.layers.norm import apply_norm, init_norm, norm_axes
from repro.sharding.axes import AxisRules
from repro.sharding.partitioning import constrain


def _has_attn(cfg: ModelConfig) -> bool:
    return cfg.family != "ssm"


def _has_mamba(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid")


def _has_moe(cfg: ModelConfig) -> bool:
    return cfg.moe is not None


def _has_mlp(cfg: ModelConfig) -> bool:
    return cfg.family != "ssm" and not _has_moe(cfg)


# ---------------------------------------------------------------------------
# single block


def init_block(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"ln1": init_norm(cfg.norm, cfg.d_model, dtype)}
    if _has_attn(cfg):
        p["attn"] = attn_lib.init_attention(ks[0], cfg, dtype)
    if _has_mamba(cfg):
        p["mamba"] = mamba_lib.init_mamba2(ks[1], cfg, dtype)
    if _has_moe(cfg):
        p["ln2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        p["moe"] = moe_lib.init_moe(ks[2], cfg.d_model, cfg.moe, dtype)
    elif _has_mlp(cfg):
        p["ln2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, dtype, gated=cfg.gated_mlp)
    return p


def block_axes(cfg: ModelConfig):
    ax: Dict[str, Any] = {"ln1": norm_axes(cfg.norm)}
    if _has_attn(cfg):
        ax["attn"] = attn_lib.attention_axes(cfg)
    if _has_mamba(cfg):
        ax["mamba"] = mamba_lib.mamba2_axes()
    if _has_moe(cfg):
        ax["ln2"] = norm_axes(cfg.norm)
        ax["moe"] = moe_lib.moe_axes()
    elif _has_mlp(cfg):
        ax["ln2"] = norm_axes(cfg.norm)
        ax["mlp"] = mlp_axes(gated=cfg.gated_mlp)
    return ax


def apply_block(
    params,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    rules: AxisRules,
    positions: jax.Array,
    mesh=None,
    token_axes=(),
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence block.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg.norm, params["ln1"], x, cfg.norm_eps)
    mix = None
    if _has_attn(cfg):
        mix = attn_lib.apply_attention(
            params["attn"], h, cfg=cfg, rules=rules, positions=positions
        )
    if _has_mamba(cfg):
        m = mamba_lib.apply_mamba2(params["mamba"], h, cfg=cfg, rules=rules)
        # hymba: parallel attention + mamba heads, fused by averaging
        mix = m if mix is None else 0.5 * (mix + m)
    x = x + mix
    x = constrain(x, rules, "batch", "act_seq", "act_embed")
    if "ln2" in params:
        h = apply_norm(cfg.norm, params["ln2"], x, cfg.norm_eps)
        if _has_moe(cfg):
            y, a = moe_lib.apply_moe(
                params["moe"], h, cfg=cfg, mesh=mesh, token_axes=token_axes
            )
            aux = aux + a
        else:
            y = apply_mlp(params["mlp"], h, cfg=cfg, rules=rules)
        x = x + y
        x = constrain(x, rules, "batch", "act_seq", "act_embed")
    return x, aux


def decode_block(
    params,
    x: jax.Array,
    layer_cache: Dict[str, jax.Array],
    *,
    cfg: ModelConfig,
    rules: AxisRules,
    cache_pos: Optional[jax.Array],
    index,
    position,
    mesh=None,
) -> Tuple[jax.Array, Dict[str, jax.Array], Optional[jax.Array]]:
    """One-token decode.  ``layer_cache`` holds this layer's slices.
    Returns (x, new_layer_cache, new_cache_pos)."""
    new_cache: Dict[str, jax.Array] = {}
    new_pos = cache_pos
    h = apply_norm(cfg.norm, params["ln1"], x, cfg.norm_eps)
    mix = None
    if _has_attn(cfg):
        a_out, nk, nv, new_pos = attn_lib.decode_attention(
            params["attn"], h, cfg=cfg, rules=rules,
            cache_k=layer_cache["k"], cache_v=layer_cache["v"],
            cache_pos=cache_pos, index=index, position=position,
        )
        new_cache["k"], new_cache["v"] = nk, nv
        mix = a_out
    if _has_mamba(cfg):
        m_out, new_state = mamba_lib.decode_mamba2(
            params["mamba"],
            h,
            {"conv": layer_cache["conv"], "ssm": layer_cache["ssm"]},
            cfg=cfg,
            rules=rules,
        )
        new_cache["conv"], new_cache["ssm"] = new_state["conv"], new_state["ssm"]
        mix = m_out if mix is None else 0.5 * (mix + m_out)
    x = x + mix
    if "ln2" in params:
        h = apply_norm(cfg.norm, params["ln2"], x, cfg.norm_eps)
        if _has_moe(cfg):
            y, _ = moe_lib.apply_moe(params["moe"], h, cfg=cfg, mesh=mesh)
        else:
            y = apply_mlp(params["mlp"], h, cfg=cfg, rules=rules)
        x = x + y
    return x, new_cache, new_pos


# ---------------------------------------------------------------------------
# full model


def init_lm(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4 + cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg, dtype))(
        jnp.stack(ks[4 : 4 + cfg.num_layers])
    )
    p = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "ln_f": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_dense(ks[1], (cfg.d_model,), (cfg.vocab_size,), dtype)
    if cfg.vision is not None:
        v = cfg.vision
        p["projector"] = {
            "fc1": init_dense(ks[2], (v.vision_dim,), (v.projector_hidden,), dtype, use_bias=True),
            "fc2": init_dense(ks[3], (v.projector_hidden,), (cfg.d_model,), dtype, use_bias=True),
        }
    return p


def lm_axes(cfg: ModelConfig):
    blk = jax.tree.map(
        lambda ax: ("layers",) + tuple(ax),
        block_axes(cfg),
        is_leaf=lambda x: isinstance(x, tuple),
    )
    ax = {
        "embed": embedding_axes(),
        "blocks": blk,
        "ln_f": norm_axes(cfg.norm),
    }
    if not cfg.tie_embeddings:
        ax["lm_head"] = dense_axes(("fsdp_embed",), ("vocab",))
    if cfg.vision is not None:
        ax["projector"] = {
            "fc1": dense_axes(("fsdp_embed",), ("mlp",), use_bias=True),
            "fc2": dense_axes(("mlp_in",), ("fsdp_embed",), use_bias=True),
        }
    return ax


def _embed_inputs(params, cfg: ModelConfig, tokens, patches, dtype):
    x = embed_tokens(params["embed"], tokens, dtype)
    if cfg.vision is not None and patches is not None:
        proj = jax.nn.gelu(
            apply_dense(params["projector"]["fc1"], patches.astype(dtype), dtype=dtype)
        )
        proj = apply_dense(params["projector"]["fc2"], proj, dtype=dtype)
        n_img = proj.shape[1]
        # patch embeddings occupy the first n_img positions (anyres tiles
        # flattened by the stub frontend)
        x = jnp.concatenate([proj, x[:, n_img:]], axis=1)
    return x


def _scan_blocks(params_blocks, x, body, remat: str, num_layers: int = 0):
    def f(carry, layer_params):
        xc, aux = carry
        y, a = body(layer_params, xc)
        return (y, aux + a), None

    if remat == "full":
        f = jax.checkpoint(f, prevent_cse=False)
    elif remat == "dots":
        f = jax.checkpoint(
            f,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False,
        )
    (x, aux), _ = jax.lax.scan(
        f, (x, jnp.zeros((), jnp.float32)), params_blocks,
        unroll=scan_unroll_amount(num_layers) if num_layers else 1,
    )
    return x, aux


def lm_forward(
    params,
    tokens: jax.Array,
    *,
    cfg: ModelConfig,
    rules: AxisRules,
    mesh=None,
    patches: Optional[jax.Array] = None,
    remat: str = "none",
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Train / prefill forward over a full sequence.  Returns (logits, aux)."""
    dtype = cfg.compute_dtype
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    token_axes = tuple(a for a in ("pod", "data") if mesh is None or a in mesh.axis_names)
    x = _embed_inputs(params, cfg, tokens, patches, dtype)
    x = constrain(x, rules, "batch", "act_seq", "act_embed")

    body = functools.partial(
        apply_block,
        cfg=cfg,
        rules=rules,
        positions=positions,
        mesh=mesh,
        token_axes=token_axes,
    )
    x, aux = _scan_blocks(
        params["blocks"], x, lambda lp, xc: body(lp, xc), remat, cfg.num_layers
    )

    x = apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = logits_from_embedding(params["embed"], x, dtype)
    else:
        logits = apply_dense(params["lm_head"], x, dtype=dtype)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = constrain(logits, rules, "batch", "act_seq", "vocab")
    return logits, aux


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode


def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    """Decode cache sized for a ``seq_len`` context.  Sliding-window and
    SSM archs keep O(window)/O(1) state — this is what makes long_500k
    feasible (see DESIGN.md long_500k policy)."""
    dtype = dtype or cfg.compute_dtype
    cache: Dict[str, Any] = {"t": jnp.zeros((), jnp.int32)}
    l = cfg.num_layers
    if _has_attn(cfg):
        c = cache_len_for(cfg, seq_len)
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache["k"] = jnp.zeros((l, batch, c, kv, hd), dtype)
        cache["v"] = jnp.zeros((l, batch, c, kv, hd), dtype)
        cache["pos"] = jnp.full((batch, c), -1, jnp.int32)
    if _has_mamba(cfg):
        st = mamba_lib.init_mamba2_state(cfg, batch, dtype)
        cache["conv"] = jnp.broadcast_to(st["conv"][None], (l,) + st["conv"].shape)
        cache["ssm"] = jnp.broadcast_to(st["ssm"][None], (l,) + st["ssm"].shape)
    return cache


def cache_axes(cfg: ModelConfig):
    ax: Dict[str, Any] = {"t": None}
    if _has_attn(cfg):
        # slot dim sharded over `model` (cache_seq): kv_heads rarely
        # divide the 16-way axis, and the slot dim always does — SS Perf
        # iteration D (qwen3 decode cache 170G -> /16 per device)
        ax["k"] = ("layers", "batch", "cache_seq", "kv_heads", None)
        ax["v"] = ("layers", "batch", "cache_seq", "kv_heads", None)
        ax["pos"] = ("batch", "cache_seq")
    if _has_mamba(cfg):
        ax["conv"] = ("layers", "batch", None, "ssm_inner")
        ax["ssm"] = ("layers", "batch", "ssm_heads", None, None)
    return ax


def _split_cache(cache):
    """Separate stacked per-layer entries from shared ones."""
    layer_keys = [k for k in ("k", "v", "conv", "ssm") if k in cache]
    per_layer = {k: cache[k] for k in layer_keys}
    return per_layer


def lm_decode_step(
    params,
    cache,
    tokens: jax.Array,
    *,
    cfg: ModelConfig,
    rules: AxisRules,
    mesh=None,
) -> Tuple[jax.Array, Any]:
    """One decode step: tokens (B, 1) -> (logits (B, vocab), new cache)."""
    dtype = cfg.compute_dtype
    position = cache["t"]
    x = embed_tokens(params["embed"], tokens, dtype)
    x = constrain(x, rules, "batch", None, "act_embed")

    per_layer = _split_cache(cache)
    cache_pos = cache.get("pos")
    if _has_attn(cfg):
        c = cache["k"].shape[2]
        index = jax.lax.rem(position, c)
    else:
        index = jnp.zeros((), jnp.int32)

    def f(xc, xs):
        lp, lc = xs
        y, new_lc, _ = decode_block(
            lp, xc, lc, cfg=cfg, rules=rules, cache_pos=cache_pos,
            index=index, position=position, mesh=mesh,
        )
        return y, new_lc

    x, new_per_layer = jax.lax.scan(
        f, x, (params["blocks"], per_layer),
        unroll=scan_unroll_amount(cfg.num_layers),
    )

    new_cache = dict(cache)
    new_cache.update(new_per_layer)
    new_cache["t"] = position + 1
    if cache_pos is not None:
        pos_arr = jnp.full((tokens.shape[0], 1), position, jnp.int32)
        new_cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
            cache_pos, pos_arr, index, axis=1
        )

    x = apply_norm(cfg.norm, params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = logits_from_embedding(params["embed"], x, dtype)
    else:
        logits = apply_dense(params["lm_head"], x, dtype=dtype)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = constrain(logits, rules, "batch", None, "vocab")
    return logits[:, 0], new_cache


def lm_prefill(
    params,
    tokens: jax.Array,
    *,
    cfg: ModelConfig,
    rules: AxisRules,
    mesh=None,
    patches: Optional[jax.Array] = None,
    remat: str = "none",
    cache_len: Optional[int] = None,
) -> Tuple[jax.Array, Any]:
    """Prefill: full forward + build the decode cache.  Returns
    (last-token logits (B, vocab), cache).  ``cache_len`` >= s leaves
    headroom for subsequent decode steps (defaults to s)."""
    dtype = cfg.compute_dtype
    b, s = tokens.shape
    cache_len = cache_len or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    token_axes = tuple(a for a in ("pod", "data") if mesh is None or a in mesh.axis_names)
    x = _embed_inputs(params, cfg, tokens, patches, dtype)
    x = constrain(x, rules, "batch", "act_seq", "act_embed")

    cache = init_cache(cfg, b, cache_len, cfg.compute_dtype)
    c = cache["k"].shape[2] if "k" in cache else 0
    n_fill = min(c, s)

    def body(lp, xc):
        """Block body that additionally emits this layer's cache slices."""
        emitted = {}
        h = apply_norm(cfg.norm, lp["ln1"], xc, cfg.norm_eps)
        mix = None
        aux = jnp.zeros((), jnp.float32)
        if _has_attn(cfg):
            # compute k/v once, reuse for both attention and the cache
            k = apply_dense(lp["attn"]["wk"], h, dtype=dtype)
            v = apply_dense(lp["attn"]["wv"], h, dtype=dtype)
            q = apply_dense(lp["attn"]["wq"], h, dtype=dtype)
            q = constrain(q, rules, "batch", None, "act_heads", None)
            k = constrain(k, rules, "batch", None, "act_heads", None)
            v = constrain(v, rules, "batch", None, "act_heads", None)
            from repro.layers.embedding import apply_rope

            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            out = attn_lib.attend(
                q, k, v, positions, positions, causal=True, window=cfg.sliding_window
            )
            mix = apply_dense(lp["attn"]["wo"], out, n_in_dims=2, dtype=dtype)
            # cache the last n_fill tokens at slot = pos % c (ring layout)
            slots = jnp.arange(s - n_fill, s, dtype=jnp.int32) % c
            ck = jnp.zeros((b, c) + k.shape[2:], k.dtype).at[:, slots].set(k[:, s - n_fill :])
            cv = jnp.zeros((b, c) + v.shape[2:], v.dtype).at[:, slots].set(v[:, s - n_fill :])
            emitted["k"], emitted["v"] = ck, cv
        if _has_mamba(cfg):
            m, final_state = _mamba_prefill(lp["mamba"], h, cfg=cfg, rules=rules)
            emitted["conv"] = final_state["conv"]
            emitted["ssm"] = final_state["ssm"]
            mix = m if mix is None else 0.5 * (mix + m)
        xc = xc + mix
        if "ln2" in lp:
            h2 = apply_norm(cfg.norm, lp["ln2"], xc, cfg.norm_eps)
            if _has_moe(cfg):
                y, a = moe_lib.apply_moe(
                    lp["moe"], h2, cfg=cfg, mesh=mesh, token_axes=token_axes
                )
                aux = aux + a
            else:
                y = apply_mlp(lp["mlp"], h2, cfg=cfg, rules=rules)
            xc = xc + y
        xc = constrain(xc, rules, "batch", "act_seq", "act_embed")
        return xc, emitted, aux

    def f(carry, lp):
        xc = carry
        y, emitted, _ = body(lp, xc)
        return y, emitted

    if remat in ("full", "dots"):
        f = jax.checkpoint(f, prevent_cse=False)
    x, emitted = jax.lax.scan(
        f, x, params["blocks"], unroll=scan_unroll_amount(cfg.num_layers)
    )

    for k in emitted:
        cache[k] = emitted[k]
    cache["t"] = jnp.array(s, jnp.int32)
    if "pos" in cache:
        slots = jnp.arange(s - n_fill, s, dtype=jnp.int32) % c
        vals = jnp.broadcast_to(jnp.arange(s - n_fill, s, dtype=jnp.int32)[None], (b, n_fill))
        cache["pos"] = jnp.full((b, c), -1, jnp.int32).at[:, slots].set(vals)

    x = apply_norm(cfg.norm, params["ln_f"], x[:, -1:], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = logits_from_embedding(params["embed"], x, dtype)
    else:
        logits = apply_dense(params["lm_head"], x, dtype=dtype)
    logits = constrain(logits, rules, "batch", None, "vocab")
    return logits[:, 0], cache


def _mamba_prefill(params, h, *, cfg, rules):
    """Mamba2 forward that also returns the final recurrent state."""
    ssm, d_in, nh, hd, n, g = mamba_lib._dims(cfg)
    dtype = cfg.compute_dtype
    bsz, s, _ = h.shape
    zxbcdt = h.astype(dtype) @ params["in_proj"]["kernel"].astype(dtype)
    z, xi, bmat, cmat, dt = mamba_lib._split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xi, bmat, cmat], axis=-1)
    conv_state = conv_in[:, -(ssm.d_conv - 1) :, :]
    conv_out = jax.nn.silu(
        mamba_lib._depthwise_conv(
            conv_in, params["conv_w"].astype(dtype), params["conv_b"].astype(dtype)
        )
    )
    xi, bmat, cmat = jnp.split(conv_out, [d_in, d_in + g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None, :])
    a = -jnp.exp(params["a_log"])
    xh = xi.reshape(bsz, s, nh, hd).astype(jnp.float32)
    xh = constrain(xh, rules, "batch", None, "ssm_heads", None)
    bg = bmat.reshape(bsz, s, g, n).astype(jnp.float32)
    cg = cmat.reshape(bsz, s, g, n).astype(jnp.float32)
    y, final = mamba_lib._ssd_chunked(xh, dt, a, bg, cg, ssm.chunk_size)
    y = y + xh * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, d_in).astype(dtype)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(dtype)
    y = y * params["norm_scale"].astype(dtype)[None, None, :]
    out = y @ params["out_proj"]["kernel"].astype(dtype)
    return out, {"conv": conv_state, "ssm": final}
