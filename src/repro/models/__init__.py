from repro.models.registry import build_model, ModelApi  # noqa: F401
