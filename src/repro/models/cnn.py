"""The paper's CIFAR-10 CNN (§5.2):

    conv(5x5, C1) -> LRN -> maxpool/2 -> conv(5x5, C2) -> LRN ->
    maxpool/2 -> fully-connected -> softmax loss

Four sizes are studied: (C1, C2) in {(50,500), (150,800), (300,1000),
(500,1500)}.  The conv output-channel axis is the paper's distribution
axis; ``core/conv_shard.py`` shards it over the mesh and
``core/master_slave.py`` runs it over the emulated socket cluster —
which can alternatively split the HEIGHT axis (spatial strips + halo
exchange) or pick the cheaper axis per layer (``partition="auto"``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig
from repro.layers.conv import apply_conv, conv_axes, init_conv, max_pool
from repro.layers.linear import apply_dense, dense_axes, init_dense
from repro.layers.norm import local_response_norm


PAPER_SIZES = {
    "cifar_cnn_50_500": (50, 500),
    "cifar_cnn_150_800": (150, 800),
    "cifar_cnn_300_1000": (300, 1000),
    "cifar_cnn_500_1500": (500, 1500),
}


def make_cnn_config(c1: int, c2: int) -> CNNConfig:
    return CNNConfig(arch_id=f"cifar_cnn_{c1}_{c2}", c1_kernels=c1, c2_kernels=c2)


def init_cnn(key, cfg: CNNConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    k = cfg.kernel_size
    feat = cfg.image_size // (cfg.pool_stride ** 2)
    return {
        "conv1": init_conv(ks[0], k, k, cfg.image_channels, cfg.c1_kernels, dtype),
        "conv2": init_conv(ks[1], k, k, cfg.c1_kernels, cfg.c2_kernels, dtype),
        "fc": init_dense(
            ks[2], (feat * feat * cfg.c2_kernels,), (cfg.num_classes,), dtype, use_bias=True
        ),
    }


def cnn_axes():
    return {
        "conv1": conv_axes(),
        "conv2": conv_axes(),
        "fc": dense_axes((None,), (None,), use_bias=True),
    }


def conv_fn_for_backend(backend: str = "xla", *, interpret=None):
    """Return a ``conv_fn`` for ``cnn_forward`` that computes the
    convolutions with the named compute backend (core/backends.py):
    ``xla`` (lax conv, the default reference), ``pallas`` (the MXU
    kernels forward + Pallas dX/dW backward), or ``numpy`` (im2col via
    host callback).  The distributed variants stay separate:
    core/conv_shard.py (mesh) and core/master_slave.py (cluster)."""
    from repro.core.backends import make_conv_fn

    return make_conv_fn(backend, interpret=interpret)


def cnn_forward(params, images: jax.Array, *, cfg: CNNConfig,
                conv_fn=apply_conv) -> jax.Array:
    """images: (B, 32, 32, 3) NHWC -> logits (B, 10).

    ``conv_fn`` is injectable so the distributed variants
    (core/conv_shard.py, core/master_slave.py) and the Pallas kernel can
    replace only the convolution, exactly as the paper replaces only the
    convolution step.
    """
    x = conv_fn(params["conv1"], images)
    x = jax.nn.relu(x)
    x = local_response_norm(x)
    x = max_pool(x, cfg.pool_stride, cfg.pool_stride)
    x = conv_fn(params["conv2"], x)
    x = jax.nn.relu(x)
    x = local_response_norm(x)
    x = max_pool(x, cfg.pool_stride, cfg.pool_stride)
    x = x.reshape(x.shape[0], -1)
    return apply_dense(params["fc"], x)


def cnn_loss(params, images: jax.Array, labels: jax.Array, *, cfg: CNNConfig,
             conv_fn=apply_conv) -> Tuple[jax.Array, jax.Array]:
    logits = cnn_forward(params, images, cfg=cfg, conv_fn=conv_fn)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc


def make_cluster_train_step(cluster, cfg: CNNConfig, *, lr: float = 0.05):
    """Full training steps of the paper's CNN over a HeteroCluster via the
    pipelined ``conv_train_step`` schedule: both conv layers run
    distributed — forward and backward — while the master-only stages
    (bias add, ReLU, LRN, pool, fc, softmax loss) overlap slave compute
    through the activation-stashing pipeline.

    This is a DIRECT driver (no jax host callbacks), so unlike
    ``make_distributed_conv`` it is safe with any master backend, and the
    cluster's comp-aware partitioner sees the master's real non-conv duty.

    The cluster's partition axis is transparent here: with
    ``partition="spatial"`` (or ``"auto"``) the chain ships height strips
    + halos instead of full activations and seam-sums the dX halos on the
    master, and with ``wire_dtype="fp16"/"bf16"`` activations/gradients
    cross the wire in 2 bytes — the step's numerics stay float32 on the
    master either way (the codec narrows only the wire).

    Returns ``step(params, images, labels) -> (new_params, loss, acc)``
    applying plain SGD with ``lr`` to every parameter.
    """

    def _stage(y, b):
        """The master-only block after each conv: +bias, ReLU, LRN, pool."""
        z = jax.nn.relu(y + b[None, None, None, :])
        z = local_response_norm(z)
        return max_pool(z, cfg.pool_stride, cfg.pool_stride)

    def _head_sums(z, fc, labels, denom):
        """Loss contribution (sum/denom) + correct-count of one microbatch."""
        logits = apply_dense(fc, z.reshape(z.shape[0], -1))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        loss = -jnp.sum(jnp.take_along_axis(logp, labels[:, None], axis=1)) / denom
        correct = jnp.sum((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return loss, correct

    # jit the master-only stages (cached per microbatch shape); the
    # backward halves rematerialize the forward instead of holding jax
    # residuals across the pipeline
    _stage_fwd = jax.jit(_stage)
    _stage_bwd = jax.jit(lambda y, b, gz: jax.vjp(_stage, y, b)[1](gz))

    @jax.jit
    def _head_both(z, fc, labels, denom):
        (loss, correct), vjp = jax.vjp(
            lambda zz, f: _head_sums(zz, f, labels, denom), z, fc
        )
        gz, gfc = vjp((jnp.ones((), jnp.float32), jnp.zeros((), jnp.float32)))
        return loss, correct, gz, gfc

    warmed: set = set()  # microbatch sizes whose jits are compiled

    def _warm(mb, params):
        """Compile the master-only jits for this microbatch size OUTSIDE
        the pipeline: one-time compilation must not pollute the cluster's
        measured non-conv duty (it would strip the master's conv share)."""
        if mb in warmed:
            return
        warmed.add(mb)
        h1 = cfg.image_size
        h2, h3 = h1 // cfg.pool_stride, h1 // cfg.pool_stride ** 2
        for h, c, b in ((h1, cfg.c1_kernels, params["conv1"]["bias"]),
                        (h2, cfg.c2_kernels, params["conv2"]["bias"])):
            y = jnp.zeros((mb, h, h, c), jnp.float32)
            gz = jnp.zeros((mb, h // cfg.pool_stride, h // cfg.pool_stride, c),
                           jnp.float32)
            _stage_fwd(y, b)
            _stage_bwd(y, b, gz)
        _head_both(
            jnp.zeros((mb, h3, h3, cfg.c2_kernels), jnp.float32), params["fc"],
            jnp.zeros((mb,), jnp.int32), jnp.float32(1.0),
        )

    def step(params, images, labels):
        images = np.asarray(images, np.float32)
        labels = np.asarray(labels)
        batch = images.shape[0]
        slices = cluster.microbatch_slices(batch)
        for sl in slices:
            _warm(sl.stop - sl.start, params)

        db = {0: None, 1: None}       # conv bias grads, summed over microbatches
        fc_grad = [None]              # fc param grads (a pytree), ditto

        def make_between(k, bias):
            def f(y):
                y = jnp.asarray(y)
                z = _stage_fwd(y, bias)

                def pull(gz):
                    gy, gb = _stage_bwd(y, bias, jnp.asarray(gz, jnp.float32))
                    gb = np.asarray(gb)
                    db[k] = gb if db[k] is None else db[k] + gb
                    return np.asarray(gy, np.float32)

                return np.asarray(z, np.float32), pull
            return f

        def head(z, i):
            lbl = jnp.asarray(labels[slices[i]])
            loss_i, correct_i, gz, gfc = _head_both(
                jnp.asarray(z), params["fc"], lbl, jnp.float32(batch)
            )
            fc_grad[0] = gfc if fc_grad[0] is None else jax.tree.map(
                jnp.add, fc_grad[0], gfc
            )
            return (float(loss_i), float(correct_i)), np.asarray(gz, np.float32)

        between = [
            make_between(0, params["conv1"]["bias"]),
            make_between(1, params["conv2"]["bias"]),
        ]
        kernels = [
            np.asarray(params["conv1"]["kernel"], np.float32),
            np.asarray(params["conv2"]["kernel"], np.float32),
        ]
        new_kernels, res = cluster.conv_train_step(
            images, kernels, between, head,
            update=lambda w, dw: w - lr * dw,
        )

        loss = float(sum(a[0] for a in res.head_aux))
        acc = float(sum(a[1] for a in res.head_aux)) / batch
        new_params = {
            "conv1": {
                "kernel": jnp.asarray(new_kernels[0]),
                "bias": params["conv1"]["bias"] - lr * db[0],
            },
            "conv2": {
                "kernel": jnp.asarray(new_kernels[1]),
                "bias": params["conv2"]["bias"] - lr * db[1],
            },
            "fc": jax.tree.map(lambda p, g: p - lr * g, params["fc"], fc_grad[0]),
        }
        return new_params, loss, acc

    return step
