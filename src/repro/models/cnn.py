"""The paper's CIFAR-10 CNN (§5.2):

    conv(5x5, C1) -> LRN -> maxpool/2 -> conv(5x5, C2) -> LRN ->
    maxpool/2 -> fully-connected -> softmax loss

Four sizes are studied: (C1, C2) in {(50,500), (150,800), (300,1000),
(500,1500)}.  The conv output-channel axis is the paper's distribution
axis; ``core/conv_shard.py`` shards it over the mesh and
``core/master_slave.py`` runs it over the emulated socket cluster.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import CNNConfig
from repro.layers.conv import apply_conv, conv_axes, init_conv, max_pool
from repro.layers.linear import apply_dense, dense_axes, init_dense
from repro.layers.norm import local_response_norm


PAPER_SIZES = {
    "cifar_cnn_50_500": (50, 500),
    "cifar_cnn_150_800": (150, 800),
    "cifar_cnn_300_1000": (300, 1000),
    "cifar_cnn_500_1500": (500, 1500),
}


def make_cnn_config(c1: int, c2: int) -> CNNConfig:
    return CNNConfig(arch_id=f"cifar_cnn_{c1}_{c2}", c1_kernels=c1, c2_kernels=c2)


def init_cnn(key, cfg: CNNConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    k = cfg.kernel_size
    feat = cfg.image_size // (cfg.pool_stride ** 2)
    return {
        "conv1": init_conv(ks[0], k, k, cfg.image_channels, cfg.c1_kernels, dtype),
        "conv2": init_conv(ks[1], k, k, cfg.c1_kernels, cfg.c2_kernels, dtype),
        "fc": init_dense(
            ks[2], (feat * feat * cfg.c2_kernels,), (cfg.num_classes,), dtype, use_bias=True
        ),
    }


def cnn_axes():
    return {
        "conv1": conv_axes(),
        "conv2": conv_axes(),
        "fc": dense_axes((None,), (None,), use_bias=True),
    }


def conv_fn_for_backend(backend: str = "xla", *, interpret=None):
    """Return a ``conv_fn`` for ``cnn_forward`` that computes the
    convolutions with the named compute backend (core/backends.py):
    ``xla`` (lax conv, the default reference), ``pallas`` (the MXU
    kernels forward + Pallas dX/dW backward), or ``numpy`` (im2col via
    host callback).  The distributed variants stay separate:
    core/conv_shard.py (mesh) and core/master_slave.py (cluster)."""
    from repro.core.backends import make_conv_fn

    return make_conv_fn(backend, interpret=interpret)


def cnn_forward(params, images: jax.Array, *, cfg: CNNConfig,
                conv_fn=apply_conv) -> jax.Array:
    """images: (B, 32, 32, 3) NHWC -> logits (B, 10).

    ``conv_fn`` is injectable so the distributed variants
    (core/conv_shard.py, core/master_slave.py) and the Pallas kernel can
    replace only the convolution, exactly as the paper replaces only the
    convolution step.
    """
    x = conv_fn(params["conv1"], images)
    x = jax.nn.relu(x)
    x = local_response_norm(x)
    x = max_pool(x, cfg.pool_stride, cfg.pool_stride)
    x = conv_fn(params["conv2"], x)
    x = jax.nn.relu(x)
    x = local_response_norm(x)
    x = max_pool(x, cfg.pool_stride, cfg.pool_stride)
    x = x.reshape(x.shape[0], -1)
    return apply_dense(params["fc"], x)


def cnn_loss(params, images: jax.Array, labels: jax.Array, *, cfg: CNNConfig,
             conv_fn=apply_conv) -> Tuple[jax.Array, jax.Array]:
    logits = cnn_forward(params, images, cfg=cfg, conv_fn=conv_fn)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc
