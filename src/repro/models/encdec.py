"""Encoder-decoder transformer backbone (whisper-medium).

The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: ``input_specs()`` provides precomputed frame embeddings
(B, num_frames, frame_dim) as the conv frontend would emit them.  The
encoder is a bidirectional transformer over those frames; the decoder is
causal with cross-attention into the encoder output.  RoPE replaces
whisper's learned absolute positions (backbone adaptation, noted in
DESIGN.md).  Whisper ties the decoder embedding with the logits head.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.unroll import scan_unroll_amount
from repro.layers import attention as attn_lib
from repro.layers.embedding import (
    embedding_axes,
    embed_tokens,
    init_embedding,
    logits_from_embedding,
)
from repro.layers.linear import apply_dense, dense_axes, init_dense
from repro.layers.mlp import apply_mlp, init_mlp, mlp_axes
from repro.layers.norm import apply_norm, init_norm, norm_axes
from repro.sharding.axes import AxisRules
from repro.sharding.partitioning import constrain


def init_enc_block(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": attn_lib.init_attention(ks[0], cfg, dtype),
        "ln2": init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype, gated=cfg.gated_mlp),
    }


def init_dec_block(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg.norm, cfg.d_model, dtype),
        "attn": attn_lib.init_attention(ks[0], cfg, dtype),
        "ln_x": init_norm(cfg.norm, cfg.d_model, dtype),
        "xattn": attn_lib.init_attention(ks[1], cfg, dtype),
        "ln2": init_norm(cfg.norm, cfg.d_model, dtype),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype, gated=cfg.gated_mlp),
    }


def _enc_block_axes(cfg):
    return {
        "ln1": norm_axes(cfg.norm),
        "attn": attn_lib.attention_axes(cfg),
        "ln2": norm_axes(cfg.norm),
        "mlp": mlp_axes(gated=cfg.gated_mlp),
    }


def _dec_block_axes(cfg):
    ax = _enc_block_axes(cfg)
    ax["ln_x"] = norm_axes(cfg.norm)
    ax["xattn"] = attn_lib.attention_axes(cfg)
    return ax


def init_encdec(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    n_enc, n_dec = cfg.num_encoder_layers, cfg.num_layers
    ks = jax.random.split(key, 3)
    enc_keys = jax.random.split(ks[0], n_enc)
    dec_keys = jax.random.split(ks[1], n_dec)
    return {
        "embed": init_embedding(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(lambda k: init_enc_block(k, cfg, dtype))(enc_keys),
        "enc_ln_f": init_norm(cfg.norm, cfg.d_model, dtype),
        "dec_blocks": jax.vmap(lambda k: init_dec_block(k, cfg, dtype))(dec_keys),
        "dec_ln_f": init_norm(cfg.norm, cfg.d_model, dtype),
    }


def encdec_axes(cfg: ModelConfig):
    stack = lambda t: jax.tree.map(
        lambda ax: ("layers",) + tuple(ax), t, is_leaf=lambda x: isinstance(x, tuple)
    )
    return {
        "embed": embedding_axes(),
        "enc_blocks": stack(_enc_block_axes(cfg)),
        "enc_ln_f": norm_axes(cfg.norm),
        "dec_blocks": stack(_dec_block_axes(cfg)),
        "dec_ln_f": norm_axes(cfg.norm),
    }


def encode(params, frames: jax.Array, *, cfg: ModelConfig, rules: AxisRules,
           remat: str = "none") -> jax.Array:
    """frames: (B, T, d_model) stub frontend embeddings -> encoder output."""
    dtype = cfg.compute_dtype
    b, t, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    x = frames.astype(dtype)
    x = constrain(x, rules, "batch", "act_seq", "act_embed")

    def body(carry, lp):
        xc = carry
        h = apply_norm(cfg.norm, lp["ln1"], xc, cfg.norm_eps)
        a = attn_lib.apply_attention(
            lp["attn"], h, cfg=cfg, rules=rules, positions=positions, causal=False
        )
        xc = xc + a
        h = apply_norm(cfg.norm, lp["ln2"], xc, cfg.norm_eps)
        xc = xc + apply_mlp(lp["mlp"], h, cfg=cfg, rules=rules)
        xc = constrain(xc, rules, "batch", "act_seq", "act_embed")
        return xc, None

    if remat in ("full", "dots"):
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(
        body, x, params["enc_blocks"],
        unroll=scan_unroll_amount(cfg.num_encoder_layers),
    )
    return apply_norm(cfg.norm, params["enc_ln_f"], x, cfg.norm_eps)


def decode_train(
    params,
    tokens: jax.Array,
    enc_out: jax.Array,
    *,
    cfg: ModelConfig,
    rules: AxisRules,
    remat: str = "none",
) -> jax.Array:
    """Teacher-forced decoder over the full token sequence -> logits."""
    dtype = cfg.compute_dtype
    b, s = tokens.shape
    t = enc_out.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    enc_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    x = embed_tokens(params["embed"], tokens, dtype)
    x = constrain(x, rules, "batch", "act_seq", "act_embed")

    def body(carry, lp):
        xc = carry
        h = apply_norm(cfg.norm, lp["ln1"], xc, cfg.norm_eps)
        a = attn_lib.apply_attention(
            lp["attn"], h, cfg=cfg, rules=rules, positions=positions
        )
        xc = xc + a
        h = apply_norm(cfg.norm, lp["ln_x"], xc, cfg.norm_eps)
        a = attn_lib.apply_attention(
            lp["xattn"], h, cfg=cfg, rules=rules, positions=positions,
            kv_x=enc_out, kv_positions=enc_pos,
        )
        xc = xc + a
        h = apply_norm(cfg.norm, lp["ln2"], xc, cfg.norm_eps)
        xc = xc + apply_mlp(lp["mlp"], h, cfg=cfg, rules=rules)
        xc = constrain(xc, rules, "batch", "act_seq", "act_embed")
        return xc, None

    if remat in ("full", "dots"):
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(
        body, x, params["dec_blocks"], unroll=scan_unroll_amount(cfg.num_layers)
    )
    x = apply_norm(cfg.norm, params["dec_ln_f"], x, cfg.norm_eps)
    logits = logits_from_embedding(params["embed"], x, dtype)
    return constrain(logits, rules, "batch", "act_seq", "vocab")


def encdec_forward(params, batch, *, cfg, rules, mesh=None, remat="none"):
    """Training forward: (frames, tokens) -> (logits, aux=0)."""
    enc_out = encode(params, batch["frames"], cfg=cfg, rules=rules, remat=remat)
    logits = decode_train(
        params, batch["tokens"], enc_out, cfg=cfg, rules=rules, remat=remat
    )
    return logits, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# serving


def init_encdec_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    l = cfg.num_layers
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    t_enc = cfg.audio.num_frames
    return {
        "t": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((l, batch, seq_len, kv, hd), dtype),
        "v": jnp.zeros((l, batch, seq_len, kv, hd), dtype),
        "pos": jnp.full((batch, seq_len), -1, jnp.int32),
        "cross_k": jnp.zeros((l, batch, t_enc, kv, hd), dtype),
        "cross_v": jnp.zeros((l, batch, t_enc, kv, hd), dtype),
        "cross_pos": jnp.zeros((batch, t_enc), jnp.int32),
    }


def encdec_cache_axes():
    return {
        "t": None,
        "k": ("layers", "batch", "cache_seq", "kv_heads", None),
        "v": ("layers", "batch", "cache_seq", "kv_heads", None),
        "pos": ("batch", "cache_seq"),
        "cross_k": ("layers", "batch", None, "kv_heads", None),
        "cross_v": ("layers", "batch", None, "kv_heads", None),
        "cross_pos": ("batch", None),
    }


def encdec_prefill(params, batch, *, cfg: ModelConfig, rules: AxisRules,
                   mesh=None, remat: str = "none", cache_len=None):
    """Encode audio frames, precompute cross K/V, prefill decoder tokens.
    Returns (last-token logits, cache)."""
    dtype = cfg.compute_dtype
    frames, tokens = batch["frames"], batch["tokens"]
    b, s = tokens.shape
    cache_len = cache_len or s
    enc_out = encode(params, frames, cfg=cfg, rules=rules, remat=remat)
    t_enc = enc_out.shape[1]
    cache = init_encdec_cache(cfg, b, cache_len, dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    enc_pos = jnp.broadcast_to(jnp.arange(t_enc, dtype=jnp.int32)[None], (b, t_enc))
    x = embed_tokens(params["embed"], tokens, dtype)

    def body(carry, lp):
        xc = carry
        h = apply_norm(cfg.norm, lp["ln1"], xc, cfg.norm_eps)
        from repro.layers.embedding import apply_rope

        q = apply_dense(lp["attn"]["wq"], h, dtype=dtype)
        k = apply_dense(lp["attn"]["wk"], h, dtype=dtype)
        v = apply_dense(lp["attn"]["wv"], h, dtype=dtype)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        out = attn_lib.attend(q, k, v, positions, positions, causal=True, window=None)
        xc = xc + apply_dense(lp["attn"]["wo"], out, n_in_dims=2, dtype=dtype)
        h = apply_norm(cfg.norm, lp["ln_x"], xc, cfg.norm_eps)
        xk, xv = attn_lib.compute_kv(lp["xattn"], enc_out, dtype)
        xq = apply_dense(lp["xattn"]["wq"], h, dtype=dtype)
        out = attn_lib.attend(xq, xk, xv, positions, enc_pos, causal=False, window=None)
        xc = xc + apply_dense(lp["xattn"]["wo"], out, n_in_dims=2, dtype=dtype)
        h = apply_norm(cfg.norm, lp["ln2"], xc, cfg.norm_eps)
        xc = xc + apply_mlp(lp["mlp"], h, cfg=cfg, rules=rules)
        xc = constrain(xc, rules, "batch", "act_seq", "act_embed")
        return xc, {"k": k, "v": v, "cross_k": xk, "cross_v": xv}

    if remat in ("full", "dots"):
        body = jax.checkpoint(body, prevent_cse=False)
    x, emitted = jax.lax.scan(
        body, x, params["dec_blocks"], unroll=scan_unroll_amount(cfg.num_layers)
    )

    pad = cache_len - s
    pad_kv = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache["k"], cache["v"] = pad_kv(emitted["k"]), pad_kv(emitted["v"])
    cache["cross_k"], cache["cross_v"] = emitted["cross_k"], emitted["cross_v"]
    cache["pos"] = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
    cache["cross_pos"] = enc_pos
    cache["t"] = jnp.array(s, jnp.int32)

    x = apply_norm(cfg.norm, params["dec_ln_f"], x[:, -1:], cfg.norm_eps)
    logits = logits_from_embedding(params["embed"], x, dtype)
    return logits[:, 0], cache


def encdec_decode_step(params, cache, tokens, *, cfg: ModelConfig,
                       rules: AxisRules, mesh=None):
    """One decode token against (self cache + fixed cross K/V)."""
    dtype = cfg.compute_dtype
    position = cache["t"]
    index = position  # full cache, no ring
    x = embed_tokens(params["embed"], tokens, dtype)

    def f(xc, xs):
        lp, lc = xs
        h = apply_norm(cfg.norm, lp["ln1"], xc, cfg.norm_eps)
        a, nk, nv, _ = attn_lib.decode_attention(
            lp["attn"], h, cfg=cfg, rules=rules,
            cache_k=lc["k"], cache_v=lc["v"], cache_pos=cache["pos"],
            index=index, position=position,
        )
        xc = xc + a
        h = apply_norm(cfg.norm, lp["ln_x"], xc, cfg.norm_eps)
        a = attn_lib.cross_decode_attention(
            lp["xattn"], h, cfg=cfg, rules=rules,
            k=lc["cross_k"], v=lc["cross_v"], kv_positions=cache["cross_pos"],
        )
        xc = xc + a
        h = apply_norm(cfg.norm, lp["ln2"], xc, cfg.norm_eps)
        xc = xc + apply_mlp(lp["mlp"], h, cfg=cfg, rules=rules)
        return xc, {"k": nk, "v": nv}

    per_layer = {
        "k": cache["k"], "v": cache["v"],
        "cross_k": cache["cross_k"], "cross_v": cache["cross_v"],
    }
    x, updated = jax.lax.scan(
        f, x, (params["dec_blocks"], per_layer),
        unroll=scan_unroll_amount(cfg.num_layers),
    )

    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = updated["k"], updated["v"]
    new_cache["t"] = position + 1
    b = tokens.shape[0]
    pos_arr = jnp.full((b, 1), position, jnp.int32)
    new_cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], pos_arr, index, axis=1
    )

    x = apply_norm(cfg.norm, params["dec_ln_f"], x, cfg.norm_eps)
    logits = logits_from_embedding(params["embed"], x, dtype)
    return logits[:, 0], new_cache
