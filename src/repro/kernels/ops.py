"""Public jit'd wrappers over the Pallas kernels.

On TPU the kernels compile natively; on any other backend they run in
``interpret=True`` mode (the kernel body executes in Python on CPU),
which is how the tests validate them against the ``ref.py`` oracles.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.conv2d import conv2d_pallas
from repro.kernels.flash_attn import flash_attention_pallas
from repro.kernels.ssd import ssd_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def conv2d(x: jax.Array, w: jax.Array, *, cout_tile: int = 128) -> jax.Array:
    """NHWC x HWIO SAME conv via the Pallas MXU kernel."""
    return conv2d_pallas(x, w, cout_tile=cout_tile, interpret=_interpret())


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: Optional[int] = None,
    block_q: int = 128, block_k: int = 128,
) -> jax.Array:
    """(B,H,S,D) x (B,H,T,D) flash attention via the Pallas kernel."""
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


def ssd(x, dt, a, bmat, cmat, *, chunk: int = 256) -> jax.Array:
    """Chunked SSD scan via the Pallas kernel (groups pre-expanded)."""
    return ssd_pallas(x, dt, a, bmat, cmat, chunk=chunk, interpret=_interpret())
