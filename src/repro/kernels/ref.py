"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def conv2d_ref(x: jax.Array, w: jax.Array, *, padding: str = "SAME") -> jax.Array:
    """NHWC x HWIO -> NHWC, stride 1."""
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), padding, dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def flash_attention_ref(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, H, T, D)
    v: jax.Array,  # (B, H, T, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    s = jnp.einsum(
        "bhsd,bhtd->bhst", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    sq, tk = q.shape[2], k.shape[2]
    q_pos = jnp.arange(sq)[:, None] + (tk - sq)  # right-aligned positions
    k_pos = jnp.arange(tk)[None, :]
    mask = jnp.ones((sq, tk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  (already softplus'd)
    a: jax.Array,   # (H,) negative
    bmat: jax.Array,  # (B, S, H, N)  (groups pre-expanded to heads)
    cmat: jax.Array,  # (B, S, H, N)
    initial_state: Optional[jax.Array] = None,  # (B, H, P, N)
):
    """Sequential (quadratic-free) SSD recurrence — the exact oracle:
        S_t = exp(dt_t a) S_{t-1} + dt_t B_t x_t^T;  y_t = C_t . S_t
    Returns (y (B,S,H,P), final_state)."""
    bsz, s, h, p = x.shape
    n = bmat.shape[-1]
    s0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )

    def step(carry, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * a[None, :])  # (B,H)
        st = carry * decay[:, :, None, None] + jnp.einsum(
            "bhp,bhn,bh->bhpn", xt, bt, dtt
        )
        yt = jnp.einsum("bhpn,bhn->bhp", st, ct)
        return st, yt

    xs = (
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(bmat, 1, 0).astype(jnp.float32),
        jnp.moveaxis(cmat, 1, 0).astype(jnp.float32),
    )
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
