"""Pallas TPU SSD (state-space duality, mamba-2) chunked-scan kernel.

Grid (batch*heads, n_chunks) with chunks innermost/sequential: the
(head_dim x d_state) recurrent state lives in fp32 VMEM scratch and is
carried across chunk iterations (reset at chunk 0 of each (b,h)); within
a chunk the duality gives a (L x L) masked-decay attention-like matmul on
the MXU plus a rank-N state update:

    y_intra = ((C B^T) o decay_mask) (dt x)        -- (L,L)x(L,P)
    y_inter = (C S_prev^T) o exp(cum)              -- (L,N)x(N,P)
    S_new   = exp(total) S_prev + (suffix o dt x)^T B

Chunk 256, head_dim 64, d_state 128: VMEM = x(256x64) + B/C(256x128) +
state(64x128 fp32) + scores(256x256 fp32) ~ 0.6 MB.  The state is
head-local, so the sequential dim crosses no device boundary — the
kernel-level mirror of why SSD head-sharding needs no collectives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, *, chunk: int):
    """Blocks per (bh, ci) step:
    x (1, L, P); dt (1, L); a (1, 1); b/c (1, L, N); y (1, L, P);
    s_ref: fp32 scratch (P, N) carried across the chunk dim."""
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0].astype(jnp.float32)        # (L, P)
    dt = dt_ref[0].astype(jnp.float32)      # (L,)
    a = a_ref[0, 0].astype(jnp.float32)     # scalar (negative)
    bm = b_ref[0].astype(jnp.float32)       # (L, N)
    cm = c_ref[0].astype(jnp.float32)       # (L, N)

    da = dt * a                             # (L,) log-decay per step
    cum = jnp.cumsum(da)                    # inclusive
    total = cum[-1]

    xdt = x * dt[:, None]                   # (L, P)

    # intra-chunk: scores (L,L) on the MXU, masked by causal decay
    scores = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)
    decay = cum[:, None] - cum[None, :]     # cum_t - cum_u
    l_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = l_idx >= u_idx
    w = jnp.exp(jnp.where(mask, decay, -1e30))
    y = jnp.dot(scores * w, xdt, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    s_prev = s_ref[...]                     # (P, N)
    y += jnp.exp(cum)[:, None] * jnp.dot(
        cm, s_prev.T, preferred_element_type=jnp.float32
    )

    # state update: S = exp(total) S_prev + sum_u exp(total-cum_u) (dt x)_u B_u
    suffix = jnp.exp(total - cum)           # (L,)
    s_ref[...] = s_prev * jnp.exp(total) + jnp.dot(
        (xdt * suffix[:, None]).T, bm, preferred_element_type=jnp.float32
    )

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H) softplus'd
    a: jax.Array,    # (H,) negative
    bmat: jax.Array,  # (B, S, H, N) groups pre-expanded
    cmat: jax.Array,  # (B, S, H, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    # (B,S,H,*) -> (B*H, S, *): head-major so the chunk dim is innermost
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, sp, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, sp)
    bf = bmat.transpose(0, 2, 1, 3).reshape(b * h, sp, n)
    cf = cmat.transpose(0, 2, 1, 3).reshape(b * h, sp, n)
    af = jnp.tile(a.astype(jnp.float32)[None, :], (b, 1)).reshape(b * h, 1)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci: (bh, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sp, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf)

    y = y.reshape(b, h, sp, p).transpose(0, 2, 1, 3)
    if pad:
        y = y[:, :s]
    return y
