"""Pallas TPU flash-attention kernel (forward).

Online-softmax over KV tiles: grid (batch*heads, q_tiles, k_tiles) with
the KV dim innermost; the running max / denominator / fp32 accumulator
live in VMEM scratch and persist across the k iterations of one q tile.
Causal + sliding-window masking by absolute positions (queries
right-aligned against kv, matching the decode contract); fully-masked KV
tiles are skipped with ``pl.when`` so the sliding-window case does
O(S·W) work, not O(S·T) — the long_500k requirement at kernel level.

Block shapes default to (128, 128): multiples of the MXU's 128 lanes;
scratch = (2 x 128 x head_dim x 4B) + fp32 acc ~ 0.4 MB VMEM at
head_dim 128.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: Optional[int],
    block_q: int, block_k: int, q_offset: int, kv_len: int,
):
    qi, ki = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions: query row i is at q_offset + qi*block_q + i
    q_pos = (
        q_offset + qi * block_q
        + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )

    def _compute():
        s = jnp.dot(
            q_ref[0].astype(jnp.float32),
            k_ref[0].astype(jnp.float32).T,
            preferred_element_type=jnp.float32,
        ) * scale  # (block_q, block_k)
        mask = k_pos < kv_len  # padded kv tail is invalid
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    # skip KV tiles that are fully masked for this q tile
    run = True
    if causal:
        run = run & (ki * block_k <= q_offset + qi * block_q + block_q - 1)
    if window is not None:
        # the tile has a live pair iff its OLDEST query is within the
        # window of its NEWEST key
        first_q = q_offset + qi * block_q
        last_k = ki * block_k + block_k - 1
        run = run & (first_q - last_k < window)
    pl.when(run)(_compute)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, H, T, D)
    v: jax.Array,  # (B, H, T, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    tk = k.shape[2]
    scale = d ** -0.5
    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(tk, 8))

    pad_q = (-sq) % block_q
    pad_k = (-tk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sqp, tkp = sq + pad_q, tk + pad_k

    qf = qp.reshape(b * h, sqp, d)
    kf = kp.reshape(b * h, tkp, d)
    vf = vp.reshape(b * h, tkp, d)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k,
            q_offset=tk - sq, kv_len=tk,
        ),
        grid=(b * h, sqp // block_q, tkp // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32),   # fp32 accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, h, sqp, d)
    if pad_q:
        out = out[:, :, :sq]
    return out
