"""Pallas TPU direct-convolution kernel — the paper's compute hot-spot,
adapted to the MXU.

The GPU papers of the era (Ward et al. [11]) tile the *image*; on TPU the
natural tiling is the one the paper itself distributes across devices:
the OUTPUT-CHANNEL axis.  Each grid step owns one batch image and one
128-wide slice of output channels (MXU lane width), unrolls the kh x kw
taps, and issues (H*W, Cin) x (Cin, 128) matmuls accumulated in fp32
VREGs — the kernel is the single-device microcosm of the distribution
scheme (output channels = kernels are the parallel axis at every level).

VMEM per step (CIFAR shapes, Cout tile 128):
  x block (1, H+kh-1, W+kw-1, Cin) + w (kh,kw,Cin,128) + acc (H*W, 128)
  = 36x36x512x4B (~2.7 MB worst case C2 layer) — fits the ~16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv2d_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, out_h: int, out_w: int):
    """x_ref: (1, out_h+kh-1, out_w+kw-1, cin) padded input block (VMEM)
    w_ref: (kh, kw, cin, tco); o_ref: (1, out_h, out_w, tco)."""
    cin = x_ref.shape[-1]
    tco = o_ref.shape[-1]
    acc = jnp.zeros((out_h * out_w, tco), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            # (out_h, out_w, cin) shifted window, flattened to an MXU matmul
            xs = x_ref[0, i : i + out_h, j : j + out_w, :].reshape(
                out_h * out_w, cin
            )
            ws = w_ref[i, j, :, :]  # (cin, tco)
            acc += jnp.dot(
                xs.astype(jnp.float32),
                ws.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
    o_ref[0] = acc.reshape(out_h, out_w, tco).astype(o_ref.dtype)


def _direct_conv(xp: jax.Array, w: jax.Array, out_h: int, out_w: int,
                 cout_tile: int, interpret: bool) -> jax.Array:
    """Shared driver: pre-padded input xp (B, out_h+kh-1, out_w+kw-1, Cin)
    against w (kh, kw, Cin, Cout), tiled over batch x Cout."""
    b = xp.shape[0]
    kh, kw, cin, cout = w.shape

    tco = min(cout_tile, cout)
    pad_co = (-cout) % tco
    if pad_co:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, pad_co)))
    n_co = w.shape[-1] // tco

    out = pl.pallas_call(
        functools.partial(_conv2d_kernel, kh=kh, kw=kw, out_h=out_h, out_w=out_w),
        grid=(b, n_co),
        in_specs=[
            pl.BlockSpec(
                (1, out_h + kh - 1, out_w + kw - 1, cin), lambda bi, ci: (bi, 0, 0, 0)
            ),
            pl.BlockSpec((kh, kw, cin, tco), lambda bi, ci: (0, 0, 0, ci)),
        ],
        out_specs=pl.BlockSpec((1, out_h, out_w, tco), lambda bi, ci: (bi, 0, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((b, out_h, out_w, w.shape[-1]), xp.dtype),
        interpret=interpret,
    )(xp, w)
    if pad_co:
        out = out[..., :cout]
    return out


@functools.partial(jax.jit, static_argnames=("interpret", "cout_tile"))
def conv2d_pallas(
    x: jax.Array,  # (B, H, W, Cin)
    w: jax.Array,  # (kh, kw, Cin, Cout)
    *,
    cout_tile: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """SAME-padded stride-1 convolution.  Cout is padded to the tile."""
    b, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    return _direct_conv(xp, w, h, wd, cout_tile, interpret)


@functools.partial(jax.jit, static_argnames=("interpret", "cin_tile"))
def conv2d_dx_pallas(
    g: jax.Array,  # (B, H, W, Cout) — upstream gradient
    w: jax.Array,  # (kh, kw, Cin, Cout) — the forward kernel (shard)
    *,
    cin_tile: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """dX of the SAME stride-1 conv: the transpose convolution, expressed
    as a direct conv of g against the spatially flipped, channel-swapped
    kernel — so it reuses the exact forward MXU kernel with Cin as the
    tiled output axis.  The pad is the complement of the forward pad
    (identical for odd kernels)."""
    kh, kw = w.shape[0], w.shape[1]
    ph, pw = kh // 2, kw // 2
    wt = jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2)  # (kh, kw, Cout, Cin)
    gp = jnp.pad(g, ((0, 0), (kh - 1 - ph, ph), (kw - 1 - pw, pw), (0, 0)))
    return _direct_conv(gp, wt, g.shape[1], g.shape[2], cin_tile, interpret)


def _conv2d_dw_kernel(x_ref, g_ref, o_ref, *, kh: int, kw: int, out_h: int, out_w: int):
    """x_ref: (1, out_h+kh-1, out_w+kw-1, cin) padded input block (VMEM)
    g_ref: (1, out_h, out_w, tco); o_ref: (kh, kw, cin, tco), accumulated
    over the batch grid axis (innermost, so writes are consecutive)."""
    cin = x_ref.shape[-1]
    tco = g_ref.shape[-1]

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros(o_ref.shape, o_ref.dtype)

    gs = g_ref[0].reshape(out_h * out_w, tco).astype(jnp.float32)
    for i in range(kh):
        for j in range(kw):
            xs = x_ref[0, i : i + out_h, j : j + out_w, :].reshape(
                out_h * out_w, cin
            ).astype(jnp.float32)
            # contract the pixel axis: (cin, tco) += xs^T @ gs on the MXU
            o_ref[i, j] += jax.lax.dot_general(
                xs, gs, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kh", "kw", "interpret", "cout_tile"))
def conv2d_dw_pallas(
    x: jax.Array,  # (B, H, W, Cin)
    g: jax.Array,  # (B, H, W, Cout)
    kh: int,
    kw: int,
    *,
    cout_tile: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """dW of the SAME stride-1 conv: per-tap (Cin, Cout) matmuls between
    shifted input windows and the upstream gradient, accumulated across
    the batch in fp32 (batch is the innermost grid axis so each Cout tile
    of dW is revisited consecutively)."""
    b, h, wd, cin = x.shape
    cout = g.shape[-1]
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))

    tco = min(cout_tile, cout)
    pad_co = (-cout) % tco
    if pad_co:
        g = jnp.pad(g, ((0, 0), (0, 0), (0, 0), (0, pad_co)))
    n_co = g.shape[-1] // tco

    out = pl.pallas_call(
        functools.partial(_conv2d_dw_kernel, kh=kh, kw=kw, out_h=h, out_w=wd),
        grid=(n_co, b),
        in_specs=[
            pl.BlockSpec(
                (1, h + kh - 1, wd + kw - 1, cin), lambda ci, bi: (bi, 0, 0, 0)
            ),
            pl.BlockSpec((1, h, wd, tco), lambda ci, bi: (bi, 0, 0, ci)),
        ],
        out_specs=pl.BlockSpec((kh, kw, cin, tco), lambda ci, bi: (0, 0, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((kh, kw, cin, g.shape[-1]), jnp.float32),
        interpret=interpret,
    )(xp, g)
    if pad_co:
        out = out[..., :cout]
    return out
