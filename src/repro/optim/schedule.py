"""Learning-rate schedules: constant, cosine, and WSD.

WSD (warmup-stable-decay) is minicpm's schedule (arXiv:2404.06395): linear
warmup, a long stable plateau, then a short exponential-ish decay tail —
implemented with the paper's 10% decay window and linear-in-log decay.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


def make_schedule(
    kind: str,
    *,
    learning_rate: float,
    warmup_steps: int,
    total_steps: int,
    final_fraction: float = 0.1,
    wsd_decay_fraction: float = 0.1,
) -> Callable:
    """Returns step -> lr (works on traced int32 steps)."""

    def warmup(step):
        return jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))

    if kind == "constant":
        def f(step):
            return learning_rate * warmup(step)
        return f

    if kind == "cosine":
        def f(step):
            t = jnp.clip(
                (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
            )
            cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
            scale = final_fraction + (1 - final_fraction) * cos
            return learning_rate * warmup(step) * scale
        return f

    if kind == "wsd":
        decay_steps = max(int(total_steps * wsd_decay_fraction), 1)
        decay_start = total_steps - decay_steps

        def f(step):
            in_decay = (step - decay_start) / decay_steps
            decay = jnp.where(
                step < decay_start,
                1.0,
                final_fraction ** jnp.clip(in_decay, 0.0, 1.0),
            )
            return learning_rate * warmup(step) * decay
        return f

    raise ValueError(f"unknown schedule {kind!r}")
