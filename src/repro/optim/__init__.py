from repro.optim.optimizers import (  # noqa: F401
    adafactor,
    adam,
    make_optimizer,
    sgd,
)
from repro.optim.schedule import make_schedule  # noqa: F401
