"""Optimizers: SGD (+momentum), Adam(W), and Adafactor.

Small optax-like interface (init/update as pure functions over pytrees)
implemented here because the container ships no optax.  Adafactor keeps
the factored second moment (row/col running means) so the 340B config's
optimizer state stays O(params/min_dim) — the substrate decision that
makes nemotron-4-340b trainable on the 16 GB/chip mesh (DESIGN.md §4).

Optimizer states are pytrees mirroring the params, so the launcher shards
them with the same logical-axis rules as the parameters (FSDP included).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    # update(grads, state, params, lr) -> (new_params, new_state)
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]


def _tree_zeros_like(params, dtype=jnp.float32):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


def sgd(momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return {"mu": _tree_zeros_like(params)}
        return {}

    def update(grads, state, params, lr):
        if momentum:
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads
            )
            step_dir = mu
            new_state = {"mu": mu}
        else:
            step_dir = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            new_state = {}
        new_params = jax.tree.map(
            lambda p, d: (
                p.astype(jnp.float32) - lr * (d + weight_decay * p.astype(jnp.float32))
            ).astype(p.dtype),
            params,
            step_dir,
        )
        return new_params, new_state

    return Optimizer(init, update)


def adam(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        return {
            "mu": _tree_zeros_like(params),
            "nu": _tree_zeros_like(params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"],
            grads,
        )
        mu_hat_scale = 1.0 / (1 - b1 ** c)
        nu_hat_scale = 1.0 / (1 - b2 ** c)

        def step(p, m, v):
            upd = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps)
            return (
                p.astype(jnp.float32) - lr * (upd + weight_decay * p.astype(jnp.float32))
            ).astype(p.dtype)

        new_params = jax.tree.map(step, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "count": count}

    return Optimizer(init, update)


def adafactor(
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    min_dim_size_to_factor: int = 128,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Adafactor (Shazeer & Stern, 2018) without first moment: the memory
    regime for the 340B config (factored second moments only)."""

    def _factored(shape) -> bool:
        return (
            len(shape) >= 2
            and shape[-1] >= min_dim_size_to_factor
            and shape[-2] >= min_dim_size_to_factor
        )

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # row
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "v": jax.tree.map(one, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        beta = 1.0 - (count.astype(jnp.float32) + 1.0) ** (-decay)

        def one(g, v, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in v:
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                r_factor = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + eps
                )
                c_factor = jax.lax.rsqrt(vc + eps)
                upd = g * r_factor[..., None] * c_factor[..., None, :]
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta * v["v"] + (1 - beta) * g2
                upd = g * jax.lax.rsqrt(vv + eps)
                new_v = {"v": vv}
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            new_p = (
                p.astype(jnp.float32) - lr * (upd + weight_decay * p.astype(jnp.float32))
            ).astype(p.dtype)
            return new_p, new_v

        flat_g, treedef = jax.tree.flatten(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        outs = [one(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_v = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_params, {"v": new_v, "count": count}

    return Optimizer(init, update)


def make_optimizer(name: str, *, weight_decay: float = 0.0) -> Optimizer:
    if name == "sgd":
        return sgd(weight_decay=weight_decay)
    if name == "adam":
        return adam(weight_decay=weight_decay)
    if name == "adafactor":
        return adafactor(weight_decay=weight_decay)
    raise ValueError(f"unknown optimizer {name!r}")


def optimizer_state_axes(name: str, param_axes, param_shapes) -> Any:
    """Logical axes for the optimizer state, mirroring the param axes so
    FSDP/TP sharding carries over to the moments — except the params'
    ``fsdp_embed`` axis becomes ``opt_embed`` so ZeRO-1 can shard the
    moments while replicating the params.  ``param_shapes`` is a matching
    pytree of arrays/ShapeDtypeStructs (needed to decide which adafactor
    leaves are factored)."""
    is_leaf = lambda x: isinstance(x, tuple) or x is None

    def _opt(axes):
        if axes is None:
            return None
        return tuple("opt_embed" if a == "fsdp_embed" else a for a in axes)

    param_axes = jax.tree.map(_opt, param_axes, is_leaf=is_leaf)
    if name == "sgd":
        return {"mu": param_axes}
    if name == "adam":
        return {"mu": param_axes, "nu": param_axes, "count": None}
    if name == "adafactor":
        def _factored(shape) -> bool:
            return len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128

        def one(axes, p):
            axes = tuple(axes) if axes is not None else (None,) * len(p.shape)
            if _factored(p.shape):
                return {"vr": axes[:-1], "vc": axes[:-2] + axes[-1:]}
            return {"v": axes}

        return {
            "v": jax.tree.map(one, param_axes, param_shapes, is_leaf=is_leaf),
            "count": None,
        }
    raise ValueError(name)
