"""Sharded numpy checkpointing.

Flat key/value .npz per step directory plus a small JSON manifest of the
pytree structure.  Arrays are gathered to host (fine at example scale; on
a real pod each host would write its addressable shards — the manifest
format already records per-leaf paths so that extension is local).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree: Any, prefix: str = ""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}{k}{_SEP}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}{i}{_SEP}")
    else:
        yield prefix.rstrip(_SEP), tree


def _unflatten(flat: dict) -> Any:
    tree: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


_NATIVE = set("biufc")  # numpy-native dtype kinds


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    """Write the pytree to <ckpt_dir>/step_<n>/arrays.npz (+manifest).
    Non-native dtypes (bfloat16, fp8) are stored as raw bit-views with
    the true dtype recorded in the manifest."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    flat = dict(_flatten(jax.tree.map(lambda x: np.asarray(x), tree)))
    dtypes = {k: str(v.dtype) for k, v in flat.items()}
    stored = {
        k: (v if v.dtype.kind in _NATIVE else v.view(f"u{v.dtype.itemsize}"))
        for k, v in flat.items()
    }
    np.savez(os.path.join(path, "arrays.npz"), **stored)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(flat), "dtypes": dtypes}, f, indent=1)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                       shardings: Any = None) -> Any:
    """Load a checkpoint; ``shardings`` (optional pytree of NamedSharding)
    places leaves directly on the mesh via jax.device_put."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {}
        for k in z.files:
            v = z[k]
            want = manifest["dtypes"].get(k, str(v.dtype))
            if want != str(v.dtype):
                v = v.view(jnp.dtype(want))
            flat[k] = v
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree
