"""PEP 562 lazy-module helper: one implementation for every package
whose ``__init__`` must stay import-light (TCP slave subprocesses import
``repro.core.cluster.protocol`` and must never pay for jax)."""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Tuple


def lazy_exports(
    module_name: str, module_globals: dict, exports: Dict[str, str]
) -> Tuple[Callable, Callable]:
    """Build the ``(__getattr__, __dir__)`` pair for a lazy package.

    ``exports`` maps attribute name -> module path (absolute, or
    relative like ``".cluster"`` resolved against ``module_name``).
    Resolved attributes are cached in ``module_globals`` so each import
    cost is paid once."""

    def __getattr__(name: str):
        if name in exports:
            mod = importlib.import_module(exports[name], module_name)
            val = getattr(mod, name)
            module_globals[name] = val
            return val
        raise AttributeError(
            f"module {module_name!r} has no attribute {name!r}"
        )

    def __dir__():
        return sorted(set(module_globals) | set(exports))

    return __getattr__, __dir__
