"""The serving lane: ``engine.py`` (LLM prefill + step-wise decode over
a KV/SSM cache) and ``server.py`` (the continuous-batching request
server over an elastic ``HeteroCluster``).  Attribute access is lazy so
importing the cluster server never pays for jax."""
from repro.lazy import lazy_exports

_EXPORTS = {
    "ServeEngine": ".engine",
    "make_serve_step": ".engine",
    "make_prefill_step": ".engine",
    "ClusterServer": ".server",
    "AutoScaler": ".server",
    "RequestQueue": ".server",
    "ServeFuture": ".server",
    "ServeResponse": ".server",
}

__all__ = sorted(_EXPORTS)

__getattr__, __dir__ = lazy_exports(__name__, globals(), _EXPORTS)
