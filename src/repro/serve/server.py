"""Continuous-batching request server over an elastic ``HeteroCluster``.

The paper distributes the conv layers because they dominate processing
time — the same argument holds at inference, so this lane routes
conv-heavy forward passes through the cluster's pipelined
scatter/gather hot path instead of training steps:

    submit() -> RequestQueue -> [serve loop] -> ServeChain -> cluster
                   |                 |
              admission control   slot-based dynamic batching,
              + deadlines         cross-batch scatter/gather overlap,
                                  AutoScaler admit()/evict()

One background thread owns the cluster.  Each loop iteration packs up
to ``max_batch`` waiting requests into a slab (prefill packing),
pushes it into a ``ServeChain`` — which returns the PREVIOUS slab's
output while the new slab's layer-0 scatter is already on the wire —
and completes futures.  Multi-step requests re-enter the ready set
between steps, so they join whatever partially-filled batch forms
next (continuous batching, JetStream-style prefill/decode separation:
fresh requests are packed alongside continuing ones).

A ``SlaveLost`` mid-request is NOT an error: the cluster's ``Pending``
recovery drains the batch on the survivors and the master recomputes
the dead slave's shard; the server surfaces it as ``retries`` on the
affected responses.  A ``SlaveError`` (a slave's backend raised) IS an
error — and so is any exception out of a user ``head``/``step_fn``:
the pipeline state is unrecoverable, so the server fails every
in-flight request with ``"error"``, rejects what is still queued, and
stops.  Once the loop has exited (error or ``stop()``), the queue is
closed atomically, so a late ``submit`` resolves ``"rejected"``
instead of stranding a future no thread will ever read.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

# statuses a ServeResponse can carry
STATUS_OK = "ok"
STATUS_REJECTED = "rejected"   # admission control: queue full / server stopped
STATUS_EXPIRED = "expired"     # deadline passed while queued
STATUS_ERROR = "error"         # unrecoverable failure (SlaveError, bad stage)


@dataclasses.dataclass
class ServeResponse:
    """The terminal outcome of one submitted request.

    Attributes:
        request_id: server-assigned id, unique per ``ClusterServer``.
        status: one of ``"ok" | "rejected" | "expired" | "error"``.
        output: the chain output for this request (head applied when
            the server has one); None unless status is ``"ok"``.
        retries: slave losses absorbed while this request was in
            flight — the survivor-recompute count, not an error count.
        steps: decode steps actually completed.
        queued_s: submit -> first batch admission wall time.
        latency_s: submit -> completion wall time.
        detail: human-readable reason for non-ok statuses.
    """

    request_id: int
    status: str
    output: Optional[np.ndarray] = None
    retries: int = 0
    steps: int = 0
    queued_s: float = 0.0
    latency_s: float = 0.0
    detail: str = ""


class ServeFuture:
    """Handle returned by ``ClusterServer.submit``; resolves exactly once."""

    def __init__(self):
        self._event = threading.Event()
        self._response: Optional[ServeResponse] = None

    def done(self) -> bool:
        """Whether the response is available (never blocks)."""
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> ServeResponse:
        """Block until the response is available and return it.

        Args:
            timeout: max seconds to wait (None = forever).

        Raises:
            TimeoutError: the response did not arrive within ``timeout``.
        """
        if not self._event.wait(timeout):
            raise TimeoutError("serve request still in flight")
        assert self._response is not None
        return self._response

    def _resolve(self, response: ServeResponse) -> None:
        self._response = response
        self._event.set()


@dataclasses.dataclass
class _Request:
    """Internal queue entry; ``x`` is mutated between decode steps."""

    request_id: int
    x: np.ndarray                 # next input to run, (H, W, Cin)
    deadline: Optional[float]     # absolute clock value, None = no deadline
    steps_left: int
    steps_done: int
    future: ServeFuture
    t_submit: float
    t_admitted: Optional[float] = None
    retries: int = 0


class RequestQueue:
    """Thread-safe bounded FIFO with admission control and deadline culling.

    ``offer`` refuses beyond ``max_depth`` (the admission-control
    backpressure signal); ``take`` pops up to ``max_n`` ready requests
    and separately returns the ones whose deadline passed while they
    waited, so the serve loop can expire them without computing.

    Args:
        max_depth: admission-control bound on queued requests.
        clock: monotonic-seconds source (injectable for tests).
    """

    def __init__(self, max_depth: int, clock: Callable[[], float] = time.monotonic):
        self.max_depth = int(max_depth)
        self.clock = clock
        self._items: deque = deque()
        self._closed = False
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)

    def __len__(self) -> int:
        """Current queue depth (thread-safe)."""
        with self._lock:
            return len(self._items)

    @property
    def closed(self) -> bool:
        """Whether ``close()`` ran — all further offers are refused."""
        with self._lock:
            return self._closed

    def offer(self, req: "_Request") -> bool:
        """Enqueue unless full or closed.  Returns False when
        admission-control rejects (depth already at ``max_depth``) or
        the queue was closed by shutdown."""
        with self._lock:
            if self._closed or len(self._items) >= self.max_depth:
                return False
            self._items.append(req)
            self._nonempty.notify()
            return True

    def take(self, max_n: int, now: Optional[float] = None
             ) -> Tuple[List["_Request"], List["_Request"]]:
        """Pop up to ``max_n`` live requests in FIFO order.

        Args:
            max_n: slot budget — at most this many ready requests.
            now: clock value for deadline checks (defaults to ``clock()``).

        Returns:
            ``(ready, expired)`` — the whole queue is scanned, so
            expired entries are culled wherever they sit (not just
            ahead of the live window), never count against ``max_n``,
            and a stale head never blocks live traffic behind it.
        """
        if now is None:
            now = self.clock()
        ready: List[_Request] = []
        expired: List[_Request] = []
        with self._lock:
            keep: deque = deque()
            while self._items:
                req = self._items.popleft()
                if req.deadline is not None and now >= req.deadline:
                    expired.append(req)
                elif len(ready) < max_n:
                    ready.append(req)
                else:
                    keep.append(req)
            self._items = keep
            return ready, expired

    def close(self) -> List["_Request"]:
        """Mark the queue closed and pop everything still queued, in
        one critical section (shutdown path).

        Closing under the same lock as ``offer`` means no request can
        slip in between the final drain and the close and be silently
        stranded: after this returns, every ``offer`` fails.
        """
        with self._lock:
            self._closed = True
            items = list(self._items)
            self._items.clear()
            return items

    def wait_nonempty(self, timeout: float) -> bool:
        """Block until the queue is non-empty or ``timeout`` elapses."""
        with self._lock:
            if self._items:
                return True
            # reprolint: allow=blocking-under-lock -- Condition.wait RELEASES the lock while blocked; holding it here is the condition-variable protocol, not a stall
            return self._nonempty.wait(timeout)


class AutoScaler:
    """Load-driven ``admit()``/``evict()`` from queue-depth signals.

    The serve loop calls ``observe(queue_depth)`` once per iteration;
    the scaler admits a slave when the backlog crosses
    ``scale_up_depth`` and evicts the youngest when it falls to
    ``scale_down_depth``, bounded by ``[min_slaves, max_slaves]`` and
    rate-limited by ``cooldown_s`` (both directions share the
    cooldown, so a burst cannot thrash admit/evict pairs).

    Args:
        cluster: the elastic ``HeteroCluster`` to scale.
        scale_up_depth: admit when ``queue_depth >= scale_up_depth``.
        scale_down_depth: evict when ``queue_depth <= scale_down_depth``.
        min_slaves: never evict below this many slaves.
        max_slaves: never admit above this many slaves.
        cooldown_s: minimum seconds between scaling actions.
        clock: monotonic-seconds source (injectable for tests).
        admit_kwargs: forwarded to ``cluster.admit`` (backend,
            slowdown, bandwidth_mbps, ...).
    """

    def __init__(self, cluster, *, scale_up_depth: int = 8,
                 scale_down_depth: int = 0, min_slaves: int = 1,
                 max_slaves: int = 4, cooldown_s: float = 2.0,
                 clock: Callable[[], float] = time.monotonic,
                 admit_kwargs: Optional[dict] = None):
        assert scale_down_depth < scale_up_depth
        self.cluster = cluster
        self.scale_up_depth = scale_up_depth
        self.scale_down_depth = scale_down_depth
        self.min_slaves = min_slaves
        self.max_slaves = max_slaves
        self.cooldown_s = cooldown_s
        self.clock = clock
        self.admit_kwargs = dict(admit_kwargs or {})
        self.events: List[Tuple[float, str, int]] = []  # (t, action, device)
        self._t_last: Optional[float] = None

    def observe(self, queue_depth: int) -> Optional[str]:
        """Feed one load sample; maybe scale.

        Args:
            queue_depth: current backlog (queued + ready requests).

        Returns:
            ``"admit"`` or ``"evict"`` when an action was taken this
            call, else None (in cooldown, in bounds, or no signal).
        """
        now = self.clock()
        if self._t_last is not None and now - self._t_last < self.cooldown_s:
            return None
        n = self.cluster.n_slaves
        if queue_depth >= self.scale_up_depth and n < self.max_slaves:
            device = self.cluster.admit(**self.admit_kwargs)
            self.events.append((now, "admit", device))
            self._t_last = now
            return "admit"
        if queue_depth <= self.scale_down_depth and n > self.min_slaves:
            device = self.cluster.slave_ids[-1]  # youngest first
            self.cluster.evict(device)
            self.events.append((now, "evict", device))
            self._t_last = now
            return "evict"
        return None


@dataclasses.dataclass
class _BatchRec:
    """One in-flight slab: its requests + the failure-count watermark.

    ``failures_mark`` is ``len(cluster.failures)`` taken right AFTER
    this slab's own push returned; completion reads the count again
    after the push/flush that drains the slab.  Consecutive slabs'
    windows are therefore disjoint — a loss is attributed to exactly
    one slab, never double-counted."""

    reqs: List[_Request]
    failures_mark: int
    t_formed: float


class ClusterServer:
    """Continuous-batching server: requests in, ``ServeChain`` slabs out.

    Lifecycle: construct -> ``submit()`` any time -> ``start()`` spins
    up the serve loop -> ``stop()`` drains in-flight work and rejects
    what is still queued.  Usable as a context manager.

    Args:
        cluster: the ``HeteroCluster`` to route forward passes through.
        layer_weights: conv kernel per distributed layer.
        between: master-only stage after each layer (``ServeChain``
            semantics; the final between runs before the head).
        head: optional master-only epilogue applied to each completed
            slab, ``head(z) -> out`` with the batch axis preserved —
            per-request outputs are ``out[i]``.  Only finished requests
            see the head; intermediate decode steps feed ``step_fn``.
        step_fn: for multi-step requests, ``step_fn(x, y, step) ->
            next_x`` maps a request's previous input and its chain
            output slice to the next step's input (None = requests must
            be single-step).
        max_batch: slot count — at most this many requests per slab.
        max_queue: admission-control bound (see ``RequestQueue``).
        default_deadline_s: deadline applied when ``submit`` gives none
            (None = no deadline).
        autoscaler: optional ``AutoScaler`` consulted every iteration.
        clock: monotonic-seconds source (injectable for tests).
    """

    def __init__(self, cluster, layer_weights: Sequence[np.ndarray], *,
                 between: Optional[Sequence[Optional[Callable]]] = None,
                 head: Optional[Callable[[np.ndarray], np.ndarray]] = None,
                 step_fn: Optional[Callable] = None,
                 max_batch: int = 8, max_queue: int = 64,
                 default_deadline_s: Optional[float] = None,
                 autoscaler: Optional[AutoScaler] = None,
                 clock: Callable[[], float] = time.monotonic):
        from repro.core.cluster.scheduler import ServeChain

        assert max_batch >= 1
        self.cluster = cluster
        self.head = head
        self.step_fn = step_fn
        self.max_batch = int(max_batch)
        self.default_deadline_s = default_deadline_s
        self.autoscaler = autoscaler
        self._clock = clock
        self._chain = ServeChain(cluster, layer_weights, between)
        self._queue = RequestQueue(max_queue, clock)
        self._ready: List[_Request] = []   # continuing multi-step requests
        self._lock = threading.Lock()
        self._next_id = 0
        self._running = False
        self._thread: Optional[threading.Thread] = None
        self._fatal: Optional[BaseException] = None
        # stats (loop thread writes, stats() reads under the lock)
        self._completed = 0
        self._rejected = 0
        self._expired = 0
        self._scaler_failures = 0
        self._scaler_last_error: Optional[str] = None
        self._latencies: deque = deque(maxlen=512)
        self._t_first_done: Optional[float] = None
        self._t_last_done: Optional[float] = None

    # ---- client side -------------------------------------------------

    def submit(self, x: np.ndarray, *, deadline_s: Optional[float] = None,
               steps: int = 1) -> ServeFuture:
        """Enqueue one request.

        Args:
            x: a single input image ``(H, W, Cin)`` (no batch axis —
                the server packs the batch).
            deadline_s: seconds from now after which the request is
                expired instead of computed (defaults to the server's
                ``default_deadline_s``; None = no deadline).
            steps: decode steps to run; > 1 requires ``step_fn``.

        Returns:
            A ``ServeFuture``; admission-control rejections resolve it
            immediately with status ``"rejected"``.

        Raises:
            ValueError: bad input rank or ``steps`` without a
                ``step_fn``.
        """
        x = np.asarray(x, np.float32)
        if x.ndim != 3:
            raise ValueError(f"expected one (H, W, Cin) image, got shape {x.shape}")
        if steps < 1 or (steps > 1 and self.step_fn is None):
            raise ValueError("steps > 1 requires a step_fn")
        now = self._clock()
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        deadline = None if deadline_s is None else now + deadline_s
        fut = ServeFuture()
        with self._lock:
            rid = self._next_id
            self._next_id += 1
        req = _Request(rid, x, deadline, steps, 0, fut, now)
        if self._fatal is not None or not self._queue.offer(req):
            if self._fatal is not None:
                detail = "server stopped on error"
            elif self._queue.closed:
                detail = "server stopped"
            else:
                detail = f"queue full (max_queue={self._queue.max_depth})"
            with self._lock:
                self._rejected += 1
            fut._resolve(ServeResponse(rid, STATUS_REJECTED, detail=detail))
        return fut

    def stats(self) -> dict:
        """Snapshot of serving counters.

        Returns:
            dict with ``completed/rejected/expired`` counts, queue
            depth, ``p50_ms``/``p99_ms`` over the last completions,
            ``throughput_rps`` across the completion window, and
            ``scaler_failures``/``scaler_last_error`` — autoscaler
            ``observe()`` exceptions the loop absorbed.
        """
        with self._lock:
            lat = np.array(self._latencies, np.float64)
            out = {
                "completed": self._completed,
                "rejected": self._rejected,
                "expired": self._expired,
                "queue_depth": len(self._queue) + len(self._ready),
                "p50_ms": float(np.percentile(lat, 50) * 1e3) if lat.size else None,
                "p99_ms": float(np.percentile(lat, 99) * 1e3) if lat.size else None,
                "scaler_failures": self._scaler_failures,
                "scaler_last_error": self._scaler_last_error,
            }
            span = ((self._t_last_done or 0.0) - (self._t_first_done or 0.0))
            out["throughput_rps"] = (
                self._completed / span if self._completed > 1 and span > 0 else None
            )
            return out

    # ---- lifecycle ---------------------------------------------------

    def start(self) -> "ClusterServer":
        """Start the serve loop thread; idempotent.  Returns self."""
        if self._thread is not None:
            return self
        self._running = True
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="cluster-serve")
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain: finish queued + in-flight requests, then stop the loop.
        Safe to call twice; no-op if never started."""
        self._running = False
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "ClusterServer":
        """Context manager: ``start()`` on entry."""
        return self.start()

    def __exit__(self, *exc) -> None:
        """Context manager: ``stop()`` on exit."""
        self.stop()

    # ---- serve loop --------------------------------------------------

    def _form_batch(self, now: float) -> List[_Request]:
        """Pack up to ``max_batch`` requests: continuing decode-step
        requests first (they already hold pipeline state), then fresh
        prefill requests from the queue — expiring stale entries from
        both sources without computing them.

        A slab is one ``np.stack``, so every request in it must share
        a shape: the oldest candidate's shape wins this slab, and
        differently-shaped candidates wait at the front of the ready
        set for the next slab (shapes alternate, nobody starves)."""
        batch: List[_Request] = []
        still_ready: List[_Request] = []
        for req in self._ready:
            if req.deadline is not None and now >= req.deadline:
                self._expire(req, now)
            elif len(batch) < self.max_batch:
                batch.append(req)
            else:
                still_ready.append(req)
        self._ready = still_ready
        fresh, expired = self._queue.take(self.max_batch - len(batch), now)
        for req in expired:
            self._expire(req, now)
        batch.extend(fresh)
        if batch:
            shape = batch[0].x.shape
            deferred = [r for r in batch if r.x.shape != shape]
            if deferred:
                batch = [r for r in batch if r.x.shape == shape]
                self._ready = deferred + self._ready
        for req in batch:
            if req.t_admitted is None:
                req.t_admitted = now
        return batch

    def _expire(self, req: _Request, now: float) -> None:
        with self._lock:
            self._expired += 1
        req.future._resolve(ServeResponse(
            req.request_id, STATUS_EXPIRED, steps=req.steps_done,
            queued_s=now - req.t_submit, latency_s=now - req.t_submit,
            detail="deadline passed before compute",
        ))

    def _complete(self, rec: _BatchRec, out: np.ndarray,
                  failures_end: int) -> None:
        """Resolve a finished slab: slave losses during its flight
        (``failures_end`` is the failure count snapshotted right after
        the push/flush that drained it) become per-request retry
        counts; finishing requests get the head applied, continuing
        ones step and rejoin the ready set."""
        now = self._clock()
        retries = failures_end - rec.failures_mark
        finishing = [i for i, r in enumerate(rec.reqs) if r.steps_left == 1]
        z = self.head(out) if (self.head is not None and finishing) else out
        for i, req in enumerate(rec.reqs):
            req.retries += retries
            req.steps_done += 1
            req.steps_left -= 1
            if req.steps_left > 0:
                req.x = np.asarray(
                    self.step_fn(req.x, out[i], req.steps_done), np.float32
                )
                self._ready.append(req)
                continue
            with self._lock:
                self._completed += 1
                self._latencies.append(now - req.t_submit)
                if self._t_first_done is None:
                    self._t_first_done = now
                self._t_last_done = now
            req.future._resolve(ServeResponse(
                req.request_id, STATUS_OK, output=np.asarray(z[i]),
                retries=req.retries, steps=req.steps_done,
                queued_s=(req.t_admitted or now) - req.t_submit,
                latency_s=now - req.t_submit,
            ))

    def _fail(self, recs: Sequence[_BatchRec], err: BaseException) -> None:
        """Unrecoverable pipeline failure: resolve every affected
        request with ``"error"`` and poison the server."""
        self._fatal = err
        for rec in recs:
            for req in rec.reqs:
                if not req.future.done():
                    req.future._resolve(ServeResponse(
                        req.request_id, STATUS_ERROR, steps=req.steps_done,
                        detail=f"{type(err).__name__}: {err}",
                    ))

    def _reject_leftovers(self) -> None:
        """Close the queue (late submits now bounce atomically) and
        reject everything still unserved."""
        for req in self._queue.close() + self._ready:
            if not req.future.done():
                with self._lock:
                    self._rejected += 1
                req.future._resolve(ServeResponse(
                    req.request_id, STATUS_REJECTED, steps=req.steps_done,
                    detail="server stopped",
                ))
        self._ready = []

    def _loop(self) -> None:
        # slabs whose futures may still be unresolved, oldest first;
        # the catch-all below fails them on ANY escape (SlaveError,
        # a user head/step_fn raising in _complete, ...) so no future
        # is ever stranded by the loop thread dying
        inflight: List[_BatchRec] = []
        try:
            while True:
                now = self._clock()
                if self.autoscaler is not None:
                    try:
                        self.autoscaler.observe(
                            len(self._queue) + len(self._ready))
                    except Exception as e:
                        # a failed admit() must not take the loop down,
                        # but it must not vanish either: surface it in
                        # stats() so operators see a scaler that can't
                        # scale
                        with self._lock:
                            self._scaler_failures += 1
                            self._scaler_last_error = repr(e)
                batch = self._form_batch(now)
                if batch:
                    rec = _BatchRec(batch, 0, now)
                    inflight.append(rec)
                    x = np.stack([r.x for r in batch], axis=0)
                    prev_out = self._chain.push(x)
                    # the slab's retry window opens here, after its own
                    # push: the previous slab owns everything earlier
                    rec.failures_mark = len(self.cluster.failures)
                    if prev_out is not None:
                        self._complete(inflight[0], prev_out,
                                       rec.failures_mark)
                        inflight.pop(0)
                elif inflight:
                    # nothing waiting: drain the in-flight slab rather
                    # than hold its latency hostage to the next arrival
                    out = self._chain.flush()
                    mark = len(self.cluster.failures)
                    self._complete(inflight[0], out, mark)
                    inflight.pop(0)
                elif not self._running:
                    break
                else:
                    self._queue.wait_nonempty(0.005)
        except BaseException as err:
            self._fail(inflight, err)
        finally:
            self._reject_leftovers()
