"""Batched serving engine: prefill + step-wise decode over a KV/SSM cache.

``make_serve_step`` builds the single-token decode function that
launch/dryrun.py lowers for the decode input shapes (decode_32k,
long_500k): ONE new token against a ``seq_len``-sized context, where the
cache is full-length for dense archs, a window ring-buffer for SWA archs,
and O(1) recurrent state for SSM/hybrid archs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.registry import ModelApi, rules_for_mode


def make_serve_step(api: ModelApi, run: RunConfig, *, mesh=None,
                    sample: bool = False, temperature: float = 1.0):
    """decode_step(params, cache, tokens (B,1)[, key]) ->
    (next_tokens (B,), logits (B,V), new_cache)."""
    rules = rules_for_mode(run.tp_mode)

    def serve_step(params, cache, tokens, key=None):
        logits, new_cache = api.decode_step(
            params, cache, tokens, rules=rules, mesh=mesh
        )
        if sample:
            assert key is not None
            nxt = jax.random.categorical(key, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), logits, new_cache

    return serve_step


def make_prefill_step(api: ModelApi, run: RunConfig, *, mesh=None,
                      cache_len: Optional[int] = None):
    """prefill(params, batch) -> (last-token logits, cache)."""
    rules = rules_for_mode(run.tp_mode)

    def prefill(params, batch):
        return api.prefill(
            params, batch, rules=rules, mesh=mesh, remat="none",
            cache_len=cache_len,
        )

    return prefill


@dataclasses.dataclass
class ServeEngine:
    """Eager convenience wrapper around prefill + decode: batched
    ``generate``.  Exercised by ``examples/serve_batched.py`` (the
    three cache regimes), ``launch/serve.py`` (the CLI), and
    ``tests/test_serve.py``; the cluster-backed request server is
    separate — ``serve/server.py``, demoed by
    ``examples/serve_cluster.py``."""

    api: ModelApi
    run: RunConfig
    params: Any
    mesh: Any = None

    def generate(
        self,
        batch: Dict[str, jax.Array],
        *,
        max_new_tokens: int,
        cache_len: Optional[int] = None,
        sample: bool = False,
        temperature: float = 1.0,
        seed: int = 0,
        eos_id: Optional[int] = None,
    ) -> jax.Array:
        """Prefill the prompt batch then decode greedily/sampled.
        Returns generated tokens (B, max_new_tokens)."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache_len = cache_len or (s + max_new_tokens)
        prefill = jax.jit(make_prefill_step(self.api, self.run, mesh=self.mesh,
                                            cache_len=cache_len))
        step = jax.jit(make_serve_step(self.api, self.run, mesh=self.mesh,
                                       sample=sample, temperature=temperature))
        logits, cache = prefill(self.params, batch)
        if sample:
            key = jax.random.key(seed)
            key, k0 = jax.random.split(key)
            nxt = jax.random.categorical(k0, logits / temperature, axis=-1).astype(jnp.int32)
        else:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [nxt]
        done = jnp.zeros((b,), bool) if eos_id is not None else None
        for i in range(max_new_tokens - 1):
            if sample:
                key, ki = jax.random.split(key)
                nxt, _, cache = step(self.params, cache, nxt[:, None], ki)
            else:
                nxt, _, cache = step(self.params, cache, nxt[:, None])
            if eos_id is not None:
                done = done | (out[-1] == eos_id)
                nxt = jnp.where(done, eos_id, nxt)
            out.append(nxt)
        return jnp.stack(out, axis=1)
