"""Token embedding, logits head, and rotary position embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_embedding(key, vocab_size: int, d_model: int, dtype=jnp.float32):
    table = jax.random.normal(key, (vocab_size, d_model), jnp.float32).astype(dtype)
    return {"table": table}


def embedding_axes():
    return {"table": ("vocab", "fsdp_embed")}


def embed_tokens(params, tokens: jax.Array, dtype) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def logits_from_embedding(params, x: jax.Array, dtype) -> jax.Array:
    """Tied read-out: x @ table.T"""
    table = params["table"].astype(dtype)
    return jnp.einsum("...d,vd->...v", x.astype(dtype), table)


# ---------------------------------------------------------------------------
# RoPE


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim//2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
