"""Multi-head attention: GQA, RoPE, sliding-window, cross-attention, KV-cache
decode, and a blockwise (online-softmax / flash-style) pure-jnp path used for
long sequences so the score matrix is never materialised.

The Pallas flash kernel in ``repro.kernels.flash_attn`` implements the same
contract for TPU; this module is the lowering-safe default (the dry-run mesh
is CPU-hosted, where Pallas kernels only run in interpret mode).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.embedding import apply_rope
from repro.layers.linear import apply_dense, dense_axes, init_dense
from repro.sharding.axes import AxisRules
from repro.sharding.partitioning import constrain

NEG_INF = -1e30
BLOCKWISE_THRESHOLD = 2048  # full-seq attention switches to blockwise above this
DEFAULT_BLOCK_K = 1024


def init_attention(key, cfg: ModelConfig, dtype, *, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], (d,), (h, hd), dtype),
        "wk": init_dense(ks[1], (d,), (kv, hd), dtype),
        "wv": init_dense(ks[2], (d,), (kv, hd), dtype),
        "wo": init_dense(ks[3], (h, hd), (d,), dtype, scale=1.0),
    }


def attention_axes(cfg: ModelConfig):
    return {
        "wq": dense_axes(("fsdp_embed",), ("heads", "head_dim")),
        "wk": dense_axes(("fsdp_embed",), ("kv_heads", "head_dim")),
        "wv": dense_axes(("fsdp_embed",), ("kv_heads", "head_dim")),
        "wo": dense_axes(("heads_in", "head_dim"), ("fsdp_embed",)),
    }


# ---------------------------------------------------------------------------
# score-level attention primitives


def _split_gqa(q: jax.Array, num_kv: int) -> jax.Array:
    """(B, S, H, D) -> (B, S, KV, G, D)"""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
) -> jax.Array:
    """Reference attention. q: (B,S,H,D); k,v: (B,T,KV,D); positions (B,S)/(B,T).
    kv slots with position < 0 are invalid (empty cache slots)."""
    num_kv = k.shape[2]
    qg = _split_gqa(q, num_kv)  # (B,S,KV,G,D)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    mask = kv_pos[:, None, :] >= 0  # (B,1,T) valid slots
    if causal:
        mask = mask & (q_pos[:, :, None] >= kv_pos[:, None, :])
    if window is not None:
        mask = mask & (q_pos[:, :, None] - kv_pos[:, None, :] < window)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    b, s = q.shape[:2]
    return out.reshape(b, s, q.shape[2], q.shape[3]).astype(q.dtype)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Online-softmax attention, scanning over KV blocks; the (S, T) score
    matrix is never materialised (flash-attention recurrence in pure jnp)."""
    b, s, h, d = q.shape
    t, num_kv = k.shape[1], k.shape[2]
    g = h // num_kv
    pad = (-t) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    nblocks = k.shape[1] // block_k
    qg = _split_gqa(q, num_kv).astype(jnp.float32)  # (B,S,KV,G,D)
    scale = d ** -0.5

    kb = k.reshape(b, nblocks, block_k, num_kv, d)
    vb = v.reshape(b, nblocks, block_k, num_kv, d)
    pb = kv_pos.reshape(b, nblocks, block_k)

    def body(carry, xs):
        m, l, acc = carry
        k_blk, v_blk, p_blk = xs  # (B,bk,KV,D), (B,bk,KV,D), (B,bk)
        scores = jnp.einsum(
            "bskgd,btkd->bkgst", qg, k_blk.astype(jnp.float32)
        ) * scale  # (B,KV,G,S,bk)
        mask = p_blk[:, None, :] >= 0
        if causal:
            mask = mask & (q_pos[:, :, None] >= p_blk[:, None, :])
        if window is not None:
            mask = mask & (q_pos[:, :, None] - p_blk[:, None, :] < window)
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
        m_blk = jnp.max(scores, axis=-1)  # (B,KV,G,S)
        m_new = jnp.maximum(m, m_blk)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])  # (B,KV,G,S,bk)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, v_blk.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, num_kv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, num_kv, g, s), jnp.float32)
    acc0 = jnp.zeros((b, num_kv, g, s, d), jnp.float32)
    # checkpoint each KV block: the backward pass recomputes one block's
    # scores at a time instead of saving every (S x block_k) f32 score
    # tensor stacked over blocks (36 GiB/device for minicpm train_4k —
    # see EXPERIMENTS.md SS Perf iteration A1)
    body = jax.checkpoint(body, prevent_cse=False)
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, acc0),
        (
            jnp.moveaxis(kb, 1, 0),
            jnp.moveaxis(vb, 1, 0),
            jnp.moveaxis(pb, 1, 0),
        ),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)  # (B,KV,G,S,D)
    out = jnp.moveaxis(out, 3, 1).reshape(b, s, h, d)
    return out.astype(q.dtype)


def attend(
    q, k, v, q_pos, kv_pos, *, causal: bool, window: Optional[int]
) -> jax.Array:
    if k.shape[1] > BLOCKWISE_THRESHOLD:
        return blockwise_attention(
            q, k, v, q_pos, kv_pos, causal=causal, window=window
        )
    return naive_attention(q, k, v, q_pos, kv_pos, causal=causal, window=window)


# ---------------------------------------------------------------------------
# full attention layer


def apply_attention(
    params,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    rules: AxisRules,
    positions: jax.Array,
    causal: bool = True,
    use_rope: bool = True,
    kv_x: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence (train / prefill) attention. ``kv_x`` switches to
    cross-attention (no causality, no rope on kv side positions)."""
    dtype = cfg.compute_dtype
    q = apply_dense(params["wq"], x, dtype=dtype)  # (B,S,H,hd)
    src = x if kv_x is None else kv_x
    k = apply_dense(params["wk"], src, dtype=dtype)
    v = apply_dense(params["wv"], src, dtype=dtype)
    # two-step layout pin: sharded right after the column matmul (the
    # distributed "convolution"), then the mode-dependent layout (gather
    # mode forces the paper's all-gather here; megatron keeps it sharded).
    q = constrain(q, rules, "batch", None, "act_heads_col", None)
    k = constrain(k, rules, "batch", None, "act_heads_col", None)
    v = constrain(v, rules, "batch", None, "act_heads_col", None)
    q = constrain(q, rules, "batch", None, "act_heads", None)
    k = constrain(k, rules, "batch", None, "act_heads", None)
    v = constrain(v, rules, "batch", None, "act_heads", None)
    if kv_x is None:
        kv_pos = positions
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        window = cfg.sliding_window
    else:
        assert kv_positions is not None
        kv_pos = kv_positions
        causal = False
        window = None
    out = attend(q, k, v, positions, kv_pos, causal=causal, window=window)
    out = constrain(out, rules, "batch", None, "act_heads", None)
    y = apply_dense(params["wo"], out, n_in_dims=2, dtype=dtype)
    return constrain(y, rules, "batch", "act_seq", "act_embed")


def compute_kv(params, kv_x: jax.Array, dtype) -> tuple:
    """Precompute cross-attention K/V (whisper decode caches these)."""
    k = apply_dense(params["wk"], kv_x, dtype=dtype)
    v = apply_dense(params["wv"], kv_x, dtype=dtype)
    return k, v


def decode_attention(
    params,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    rules: AxisRules,
    cache_k: jax.Array,
    cache_v: jax.Array,
    cache_pos: jax.Array,
    index: jax.Array,
    position: jax.Array,
    use_rope: bool = True,
):
    """One-token decode against a KV cache.

    cache_k/v: (B, L, KV, hd) — L is full seq_len or the sliding window
    (ring buffer).  cache_pos: (B, L) the absolute position stored in each
    slot (-1 = empty).  index: scalar slot to write (already wrapped for
    ring buffers).  position: scalar absolute position of the new token.

    Returns (out (B,1,D), new_k, new_v, new_pos).
    """
    dtype = cfg.compute_dtype
    b = x.shape[0]
    q = apply_dense(params["wq"], x, dtype=dtype)  # (B,1,H,hd)
    k = apply_dense(params["wk"], x, dtype=dtype)  # (B,1,KV,hd)
    v = apply_dense(params["wv"], x, dtype=dtype)
    pos_arr = jnp.full((b, 1), position, dtype=jnp.int32)
    if use_rope:
        q = apply_rope(q, pos_arr, cfg.rope_theta)
        k = apply_rope(k, pos_arr, cfg.rope_theta)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), index, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), index, axis=1)
    new_pos = jax.lax.dynamic_update_slice_in_dim(
        cache_pos, pos_arr, index, axis=1
    )
    new_k = constrain(new_k, rules, "batch", None, "act_heads", None)
    new_v = constrain(new_v, rules, "batch", None, "act_heads", None)
    out = attend(
        q,
        new_k.astype(dtype),
        new_v.astype(dtype),
        pos_arr,
        new_pos,
        causal=True,
        window=cfg.sliding_window,
    )
    y = apply_dense(params["wo"], out, n_in_dims=2, dtype=dtype)
    return y, new_k, new_v, new_pos


def cross_decode_attention(
    params,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    rules: AxisRules,
    k: jax.Array,
    v: jax.Array,
    kv_positions: jax.Array,
):
    """Cross-attention during decode: fixed precomputed encoder K/V."""
    dtype = cfg.compute_dtype
    b = x.shape[0]
    q = apply_dense(params["wq"], x, dtype=dtype)
    pos_arr = jnp.zeros((b, 1), dtype=jnp.int32)
    out = naive_attention(
        q, k.astype(dtype), v.astype(dtype), pos_arr, kv_positions,
        causal=False, window=None,
    )
    return apply_dense(params["wo"], out, n_in_dims=2, dtype=dtype)
