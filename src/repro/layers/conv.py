"""Convolution + pooling layers for the paper's CIFAR-10 CNN.

NHWC activations, HWIO kernels.  The output-channel axis (``conv_out``)
is the paper's "kernel" axis — the one sharded across devices by the
distribution technique (core/conv_shard.py) and tiled across the MXU by
the Pallas kernel (kernels/conv2d.py).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def init_conv(key, kh: int, kw: int, c_in: int, c_out: int, dtype=jnp.float32):
    fan_in = kh * kw * c_in
    w = jax.random.normal(key, (kh, kw, c_in, c_out), jnp.float32) / math.sqrt(fan_in)
    return {"kernel": w.astype(dtype), "bias": jnp.zeros((c_out,), dtype)}


def conv_axes():
    return {"kernel": (None, None, "conv_in", "conv_out"), "bias": ("conv_out",)}


def apply_conv(params, x: jax.Array, *, padding: str = "SAME") -> jax.Array:
    """x: (B, H, W, Cin) -> (B, H', W', Cout)."""
    y = jax.lax.conv_general_dilated(
        x,
        params["kernel"].astype(x.dtype),
        window_strides=(1, 1),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + params["bias"].astype(y.dtype)


def max_pool(x: jax.Array, window: int = 2, stride: int = 2) -> jax.Array:
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )


def avg_pool(x: jax.Array, window: int = 2, stride: int = 2) -> jax.Array:
    s = jax.lax.reduce_window(
        x,
        0.0,
        jax.lax.add,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )
    return s / (window * window)
