"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) layer.

Chunked SSD algorithm: within-chunk quadratic attention-like term +
cross-chunk recurrence over a per-head (head_dim x d_state) state, scanned
with ``lax.scan``.  The paper's technique maps onto the SSD *head* axis:
heads are the output-feature groups sharded over the ``model`` mesh axis
(the conv-kernel analogue); the recurrent state is head-local, so the
sequential scan crosses no device boundary — zero collectives inside the
scan (noted in DESIGN.md §Arch-applicability).

Decode keeps O(1) state per token: (conv_state, ssm_state) — this is what
makes ``long_500k`` native for SSM/hybrid archs.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.sharding.axes import AxisRules
from repro.sharding.partitioning import constrain


def _dims(cfg: ModelConfig):
    ssm = cfg.ssm
    d_in = ssm.d_inner(cfg.d_model)
    nh = ssm.n_heads(cfg.d_model)
    return ssm, d_in, nh, ssm.head_dim, ssm.d_state, ssm.n_groups


def init_mamba2(key, cfg: ModelConfig, dtype):
    ssm, d_in, nh, hd, n, g = _dims(cfg)
    d = cfg.d_model
    conv_ch = d_in + 2 * g * n
    ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d)
    proj_out = 2 * d_in + 2 * g * n + nh  # z, x, B, C, dt
    return {
        "in_proj": {
            "kernel": (jax.random.normal(ks[0], (d, proj_out), jnp.float32) * std).astype(dtype)
        },
        "conv_w": (jax.random.normal(ks[1], (ssm.d_conv, conv_ch), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ),  # A = -exp(a_log), mamba2 init A in [1,16]
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": {
            "kernel": (jax.random.normal(ks[2], (d_in, d), jnp.float32) * std / math.sqrt(2 * cfg.num_layers)).astype(dtype)
        },
    }


def mamba2_axes():
    return {
        "in_proj": {"kernel": ("fsdp_embed", "ssm_inner")},
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "dt_bias": ("ssm_heads",),
        "a_log": ("ssm_heads",),
        "d_skip": ("ssm_heads",),
        "norm_scale": ("ssm_inner",),
        "out_proj": {"kernel": ("ssm_inner", "fsdp_embed")},
    }


def _split_proj(zxbcdt: jax.Array, cfg: ModelConfig):
    ssm, d_in, nh, hd, n, g = _dims(cfg)
    z, x, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + g * n, 2 * d_in + 2 * g * n], axis=-1
    )
    return z, x, bmat, cmat, dt


def _depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Causal depthwise conv over time.  x: (B,S,C), w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :]


def _ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) — already softplus'd
    a: jax.Array,  # (H,) negative
    bmat: jax.Array,  # (B, S, G, N)
    cmat: jax.Array,  # (B, S, G, N)
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // chunk
    # heads per group
    hg = h // g

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = bmat.reshape(bsz, nc, chunk, g, n)
    cc = cmat.reshape(bsz, nc, chunk, g, n)

    da = dtc * a[None, None, None, :]  # (B,nc,L,H) log-decay per step, negative
    cum = jnp.cumsum(da, axis=2)  # inclusive cumsum within chunk
    total = cum[:, :, -1, :]  # (B,nc,H) full-chunk decay (log)

    # intra-chunk: y[t] = sum_{u<=t} C_t . B_u * exp(cum_t - cum_u) * dt_u * x_u
    def to_heads(m):  # (B,nc,L,G,N) -> (B,nc,L,H,N)
        return jnp.repeat(m, hg, axis=3)

    bh = to_heads(bc)
    ch = to_heads(cc)
    scores = jnp.einsum("bclhn,bcuhn->bchlu", ch, bh)  # (B,nc,H,L,L)
    decay = cum[:, :, :, None, :].transpose(0, 1, 4, 2, 3) - cum[
        :, :, :, None, :
    ].transpose(0, 1, 4, 3, 2)  # cum_t - cum_u, (B,nc,H,L,L)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask INSIDE the exp: exp of the masked (positive, large) entries
    # would produce inf gradients through the where (NaN-grad trap)
    m = jnp.exp(jnp.where(causal[None, None, None], decay, -1e30))
    xdt = xc * dtc[..., None]  # (B,nc,L,H,P) — dt-weighted input
    y_intra = jnp.einsum("bchlu,bcuhp->bclhp", scores * m, xdt)

    # chunk states: S_c = sum_u exp(total - cum_u) B_u (dt_u x_u)
    suffix = jnp.exp(total[:, :, None, :] - cum)  # (B,nc,L,H)
    state_c = jnp.einsum("bclhn,bclh,bclhp->bchpn", bh, suffix, xdt)

    # inter-chunk recurrence over chunks
    s0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), x.dtype)
    )

    def step(carry, inp):
        st = carry  # (B,H,P,N)
        tot_c, new_state = inp  # (B,H), (B,H,P,N)
        out_state = st  # state entering this chunk
        st = st * jnp.exp(tot_c)[:, :, None, None] + new_state
        return st, out_state

    final, prev_states = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(total, 1, 0), jnp.moveaxis(state_c, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,nc,H,P,N)

    # inter contribution: y_t += C_t . prev_state * exp(cum_t)
    y_inter = jnp.einsum(
        "bclhn,bchpn,bclh->bclhp", ch, prev_states, jnp.exp(cum)
    )
    y = (y_intra + y_inter).reshape(bsz, nc * chunk, h, p)
    if pad:
        y = y[:, :s]
    return y, final


def apply_mamba2(
    params,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    rules: AxisRules,
) -> jax.Array:
    """Full-sequence mamba2 block body (pre-norm residual handled by caller)."""
    ssm, d_in, nh, hd, n, g = _dims(cfg)
    dtype = cfg.compute_dtype
    bsz, s, _ = x.shape
    zxbcdt = (x.astype(dtype) @ params["in_proj"]["kernel"].astype(dtype))
    z, xi, bmat, cmat, dt = _split_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([xi, bmat, cmat], axis=-1)
    conv_out = jax.nn.silu(
        _depthwise_conv(conv_in, params["conv_w"].astype(dtype), params["conv_b"].astype(dtype))
    )
    xi, bmat, cmat = jnp.split(conv_out, [d_in, d_in + g * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None, :])
    a = -jnp.exp(params["a_log"])  # (H,)

    xh = xi.reshape(bsz, s, nh, hd).astype(jnp.float32)
    xh = constrain(xh, rules, "batch", None, "ssm_heads", None)
    bg = bmat.reshape(bsz, s, g, n).astype(jnp.float32)
    cg = cmat.reshape(bsz, s, g, n).astype(jnp.float32)

    y, _ = _ssd_chunked(xh, dt, a, bg, cg, ssm.chunk_size)
    y = y + xh * params["d_skip"][None, None, :, None]
    y = constrain(y, rules, "batch", None, "ssm_heads", None)
    y = y.reshape(bsz, s, d_in).astype(dtype)

    # gated RMSNorm (mamba2 normalises the gated output)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(dtype)
    y = y * params["norm_scale"].astype(dtype)[None, None, :]

    return y @ params["out_proj"]["kernel"].astype(dtype)


# ---------------------------------------------------------------------------
# decode: O(1) state per step


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype):
    ssm, d_in, nh, hd, n, g = _dims(cfg)
    conv_ch = d_in + 2 * g * n
    return {
        "conv": jnp.zeros((batch, ssm.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, nh, hd, n), jnp.float32),
    }


def mamba2_state_axes():
    return {"conv": ("batch", None, "ssm_inner"), "ssm": ("batch", "ssm_heads", None, None)}


def decode_mamba2(
    params,
    x: jax.Array,  # (B, 1, d)
    state,
    *,
    cfg: ModelConfig,
    rules: AxisRules,
):
    """Single-token recurrent step.  Returns (y (B,1,d), new_state)."""
    ssm, d_in, nh, hd, n, g = _dims(cfg)
    dtype = cfg.compute_dtype
    bsz = x.shape[0]
    zxbcdt = x[:, 0].astype(dtype) @ params["in_proj"]["kernel"].astype(dtype)
    z, xi, bmat, cmat, dt = _split_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([xi, bmat, cmat], axis=-1)  # (B, C)
    window = jnp.concatenate([state["conv"], conv_in[:, None, :]], axis=1)  # (B,K,C)
    w = params["conv_w"].astype(dtype)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"].astype(dtype)
    )
    new_conv = window[:, 1:, :]
    xi, bmat, cmat = jnp.split(conv_out, [d_in, d_in + g * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, :])  # (B,H)
    a = -jnp.exp(params["a_log"])
    xh = xi.reshape(bsz, nh, hd).astype(jnp.float32)
    bg = jnp.repeat(bmat.reshape(bsz, g, n), nh // g, axis=1).astype(jnp.float32)
    cg = jnp.repeat(cmat.reshape(bsz, g, n), nh // g, axis=1).astype(jnp.float32)

    decay = jnp.exp(dt * a[None, :])  # (B,H)
    new_ssm = state["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xh, bg, dt
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, cg)
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(bsz, d_in).astype(dtype)

    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-5)).astype(dtype)
    y = y * params["norm_scale"].astype(dtype)[None, :]
    out = (y @ params["out_proj"]["kernel"].astype(dtype))[:, None, :]
    return out, {"conv": new_conv, "ssm": new_ssm}
