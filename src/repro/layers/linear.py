"""DenseGeneral: multi-dimensional linear layers with logical-axis metadata."""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def _as_tuple(x) -> Tuple[int, ...]:
    return (x,) if isinstance(x, int) else tuple(x)


def init_dense(
    key: jax.Array,
    in_dims,
    out_dims,
    dtype=jnp.float32,
    *,
    scale: float = 1.0,
    use_bias: bool = False,
):
    """Variance-scaling (fan-in) init, kernel shape = in_dims + out_dims."""
    in_dims, out_dims = _as_tuple(in_dims), _as_tuple(out_dims)
    fan_in = math.prod(in_dims)
    std = scale / math.sqrt(fan_in)
    kernel = (jax.random.normal(key, in_dims + out_dims, jnp.float32) * std).astype(dtype)
    params = {"kernel": kernel}
    if use_bias:
        params["bias"] = jnp.zeros(out_dims, dtype=dtype)
    return params


def dense_axes(in_axes: Sequence[Optional[str]], out_axes: Sequence[Optional[str]], use_bias=False):
    ax = {"kernel": tuple(in_axes) + tuple(out_axes)}
    if use_bias:
        ax["bias"] = tuple(out_axes)
    return ax


def apply_dense(params, x: jax.Array, *, n_in_dims: int = 1, dtype=None) -> jax.Array:
    """Contract the last ``n_in_dims`` dims of x with the kernel's leading dims."""
    kernel = params["kernel"]
    if dtype is None:
        dtype = x.dtype
    kernel = kernel.astype(dtype)
    x = x.astype(dtype)
    contracting = (
        tuple(range(x.ndim - n_in_dims, x.ndim)),
        tuple(range(n_in_dims)),
    )
    y = jax.lax.dot_general(x, kernel, dimension_numbers=(contracting, ((), ())))
    if "bias" in params:
        y = y + params["bias"].astype(dtype)
    return y
