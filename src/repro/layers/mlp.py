"""Feed-forward layers.

This is the transformer generalisation of the paper's scheme: the FFN
weight matrix is the "kernel set" of the compute-dominant layer, sharded
along its *output-feature* axis (``mlp``), exactly like the conv kernels
are sharded along the output-channel axis (``conv_out``).

Two activation-return modes exist, selected by the axis rules:
* gather  (paper-faithful) — the second matmul's output is immediately
  all-gathered back to a replicated residual stream (the "master collects
  every feature map" step of Algorithm 1);
* megatron (beyond-paper) — column-parallel w_in, row-parallel w_out, one
  reduce-scatter/all-reduce instead of gathers.

Both are expressed purely via sharding constraints: XLA GSPMD inserts the
collectives, we only pin the layouts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.layers.linear import apply_dense, dense_axes, init_dense
from repro.sharding.axes import AxisRules
from repro.sharding.partitioning import constrain


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu":
        return jax.nn.relu
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def init_mlp(key, d_model: int, d_ff: int, dtype, *, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {
        "w_in": init_dense(ks[0], (d_model,), (d_ff,), dtype),
        "w_out": init_dense(ks[1], (d_ff,), (d_model,), dtype, scale=1.0),
    }
    if gated:
        p["w_gate"] = init_dense(ks[2], (d_model,), (d_ff,), dtype)
    return p


def mlp_axes(*, gated: bool = True):
    ax = {
        "w_in": dense_axes(("fsdp_embed",), ("mlp",)),
        "w_out": dense_axes(("mlp_in",), ("fsdp_embed",)),
    }
    if gated:
        ax["w_gate"] = dense_axes(("fsdp_embed",), ("mlp",))
    return ax


def apply_mlp(params, x: jax.Array, *, cfg: ModelConfig, rules: AxisRules) -> jax.Array:
    dtype = cfg.compute_dtype
    act = activation_fn(cfg.activation)
    h = apply_dense(params["w_in"], x, dtype=dtype)
    if "w_gate" in params:
        g = apply_dense(params["w_gate"], x, dtype=dtype)
        h = act(g) * h
    else:
        h = act(h)
    # two-step layout pin: column-parallel output, then the mode-dependent
    # layout (gather mode all-gathers here -- the paper's Alg.1 gather).
    h = constrain(h, rules, "batch", None, "act_mlp_col")
    h = constrain(h, rules, "batch", None, "act_mlp")
    y = apply_dense(params["w_out"], h, dtype=dtype)
    return constrain(y, rules, "batch", "act_seq", "act_embed")
