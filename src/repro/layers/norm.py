"""Normalisation layers: RMSNorm, LayerNorm, and the paper CNN's local
response normalisation (cuda-convnet style, as used for CIFAR-10)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_axes():
    return {"scale": ("embed_norm",)}


def apply_rmsnorm(params, x, eps: float = 1e-5):
    in_dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(in_dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm_axes():
    return {"scale": ("embed_norm",), "bias": ("embed_norm",)}


def apply_layernorm(params, x, eps: float = 1e-5):
    in_dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(in_dtype)


def init_norm(kind: str, d: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return init_rmsnorm(d, dtype)
    if kind == "layernorm":
        return init_layernorm(d, dtype)
    raise ValueError(f"unknown norm {kind!r}")


def norm_axes(kind: str):
    return rmsnorm_axes() if kind == "rmsnorm" else layernorm_axes()


def apply_norm(kind: str, params, x, eps: float = 1e-5):
    if kind == "rmsnorm":
        return apply_rmsnorm(params, x, eps)
    return apply_layernorm(params, x, eps)


def local_response_norm(
    x: jax.Array, *, size: int = 5, alpha: float = 1e-4, beta: float = 0.75, k: float = 2.0
) -> jax.Array:
    """Cross-channel LRN over NHWC feature maps (the paper's CNN
    "normalisation layer", cuda-convnet / AlexNet style).

    Channel-local within a +-size/2 window, so it stays valid on
    channel-sharded feature maps as long as the halo is gathered; the
    sharded CNN path uses per-shard LRN (see core/conv_shard.py notes).
    """
    sq = jnp.square(x.astype(jnp.float32))
    c = x.shape[-1]
    half = size // 2
    padded = jnp.pad(sq, [(0, 0)] * (x.ndim - 1) + [(half, half)])
    # windowed sum over the channel axis
    window = sum(
        jax.lax.dynamic_slice_in_dim(padded, i, c, axis=x.ndim - 1)
        for i in range(size)
    )
    denom = jnp.power(k + alpha * window, beta)
    return (x.astype(jnp.float32) / denom).astype(x.dtype)
