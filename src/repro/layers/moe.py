"""Mixture-of-Experts layer with expert-parallel sharding.

Mapping of the paper's scheme onto MoE: the experts are the "kernel sets"
of the compute-dominant layer.  Tokens stay sharded on the batch axes
(``pod``/``data``) — the paper keeps the batch local to the master — and
are *replicated* across the ``model`` axis ("all slaves receive the same
inputs").  Each model rank owns a contiguous slice of experts ("different
kernels"), gathers the tokens routed to its experts (capacity-bounded,
GShard-style), runs the expert FFNs, scatter-adds its contribution, and a
``psum`` over ``model`` plays the role of the master gathering the feature
maps (Algorithm 1 line 19-22).

When the expert count does not divide the model axis (mixtral: 8 experts
on a 16-way axis) the same code path shards each expert's *d_ff* instead
(per-expert tensor parallelism); the psum-combine is unchanged.

Dispatch is sort-based (argsort by expert id + rank-within-expert), never
materialising a (tokens, experts, capacity) one-hot.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig, MoEConfig
from repro.layers.linear import init_dense
from repro.layers.mlp import activation_fn


def init_moe(key, d_model: int, moe: MoEConfig, dtype):
    e, ff = moe.num_experts, moe.expert_d_ff
    ks = jax.random.split(key, 4)
    import math

    std = 1.0 / math.sqrt(d_model)
    return {
        "router": init_dense(ks[0], (d_model,), (e,), jnp.float32),
        "w_in": (jax.random.normal(ks[1], (e, d_model, ff), jnp.float32) * std).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (e, d_model, ff), jnp.float32) * std).astype(dtype),
        "w_out": (jax.random.normal(ks[3], (e, ff, d_model), jnp.float32) * std).astype(dtype),
    }


def moe_axes():
    return {
        "router": {"kernel": ("fsdp_embed", None)},  # router always replicated on model
        "w_in": ("experts", "fsdp_embed", "expert_mlp"),
        "w_gate": ("experts", "fsdp_embed", "expert_mlp"),
        "w_out": ("experts", "expert_mlp", "fsdp_embed"),
    }


def _capacity(num_tokens: int, moe: MoEConfig) -> int:
    cap = int(num_tokens * moe.experts_per_token * moe.capacity_factor / moe.num_experts)
    return max(moe.experts_per_token, min(cap, num_tokens))


def _dispatch_tables(
    top_idx: jax.Array, top_gate: jax.Array, num_experts: int, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sort-based GShard dispatch.

    top_idx/top_gate: (T, k) expert assignment per token.
    Returns (token_table (E, C) int32 — index into [0, T] with T = sentinel,
             gate_table (E, C) f32, aux stats (fraction per expert (E,))).
    """
    t, k = top_idx.shape
    a = t * k
    flat_e = top_idx.reshape(a)
    flat_gate = top_gate.reshape(a)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=num_experts)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(a, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    rank = jnp.zeros(a, jnp.int32).at[order].set(rank_sorted)

    valid = rank < capacity
    slot = jnp.where(valid, flat_e * capacity + rank, num_experts * capacity)
    token_table = (
        jnp.full(num_experts * capacity + 1, t, jnp.int32).at[slot].set(flat_tok)
    )[:-1].reshape(num_experts, capacity)
    gate_table = (
        jnp.zeros(num_experts * capacity + 1, jnp.float32).at[slot].set(flat_gate)
    )[:-1].reshape(num_experts, capacity)
    return token_table, gate_table, counts.astype(jnp.float32) / a


def _expert_ffn(xs: jax.Array, w_in, w_gate, w_out, activation: str) -> jax.Array:
    """xs: (E_loc, C, d); weights (E_loc, d, ff_loc)/(E_loc, ff_loc, d)."""
    act = activation_fn(activation)
    h = jnp.einsum("ecd,edf->ecf", xs, w_in)
    g = jnp.einsum("ecd,edf->ecf", xs, w_gate)
    h = act(g) * h
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def _moe_local(
    x_flat: jax.Array,
    params,
    *,
    moe: MoEConfig,
    activation: str,
    dtype,
    expert_shards: int,
    expert_rank,
) -> Tuple[jax.Array, jax.Array]:
    """Per-shard MoE body.  x_flat: (T_loc, d).  params' expert weights are
    the *local* slice (E_loc on the expert axis when experts are sharded,
    otherwise ff_loc on the hidden axis).  Returns (out (T_loc, d), aux)."""
    t, d = x_flat.shape
    e = moe.num_experts
    k = moe.experts_per_token
    cap = _capacity(t, moe)

    logits = (x_flat.astype(jnp.float32) @ params["router"]["kernel"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    top_gate, top_idx = jax.lax.top_k(probs, k)
    top_gate = top_gate / jnp.maximum(top_gate.sum(-1, keepdims=True), 1e-9)

    token_table, gate_table, frac_tokens = _dispatch_tables(top_idx, top_gate, e, cap)

    e_loc = params["w_in"].shape[0]
    if expert_shards > 1 and e_loc < e:
        # experts sharded: keep only this rank's rows of the dispatch table
        start = expert_rank * e_loc
        token_table = jax.lax.dynamic_slice_in_dim(token_table, start, e_loc, axis=0)
        gate_table = jax.lax.dynamic_slice_in_dim(gate_table, start, e_loc, axis=0)

    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, d), x_flat.dtype)], axis=0)
    xs = x_pad[token_table]  # (E_loc, C, d) — "the slaves receive the inputs"
    ys = _expert_ffn(
        xs.astype(dtype), params["w_in"].astype(dtype),
        params["w_gate"].astype(dtype), params["w_out"].astype(dtype), activation,
    )
    ys = ys * gate_table[..., None].astype(ys.dtype)

    out = jnp.zeros((t + 1, d), ys.dtype)
    out = out.at[token_table.reshape(-1)].add(ys.reshape(-1, d))
    out = out[:-1]

    # load-balance loss (Switch): E * sum_e f_e * p_e
    mean_prob = probs.mean(0)
    aux = e * jnp.sum(frac_tokens * mean_prob) * moe.load_balance_loss_weight
    return out, aux


def apply_moe(
    params,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    mesh=None,
    token_axes: Tuple[str, ...] = (),
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d).  Returns (out, aux_loss).

    ``mesh`` + ``token_axes``: when running under a mesh, the flattened
    token dim is sharded over ``token_axes`` (typically ("pod","data")),
    experts over the ``model`` axis (or d_ff over model when E % model != 0),
    and the outputs are psum-combined over ``model``.
    """
    moe = cfg.moe
    assert moe is not None
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    dtype = cfg.compute_dtype

    if mesh is None or "model" not in mesh.axis_names:
        out, aux = _moe_local(
            x_flat, params, moe=moe, activation=cfg.activation, dtype=dtype,
            expert_shards=1, expert_rank=0,
        )
        return out.reshape(b, s, d).astype(x.dtype), aux

    n_model = mesh.axis_sizes[mesh.axis_names.index("model")]
    experts_sharded = moe.num_experts % n_model == 0
    ff_sharded = (not experts_sharded) and moe.expert_d_ff % n_model == 0

    tok_axes = tuple(
        a for a in token_axes if a in mesh.axis_names
    )
    # only shard the token dim if it divides
    prod = 1
    kept = []
    for a in tok_axes:
        sz = mesh.axis_sizes[mesh.axis_names.index(a)]
        if (b * s) % (prod * sz) == 0:
            kept.append(a)
            prod *= sz
    # beyond-paper all-to-all dispatch: shard tokens over `model` as well
    use_a2a = (
        moe.dispatch == "alltoall"
        and experts_sharded
        and "model" not in kept
        and (b * s) % (prod * n_model) == 0
    )
    if use_a2a:
        out, aux = _apply_moe_a2a(
            params, x_flat, cfg=cfg, mesh=mesh,
            tok_spec=tuple(kept) + ("model",), n_model=n_model,
        )
        return out.reshape(b, s, d).astype(x.dtype), aux
    tok_spec = tuple(kept) if kept else None

    if experts_sharded:
        w_spec = {"router": {"kernel": P(None, None)},
                  "w_in": P("model", None, None),
                  "w_gate": P("model", None, None),
                  "w_out": P("model", None, None)}
    elif ff_sharded:
        w_spec = {"router": {"kernel": P(None, None)},
                  "w_in": P(None, None, "model"),
                  "w_gate": P(None, None, "model"),
                  "w_out": P(None, "model", None)}
    else:  # fully replicated experts (smoke-scale fallback)
        w_spec = {"router": {"kernel": P(None, None)},
                  "w_in": P(None, None, None),
                  "w_gate": P(None, None, None),
                  "w_out": P(None, None, None)}

    def body(x_loc, p_loc):
        rank = jax.lax.axis_index("model")
        out, aux = _moe_local(
            x_loc, p_loc, moe=moe, activation=cfg.activation, dtype=dtype,
            expert_shards=n_model if experts_sharded else 1,
            expert_rank=rank,
        )
        if experts_sharded or ff_sharded:
            out = jax.lax.psum(out, "model")
        # aux must be identical on every rank for the replicated out_spec
        for ax in mesh.axis_names:
            aux = jax.lax.pmean(aux, ax)
        return out, aux

    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(tok_spec, None), w_spec),
        out_specs=(P(tok_spec, None), P()),
        check_vma=False,
    )(x_flat, params)
    return out.reshape(b, s, d).astype(x.dtype), aux


def _apply_moe_a2a(params, x_flat, *, cfg: ModelConfig, mesh, tok_spec, n_model):
    """All-to-all expert dispatch (beyond-paper combine schedule).

    Tokens are sharded over the `model` axis too; every rank routes only
    its own T/(data*model) tokens, packs per-expert capacity buffers, and
    two all-to-alls move ONLY the routed tokens to/from the expert owners
    — replacing the paper-style broadcast (tokens replicated over model)
    + psum-gather, whose traffic is the full activation volume.
    """
    moe = cfg.moe
    dtype = cfg.compute_dtype
    e = moe.num_experts
    e_loc = e // n_model

    w_spec = {"router": {"kernel": P(None, None)},
              "w_in": P("model", None, None),
              "w_gate": P("model", None, None),
              "w_out": P("model", None, None)}

    def body(x_loc, p_loc):
        t, d = x_loc.shape  # T/(pod*data*model) local tokens
        k = moe.experts_per_token
        cap = _capacity(t, moe)

        logits = (x_loc.astype(jnp.float32) @ p_loc["router"]["kernel"])
        probs = jax.nn.softmax(logits, axis=-1)
        top_gate, top_idx = jax.lax.top_k(probs, k)
        top_gate = top_gate / jnp.maximum(top_gate.sum(-1, keepdims=True), 1e-9)
        token_table, gate_table, frac = _dispatch_tables(top_idx, top_gate, e, cap)

        x_pad = jnp.concatenate([x_loc, jnp.zeros((1, d), x_loc.dtype)], axis=0)
        xs = x_pad[token_table].astype(dtype)  # (E, cap, d) — send buffers

        # forward a2a: rows [i*e_loc:(i+1)*e_loc] go to model-rank i
        recv = jax.lax.all_to_all(
            xs, "model", split_axis=0, concat_axis=0, tiled=True
        )  # (E, cap, d): n_model source blocks of (e_loc, cap, d)
        recv = recv.reshape(n_model, e_loc, cap, d).transpose(1, 0, 2, 3)
        recv = recv.reshape(e_loc, n_model * cap, d)

        ys = _expert_ffn(
            recv, p_loc["w_in"].astype(dtype), p_loc["w_gate"].astype(dtype),
            p_loc["w_out"].astype(dtype), cfg.activation,
        )  # (e_loc, n_model*cap, d)

        # return a2a: block j of each rank goes back to source rank j
        ys = ys.reshape(e_loc, n_model, cap, d).transpose(1, 0, 2, 3)
        ys = ys.reshape(e, cap, d)
        back = jax.lax.all_to_all(
            ys, "model", split_axis=0, concat_axis=0, tiled=True
        )  # (E, cap, d) — expert-major rows for OUR tokens

        back = back * gate_table[..., None].astype(back.dtype)
        out = jnp.zeros((t + 1, d), back.dtype)
        out = out.at[token_table.reshape(-1)].add(back.reshape(-1, d))
        out = out[:-1]

        aux = e * jnp.sum(frac * probs.mean(0)) * moe.load_balance_loss_weight
        for ax in mesh.axis_names:
            aux = jax.lax.pmean(aux, ax)
        return out, aux

    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(tok_spec, None), w_spec),
        out_specs=(P(tok_spec, None), P()),
        check_vma=False,
    )(x_flat, params)
    return out, aux
