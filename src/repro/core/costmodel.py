"""Communication cost model — the paper's Eq. 2 — plus the step-time
predictor used for the 32/128-node scalability simulations (Figs 9-13).

Eq. 2 counts the elements exchanged between master and slaves per batch:

    upload = sum_i  in_i^2 * inCh_i * batch        (broadcast the inputs)
           + k_i^2 * numK_i * inCh_i               (scatter the kernels)
           + out_i^2 * numK_i * batch              (gather the outputs)

All values are doubles (8 bytes) in the paper's Matlab implementation.
The same expression evaluated at ICI bandwidth is the collective term of
the TPU roofline (see repro/roofline) — the model transfers unchanged,
only the bandwidth constant differs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

BYTES_PER_ELEMENT = 8  # Matlab double


@dataclasses.dataclass(frozen=True)
class ConvLayerSpec:
    """Geometry of one distributed convolutional layer."""

    in_size: int  # input width == height (square, like the paper)
    in_channels: int
    kernel_size: int
    num_kernels: int
    padding: str = "SAME"

    @property
    def out_size(self) -> int:
        if self.padding == "SAME":
            return self.in_size
        return self.in_size - self.kernel_size + 1


def upload_elements(layers: Sequence[ConvLayerSpec], batch: int) -> int:
    """Eq. 2: total elements master<->slaves per batch over all layers."""
    total = 0
    for l in layers:
        total += l.in_size ** 2 * l.in_channels * batch          # inputs
        total += l.kernel_size ** 2 * l.num_kernels * l.in_channels  # kernels
        total += l.out_size ** 2 * l.num_kernels * batch          # outputs
    return int(total)


def upload_bytes(layers: Sequence[ConvLayerSpec], batch: int,
                 bytes_per_element: int = BYTES_PER_ELEMENT) -> int:
    return upload_elements(layers, batch) * bytes_per_element


def comm_time_s(layers: Sequence[ConvLayerSpec], batch: int,
                bandwidth_mbps: float, *,
                bytes_per_element: int = BYTES_PER_ELEMENT) -> float:
    """Seconds to move Eq. 2's volume at the given link rate (paper
    measures ~5 Mbps on Wi-Fi)."""
    bits = upload_bytes(layers, batch, bytes_per_element) * 8
    return bits / (bandwidth_mbps * 1e6)


def upload_elements_nodes(
    layers: Sequence[ConvLayerSpec], batch: int, slave_shares: Sequence[float],
    *, broadcast_inputs: bool = False,
) -> float:
    """Node-aware refinement of Eq. 2 used by the simulator.  Kernels and
    outputs move only for the slaves' workload shares (the master keeps
    its own shard local); with one device the volume is 0.

    ``broadcast_inputs``: the paper's Eq. 2 counts the input volume ONCE
    (and its Figs 9-13 scalability conclusions — "stabilises, no loss" —
    depend on that); Algorithm 1 line 10 however writes the inputs to
    EVERY slave socket, so the physically-consistent model scales the
    input term by n_slaves.  False reproduces the paper's own simulator;
    True is the corrected (beyond-paper) model — both are reported in
    benchmarks/bench_scalability.py.

    ``slave_shares``: Eq. 1 shares of the slave nodes (excludes master).
    """
    n_slaves = len(slave_shares)
    frac = float(np.sum(slave_shares))
    in_mult = n_slaves if broadcast_inputs else 1.0
    total = 0.0
    for l in layers:
        total += l.in_size ** 2 * l.in_channels * batch * in_mult
        total += l.kernel_size ** 2 * l.num_kernels * l.in_channels * frac
        total += l.out_size ** 2 * l.num_kernels * batch * frac
    return total


def comm_time_nodes_s(
    layers: Sequence[ConvLayerSpec], batch: int, slave_shares: Sequence[float],
    bandwidth_mbps: float, *, bytes_per_element: int = BYTES_PER_ELEMENT,
    broadcast_inputs: bool = False,
) -> float:
    bits = (
        upload_elements_nodes(
            layers, batch, slave_shares, broadcast_inputs=broadcast_inputs
        )
        * bytes_per_element * 8
    )
    return bits / (bandwidth_mbps * 1e6)


def paper_network(c1: int, c2: int, *, image_size: int = 32,
                  kernel_size: int = 5, image_channels: int = 3,
                  pool_stride: int = 2) -> List[ConvLayerSpec]:
    """The paper's 2-conv-layer CIFAR-10 network geometry."""
    l1 = ConvLayerSpec(image_size, image_channels, kernel_size, c1)
    l2_in = image_size // pool_stride
    l2 = ConvLayerSpec(l2_in, c1, kernel_size, c2)
    return [l1, l2]


# ---------------------------------------------------------------------------
# step-time predictor (the scalability simulator's inner model)


@dataclasses.dataclass(frozen=True)
class StepTimePrediction:
    comm_time: float
    conv_time: float  # slowest device's conv time (they finish together under Eq. 1)
    comp_time: float  # non-conv layers, computed serially on the master
    num_devices: int

    @property
    def total(self) -> float:
        return self.comm_time + self.conv_time + self.comp_time


def predict_step_time(
    *,
    layers: Sequence[ConvLayerSpec],
    batch: int,
    device_conv_times: Sequence[float],
    master_comp_time: float,
    bandwidth_mbps: float,
    bytes_per_element: int = BYTES_PER_ELEMENT,
    broadcast_inputs: bool = False,
) -> StepTimePrediction:
    """Predict one distributed training-step's wall time.

    ``device_conv_times[i]``: time for device i to convolve ALL kernels of
    the network alone (the probe, scaled to the full workload).  Under the
    Eq. 1 balanced shares every device finishes in

        T_conv = 1 / sum_i (1 / t_i)

    (the harmonic aggregate — equal-finish-time work splitting).
    With a single device there is no communication.
    """
    t = np.asarray(device_conv_times, dtype=np.float64)
    n = t.size
    if n == 1:
        return StepTimePrediction(0.0, float(t[0]), master_comp_time, 1)
    conv = 1.0 / np.sum(1.0 / t)
    shares = (1.0 / t) / np.sum(1.0 / t)  # Eq. 1
    comm = comm_time_nodes_s(layers, batch, shares[1:], bandwidth_mbps,
                             bytes_per_element=bytes_per_element,
                             broadcast_inputs=broadcast_inputs)
    return StepTimePrediction(float(comm), float(conv), master_comp_time, int(n))
