"""The paper's pre-processing probe (§4.1.1).

Before training, every device runs an N-dimensional convolution with the
real image and kernel sizes, on random values ("only the time spent
performing calculations is relevant"), and reports the elapsed time to
the master.  Eq. 1 converts the times into workload shares.

On this host all "devices" are CPU threads, so a *slowdown factor* per
emulated device lets tests and examples reproduce heterogeneous clusters
deterministically (a device with slowdown 2.0 sleeps to appear half as
fast — the probe measures it exactly as it would a slower machine).
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def probe_conv_time(
    *,
    image_size: int,
    in_channels: int,
    kernel_size: int,
    num_kernels: int,
    batch: int,
    repeats: int = 3,
    slowdown: float = 1.0,
    seed: int = 0,
) -> float:
    """Run the reference convolution and return median elapsed seconds."""
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (batch, image_size, image_size, in_channels), jnp.float32)
    w = jax.random.normal(
        k2, (kernel_size, kernel_size, in_channels, num_kernels), jnp.float32
    )

    @jax.jit
    def conv(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )

    conv(x, w).block_until_ready()  # compile outside the timed region
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        conv(x, w).block_until_ready()
        times.append(time.perf_counter() - t0)
    measured = float(np.median(times))
    if slowdown > 1.0:
        # emulate a slower device: it would have taken slowdown x longer
        measured *= slowdown
    return measured


def probe_devices(
    num_devices: int,
    *,
    image_size: int = 32,
    in_channels: int = 3,
    kernel_size: int = 5,
    num_kernels: int = 100,
    batch: int = 64,
    slowdowns: Optional[Sequence[float]] = None,
) -> list:
    """Probe every emulated device (the master's §4.1.1 pre-processing)."""
    slowdowns = slowdowns or [1.0] * num_devices
    assert len(slowdowns) == num_devices
    return [
        probe_conv_time(
            image_size=image_size,
            in_channels=in_channels,
            kernel_size=kernel_size,
            num_kernels=num_kernels,
            batch=batch,
            slowdown=s,
            seed=i,
        )
        for i, s in enumerate(slowdowns)
    ]
