"""Algorithms 1 & 2 — the master node and its cluster of slaves.

``HeteroCluster`` is the master (Algorithm 1): it probes every device,
computes the Eq. 1(+comm, +comp-duty) shares, and drives the per-op
scatter/gather halves the schedulers (core/cluster/scheduler.py)
pipeline.  The protocol per convolutional layer (Algorithm 1 lines
6-23): broadcast the inputs, scatter per-device kernel shards (or ship
row strips + halos in spatial mode), every node convolves its shard —
master included — then gather and reassemble on the master, which also
computes every non-convolutional layer alone.

``transport`` picks the wire:

    "inproc" (default) — every slave is a daemon THREAD, every link an
        ``InProcTransport`` queue pair with optional emulated
        ``bandwidth_mbps`` (the seed behaviour: heterogeneity emulated
        with per-slave slowdown sleeps, links with delivery threads).

    "tcp" — every slave is a real OS PROCESS (spawned with
        ``python -m repro.core.cluster.protocol``) connected back over a
        localhost ``TCPTransport``: comm cost, serialization, and
        slave-side compute are measured, not emulated.  ``probe()``
        additionally measures each link's real bandwidth with an echo
        probe and feeds it to the comm-aware partitioner
        (``bandwidth_mbps`` then only serves as an explicit override for
        the planning terms; nothing is delayed artificially).

Heterogeneity is emulated with per-slave *slowdown factors*: after
computing, a slave sleeps (slowdown-1) x the measured compute time,
appearing exactly like a proportionally slower machine to both the
probe and the training loop — in a thread or a subprocess alike.
"""
from __future__ import annotations

import hmac
import os
import secrets
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.backends import (
    get_backend,
    probe_conv_time,
    strip_conv,
    strip_conv_vjp,
)
from repro.core.cluster import codec, plans, protocol, scheduler
from repro.core.cluster.transport import (
    TRANSPORT_KINDS,
    InProcTransport,
    TCPListener,
    TCPTransport,
    _recv_exact,
)
from repro.core.partitioner import allocate_kernels, effective_times


def _np_probe(*, slowdown: float = 1.0, **probe_kwargs) -> float:
    """The paper's §4.1.1 probe on the numpy backend (seed behaviour)."""
    return probe_conv_time("numpy", slowdown=slowdown, **probe_kwargs)


def _src_pythonpath() -> str:
    """The import root of this package, prepended to a slave subprocess's
    PYTHONPATH so ``-m repro.core.cluster.protocol`` resolves without an
    installed wheel (the repo's src/ layout)."""
    here = os.path.abspath(os.path.dirname(__file__))  # .../src/repro/core/cluster
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


class HeteroCluster:
    """The master node (Algorithm 1) plus ``n_slaves`` slaves.

    Device 0 is the master itself (it convolves its own shard while the
    slaves work).  ``slowdowns[i]`` emulates device i's relative speed
    (1.0 = this host's full speed); slowdowns[0] applies to the master.

    ``backends[i]`` names device i's conv backend (core/backends.py);
    defaults to ``numpy`` everywhere, the seed behaviour.

    ``pipeline=True`` enables the double-buffered microbatch protocol:
    ``conv_forward``/``conv_backward`` split the batch into up to
    ``microbatches`` slices and keep one scatter in flight ahead of every
    gather.  With ``pipeline=False`` (default) every call is a single
    scatter -> compute -> gather barrier, the paper's Algorithm 1.

    ``transport`` is the wire: ``"inproc"`` threads+queues (default) or
    ``"tcp"`` subprocess slaves over real localhost sockets — see the
    module docstring.  ``bandwidth_mbps`` (single float or one value PER
    SLAVE) emulates finite links on inproc; on tcp it only overrides the
    measured planning bandwidth.  Default ``None`` = infinitely fast
    emulated links (inproc) / measure at ``probe()`` (tcp).

    ``comp_aware=True`` (default) makes the Eq. 1 shares discount the
    master's measured non-conv duty: once ``conv_forward_chain`` or
    ``conv_train_chain`` has observed master-only between/head work
    (``LayerTiming.comp_s`` vs ``master_conv_s``), ``shares_for`` inflates
    the master's probe time by ``1/(1-duty)`` automatically.

    ``partition`` picks the conv split axis: ``"kernel"`` (the paper,
    default), ``"spatial"`` (height strips + halo exchange — each slave
    gets only its rows instead of the full activation), or ``"auto"``
    (per layer, the axis with the smaller predicted wall-clock over the
    measured links).  ``wire_dtype`` ("fp16"/"bf16") turns on the
    compact wire codec on either transport.
    """

    def __init__(
        self,
        slowdowns: Sequence[float],
        backends: Optional[Sequence[str]] = None,
        *,
        pipeline: bool = False,
        microbatches: int = 4,
        bandwidth_mbps: Union[None, float, Sequence[Optional[float]]] = None,
        comp_aware: bool = True,
        partition: str = "kernel",
        wire_dtype: Optional[str] = None,
        transport: str = "inproc",
    ):
        assert len(slowdowns) >= 1
        if any(sd < 1.0 for sd in slowdowns):
            # the op-level emulation can only SLEEP (slowdown-1)x the
            # measured compute — it cannot make the host faster — so a
            # sub-1 slowdown would probe fast (probe_conv_time scales
            # both directions) yet compute at 1.0x, and Eq. 1 would
            # overfeed the device.  Emulate faster devices with a
            # parameterized sim backend instead.
            raise ValueError(
                f"slowdowns must be >= 1.0 (got {list(slowdowns)}): the "
                f"cluster emulates slower devices by sleeping; for a "
                f"FASTER virtual device use a parameterized sim backend, "
                f"e.g. backends=['sim:5e9', ...]"
            )
        self.slowdowns = list(slowdowns)
        self.n_slaves = len(slowdowns) - 1
        if backends is None:
            backends = ["numpy"] * len(self.slowdowns)
        assert len(backends) == len(self.slowdowns), "one backend per device"
        self.backends = list(backends)
        # resolve every name NOW: an unknown backend must raise here, not
        # kill a slave later and leave the master blocked forever
        for name in self.backends:
            get_backend(name)
        self._master_backend = get_backend(self.backends[0])
        self.pipeline = bool(pipeline)
        self.microbatches = int(microbatches)
        if partition not in plans.PARTITION_MODES:
            raise ValueError(
                f"partition must be one of {plans.PARTITION_MODES}, "
                f"got {partition!r}"
            )
        self.partition = partition
        self.partition_choices: Dict[tuple, str] = {}  # auto's per-layer picks
        self.wire_dtype = wire_dtype
        self._wire_np_dtype = codec.resolve_wire_dtype(wire_dtype)
        self._wire_itemsize = (
            self._wire_np_dtype.itemsize if self._wire_np_dtype is not None else 4
        )
        if transport not in TRANSPORT_KINDS:
            raise ValueError(
                f"transport must be one of {TRANSPORT_KINDS}, got {transport!r}"
            )
        self.transport = transport
        if bandwidth_mbps is None or isinstance(bandwidth_mbps, (int, float)):
            self.bandwidths: List[Optional[float]] = (
                [bandwidth_mbps] * self.n_slaves
            )
        else:
            self.bandwidths = list(bandwidth_mbps)
            assert len(self.bandwidths) == self.n_slaves, "one bandwidth per slave"
        # what the USER pinned, frozen: re-probing on tcp must overwrite
        # stale measurements, never a deliberate override (and never
        # mistake an old measurement for one)
        self._bandwidth_overrides = list(self.bandwidths)
        self.threads: list = []
        self.procs: List[subprocess.Popen] = []
        self._listener: Optional[TCPListener] = None
        if transport == "tcp":
            self.sockets = self._spawn_tcp_slaves()
        else:
            self.sockets = [
                InProcTransport(bw, self._wire_np_dtype) for bw in self.bandwidths
            ]
            import threading

            self.threads = [
                threading.Thread(
                    target=protocol.slave_loop,
                    args=(s.slave_endpoint(), sd, bk, i),
                    daemon=True,
                )
                for i, (s, sd, bk) in enumerate(
                    zip(self.sockets, self.slowdowns[1:], self.backends[1:]),
                    start=1,
                )
            ]
            for t in self.threads:
                t.start()
        self.probe_times: Optional[List[float]] = None
        self.probe_flops: Optional[float] = None  # flops of the probe workload
        self.measured_bandwidths: List[Optional[float]] = [None] * self.n_slaves
        self.timing = scheduler.LayerTiming()
        self.comp_aware = bool(comp_aware)
        self.comp_duty = 0.0  # measured master non-conv duty (see shares_for)
        self._duty_mark = (0.0, 0.0)  # (comp_s, master_conv_s) at last update
        self._seq_issued = 0
        self._seq_gathered = 0
        self._shut = False

    # -- tcp slave process management -------------------------------------
    _AUTH_BYTES = 32

    def _spawn_tcp_slaves(self) -> List[TCPTransport]:
        """Spawn one OS process per slave, accept their connections on a
        localhost listener, and hand back the per-device channels in
        device order (accept order is whoever wins the connect race; the
        ("hello", device) handshake re-sorts).

        Connections are AUTHENTICATED before anything is unpickled: each
        slave receives a fresh per-cluster random token via its
        environment (REPRO_CLUSTER_AUTH — env, not argv, so it never
        shows in ps) and must present it as its first raw bytes.  The
        wire is pickle, so an unauthenticated listener would hand any
        local process arbitrary code execution in the master."""
        self._listener = TCPListener()
        token = secrets.token_bytes(self._AUTH_BYTES)
        env = os.environ.copy()
        src = _src_pythonpath()
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        env["REPRO_CLUSTER_AUTH"] = token.hex()
        for i, (sd, bk) in enumerate(
            zip(self.slowdowns[1:], self.backends[1:]), start=1
        ):
            cmd = [
                sys.executable, "-m", "repro.core.cluster.protocol",
                "--host", self._listener.host,
                "--port", str(self._listener.port),
                "--device", str(i),
                "--slowdown", str(sd),
                "--backend", bk,
            ]
            if self.wire_dtype is not None:
                cmd += ["--wire-dtype", self.wire_dtype]
            self.procs.append(subprocess.Popen(cmd, env=env))
        by_device: Dict[int, TCPTransport] = {}
        try:
            for _ in range(self.n_slaves):
                conn = self._listener.accept(timeout_s=60.0)
                conn.settimeout(10.0)  # a silent stranger must not hang us
                presented = _recv_exact(conn, self._AUTH_BYTES)
                if not hmac.compare_digest(presented, token):
                    conn.close()
                    raise RuntimeError(
                        "TCP slave handshake failed: connection did not "
                        "present the cluster auth token (stray local "
                        "process on the listener port?)"
                    )
                conn.settimeout(None)
                chan = TCPTransport(conn, self._wire_np_dtype)
                hello = chan.read_on_master()
                # RuntimeError, not assert: -O must not let a malformed
                # handshake mispair device channels
                if (
                    not isinstance(hello, tuple) or len(hello) != 2
                    or hello[0] != "hello"
                ):
                    raise RuntimeError(f"bad slave handshake frame {hello!r}")
                by_device[hello[1]] = chan
        except Exception:
            for p in self.procs:
                p.kill()
            self._listener.close()
            raise
        for chan in by_device.values():
            chan.reset_counters()  # the handshake is not protocol traffic
        return [by_device[i] for i in range(1, self.n_slaves + 1)]

    # -- §4.1.1 pre-processing -------------------------------------------
    def probe(self, **probe_kwargs) -> List[float]:
        """Every device runs the timed reference convolution on its OWN
        backend — sequential so the 1-core host's timings do not
        interfere.  Also records the probe workload's FLOPs (the scale
        factor that lets the comm-aware partitioner and the auto axis
        chooser turn probe times into absolute per-layer predictions)
        and, on the tcp transport, each link's measured round-trip
        bandwidth — the real wire feeds ``link_aware_times`` instead of
        the ``bandwidth_mbps`` knob."""
        master_t = probe_conv_time(
            self._master_backend, slowdown=self.slowdowns[0], **probe_kwargs
        )
        slave_ts = []
        for s in self.sockets:
            s.write_to_slave(("probe", probe_kwargs))
            slave_ts.append(self._check_result(s.read_on_master()))
        self.probe_times = [master_t] + slave_ts
        self.probe_flops = (
            2.0
            * probe_kwargs["batch"]
            * probe_kwargs["image_size"] ** 2
            * probe_kwargs["kernel_size"] ** 2
            * probe_kwargs["in_channels"]
            * probe_kwargs["num_kernels"]
        )
        if self.transport == "tcp":
            self.measured_bandwidths = [
                s.measure_bandwidth_mbps() for s in self.sockets
            ]
            # an explicit constructor bandwidth_mbps stays an override for
            # planning; otherwise every probe() refreshes the measurement
            self.bandwidths = [
                ovr if ovr is not None else meas
                for ovr, meas in zip(
                    self._bandwidth_overrides, self.measured_bandwidths
                )
            ]
        return self.probe_times

    def _effective_times(self) -> List[float]:
        """Probe times with the comp-aware master discount applied."""
        assert self.probe_times is not None, "run probe() first"
        times = self.probe_times
        if self.comp_aware and self.comp_duty > 0.0:
            times = effective_times(
                times, comp_duties={0: self.comp_duty}
            )
        return list(times)

    def shares_for(
        self,
        num_kernels: int,
        *,
        unit_bytes: float = 0.0,
        layer_flops: Optional[float] = None,
    ) -> np.ndarray:
        """Eq. 1 unit counts (kernels or rows) from the probe times; with
        ``comp_aware`` the master's measured non-conv duty discounts its
        share.  When the layer's wire cost is known (``unit_bytes`` per
        unit, ``layer_flops`` to scale probe times to this layer) and the
        links are finite, each slave's comm term joins its compute term —
        the comm-extended Eq. 1 (partitioner.effective_times)."""
        times = self._effective_times()
        if (
            unit_bytes > 0.0
            and layer_flops
            and self.probe_flops
            and any(bw is not None for bw in self.bandwidths)
        ):
            scale = layer_flops / self.probe_flops
            wire = [0.0] + [
                float(num_kernels) * unit_bytes if bw is not None else 0.0
                for bw in self.bandwidths
            ]
            times = effective_times(
                [t * scale for t in times],
                wire_bytes=wire,
                bandwidths_mbps=[None] + list(self.bandwidths),
            )
        return allocate_kernels(num_kernels, times)

    def _update_comp_duty(self):
        """Refresh the measured non-conv duty — the fraction of the
        master's busy time spent OUTSIDE its conv shard — from the window
        since the LAST update (deltas, not cumulative): a one-off cost in
        an early step (jit compilation of the master-only stages, cold
        caches) then mis-shapes at most the next step's shares before the
        first clean window corrects it."""
        t = self.timing
        dc = t.comp_s - self._duty_mark[0]
        dm = t.master_conv_s - self._duty_mark[1]
        self._duty_mark = (t.comp_s, t.master_conv_s)
        if dc + dm > 0.0:
            self.comp_duty = dc / (dc + dm)

    # -- partition planning (core/cluster/plans.py) -----------------------
    def _unit_bytes(self, x_shape, w_shape, mode: str, op: str) -> float:
        return plans.unit_bytes(x_shape, w_shape, mode, op, self._wire_itemsize)

    def predict_partition_seconds(
        self, x_shape, w_shape, op: str = "conv"
    ) -> Dict[str, float]:
        return plans.predict_partition_seconds(self, x_shape, w_shape, op)

    def _resolve_mode(
        self, x_shape, w_shape, override: Optional[str], op: str = "conv"
    ) -> str:
        return plans.resolve_mode(self, x_shape, w_shape, override, op)

    def plan_conv(
        self, x_shape, w: np.ndarray, op: str = "conv",
        partition: Optional[str] = None,
    ) -> plans.LayerPlan:
        return plans.plan_conv(self, x_shape, w, op, partition)

    # -- async scatter/gather halves -------------------------------------
    def _split(self, w: np.ndarray, counts: np.ndarray) -> List[np.ndarray]:
        return plans.split_kernels(w, counts)

    def scatter_conv(
        self, x: np.ndarray, w: np.ndarray, *, partition: Optional[str] = None
    ) -> scheduler.Pending:
        """Scatter one conv: broadcast x + kernel shards (kernel mode) or
        height strips + the full kernel (spatial mode); returns a handle.
        The master's own shard runs at gather time."""
        x = np.asarray(x, np.float32)
        plan = self.plan_conv(x.shape, w, "conv", partition)
        return self._scatter_conv_planned(x, plan, send_weights=True)

    def _scatter_conv_planned(
        self, x: np.ndarray, plan: plans.LayerPlan, send_weights: bool
    ) -> scheduler.Pending:
        if plan.mode == "kernel":
            return self._scatter_conv_shards(x, plan.shards, send_weights)
        t0 = time.perf_counter()
        for sock, (lo, hi, pt, pb) in zip(self.sockets, plan.halos[1:]):
            sock.write_to_slave(
                ("sconv", (x[:, lo:hi], plan.w if send_weights else None, pt, pb))
            )
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        return scheduler.Pending(
            "conv", self._seq_issued, x, plan.w, None, now,
            mode="spatial", rows=plan.rows, halos=plan.halos,
        )

    def _scatter_conv_shards(
        self, x: np.ndarray, shards: List[np.ndarray], send_weights: bool
    ) -> scheduler.Pending:
        """send_weights=False sends w=None: the slave reuses its cached
        shard, so pipelined microbatches pay the weight traffic once."""
        t0 = time.perf_counter()
        for sock, shard in zip(self.sockets, shards[1:]):
            sock.write_to_slave(("conv", (x, shard if send_weights else None)))
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        return scheduler.Pending("conv", self._seq_issued, x, shards[0], None, now)

    def gather_conv(self, p: scheduler.Pending) -> np.ndarray:
        """Compute the master's shard, collect the slaves' feature maps
        (FIFO: gathers must be issued in scatter order), concatenate —
        along channels (kernel mode) or height (spatial strips)."""
        self._check_order(p, "conv")
        t0 = time.perf_counter()
        if p.mode == "spatial":
            lo, hi, pt, pb = p.halos[0]
            my_out = self._master_compute(
                lambda: strip_conv(self._master_backend, p.x[:, lo:hi], p.my_w, pt, pb)
            )
            axis = 1
        else:
            my_out = self._master_compute(
                lambda: protocol.conv_shard(self._master_backend, p.x, p.my_w)
            )
            axis = -1
        outs = [my_out]
        t_wait = time.perf_counter()
        for sock in self.sockets:
            outs.append(self._check_result(sock.read_on_master()))
        t1 = time.perf_counter()
        self._account_gather(p, t0, t_wait, t1)
        return np.concatenate(outs, axis=axis)

    def scatter_bwd(
        self, x: np.ndarray, w: np.ndarray, g: np.ndarray,
        *, partition: Optional[str] = None,
    ) -> scheduler.Pending:
        x = np.asarray(x, np.float32)
        g = np.asarray(g, np.float32)
        plan = self.plan_conv(x.shape, w, "bwd", partition)
        return self._scatter_bwd_planned(x, plan, g, send_weights=True)

    def _scatter_bwd_planned(
        self, x: np.ndarray, plan: plans.LayerPlan, g: np.ndarray,
        send_weights: bool,
    ) -> scheduler.Pending:
        if plan.mode == "kernel":
            return self._scatter_bwd_shards(
                x, plan.shards, g, plan.counts, send_weights
            )
        t0 = time.perf_counter()
        for sock, (r0, r1), (lo, hi, pt, pb) in zip(
            self.sockets, plan.rows[1:], plan.halos[1:]
        ):
            sock.write_to_slave(
                ("sbwd", (
                    x[:, lo:hi], plan.w if send_weights else None,
                    g[:, r0:r1], pt, pb,
                ))
            )
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        r0, r1 = plan.rows[0]
        return scheduler.Pending(
            "bwd", self._seq_issued, x, plan.w, g[:, r0:r1], now,
            mode="spatial", rows=plan.rows, halos=plan.halos,
        )

    def _scatter_bwd_shards(
        self,
        x: np.ndarray,
        w_shards: List[np.ndarray],
        g: np.ndarray,
        counts: np.ndarray,
        send_weights: bool,
    ) -> scheduler.Pending:
        g_shards = self._split(g, counts)
        t0 = time.perf_counter()
        for sock, ws, gs in zip(self.sockets, w_shards[1:], g_shards[1:]):
            sock.write_to_slave(("bwd", (x, ws if send_weights else None, gs)))
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        return scheduler.Pending(
            "bwd", self._seq_issued, x, w_shards[0], g_shards[0], now
        )

    def gather_bwd(self, p: scheduler.Pending) -> Tuple[np.ndarray, np.ndarray]:
        """Master's shard VJP + gather.  Kernel mode: sum partial dX,
        concat dW shards.  Spatial mode: overlap-ADD each device's halo'd
        dX rows into the full dX (the seam sums) and SUM the full-kernel
        dW contributions."""
        self._check_order(p, "bwd")
        t0 = time.perf_counter()
        if p.mode == "spatial":
            lo, hi, pt, pb = p.halos[0]
            dxh, dw = self._master_compute(
                lambda: strip_conv_vjp(
                    self._master_backend, p.x[:, lo:hi], p.my_w, p.my_g, pt, pb
                )
            )
            dx = np.zeros(p.x.shape, np.float32)
            dx[:, lo:hi] += dxh
            t_wait = time.perf_counter()
            for sock, (lo_i, hi_i, _pt, _pb) in zip(self.sockets, p.halos[1:]):
                dxh_i, dw_i = self._check_result(sock.read_on_master())
                dx[:, lo_i:hi_i] += dxh_i  # the halo seams overlap-sum here
                dw = dw + dw_i
            t1 = time.perf_counter()
            self._account_gather(p, t0, t_wait, t1)
            return dx, dw
        dx, dw0 = self._master_compute(
            lambda: protocol.bwd_shard(self._master_backend, p.x, p.my_w, p.my_g)
        )
        dws = [dw0]
        t_wait = time.perf_counter()
        for sock in self.sockets:
            dxi, dwi = self._check_result(sock.read_on_master())
            dx = dx + dxi
            dws.append(dwi)
        t1 = time.perf_counter()
        self._account_gather(p, t0, t_wait, t1)
        return dx, np.concatenate(dws, axis=-1)

    def _check_result(self, out):
        """Re-raise a slave's shipped exception at the gather that would
        otherwise consume its (missing) result."""
        if isinstance(out, protocol.SlaveError):
            raise RuntimeError(
                f"slave device {out.device} failed while computing its "
                f"shard:\n{out.tb}"
            )
        return out

    def _check_order(self, p: scheduler.Pending, op: str):
        # real exceptions, not asserts: an out-of-order gather would pair
        # one scatter's master shard with another's slave outputs and
        # return silently corrupted feature maps (and -O strips asserts)
        if p.op != op:
            raise RuntimeError(f"pending is a {p.op!r} op, gathered as {op!r}")
        if p.seq != self._seq_gathered + 1:
            raise RuntimeError(
                "gathers must follow scatter order (FIFO links): "
                f"expected seq {self._seq_gathered + 1}, got {p.seq}"
            )
        self._seq_gathered = p.seq

    def _master_compute(self, fn):
        t0 = time.perf_counter()
        out = fn()
        el = time.perf_counter() - t0
        if self.slowdowns[0] > 1.0:
            time.sleep(el * (self.slowdowns[0] - 1.0))
        self.timing.master_conv_s += time.perf_counter() - t0
        return out

    def _account_gather(self, p: scheduler.Pending, t0, t_wait, t1):
        self.timing.conv_s += t1 - t0
        self.timing.gather_wait_s += t1 - t_wait
        # in-flight window minus the time the master actually blocked:
        # the comm/compute overlap the pipeline buys
        self.timing.overlap_s += max(0.0, (t_wait - p.t_issued))

    def _master_comp(self, f, y: np.ndarray) -> np.ndarray:
        t0 = time.perf_counter()
        out = f(y)
        self.timing.comp_s += time.perf_counter() - t0
        return out

    # -- the schedules (core/cluster/scheduler.py) ------------------------
    def _n_micro(self, batch: int) -> int:
        if not self.pipeline:
            return 1
        return max(1, min(self.microbatches, batch))

    def microbatch_slices(self, batch: int) -> List[slice]:
        return scheduler.microbatch_slices(self, batch)

    def conv_forward(self, x, w, *, partition: Optional[str] = None):
        return scheduler.conv_forward(self, x, w, partition=partition)

    def conv_backward(self, x, w, g, *, partition: Optional[str] = None):
        return scheduler.conv_backward(self, x, w, g, partition=partition)

    def conv_forward_chain(self, x, layer_weights, between=None):
        return scheduler.conv_forward_chain(self, x, layer_weights, between)

    def conv_train_chain(self, x, layer_weights, between=None, head=None):
        return scheduler.conv_train_chain(self, x, layer_weights, between, head)

    def conv_train_step(self, x, layer_weights, between=None, head=None, *,
                        update=None):
        return scheduler.conv_train_step(
            self, x, layer_weights, between, head, update=update
        )

    # ---------------------------------------------------------------------
    @property
    def comm_bytes(self) -> int:
        return sum(s.total_bytes for s in self.sockets)

    def reset_stats(self):
        self.timing = scheduler.LayerTiming()
        self._duty_mark = (0.0, 0.0)
        for s in self.sockets:
            s.reset_counters()

    def shutdown(self):
        if self._shut:
            return
        self._shut = True
        for s in self.sockets:
            try:
                s.write_to_slave(protocol.TRAIN_OVER)
            except RuntimeError:  # link already down (dead slave)
                pass
        for t in self.threads:
            t.join(timeout=10)
        deadline = time.monotonic() + 10
        for p in self.procs:
            try:
                p.wait(timeout=max(0.1, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait(timeout=5)
        for s in self.sockets:
            s.close()
        if self._listener is not None:
            self._listener.close()


def make_distributed_conv(cluster: HeteroCluster):
    """A drop-in ``conv_fn`` for models/cnn.py: jax custom-VJP convolution
    whose forward and backward run over the cluster via callbacks.  If the
    cluster is pipelined, every conv call is internally microbatched and
    double-buffered; keep the master's backend ``numpy`` here (re-entering
    jit dispatch on the blocked runtime thread can deadlock)."""
    import jax
    import jax.numpy as jnp

    # Fail fast on the documented deadlock instead of hanging at 0% CPU:
    # the callbacks below block the jax runtime thread while the master
    # computes its shard, so any master backend that re-enters jit
    # dispatch — everything but numpy — deadlocks, as does a pallas slave
    # in interpret mode (interpret re-enters jax from the slave thread
    # against the blocked callback; subprocess TCP slaves dodge this by
    # construction, but inproc slave threads share the runtime).
    if cluster.backends[0] != "numpy":
        raise RuntimeError(
            f"make_distributed_conv drives the cluster through jax host "
            f"callbacks; the master (device 0) backend must be 'numpy', got "
            f"{cluster.backends[0]!r}: re-entering jax from inside "
            f"pure_callback deadlocks the runtime thread.  Use the direct "
            f"conv_train_step / conv_forward drivers (no callbacks) for a "
            f"non-numpy master."
        )
    if cluster.transport != "tcp":
        interp_pallas = [
            i for i, b in enumerate(cluster.backends)
            if i > 0 and b.partition(":")[0] == "pallas"
            and getattr(get_backend(b), "interpret", False)
        ]
        if interp_pallas:
            raise RuntimeError(
                f"slave device(s) {interp_pallas} run the 'pallas' backend in "
                f"interpret mode, which re-enters jax from the slave thread "
                f"and can deadlock against a blocked make_distributed_conv "
                f"callback.  Use compiled TPU pallas, 'xla', or 'numpy' "
                f"slaves here, drive the cluster directly via "
                f"conv_train_step, or use transport='tcp' (subprocess slaves "
                f"own their runtime)."
            )

    @jax.custom_vjp
    def dconv(x, w, b):
        y = _call_fwd(x, w)
        return y + b[None, None, None, :]

    def fwd(x, w, b):
        y = _call_fwd(x, w)
        return y + b[None, None, None, :], (x, w)

    def bwd(res, g):
        x, w = res
        dx, dw = _call_bwd(x, w, g)
        db = jnp.sum(g, axis=(0, 1, 2))
        return dx, dw, db

    def _call_fwd(x, w):
        out_shape = jax.ShapeDtypeStruct(x.shape[:-1] + (w.shape[-1],), x.dtype)
        return jax.pure_callback(
            lambda xx, ww: cluster.conv_forward(np.asarray(xx), np.asarray(ww)),
            out_shape, x, w,
        )

    def _call_bwd(x, w, g):
        out_shape = (
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
        )
        return jax.pure_callback(
            lambda xx, ww, gg: cluster.conv_backward(
                np.asarray(xx), np.asarray(ww), np.asarray(gg)
            ),
            out_shape, x, w, g,
        )

    dconv.defvjp(fwd, bwd)

    def conv_fn(params, x, padding: str = "SAME"):
        return dconv(x, params["kernel"], params["bias"])

    return conv_fn
