"""Algorithms 1 & 2 — the master node and its cluster of slaves.

``HeteroCluster`` is the master (Algorithm 1): it probes every device,
computes the Eq. 1(+comm, +comp-duty) shares, and drives the per-op
scatter/gather halves the schedulers (core/cluster/scheduler.py)
pipeline.  The protocol per convolutional layer (Algorithm 1 lines
6-23): broadcast the inputs, scatter per-device kernel shards (or ship
row strips + halos in spatial mode, or batch-row slices + the
replicated kernel in batch mode), every node convolves its shard —
master included — then gather and reassemble on the master, which also
computes every non-convolutional layer alone.  The backward mirrors
each axis: kernel sums partial dX, spatial overlap-adds strips, batch
sums per-member full dW (an exact all-reduce over disjoint rows).

``transport`` picks the wire:

    "inproc" (default) — every slave is a daemon THREAD, every link an
        ``InProcTransport`` queue pair with optional emulated
        ``bandwidth_mbps`` (the seed behaviour: heterogeneity emulated
        with per-slave slowdown sleeps, links with delivery threads).

    "tcp" — every slave is a real OS PROCESS (spawned with
        ``python -m repro.core.cluster.protocol``) connected back over a
        localhost ``TCPTransport``: comm cost, serialization, and
        slave-side compute are measured, not emulated.  ``probe()``
        additionally measures each link's real bandwidth with an echo
        probe and feeds it to the comm-aware partitioner
        (``bandwidth_mbps`` then only serves as an explicit override for
        the planning terms; nothing is delayed artificially).

    "shm" — tcp's process model, but bulk arrays ride zero-copy
        shared-memory ring buffers (``ShmTransport``); only tiny
        skeleton/control frames cross the socket.  Co-located slaves
        only (the rings are host-local).  Everything else — auth,
        heartbeats, elasticity, byte accounting, bandwidth probing
        (which then times the ring, what the plans will actually see)
        — behaves exactly like tcp.

Heterogeneity is emulated with per-slave *slowdown factors*: after
computing, a slave sleeps (slowdown-1) x the measured compute time,
appearing exactly like a proportionally slower machine to both the
probe and the training loop — in a thread or a subprocess alike.

The cluster is ELASTIC: membership may change while it runs.

* ``expected_slaves=N`` (tcp) skips spawning and waits for N slaves
  launched by hand — on this host or any remote one — via
  ``python -m repro.core.cluster.protocol --host H --port P``; the
  hello handshake brings each joiner's backend/slowdown and the master
  assigns its device slot.  ``listen_host="0.0.0.0"`` opens the
  listener to remote hosts (the REPRO_CLUSTER_AUTH secret must be set
  in BOTH environments — the wire is pickle).
* ``admit()`` grows a running cluster by one slave (a spawned local
  one, or ``spawn=False`` to wait for an external join); ``evict()``
  retires one gracefully.  Either way the next plan re-runs the
  comm-aware Eq. 1 over the new membership.
* ``heartbeat_s`` arms liveness: slaves beat small frames from a side
  thread and the master's reads enforce a deadline, so a crashed OR
  wedged slave raises ``SlaveLost`` within the timeout instead of
  hanging the scheduler.  A lost slave is auto-evicted, every
  in-flight op's missing shard is recomputed BY THE MASTER from the
  plan the op rode (``Pending.plan``/``parts``), and the step drains
  on the survivors with correct numerics — then the next step's plans
  re-partition.  ``failures`` records each loss.
"""
from __future__ import annotations

import hmac
import os
import secrets
import subprocess
import sys
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.backends import (
    get_backend,
    probe_conv_time,
    strip_conv,
    strip_conv_vjp,
)
from repro.core.cluster import codec, plans, protocol, scheduler
from repro.core.cluster.transport import (
    TRANSPORT_KINDS,
    InProcTransport,
    SharedNIC,
    ShmListener,
    ShmTransport,
    SlaveLost,
    TCPListener,
    TCPTransport,
    Transport,
    _recv_exact,
)
from repro.core.partitioner import allocate_kernels, effective_times


def _np_probe(*, slowdown: float = 1.0, **probe_kwargs) -> float:
    """The paper's §4.1.1 probe on the numpy backend (seed behaviour)."""
    return probe_conv_time("numpy", slowdown=slowdown, **probe_kwargs)


def _src_pythonpath() -> str:
    """The import root of this package, prepended to a slave subprocess's
    PYTHONPATH so ``-m repro.core.cluster.protocol`` resolves without an
    installed wheel (the repo's src/ layout)."""
    here = os.path.abspath(os.path.dirname(__file__))  # .../src/repro/core/cluster
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


class HeteroCluster:
    """The master node (Algorithm 1) plus ``n_slaves`` slaves.

    Device 0 is the master itself (it convolves its own shard while the
    slaves work).  ``slowdowns[i]`` emulates device i's relative speed
    (1.0 = this host's full speed); slowdowns[0] applies to the master.

    ``backends[i]`` names device i's conv backend (core/backends.py);
    defaults to ``numpy`` everywhere, the seed behaviour.

    ``pipeline=True`` enables the double-buffered microbatch protocol:
    ``conv_forward``/``conv_backward`` split the batch into up to
    ``microbatches`` slices and keep one scatter in flight ahead of every
    gather.  With ``pipeline=False`` (default) every call is a single
    scatter -> compute -> gather barrier, the paper's Algorithm 1.

    ``transport`` is the wire: ``"inproc"`` threads+queues (default) or
    ``"tcp"`` subprocess slaves over real localhost sockets — see the
    module docstring.  ``bandwidth_mbps`` (single float or one value PER
    SLAVE) emulates finite links on inproc; on tcp it only overrides the
    measured planning bandwidth.  Default ``None`` = infinitely fast
    emulated links (inproc) / measure at ``probe()`` (tcp).
    ``master_nic_mbps`` (inproc only) additionally puts ONE emulated
    shared port on the master: traffic on all its links serializes per
    direction through a ``transport.SharedNIC``, modeling the
    master-ingress bottleneck the two-tier hierarchy relieves; planning
    prices each link's fair share (nic/n) unless a per-link value is
    set.

    ``comp_aware=True`` (default) makes the Eq. 1 shares discount the
    master's measured non-conv duty: once ``conv_forward_chain`` or
    ``conv_train_chain`` has observed master-only between/head work
    (``LayerTiming.comp_s`` vs ``master_conv_s``), ``shares_for`` inflates
    the master's probe time by ``1/(1-duty)`` automatically.

    ``partition`` picks the conv split axis: ``"kernel"`` (the paper,
    default), ``"spatial"`` (height strips + halo exchange — each slave
    gets only its rows instead of the full activation), or ``"auto"``
    (per layer, the axis with the smaller predicted wall-clock over the
    measured links).  ``wire_dtype`` ("fp16"/"bf16") turns on the
    compact wire codec on any transport; ``wire_codec`` is the full
    compressor stack — a single stage name ("fp16", "int8") for every
    message class, or per-class ``"weights=fp16,acts=int8,
    grads=topk:0.05"`` (top-k applies to gradients only, with
    master-side error feedback).  Pass one or the other, not both.

    ``weight_cache=True`` (default) turns on the versioned
    weight-broadcast cache for the chain drivers and the serve lane:
    slaves cache kernels under a stable per-layer key and the master
    ships a ~24-byte version token instead of re-broadcasting a kernel
    it already shipped — static serve weights cross the wire once per
    slave instead of once per slab.

    Elastic / fault-tolerance knobs (see the module docstring):
    ``expected_slaves`` waits for hand-launched tcp joiners instead of
    spawning; ``listen_host``/``listen_port`` place the tcp listener
    (remote slaves need a routable host and usually a fixed port);
    ``heartbeat_s`` makes spawned slaves beat liveness frames every
    that many seconds and arms the master's read deadline
    (``heartbeat_timeout_s``, default 3x the interval) — tcp only, the
    in-proc queue wire cannot lose a slave silently.  ``admit()`` /
    ``evict()`` change membership at runtime; a slave that dies is
    detected within the deadline, auto-evicted and its in-flight work
    recomputed by the master, and ``failures`` records the event.

    ``clock`` injects the time source behind every master-side deadline
    (joins, heartbeat expiry, shutdown waits) so tests can drive them
    without real waiting; defaults to ``time.monotonic`` and is passed
    through to each ``TCPTransport``.  Emulation sleeps (slowdown /
    bandwidth stretching) intentionally stay on the real clock.
    """

    def __init__(
        self,
        slowdowns: Sequence[float],
        backends: Optional[Sequence[str]] = None,
        *,
        pipeline: bool = False,
        microbatches: int = 4,
        bandwidth_mbps: Union[None, float, Sequence[Optional[float]]] = None,
        comp_aware: bool = True,
        partition: str = "kernel",
        wire_dtype: Optional[str] = None,
        wire_codec: Optional[str] = None,
        weight_cache: bool = True,
        transport: str = "inproc",
        master_nic_mbps: Optional[float] = None,
        expected_slaves: Optional[int] = None,
        listen_host: str = "127.0.0.1",
        listen_port: int = 0,
        heartbeat_s: Optional[float] = None,
        heartbeat_timeout_s: Optional[float] = None,
        join_timeout_s: float = 300.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        self._clock = clock  # first: deadline math below and in helpers uses it
        assert len(slowdowns) >= 1
        if any(sd < 1.0 for sd in slowdowns):
            # the op-level emulation can only SLEEP (slowdown-1)x the
            # measured compute — it cannot make the host faster — so a
            # sub-1 slowdown would probe fast (probe_conv_time scales
            # both directions) yet compute at 1.0x, and Eq. 1 would
            # overfeed the device.  Emulate faster devices with a
            # parameterized sim backend instead.
            raise ValueError(
                f"slowdowns must be >= 1.0 (got {list(slowdowns)}): the "
                f"cluster emulates slower devices by sleeping; for a "
                f"FASTER virtual device use a parameterized sim backend, "
                f"e.g. backends=['sim:5e9', ...]"
            )
        if expected_slaves is not None:
            if transport != "tcp":
                raise ValueError(
                    "expected_slaves waits for external TCP joins; it "
                    "needs transport='tcp'"
                )
            if expected_slaves < 1:
                raise ValueError("expected_slaves must be >= 1")
            if len(slowdowns) != 1 or (backends is not None and len(backends) != 1):
                raise ValueError(
                    "with expected_slaves, pass ONLY the master's "
                    "slowdown/backend — joining slaves bring their own "
                    "in the hello handshake"
                )
        self.slowdowns = list(slowdowns)
        if backends is None:
            backends = ["numpy"] * len(self.slowdowns)
        assert len(backends) == len(self.slowdowns), "one backend per device"
        self.backends = list(backends)
        # resolve every LOCAL name NOW: an unknown backend must raise
        # here, not kill a slave later and leave the master blocked
        # forever.  (External joiners' backends run on THEIR host and
        # are recorded as-is.)
        for name in self.backends:
            get_backend(name)
        self._master_backend = get_backend(self.backends[0])
        self.pipeline = bool(pipeline)
        self.microbatches = int(microbatches)
        if partition not in plans.PARTITION_MODES:
            raise ValueError(
                f"partition must be one of {plans.PARTITION_MODES}, "
                f"got {partition!r}"
            )
        self.partition = partition
        # auto's per-layer picks, keyed (x_shape, w_shape), plus the
        # memo that lets repeated serve slabs skip the predictor — both
        # bounded (dynamic batching mints a key per slab batch size) and
        # both invalidated together on any membership change
        self.partition_choices: Dict[tuple, str] = plans.BoundedDict()
        self._mode_cache: Dict[tuple, str] = plans.BoundedDict()
        if wire_codec is not None and wire_dtype is not None:
            raise ValueError(
                "pass wire_codec OR wire_dtype, not both: wire_codec "
                "subsumes the single-dtype knob (wire_codec='fp16' is "
                "the same stack)"
            )
        self.wire_dtype = wire_dtype
        self.wire_codec = wire_codec
        self._wire_np_dtype = codec.resolve_wire_dtype(wire_dtype)
        # the codec TEMPLATE prices the wire for the Eq. 1(+comm) byte
        # predictions; every link gets its own instance from the same
        # spec (top-k error-feedback state is per destination)
        self._codec_cfg = codec.WireCodec.from_spec(wire_codec, wire_dtype)
        self._wire_itemsize = self._codec_cfg.itemsize("acts")
        self._wire_itemsize_w = self._codec_cfg.itemsize("weights")
        self._wire_itemsize_g = self._codec_cfg.itemsize("grads")
        self.weight_cache = bool(weight_cache)
        # versioned weight-broadcast cache, master side: what version of
        # each keyed kernel is current, and which (version, geometry)
        # token each live link last received for it
        self._wstore: Dict[object, Tuple[int, np.ndarray]] = {}
        self._wshipped: Dict[Transport, dict] = {}
        if transport not in TRANSPORT_KINDS:
            raise ValueError(
                f"transport must be one of {TRANSPORT_KINDS}, got {transport!r}"
            )
        self.transport = transport
        if master_nic_mbps is not None and transport != "inproc":
            raise ValueError(
                "master_nic_mbps is bandwidth EMULATION for the in-proc "
                "wire; tcp/shm links share the host's real NIC already"
            )
        self.master_nic_mbps = master_nic_mbps
        self._nic = (
            SharedNIC(master_nic_mbps) if master_nic_mbps is not None else None
        )
        n_cfg = (
            expected_slaves if expected_slaves is not None
            else len(self.slowdowns) - 1
        )
        if bandwidth_mbps is None or isinstance(bandwidth_mbps, (int, float)):
            self.bandwidths: List[Optional[float]] = [bandwidth_mbps] * n_cfg
        else:
            self.bandwidths = list(bandwidth_mbps)
            assert len(self.bandwidths) == n_cfg, "one bandwidth per slave"
        # what the USER pinned, frozen: re-probing on tcp must overwrite
        # stale measurements, never a deliberate override (and never
        # mistake an old measurement for one)
        self._bandwidth_overrides = list(self.bandwidths)
        if heartbeat_s is not None and heartbeat_s <= 0:
            raise ValueError("heartbeat_s must be positive (or None)")
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout_s = (
            heartbeat_timeout_s
            if heartbeat_timeout_s is not None
            else (3.0 * heartbeat_s if heartbeat_s is not None else None)
        )
        self.expected_slaves = expected_slaves
        self.listen_host = listen_host
        self.listen_port = listen_port
        # -- elastic membership: aligned per-slave slots -------------------
        # slot i <-> sockets[i], procs[i], threads[i], slave_ids[i],
        # slowdowns[i+1], backends[i+1], bandwidths[i], measured[i].
        # slave_ids are STABLE (never reused): live plans reference the
        # membership they were built for through them (LayerPlan.member_ids
        # -> _registry), so a plan outlives any eviction.
        self.n_slaves = 0
        self.slave_ids: List[int] = []
        self._next_slave_id = 1
        self._registry: Dict[int, Transport] = {}  # every slave EVER, dead too
        # each member's hello metadata by device id ({} for in-proc
        # threads, which have no handshake): an open dict — sub-masters
        # ride a "group" entry through it without touching the grammar
        self.hello_meta: Dict[int, dict] = {}
        self.sockets: List[Transport] = []
        self.procs: List[Optional[subprocess.Popen]] = []
        self.threads: List[Optional[threading.Thread]] = []
        self.reaped: List[subprocess.Popen] = []  # evicted/killed, waited on
        self.failures: List[dict] = []  # {"device", "t_detected", "error"}
        self.probe_times: Optional[List[float]] = None
        self.probe_flops: Optional[float] = None  # flops of the probe workload
        self._probe_kwargs: Optional[dict] = None  # last probe() workload
        self.measured_bandwidths: List[Optional[float]] = [None] * n_cfg
        self._listener: Optional[TCPListener] = None
        self._token: Optional[bytes] = None
        self.timing = scheduler.LayerTiming()
        self.comp_aware = bool(comp_aware)
        self.comp_duty = 0.0  # measured master non-conv duty (see shares_for)
        self._duty_mark = (0.0, 0.0)  # (comp_s, master_conv_s) at last update
        self._seq_issued = 0
        self._seq_gathered = 0
        self._shut = False
        if transport in ("tcp", "shm"):
            listener_cls = ShmListener if transport == "shm" else TCPListener
            self._listener = listener_cls(listen_host, listen_port)
            if expected_slaves is None:
                self._token = secrets.token_bytes(self._AUTH_BYTES)
                self._spawn_tcp_slaves()
            else:
                # the join secret comes from the operator's environment —
                # hand-launched (possibly remote) slaves must present the
                # same one, and there is no side channel to hand a
                # generated secret to another terminal/host
                env_tok = os.environ.get("REPRO_CLUSTER_AUTH")
                if not env_tok:
                    self._listener.close()
                    raise RuntimeError(
                        "expected_slaves mode needs the REPRO_CLUSTER_AUTH "
                        "env var set (hex token) in BOTH the master's and "
                        "every slave's environment: the wire is pickle, "
                        "and an unauthenticated listener would hand any "
                        "process that can reach it code execution here.  "
                        "Generate one with: python -c 'import secrets; "
                        "print(secrets.token_hex(32))'"
                    )
                self._token = bytes.fromhex(env_tok)
                if len(self._token) != self._AUTH_BYTES:
                    self._listener.close()
                    raise RuntimeError(
                        f"REPRO_CLUSTER_AUTH must be {self._AUTH_BYTES} "
                        f"bytes ({2 * self._AUTH_BYTES} hex chars), got "
                        f"{len(self._token)} bytes"
                    )
                try:
                    self._await_tcp_joins(expected_slaves, join_timeout_s)
                except Exception:
                    # failed startup must not leak the listener or the
                    # links of slaves that DID join (EOF tells them to
                    # exit; their operators own the processes)
                    for s in self.sockets:
                        s.close()
                    self._listener.close()
                    raise
        else:
            for sd, bk, bw in zip(
                self.slowdowns[1:], self.backends[1:], self.bandwidths
            ):
                self._start_inproc_slave(sd, bk, bw)
            self._apply_nic_planning()

    # -- membership plumbing: slots, spawn, accept, join -------------------
    _AUTH_BYTES = 32

    def _add_slot(
        self,
        dev: int,
        sock: Transport,
        proc: Optional[subprocess.Popen],
        thread: Optional[threading.Thread],
    ) -> None:
        """Append one live slave slot; every aligned list grows by one."""
        self.slave_ids.append(dev)
        self._registry[dev] = sock
        self.sockets.append(sock)
        self.procs.append(proc)
        self.threads.append(thread)
        self.n_slaves = len(self.sockets)

    def _link_codec(self) -> codec.WireCodec:
        """A fresh codec instance for ONE link — never shared: top-k
        error-feedback residuals accumulate per destination."""
        return codec.WireCodec.from_spec(self.wire_codec, self.wire_dtype)

    def _start_inproc_slave(
        self, slowdown: float, backend: str, bandwidth: Optional[float]
    ) -> int:
        link = InProcTransport(
            bandwidth, self._wire_np_dtype, wire_codec=self._link_codec(),
            nic=self._nic,
        )
        dev = self._next_slave_id
        self._next_slave_id += 1
        t = threading.Thread(
            target=protocol.slave_loop,
            args=(link.slave_endpoint(), slowdown, backend, dev),
            daemon=True,
        )
        t.start()
        self._add_slot(dev, link, None, t)
        self.hello_meta[dev] = {}
        return dev

    def _apply_nic_planning(self) -> None:
        """Fold the shared master NIC into the PLANNING bandwidths: with
        one emulated port serialized across n links, each link's fair
        steady-state share is nic/n — the static approximation Eq. 1
        prices (per-message serialization is runtime emulation, not
        plannable).  Explicit per-link overrides win (a link can be
        narrower than its NIC share); no-op without a NIC."""
        if self._nic is None or self.n_slaves == 0:
            return
        share = self._nic.bandwidth_mbps / self.n_slaves
        self.bandwidths = [
            ovr if ovr is not None else share
            for ovr in self._bandwidth_overrides
        ]

    def _slave_env(self) -> dict:
        """Environment for a spawned slave process: the src/ import root
        and the per-cluster auth secret (env, not argv — argv shows in
        ps)."""
        env = os.environ.copy()
        src = _src_pythonpath()
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        env["REPRO_CLUSTER_AUTH"] = self._token.hex()
        return env

    def _slave_cmd(self, dev: int, slowdown: float, backend: str) -> list:
        """The argv a spawned slave process runs — a seam subclasses
        extend (the hierarchy appends ``--group-*`` flags to turn the
        process into a sub-master).  The auth token is NOT here: it
        rides the environment (argv shows in ps)."""
        # a listener bound to the wildcard interface is not a connect
        # target; local spawns dial loopback
        host = (
            "127.0.0.1" if self._listener.host == "0.0.0.0"
            else self._listener.host
        )
        cmd = [
            sys.executable, "-m", "repro.core.cluster.protocol",
            "--host", host,
            "--port", str(self._listener.port),
            "--device", str(dev),
            "--slowdown", str(slowdown),
            "--backend", backend,
        ]
        if self.transport == "shm":
            cmd += ["--transport", "shm"]
        if self.wire_dtype is not None:
            cmd += ["--wire-dtype", self.wire_dtype]
        if self.wire_codec is not None:
            cmd += ["--wire-codec", self.wire_codec]
        if self.heartbeat_s is not None:
            cmd += ["--heartbeat-s", str(self.heartbeat_s)]
        return cmd

    def _spawn_slave_proc(
        self, dev: int, slowdown: float, backend: str, env: dict
    ) -> subprocess.Popen:
        return subprocess.Popen(
            self._slave_cmd(dev, slowdown, backend), env=env
        )

    def _accept_slave(self, timeout_s: float) -> Tuple[TCPTransport, int, dict]:
        """Accept + authenticate + handshake ONE joining slave, skipping
        over junk connections.

        Connections are AUTHENTICATED before anything is unpickled: the
        joiner must present the per-cluster token as its first raw
        bytes.  The wire is pickle, so an unauthenticated listener
        would hand any process that can reach it arbitrary code
        execution in the master.  A connection that fails the handshake
        — no/wrong token, EOF, silence, garbled hello — is closed and
        REJECTED, and the accept loop keeps waiting for a real slave
        until ``timeout_s`` runs out: on an exposed listener a port
        scanner or health check must never abort cluster startup.  The
        hello frame carries the requested device slot (-1 = assign one)
        and the joiner's backend/slowdown metadata; the master replies
        ("welcome", dev) — it owns device numbering, and ids are never
        reused so live plans can keep naming dead members."""
        deadline = self._clock() + timeout_s
        while True:
            remaining = deadline - self._clock()
            if remaining <= 0:
                raise TimeoutError(
                    f"no valid slave joined within {timeout_s:.0f}s"
                )
            conn = self._listener.accept(timeout_s=remaining)
            conn.settimeout(10.0)  # a silent stranger must not hang us
            chan: Optional[TCPTransport] = None
            try:
                presented = _recv_exact(conn, self._AUTH_BYTES)
                if not hmac.compare_digest(presented, self._token):
                    raise RuntimeError(
                        "connection did not present the cluster auth "
                        "token (stray process, or REPRO_CLUSTER_AUTH "
                        "mismatch?)"
                    )
                # the 10s timeout stays armed through the hello so a
                # peer that authenticates then stalls cannot hang us
                chan_cls = (
                    ShmTransport if self.transport == "shm" else TCPTransport
                )
                chan = chan_cls(
                    conn, self._wire_np_dtype,
                    heartbeat_timeout_s=self.heartbeat_timeout_s,
                    clock=self._clock,
                    wire_codec=self._link_codec(),
                )
                requested, meta = protocol.parse_hello(chan.read_on_master())
            except (OSError, EOFError, RuntimeError) as e:
                if chan is not None:
                    chan.close()
                else:
                    conn.close()
                print(
                    f"[hetero] rejected a connection on the cluster "
                    f"listener: {e}",
                    file=sys.stderr, flush=True,
                )
                continue
            conn.settimeout(None)  # ops block indefinitely from here on
            if requested >= 1 and requested not in self._registry:
                dev = requested
                self._next_slave_id = max(self._next_slave_id, dev + 1)
            else:
                dev = self._next_slave_id
                self._next_slave_id += 1
            chan.write_to_slave(("welcome", dev))
            return chan, dev, meta

    def _spawn_tcp_slaves(self) -> None:
        """Spawn one OS process per configured slave, accept their
        connections back, and register the channels in device order
        (accept order is whoever wins the connect race; the hello
        handshake re-sorts)."""
        env = self._slave_env()
        pending: Dict[int, subprocess.Popen] = {}
        for sd, bk in zip(self.slowdowns[1:], self.backends[1:]):
            dev = self._next_slave_id
            self._next_slave_id += 1
            pending[dev] = self._spawn_slave_proc(dev, sd, bk, env)
        by_device: Dict[int, TCPTransport] = {}
        metas: Dict[int, dict] = {}
        try:
            for _ in range(len(pending)):
                chan, dev, meta = self._accept_slave(timeout_s=60.0)
                # RuntimeError, not assert: -O must not let a malformed
                # handshake mispair device channels
                if dev not in pending or dev in by_device:
                    raise RuntimeError(
                        f"unexpected device id {dev} in spawn handshake "
                        f"(expected one of {sorted(pending)})"
                    )
                by_device[dev] = chan
                metas[dev] = meta
        except Exception:
            for p in pending.values():
                p.kill()
            self._listener.close()
            raise
        for dev in sorted(by_device):
            by_device[dev].reset_counters()  # handshake isn't protocol traffic
            self._add_slot(dev, by_device[dev], pending[dev], None)
            self.hello_meta[dev] = metas[dev]

    def _await_tcp_joins(self, n: int, timeout_s: float) -> None:
        """Wait for ``n`` hand-launched slaves to join the listener —
        the remote-host path.  Each joiner's backend/slowdown come from
        its hello metadata; the wait is announced on stderr so the
        operator knows where to point the slaves."""
        print(
            f"[hetero] waiting for {n} slave(s) on "
            f"{self._listener.host}:{self._listener.port} "
            f"(auth: REPRO_CLUSTER_AUTH)",
            file=sys.stderr, flush=True,
        )
        deadline = self._clock() + timeout_s
        for _ in range(n):
            chan, dev, meta = self._accept_slave(
                timeout_s=max(1.0, deadline - self._clock())
            )
            self.slowdowns.append(float(meta.get("slowdown", 1.0)))
            self.backends.append(str(meta.get("backend", "numpy")))
            chan.reset_counters()
            self._add_slot(dev, chan, None, None)
            self.hello_meta[dev] = meta
            print(
                f"[hetero] slave {dev} joined "
                f"(backend={self.backends[-1]}, "
                f"slowdown={self.slowdowns[-1]})",
                file=sys.stderr, flush=True,
            )

    # -- elastic membership: admit / evict / loss --------------------------
    @property
    def auth_token_hex(self) -> Optional[str]:
        """The cluster's join secret (hex), for handing to a slave an
        operator launches AFTER the cluster came up (``admit(
        spawn=False)``): export it as REPRO_CLUSTER_AUTH in the slave's
        environment.  None on the in-proc transport (no listener)."""
        return self._token.hex() if self._token is not None else None

    @property
    def listen_address(self) -> Optional[Tuple[str, int]]:
        """(host, port) a joining slave should dial, or None (inproc)."""
        if self._listener is None:
            return None
        return self._listener.host, self._listener.port

    def admit(
        self,
        slowdown: float = 1.0,
        backend: str = "numpy",
        *,
        bandwidth_mbps: Optional[float] = None,
        spawn: bool = True,
        timeout_s: float = 120.0,
        probe_time: Optional[float] = None,
    ) -> int:
        """Grow the running cluster by one slave and fold it into the
        next plan's comm-aware Eq. 1 split.  Returns the new device id.

        ``spawn=True`` starts it here: a slave thread (inproc) or a
        local subprocess (tcp) with the given slowdown/backend.
        ``spawn=False`` (tcp only) WAITS for an external join — a slave
        someone launched by hand via ``python -m
        repro.core.cluster.protocol`` on any reachable host; its
        backend/slowdown come from the hello handshake.

        If the cluster has probe times, the newcomer is probed with the
        same workload (or takes the explicit ``probe_time`` — pass one
        when ``probe_times`` were pinned by hand, as the benches do,
        so the synthetic scale stays consistent); on tcp its link
        bandwidth is measured.  In-flight plans are untouched — they
        bind the old membership — and ``partition_choices`` is cleared
        so auto re-resolves per layer."""
        if self._shut:
            raise RuntimeError("cluster is shut down")
        if slowdown < 1.0 and spawn:
            raise ValueError("slowdowns must be >= 1.0 (see __init__)")
        if self.transport == "inproc":
            if not spawn:
                raise ValueError(
                    "inproc slaves are threads in this process; external "
                    "joins (spawn=False) need transport='tcp'"
                )
            get_backend(backend)  # fail here, not in the slave thread
            self._start_inproc_slave(slowdown, backend, bandwidth_mbps)
            self.slowdowns.append(slowdown)
            self.backends.append(backend)
        else:
            dev_hint = None
            if spawn:
                get_backend(backend)
                dev_hint = self._next_slave_id
                self._next_slave_id += 1
                proc = self._spawn_slave_proc(
                    dev_hint, slowdown, backend, self._slave_env()
                )
            else:
                proc = None
            try:
                chan, dev, meta = self._accept_slave(timeout_s=timeout_s)
            except Exception:
                # never leak the just-spawned process on a failed accept
                # (it holds the auth token and would retry forever)
                if proc is not None:
                    proc.kill()
                    proc.wait(timeout=5)
                raise
            if spawn and dev != dev_hint:
                # an external joiner won the accept race: keep IT (its
                # hello metadata applies) and abort our spawn attempt —
                # pairing our Popen with a stranger's channel would make
                # a later evict kill the wrong process
                proc.kill()
                proc.wait(timeout=5)
                proc = None
                spawn = False
            if not spawn:
                slowdown = float(meta.get("slowdown", 1.0))
                backend = str(meta.get("backend", "numpy"))
            chan.reset_counters()
            self.slowdowns.append(slowdown)
            self.backends.append(backend)
            self._add_slot(dev, chan, proc, None)
            self.hello_meta[dev] = meta
        self.bandwidths.append(bandwidth_mbps)
        self._bandwidth_overrides.append(bandwidth_mbps)
        self.measured_bandwidths.append(None)
        self._apply_nic_planning()
        sock, dev = self.sockets[-1], self.slave_ids[-1]
        if self.transport in ("tcp", "shm"):
            try:
                meas = sock.measure_bandwidth_mbps()
            except SlaveLost as e:
                self._on_slave_lost(sock, e)
                raise
            self.measured_bandwidths[-1] = meas
            if self._bandwidth_overrides[-1] is None:
                self.bandwidths[-1] = meas
        if self.probe_times is not None:
            if probe_time is None:
                kw = self._probe_kwargs or dict(
                    image_size=16, in_channels=3, kernel_size=3,
                    num_kernels=8, batch=4, repeats=1,
                )
                try:
                    sock.write_to_slave(("probe", kw))
                    probe_time = self._check_result(sock.read_on_master())
                except SlaveLost as e:
                    self._on_slave_lost(sock, e)
                    raise
            self.probe_times.append(float(probe_time))
        self.partition_choices.clear()
        self._mode_cache.clear()
        return dev

    def evict(self, device: int) -> None:
        """Gracefully retire slave ``device`` (its stable id): it is
        told to exit, reaped, and removed from membership; the next
        plan re-runs the comm-aware Eq. 1 over the survivors.  Plans
        already in flight keep naming it and the master absorbs its
        shards — an evict mid-step is safe, just not free."""
        if device not in self.slave_ids:
            raise KeyError(
                f"no live slave with device id {device}; live: "
                f"{self.slave_ids}"
            )
        pos = self.slave_ids.index(device)
        sock = self.sockets[pos]
        try:
            sock.write_to_slave(protocol.TRAIN_OVER)
        except RuntimeError:  # link already down; remove it anyway
            pass
        self._remove_slot(pos, kill=False)

    def _remove_slot(self, pos: int, *, kill: bool) -> None:
        """Drop slot ``pos`` from every aligned membership list.  The
        socket is marked lost FIRST so any plan that still names this
        member routes its shards to the master's recovery path."""
        sock = self.sockets[pos]
        sock.lost = True
        proc, thread = self.procs[pos], self.threads[pos]
        if kill and proc is not None:
            proc.kill()
        if thread is not None:
            thread.join(timeout=10)
        if proc is not None:
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:  # pragma: no cover - stuck exit
                proc.kill()
                proc.wait(timeout=5)
            self.reaped.append(proc)
        sock.close()
        self._wshipped.pop(sock, None)  # its weight-cache tokens die with it
        had = self.n_slaves
        for lst in (
            self.slave_ids, self.sockets, self.procs, self.threads,
            self.measured_bandwidths,
        ):
            del lst[pos]
        del self.slowdowns[pos + 1]
        del self.backends[pos + 1]
        del self.bandwidths[pos]
        del self._bandwidth_overrides[pos]
        if self.probe_times is not None and len(self.probe_times) == had + 1:
            del self.probe_times[pos + 1]
        self.n_slaves = len(self.sockets)
        self._apply_nic_planning()
        self.partition_choices.clear()
        self._mode_cache.clear()

    def _on_slave_lost(self, sock: Transport, err: BaseException) -> None:
        """A link reported its slave dead: record the failure, kill any
        local process remnant, and auto-evict the slot.  Idempotent —
        a slave's loss may surface on several reads."""
        sock.lost = True
        if sock not in self.sockets:
            return  # already evicted
        pos = self.sockets.index(sock)
        self.failures.append({
            "device": self.slave_ids[pos],
            "t_detected": self._clock(),
            "error": str(err),
        })
        self._remove_slot(pos, kill=True)

    def _plan_sockets(self, plan: plans.LayerPlan) -> List[Transport]:
        """The participant links of a plan, in plan order — resolved
        through the stable-id registry so a plan built before an
        evict/admit still addresses exactly the members it split for."""
        if plan.member_ids is None:
            return list(self.sockets)
        return [self._registry[d] for d in plan.member_ids]

    # -- §4.1.1 pre-processing -------------------------------------------
    def probe(self, **probe_kwargs) -> List[float]:
        """Every device runs the timed reference convolution on its OWN
        backend — sequential so the 1-core host's timings do not
        interfere.  Also records the probe workload's FLOPs (the scale
        factor that lets the comm-aware partitioner and the auto axis
        chooser turn probe times into absolute per-layer predictions)
        and, on the tcp transport, each link's measured round-trip
        bandwidth — the real wire feeds ``link_aware_times`` instead of
        the ``bandwidth_mbps`` knob.  A slave lost mid-probe is
        auto-evicted and the times cover the survivors."""
        master_t = probe_conv_time(
            self._master_backend, slowdown=self.slowdowns[0], **probe_kwargs
        )
        slave_ts: Dict[Transport, float] = {}
        for s in list(self.sockets):
            try:
                s.write_to_slave(("probe", probe_kwargs))
                slave_ts[s] = self._check_result(s.read_on_master())
            except SlaveLost as e:
                self._on_slave_lost(s, e)
        if self.transport in ("tcp", "shm"):
            measured: Dict[Transport, Optional[float]] = {}
            for s in list(self.sockets):
                try:
                    measured[s] = s.measure_bandwidth_mbps()
                except SlaveLost as e:
                    self._on_slave_lost(s, e)
            self.measured_bandwidths = [measured.get(s) for s in self.sockets]
            # an explicit constructor bandwidth_mbps stays an override for
            # planning; otherwise every probe() refreshes the measurement
            self.bandwidths = [
                ovr if ovr is not None else meas
                for ovr, meas in zip(
                    self._bandwidth_overrides, self.measured_bandwidths
                )
            ]
        self.probe_times = [master_t] + [
            slave_ts[s] for s in self.sockets if s in slave_ts
        ]
        self.probe_flops = (
            2.0
            * probe_kwargs["batch"]
            * probe_kwargs["image_size"] ** 2
            * probe_kwargs["kernel_size"] ** 2
            * probe_kwargs["in_channels"]
            * probe_kwargs["num_kernels"]
        )
        self._probe_kwargs = dict(probe_kwargs)
        return self.probe_times

    def _effective_times(self) -> List[float]:
        """Probe times with the comp-aware master discount applied."""
        assert self.probe_times is not None, "run probe() first"
        times = self.probe_times
        if self.comp_aware and self.comp_duty > 0.0:
            times = effective_times(
                times, comp_duties={0: self.comp_duty}
            )
        return list(times)

    def shares_for(
        self,
        num_kernels: int,
        *,
        unit_bytes: float = 0.0,
        layer_flops: Optional[float] = None,
    ) -> np.ndarray:
        """Eq. 1 unit counts (kernels or rows) from the probe times; with
        ``comp_aware`` the master's measured non-conv duty discounts its
        share.  When the layer's wire cost is known (``unit_bytes`` per
        unit, ``layer_flops`` to scale probe times to this layer) and the
        links are finite, each slave's comm term joins its compute term —
        the comm-extended Eq. 1 (partitioner.effective_times)."""
        times = self._effective_times()
        if (
            unit_bytes > 0.0
            and layer_flops
            and self.probe_flops
            and any(bw is not None for bw in self.bandwidths)
        ):
            scale = layer_flops / self.probe_flops
            wire = [0.0] + [
                float(num_kernels) * unit_bytes if bw is not None else 0.0
                for bw in self.bandwidths
            ]
            times = effective_times(
                [t * scale for t in times],
                wire_bytes=wire,
                bandwidths_mbps=[None] + list(self.bandwidths),
            )
        return allocate_kernels(num_kernels, times)

    def _update_comp_duty(self):
        """Refresh the measured non-conv duty — the fraction of the
        master's busy time spent OUTSIDE its conv shard — from the window
        since the LAST update (deltas, not cumulative): a one-off cost in
        an early step (jit compilation of the master-only stages, cold
        caches) then mis-shapes at most the next step's shares before the
        first clean window corrects it."""
        t = self.timing
        dc = t.comp_s - self._duty_mark[0]
        dm = t.master_conv_s - self._duty_mark[1]
        self._duty_mark = (t.comp_s, t.master_conv_s)
        if dc + dm > 0.0:
            self.comp_duty = dc / (dc + dm)

    # -- partition planning (core/cluster/plans.py) -----------------------
    def _unit_bytes(self, x_shape, w_shape, mode: str, op: str) -> float:
        return plans.unit_bytes(
            x_shape, w_shape, mode, op, self._wire_itemsize,
            w_itemsize=self._wire_itemsize_w,
            g_itemsize=self._wire_itemsize_g,
        )

    # -- versioned weight-broadcast cache ---------------------------------
    def _weight_version(self, key, w: np.ndarray) -> Tuple[int, bool]:
        """The cache version of kernel ``w`` under ``key``, and whether
        the slaves may already hold it.  Identity, not equality: the
        serve lane holds one kernel OBJECT across every request (hit),
        a training loop makes a new array each step (miss + bump) —
        and an elementwise compare of every kernel every microbatch
        would eat the bytes the cache saves."""
        cur = self._wstore.get(key)
        if cur is not None and cur[1] is w:
            return cur[0], True
        version = cur[0] + 1 if cur is not None else 0
        self._wstore[key] = (version, w)
        return version, False

    def _wire_weights(
        self, sock: Transport, plan: plans.LayerPlan, pos: int,
        shard: Optional[np.ndarray], send_weights: bool,
    ):
        """The weight slot for plan position ``pos``'s scatter to
        ``sock``.  Legacy path (no ``plan.wkey``): the raw shard, or
        ``None`` for "reuse your per-op cache".  Versioned path: a
        ``WeightRef`` — bare token when this link already received this
        exact (version, geometry, position), kernel attached otherwise,
        so an unchanged serve kernel crosses each link once."""
        if plan.wkey is None:
            return shard if send_weights else None
        token = (
            plan.wversion, plan.mode,
            tuple(int(c) for c in plan.counts), pos,
        )
        shipped = self._wshipped.setdefault(sock, {})
        if shipped.get(plan.wkey) == token:
            return codec.WeightRef(plan.wkey, plan.wversion, None)
        shipped[plan.wkey] = token
        return codec.WeightRef(plan.wkey, plan.wversion, shard)

    def predict_partition_seconds(
        self, x_shape, w_shape, op: str = "conv"
    ) -> Dict[str, float]:
        """Predicted wall-clock per partition mode for one layer —
        the Eq. 1(+comm) model over this cluster's probe times and
        link bandwidths (see ``plans.predict_partition_seconds``).

        Args:
            x_shape: input activation shape ``(B, H, W, Cin)``.
            w_shape: kernel shape ``(kh, kw, Cin, Cout)``.
            op: ``"conv"`` | ``"bwd"`` | ``"train"`` — which sweep(s)
                the prediction weighs.

        Returns:
            dict mode -> predicted seconds, for every eligible mode.
        """
        return plans.predict_partition_seconds(self, x_shape, w_shape, op)

    def _resolve_mode(
        self, x_shape, w_shape, override: Optional[str], op: str = "conv"
    ) -> str:
        return plans.resolve_mode(self, x_shape, w_shape, override, op)

    def plan_conv(
        self, x_shape, w: np.ndarray, op: str = "conv",
        partition: Optional[str] = None, weight_key=None,
    ) -> plans.LayerPlan:
        """Build the partition plan one conv layer rides: resolve the
        split axis, cut the Eq. 1(+comm) shares over the CURRENT
        membership, and pre-split kernels/rows/halos.

        Args:
            x_shape: input activation shape ``(B, H, W, Cin)``.
            w: the layer's full kernel ``(kh, kw, Cin, Cout)``.
            op: ``"conv"`` | ``"bwd"`` | ``"train"`` — what the plan
                will be used for (weighs the auto-axis choice).
            partition: per-call override of the cluster's axis
                (``"kernel"`` | ``"spatial"`` | ``"batch"`` |
                ``"auto"``).
            weight_key: stable key opting this layer into the
                versioned weight-broadcast cache (None = legacy
                per-op caching only).

        Returns:
            A ``plans.LayerPlan`` naming members by stable slave id.
        """
        return plans.plan_conv(self, x_shape, w, op, partition, weight_key)

    # -- async scatter/gather halves -------------------------------------
    def _split(self, w: np.ndarray, counts: np.ndarray) -> List[np.ndarray]:
        return plans.split_kernels(w, counts)

    def scatter_conv(
        self, x: np.ndarray, w: np.ndarray, *, partition: Optional[str] = None
    ) -> scheduler.Pending:
        """Scatter one conv: broadcast x + kernel shards (kernel mode),
        height strips + the full kernel (spatial mode), or batch-row
        slices + the replicated kernel (batch mode); returns a handle.
        The master's own shard runs at gather time."""
        x = np.asarray(x, np.float32)
        plan = self.plan_conv(x.shape, w, "conv", partition)
        return self._scatter_conv_planned(x, plan, send_weights=True)

    def _write_op(self, sock, msg) -> None:
        """One scatter write; a link that died under the write is folded
        into the loss path (its shard will be recomputed at the gather)
        instead of aborting the step."""
        if sock.lost:
            return
        try:
            sock.write_to_slave(msg)
        except SlaveLost as e:
            self._on_slave_lost(sock, e)

    def _scatter_conv_planned(
        self, x: np.ndarray, plan: plans.LayerPlan, send_weights: bool
    ) -> scheduler.Pending:
        if plan.mode == "kernel":
            return self._scatter_conv_shards(x, plan, send_weights)
        if plan.mode == "batch":
            return self._scatter_conv_batch(x, plan, send_weights)
        socks = self._plan_sockets(plan)
        t0 = time.perf_counter()
        for pos, (sock, (lo, hi, pt, pb)) in enumerate(
            zip(socks, plan.halos[1:]), start=1
        ):
            ws = self._wire_weights(sock, plan, pos, plan.w, send_weights)
            self._write_op(sock, ("sconv", (x[:, lo:hi], ws, pt, pb)))
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        return scheduler.Pending(
            "conv", self._seq_issued, x, plan.w, None, now,
            mode="spatial", rows=plan.rows, halos=plan.halos,
            plan=plan, parts=socks,
        )

    def _scatter_conv_shards(
        self, x: np.ndarray, plan: plans.LayerPlan, send_weights: bool
    ) -> scheduler.Pending:
        """send_weights=False sends w=None: the slave reuses its cached
        shard, so pipelined microbatches pay the weight traffic once."""
        socks = self._plan_sockets(plan)
        t0 = time.perf_counter()
        for pos, (sock, shard) in enumerate(
            zip(socks, plan.shards[1:]), start=1
        ):
            ws = self._wire_weights(sock, plan, pos, shard, send_weights)
            self._write_op(sock, ("conv", (x, ws)))
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        return scheduler.Pending(
            "conv", self._seq_issued, x, plan.shards[0], None, now,
            plan=plan, parts=socks,
        )

    def _scatter_conv_batch(
        self, x: np.ndarray, plan: plans.LayerPlan, send_weights: bool
    ) -> scheduler.Pending:
        """Batch axis: each member gets its N-axis row slice plus the
        full replicated kernel (a ~24-byte ``WeightRef`` token after the
        first ship, weight cache on).  The plan's proportions are re-cut
        to THIS slab's batch size (``plans.batch_ranges``) so pipelined
        microbatches — whose N differs from the planning shape — keep
        the Eq. 1 shares; the actual ranges ride the ``Pending`` for the
        gather and the lost-slave recovery path."""
        socks = self._plan_sockets(plan)
        rows = plans.batch_ranges(plan.counts, x.shape[0])
        t0 = time.perf_counter()
        for pos, (sock, (r0, r1)) in enumerate(zip(socks, rows[1:]), start=1):
            ws = self._wire_weights(sock, plan, pos, plan.w, send_weights)
            self._write_op(sock, ("conv", (x[r0:r1], ws)))
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        return scheduler.Pending(
            "conv", self._seq_issued, x, plan.w, None, now,
            mode="batch", rows=rows, plan=plan, parts=socks,
        )

    def gather_conv(self, p: scheduler.Pending) -> np.ndarray:
        """Compute the master's shard, collect the slaves' feature maps
        (FIFO: gathers must be issued in scatter order), concatenate —
        along channels (kernel mode), height (spatial strips), or the
        N axis (batch rows).  A participant lost since the scatter
        contributes via the master's recovery compute instead of the
        wire."""
        self._check_order(p, "conv")
        t0 = time.perf_counter()
        if p.mode == "spatial":
            lo, hi, pt, pb = p.halos[0]
            my_out = self._master_compute(
                lambda: strip_conv(self._master_backend, p.x[:, lo:hi], p.my_w, pt, pb)
            )
            axis = 1
        elif p.mode == "batch":
            r0, r1 = p.rows[0]
            my_out = self._master_compute(
                lambda: protocol.conv_shard(
                    self._master_backend, p.x[r0:r1], p.my_w
                )
            )
            axis = 0
        else:
            my_out = self._master_compute(
                lambda: protocol.conv_shard(self._master_backend, p.x, p.my_w)
            )
            axis = -1
        outs = [my_out]
        t_wait = time.perf_counter()
        for idx, sock in enumerate(p.parts):
            outs.append(self._read_or_recover(sock, p, idx))
        t1 = time.perf_counter()
        self._account_gather(p, t0, t_wait, t1)
        return np.concatenate(outs, axis=axis)

    def scatter_bwd(
        self, x: np.ndarray, w: np.ndarray, g: np.ndarray,
        *, partition: Optional[str] = None,
    ) -> scheduler.Pending:
        """Issue the backward (VJP) halves: plan, ship each member its
        input + kernel shard + grad slice, defer the master's own
        shard.  Pair with ``gather_bwd``.

        Args:
            x: the layer's forward input ``(B, H, W, Cin)``.
            w: the layer's full kernel.
            g: upstream gradient wrt the layer output.
            partition: per-call partition-axis override.

        Returns:
            The in-flight ``Pending`` (op ``"bwd"``) to gather.
        """
        x = np.asarray(x, np.float32)
        g = np.asarray(g, np.float32)
        plan = self.plan_conv(x.shape, w, "bwd", partition)
        return self._scatter_bwd_planned(x, plan, g, send_weights=True)

    def _scatter_bwd_planned(
        self, x: np.ndarray, plan: plans.LayerPlan, g: np.ndarray,
        send_weights: bool,
    ) -> scheduler.Pending:
        if plan.mode == "kernel":
            return self._scatter_bwd_shards(x, plan, g, send_weights)
        if plan.mode == "batch":
            return self._scatter_bwd_batch(x, plan, g, send_weights)
        socks = self._plan_sockets(plan)
        t0 = time.perf_counter()
        for pos, (sock, (r0, r1), (lo, hi, pt, pb)) in enumerate(
            zip(socks, plan.rows[1:], plan.halos[1:]), start=1
        ):
            ws = self._wire_weights(sock, plan, pos, plan.w, send_weights)
            self._write_op(
                sock, ("sbwd", (x[:, lo:hi], ws, g[:, r0:r1], pt, pb))
            )
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        r0, r1 = plan.rows[0]
        return scheduler.Pending(
            "bwd", self._seq_issued, x, plan.w, g[:, r0:r1], now,
            mode="spatial", rows=plan.rows, halos=plan.halos,
            plan=plan, parts=socks, g_all=g,
        )

    def _scatter_bwd_batch(
        self, x: np.ndarray, plan: plans.LayerPlan, g: np.ndarray,
        send_weights: bool,
    ) -> scheduler.Pending:
        """Batch-axis backward: each member VJPs its own rows (x slice,
        full kernel, matching g slice) and returns (dX rows, FULL dW) —
        the master sums the per-member dW into an exact all-reduce at
        the gather.  Rows are re-cut to this slab like the forward."""
        socks = self._plan_sockets(plan)
        rows = plans.batch_ranges(plan.counts, x.shape[0])
        t0 = time.perf_counter()
        for pos, (sock, (r0, r1)) in enumerate(zip(socks, rows[1:]), start=1):
            ws = self._wire_weights(sock, plan, pos, plan.w, send_weights)
            self._write_op(sock, ("bwd", (x[r0:r1], ws, g[r0:r1])))
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        r0, r1 = rows[0]
        return scheduler.Pending(
            "bwd", self._seq_issued, x, plan.w, g[r0:r1], now,
            mode="batch", rows=rows, plan=plan, parts=socks, g_all=g,
        )

    def _scatter_bwd_shards(
        self, x: np.ndarray, plan: plans.LayerPlan, g: np.ndarray,
        send_weights: bool,
    ) -> scheduler.Pending:
        socks = self._plan_sockets(plan)
        g_shards = self._split(g, plan.counts)
        t0 = time.perf_counter()
        for pos, (sock, shard, gs) in enumerate(
            zip(socks, plan.shards[1:], g_shards[1:]), start=1
        ):
            ws = self._wire_weights(sock, plan, pos, shard, send_weights)
            self._write_op(sock, ("bwd", (x, ws, gs)))
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        return scheduler.Pending(
            "bwd", self._seq_issued, x, plan.shards[0], g_shards[0], now,
            plan=plan, parts=socks, g_all=g,
        )

    def gather_bwd(self, p: scheduler.Pending) -> Tuple[np.ndarray, np.ndarray]:
        """Master's shard VJP + gather.  Kernel mode: sum partial dX,
        concat dW shards.  Spatial mode: overlap-ADD each device's halo'd
        dX rows into the full dX (the seam sums) and SUM the full-kernel
        dW contributions.  Batch mode: concat dX rows along the N axis
        and SUM the per-member full dW — dW is a sum over disjoint batch
        rows, so the reduction is exact.  Lost participants'
        contributions come from the master's recovery compute."""
        self._check_order(p, "bwd")
        t0 = time.perf_counter()
        if p.mode == "batch":
            r0, r1 = p.rows[0]
            dx0, dw = self._master_compute(
                lambda: protocol.bwd_shard(
                    self._master_backend, p.x[r0:r1], p.my_w, p.my_g
                )
            )
            dxs = [dx0]
            t_wait = time.perf_counter()
            for idx, sock in enumerate(p.parts):
                dx_i, dw_i = self._read_or_recover(sock, p, idx)
                dxs.append(dx_i)
                dw = dw + dw_i
            t1 = time.perf_counter()
            self._account_gather(p, t0, t_wait, t1)
            return np.concatenate(dxs, axis=0), dw
        if p.mode == "spatial":
            lo, hi, pt, pb = p.halos[0]
            dxh, dw = self._master_compute(
                lambda: strip_conv_vjp(
                    self._master_backend, p.x[:, lo:hi], p.my_w, p.my_g, pt, pb
                )
            )
            dx = np.zeros(p.x.shape, np.float32)
            dx[:, lo:hi] += dxh
            t_wait = time.perf_counter()
            for idx, sock in enumerate(p.parts):
                dxh_i, dw_i = self._read_or_recover(sock, p, idx)
                lo_i, hi_i, _pt, _pb = p.halos[idx + 1]
                dx[:, lo_i:hi_i] += dxh_i  # the halo seams overlap-sum here
                dw = dw + dw_i
            t1 = time.perf_counter()
            self._account_gather(p, t0, t_wait, t1)
            return dx, dw
        dx, dw0 = self._master_compute(
            lambda: protocol.bwd_shard(self._master_backend, p.x, p.my_w, p.my_g)
        )
        dws = [dw0]
        t_wait = time.perf_counter()
        for idx, sock in enumerate(p.parts):
            dxi, dwi = self._read_or_recover(sock, p, idx)
            dx = dx + dxi
            dws.append(dwi)
        t1 = time.perf_counter()
        self._account_gather(p, t0, t_wait, t1)
        return dx, np.concatenate(dws, axis=-1)

    def _check_result(self, out):
        """Re-raise a slave's shipped exception at the gather that would
        otherwise consume its (missing) result."""
        if isinstance(out, protocol.SlaveError):
            raise RuntimeError(
                f"slave device {out.device} failed while computing its "
                f"shard:\n{out.tb}"
            )
        return out

    def _read_or_recover(self, sock, p: scheduler.Pending, idx: int):
        """Device ``idx+1``'s contribution to this gather: read it from
        the live link, or — the slave being gone — compute it HERE.
        The master re-issues the lost shard's work to itself from the
        plan the op rode, so every in-flight op drains on the survivors
        with identical numerics.  A ``SlaveError`` (the slave computed
        and FAILED) still raises: that is a broken backend, not a
        broken link."""
        if not sock.lost:
            try:
                return self._check_result(sock.read_on_master())
            except SlaveLost as e:
                self._on_slave_lost(sock, e)
        return self._recover_shard(p, idx + 1)

    def _recover_shard(self, p: scheduler.Pending, dev_pos: int):
        """Compute plan position ``dev_pos``'s shard of the pending op
        on the master's own backend — the recovery path for a member
        that died between scatter and gather.  Batch mode recomputes the
        dead member's ROWS from the ranges the op actually shipped
        (``p.rows``, re-cut per slab), not the plan's full-batch
        ranges."""
        plan = p.plan
        t0 = time.perf_counter()
        if p.op == "conv":
            if plan.mode == "kernel":
                out = protocol.conv_shard(
                    self._master_backend, p.x, plan.shards[dev_pos]
                )
            elif plan.mode == "batch":
                r0, r1 = p.rows[dev_pos]
                out = protocol.conv_shard(
                    self._master_backend, p.x[r0:r1], plan.w
                )
            else:
                lo, hi, pt, pb = plan.halos[dev_pos]
                out = strip_conv(
                    self._master_backend, p.x[:, lo:hi], plan.w, pt, pb
                )
        else:
            if plan.mode == "kernel":
                gs = plans.split_kernels(p.g_all, plan.counts)
                out = protocol.bwd_shard(
                    self._master_backend, p.x, plan.shards[dev_pos],
                    gs[dev_pos],
                )
            elif plan.mode == "batch":
                r0, r1 = p.rows[dev_pos]
                out = protocol.bwd_shard(
                    self._master_backend, p.x[r0:r1], plan.w,
                    p.g_all[r0:r1],
                )
            else:
                r0, r1 = plan.rows[dev_pos]
                lo, hi, pt, pb = plan.halos[dev_pos]
                out = strip_conv_vjp(
                    self._master_backend, p.x[:, lo:hi], plan.w,
                    p.g_all[:, r0:r1], pt, pb,
                )
        el = time.perf_counter() - t0
        if self.slowdowns[0] > 1.0:
            # reprolint: allow=clock-injection -- slowdown emulation IS a real delay: it stretches measured compute to the emulated device's speed
            time.sleep(el * (self.slowdowns[0] - 1.0))
        self.timing.recompute_s += time.perf_counter() - t0
        return out

    def _check_order(self, p: scheduler.Pending, op: str):
        # real exceptions, not asserts: an out-of-order gather would pair
        # one scatter's master shard with another's slave outputs and
        # return silently corrupted feature maps (and -O strips asserts)
        if p.op != op:
            raise RuntimeError(f"pending is a {p.op!r} op, gathered as {op!r}")
        if p.seq != self._seq_gathered + 1:
            raise RuntimeError(
                "gathers must follow scatter order (FIFO links): "
                f"expected seq {self._seq_gathered + 1}, got {p.seq}"
            )
        self._seq_gathered = p.seq

    def _master_compute(self, fn):
        t0 = time.perf_counter()
        out = fn()
        el = time.perf_counter() - t0
        if self.slowdowns[0] > 1.0:
            # reprolint: allow=clock-injection -- slowdown emulation IS a real delay: it stretches measured compute to the emulated device's speed
            time.sleep(el * (self.slowdowns[0] - 1.0))
        self.timing.master_conv_s += time.perf_counter() - t0
        return out

    def _account_gather(self, p: scheduler.Pending, t0, t_wait, t1):
        self.timing.conv_s += t1 - t0
        self.timing.gather_wait_s += t1 - t_wait
        # in-flight window minus the time the master actually blocked:
        # the comm/compute overlap the pipeline buys
        self.timing.overlap_s += max(0.0, (t_wait - p.t_issued))

    def _master_comp(self, f, y: np.ndarray) -> np.ndarray:
        t0 = time.perf_counter()
        out = f(y)
        self.timing.comp_s += time.perf_counter() - t0
        return out

    # -- the schedules (core/cluster/scheduler.py) ------------------------
    def _n_micro(self, batch: int) -> int:
        if not self.pipeline:
            return 1
        return max(1, min(self.microbatches, batch))

    def microbatch_slices(self, batch: int) -> List[slice]:
        """The batch-axis slices the pipelined schedules will cut —
        drivers split labels/targets identically (see
        ``scheduler.microbatch_slices``)."""
        return scheduler.microbatch_slices(self, batch)

    def conv_forward(self, x, w, *, partition: Optional[str] = None):
        """Distributed convolution of one layer; microbatches are
        double-buffered when the cluster is pipelined.  See
        ``scheduler.conv_forward``."""
        return scheduler.conv_forward(self, x, w, partition=partition)

    def conv_backward(self, x, w, g, *, partition: Optional[str] = None):
        """Distributed VJP of one layer: returns ``(dx, dw)``.  See
        ``scheduler.conv_backward``."""
        return scheduler.conv_backward(self, x, w, g, partition=partition)

    def conv_forward_chain(self, x, layer_weights, between=None):
        """Forward pass of consecutive conv layers with master-only
        ``between`` stages pipelined against slave compute.  See
        ``scheduler.conv_forward_chain``."""
        return scheduler.conv_forward_chain(self, x, layer_weights, between)

    def conv_train_chain(self, x, layer_weights, between=None, head=None):
        """One fully-pipelined distributed training step (forward +
        backward) over consecutive conv layers; returns a
        ``TrainStepResult``.  See ``scheduler.conv_train_chain``."""
        return scheduler.conv_train_chain(self, x, layer_weights, between, head)

    def conv_train_step(self, x, layer_weights, between=None, head=None, *,
                        update=None):
        """``conv_train_chain`` plus the optimizer step on the conv
        kernels: returns ``(new_weights, TrainStepResult)``.  See
        ``scheduler.conv_train_step``."""
        return scheduler.conv_train_step(
            self, x, layer_weights, between, head, update=update
        )

    # ---------------------------------------------------------------------
    @property
    def comm_bytes(self) -> int:
        """Total bytes crossed master<->slave links since the last
        ``reset_stats`` (canonical codec accounting, both ways)."""
        return sum(s.total_bytes for s in self.sockets)

    def reset_stats(self):
        """Zero the timing breakdown, the comp-duty marks, and every
        link's byte counters (benchmarks call this between phases)."""
        self.timing = scheduler.LayerTiming()
        self._duty_mark = (0.0, 0.0)
        for s in self.sockets:
            s.reset_counters()

    def shutdown(self):
        """Tear the cluster down: every live slave is told to exit
        (``TRAIN_OVER``), joined/reaped, and every link closed.
        Idempotent; also runs at interpreter exit via ``atexit``."""
        if self._shut:
            return
        self._shut = True
        for s in self.sockets:
            try:
                s.write_to_slave(protocol.TRAIN_OVER)
            except RuntimeError:  # link already down (dead slave)
                pass
        for t in self.threads:
            if t is not None:
                t.join(timeout=10)
        deadline = self._clock() + 10
        for p in self.procs:
            if p is None:  # external join: its operator owns the process
                continue
            try:
                p.wait(timeout=max(0.1, deadline - self._clock()))
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait(timeout=5)
        for s in self.sockets:
            s.close()
        if self._listener is not None:
            self._listener.close()


def make_distributed_conv(cluster: HeteroCluster):
    """A drop-in ``conv_fn`` for models/cnn.py: jax custom-VJP convolution
    whose forward and backward run over the cluster via callbacks.  If the
    cluster is pipelined, every conv call is internally microbatched and
    double-buffered; keep the master's backend ``numpy`` here (re-entering
    jit dispatch on the blocked runtime thread can deadlock)."""
    import jax
    import jax.numpy as jnp

    # Fail fast on the documented deadlock instead of hanging at 0% CPU:
    # the callbacks below block the jax runtime thread while the master
    # computes its shard, so any master backend that re-enters jit
    # dispatch — everything but numpy — deadlocks, as does a pallas slave
    # in interpret mode (interpret re-enters jax from the slave thread
    # against the blocked callback; subprocess TCP slaves dodge this by
    # construction, but inproc slave threads share the runtime).
    if cluster.backends[0] != "numpy":
        raise RuntimeError(
            f"make_distributed_conv drives the cluster through jax host "
            f"callbacks; the master (device 0) backend must be 'numpy', got "
            f"{cluster.backends[0]!r}: re-entering jax from inside "
            f"pure_callback deadlocks the runtime thread.  Use the direct "
            f"conv_train_step / conv_forward drivers (no callbacks) for a "
            f"non-numpy master."
        )
    if cluster.transport != "tcp":
        interp_pallas = [
            i for i, b in enumerate(cluster.backends)
            if i > 0 and b.partition(":")[0] == "pallas"
            and getattr(get_backend(b), "interpret", False)
        ]
        if interp_pallas:
            raise RuntimeError(
                f"slave device(s) {interp_pallas} run the 'pallas' backend in "
                f"interpret mode, which re-enters jax from the slave thread "
                f"and can deadlock against a blocked make_distributed_conv "
                f"callback.  Use compiled TPU pallas, 'xla', or 'numpy' "
                f"slaves here, drive the cluster directly via "
                f"conv_train_step, or use transport='tcp' (subprocess slaves "
                f"own their runtime)."
            )

    @jax.custom_vjp
    def dconv(x, w, b):
        y = _call_fwd(x, w)
        return y + b[None, None, None, :]

    def fwd(x, w, b):
        y = _call_fwd(x, w)
        return y + b[None, None, None, :], (x, w)

    def bwd(res, g):
        x, w = res
        dx, dw = _call_bwd(x, w, g)
        db = jnp.sum(g, axis=(0, 1, 2))
        return dx, dw, db

    def _call_fwd(x, w):
        out_shape = jax.ShapeDtypeStruct(x.shape[:-1] + (w.shape[-1],), x.dtype)
        return jax.pure_callback(
            lambda xx, ww: cluster.conv_forward(np.asarray(xx), np.asarray(ww)),
            out_shape, x, w,
        )

    def _call_bwd(x, w, g):
        out_shape = (
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
        )
        return jax.pure_callback(
            lambda xx, ww, gg: cluster.conv_backward(
                np.asarray(xx), np.asarray(ww), np.asarray(gg)
            ),
            out_shape, x, w, g,
        )

    dconv.defvjp(fwd, bwd)

    def conv_fn(params, x, padding: str = "SAME"):
        return dconv(x, params["kernel"], params["bias"])

    return conv_fn
