"""Two-tier cluster hierarchy: a root master over sub-master groups.

One master fanning out to N slaves is the runtime's scalability
ceiling — every scatter, gather and recovery path funnels through one
protocol node and one NIC.  The Bi-layered Parallel Training
Architecture (PAPERS.md, 1810.07742) breaks that ceiling by layering
data-parallel groups that each run model parallelism internally, with
gradient aggregation between groups.  The layered ``core/cluster/``
split makes that a composition job, and this module is the
composition:

* A **sub-master** (``protocol.sub_master_loop``) is simultaneously a
  slave to the root — it speaks the ordinary wire grammar over any
  transport — and a full ``HeteroCluster`` master to its own group,
  which internally uses the existing kernel/spatial/batch/auto
  per-layer partitioning, pipelining and fault tolerance.
* The **root** (:class:`HierarchicalCluster`) is a ``HeteroCluster``
  whose "slaves" are sub-masters and whose partition axis is pinned to
  ``"batch"``: each group gets disjoint sample rows priced by its
  aggregate Eq. 1 capacity (member compute rates SUM —
  ``plans.group_aggregate_time``; internal bandwidth is the MIN member
  link, folded into the uplink price), and the root's sum of per-group
  full dW over disjoint rows is the exact all-reduce PR 9 proved for
  flat batch parallelism.  Two-tier losses therefore match
  single-device training to fp32 tolerance.

Fault tolerance composes instead of multiplying:

* a lost **leaf slave** is handled entirely by its group's sub-master
  (evict + master-side recompute of its in-flight rows) — the root
  never sees the failure, only the capacity drop the next ``probe()``
  reports, which it re-plans on (``refresh_capacity``);
* a lost **sub-master** is one dead batch member to the root: the
  stock batch-axis recovery recomputes the whole GROUP's rows on the
  root and evicts the slot, VJP-exact for the survivors.

Topology strings: ``"2x3"`` = 2 groups x 3 devices each, where each
group's first device IS its sub-master's own compute (the inner
master) — a 2x3 hierarchy totals 7 protocol nodes, the same device
count as a flat 1-master/6-slave cluster, which is what makes the
``hierarchy_vs_flat_gain`` bench a fair fight.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Union

from repro.core.cluster import plans, protocol
from repro.core.cluster.cluster import HeteroCluster
from repro.core.cluster.transport import InProcTransport


@dataclasses.dataclass
class GroupSpec:
    """One group's recipe: the inner cluster a sub-master builds and
    masters.  ``slowdowns[0]``/``backends[0]`` are the sub-master's OWN
    compute (it is the group's inner master, not a pure router); the
    rest are its leaf slaves.  ``transport`` is the INNER wire —
    ``"inproc"`` leaf threads inside the sub-master, or ``"tcp"``/
    ``"shm"`` real leaf subprocesses (give those a ``heartbeat_s`` so
    the sub-master can tell busy from dead)."""

    slowdowns: Sequence[float]
    backends: Optional[Sequence[str]] = None
    transport: str = "inproc"
    partition: str = "auto"
    pipeline: bool = True
    microbatches: int = 4
    bandwidth_mbps: Optional[float] = None
    nic_mbps: Optional[float] = None
    heartbeat_s: Optional[float] = None

    @property
    def size(self) -> int:
        """Device count of the group, sub-master's own compute included."""
        return len(self.slowdowns)


def parse_groups(
    spec: str,
    slowdowns: Optional[Sequence[float]] = None,
    backends: Optional[Sequence[str]] = None,
    **kw,
) -> List[GroupSpec]:
    """``"GxM"`` -> G :class:`GroupSpec` of M devices each (the
    ``--groups 2x3`` CLI).  ``slowdowns``/``backends`` optionally carry
    the G*M per-device values, chunked M per group in order; omitted
    they default to 1.0 / numpy.  Extra keyword args (``transport``,
    ``nic_mbps``, ...) apply to every group."""
    try:
        g_s, m_s = spec.lower().split("x")
        g, m = int(g_s), int(m_s)
    except ValueError:
        raise ValueError(
            f"groups topology must look like '2x3' (groups x devices "
            f"per group), got {spec!r}"
        ) from None
    if g < 1 or m < 1:
        raise ValueError(f"topology {spec!r} needs >= 1 group of >= 1 device")
    if slowdowns is not None and len(slowdowns) != g * m:
        raise ValueError(
            f"topology {spec} has {g * m} group devices but "
            f"{len(slowdowns)} slowdowns were given"
        )
    if backends is not None and len(backends) != g * m:
        raise ValueError(
            f"topology {spec} has {g * m} group devices but "
            f"{len(backends)} backends were given"
        )
    out = []
    for i in range(g):
        sl = (
            list(slowdowns[i * m:(i + 1) * m]) if slowdowns is not None
            else [1.0] * m
        )
        bk = (
            list(backends[i * m:(i + 1) * m]) if backends is not None
            else None
        )
        out.append(GroupSpec(slowdowns=sl, backends=bk, **kw))
    return out


def build_group_cluster(
    spec: GroupSpec, clock: Callable[[], float] = time.monotonic
) -> HeteroCluster:
    """The inner ``HeteroCluster`` a sub-master masters, straight from
    its :class:`GroupSpec` — every per-layer partition axis, the
    pipeline and the group's own elastic machinery come along for
    free."""
    return HeteroCluster(
        list(spec.slowdowns),
        list(spec.backends) if spec.backends is not None else None,
        transport=spec.transport,
        partition=spec.partition,
        pipeline=spec.pipeline,
        microbatches=spec.microbatches,
        bandwidth_mbps=spec.bandwidth_mbps,
        master_nic_mbps=spec.nic_mbps,
        heartbeat_s=spec.heartbeat_s,
        clock=clock,
    )


def group_hello_meta(inner: HeteroCluster) -> dict:
    """The ``"group"`` entry a sub-master's hello meta carries upward:
    the group's size and its internal bandwidth bottleneck (MIN of the
    members' finite planning bandwidths, None when every inner link is
    unmetered).  The root folds the bandwidth into the group's uplink
    price — rows must never be priced faster than the group can
    internally redistribute them."""
    finite = [b for b in inner.bandwidths if b is not None]
    return {
        "size": 1 + inner.n_slaves,
        "bandwidth_mbps": min(finite) if finite else None,
    }


class HierarchicalCluster(HeteroCluster):
    """The two-tier root: a ``HeteroCluster`` whose members are whole
    groups behind sub-masters, planned on the batch axis.

    ``groups`` is a topology string (``"2x3"``), one :class:`GroupSpec`,
    or a sequence of them — heterogeneous group shapes are fine.  With
    ``transport="inproc"`` each sub-master runs as a thread in this
    process (its inner cluster built eagerly and reachable through
    ``group_clusters`` — what the leaf-failure tests poke); with
    ``"tcp"``/``"shm"`` each sub-master is an OS subprocess built from
    ``--group-*`` CLI flags, and SIGKILLing it takes its whole group
    down in one failure domain.

    Everything elastic is inherited: the stock batch scatter/gather,
    ``Pending`` recovery (a dead sub-master's ROWS recompute on the
    root), heartbeat deadlines, ``admit()``/``evict()``.  This class
    only adds the group plumbing: spec-driven member startup,
    group-aggregate capacity (sub-masters answer ``probe`` with their
    Eq. 1 harmonic aggregate), hello-meta bandwidth folding, and
    ``admit_group``/``refresh_capacity``."""

    def __init__(
        self,
        groups: Union[str, GroupSpec, Sequence[GroupSpec]],
        *,
        master_slowdown: float = 1.0,
        master_backend: str = "numpy",
        pipeline: bool = True,
        microbatches: int = 4,
        bandwidth_mbps=None,
        master_nic_mbps: Optional[float] = None,
        comp_aware: bool = True,
        wire_dtype: Optional[str] = None,
        wire_codec: Optional[str] = None,
        weight_cache: bool = True,
        transport: str = "inproc",
        heartbeat_s: Optional[float] = None,
        heartbeat_timeout_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if isinstance(groups, str):
            groups = parse_groups(groups)
        elif isinstance(groups, GroupSpec):
            groups = [groups]
        groups = list(groups)
        if not groups:
            raise ValueError("a hierarchy needs at least one group")
        # state the base __init__'s member startup (which we override)
        # consumes — must exist before super().__init__ runs
        self._pending_specs: "collections.deque[GroupSpec]" = (
            collections.deque(groups)
        )
        self._group_by_dev: Dict[int, HeteroCluster] = {}
        self._spec_by_dev: Dict[int, GroupSpec] = {}
        super().__init__(
            [master_slowdown] + [float(g.slowdowns[0]) for g in groups],
            [master_backend] + [
                (g.backends[0] if g.backends else "numpy") for g in groups
            ],
            pipeline=pipeline,
            microbatches=microbatches,
            bandwidth_mbps=bandwidth_mbps,
            comp_aware=comp_aware,
            partition="batch",  # the inter-group axis: exact dW all-reduce
            wire_dtype=wire_dtype,
            wire_codec=wire_codec,
            weight_cache=weight_cache,
            transport=transport,
            master_nic_mbps=master_nic_mbps,
            heartbeat_s=heartbeat_s,
            heartbeat_timeout_s=heartbeat_timeout_s,
            clock=clock,
        )
        self._fold_group_bandwidths()

    # -- member startup: a pending GroupSpec turns a slot into a group ----
    @property
    def group_clusters(self) -> List[HeteroCluster]:
        """The LIVE in-proc groups' inner clusters, in slot order —
        empty on tcp/shm (those groups live inside their sub-master
        subprocesses).  Tests reach a group's leaf procs through this;
        inner-tier ``admit``/``evict`` go through these handles too."""
        return [
            self._group_by_dev[d]
            for d in self.slave_ids
            if d in self._group_by_dev
        ]

    def group_of(self, device: int) -> Optional[HeteroCluster]:
        """The inner cluster behind root member ``device`` (in-proc
        sub-masters only; None for plain leaves and subprocess
        sub-masters)."""
        return self._group_by_dev.get(device)

    def _start_inproc_slave(self, slowdown, backend, bandwidth) -> int:
        """A root in-proc slot: with a pending :class:`GroupSpec` it
        becomes a SUB-MASTER thread driving ``protocol.sub_master_loop``
        over an eagerly-built inner cluster; without one it falls back
        to a plain leaf slave (so ``admit()`` of a bare device at the
        root tier still works)."""
        if not self._pending_specs:
            return super()._start_inproc_slave(slowdown, backend, bandwidth)
        spec = self._pending_specs.popleft()
        inner = build_group_cluster(spec, clock=self._clock)
        try:
            link = InProcTransport(
                bandwidth, self._wire_np_dtype,
                wire_codec=self._link_codec(), nic=self._nic,
            )
            dev = self._next_slave_id
            self._next_slave_id += 1
            t = threading.Thread(
                target=protocol.sub_master_loop,
                args=(link.slave_endpoint(), inner, dev),
                daemon=True,
            )
            t.start()
        except Exception:
            inner.shutdown()  # never leak a built group on a failed start
            raise
        self._add_slot(dev, link, None, t)
        self._group_by_dev[dev] = inner
        self._spec_by_dev[dev] = spec
        self.hello_meta[dev] = {"group": group_hello_meta(inner)}
        return dev

    def _slave_cmd(self, dev: int, slowdown: float, backend: str) -> list:
        """A root tcp/shm spawn: with a pending :class:`GroupSpec` the
        subprocess gets ``--group-*`` flags and comes up as a
        sub-master (its inner group is in-proc INSIDE that process —
        one process, one failure domain); without one it is a plain
        leaf slave."""
        cmd = super()._slave_cmd(dev, slowdown, backend)
        if not self._pending_specs:
            return cmd
        spec = self._pending_specs.popleft()
        self._spec_by_dev[dev] = spec
        cmd += [
            "--group-slowdowns", ",".join(str(s) for s in spec.slowdowns),
            "--group-partition", spec.partition,
            "--group-microbatches", str(spec.microbatches),
        ]
        if spec.backends is not None:
            cmd += ["--group-backends", ",".join(spec.backends)]
        if not spec.pipeline:
            cmd += ["--group-no-pipeline"]
        if spec.bandwidth_mbps is not None:
            cmd += ["--group-bandwidth-mbps", str(spec.bandwidth_mbps)]
        if spec.nic_mbps is not None:
            cmd += ["--group-nic-mbps", str(spec.nic_mbps)]
        return cmd

    # -- group-aggregate capacity -----------------------------------------
    def _fold_group_bandwidths(self) -> None:
        """Cap each group's planning bandwidth at its internal
        bottleneck (the hello meta's ``group.bandwidth_mbps``): the
        root's uplink may be fast, but rows still have to fan out
        inside the group over its narrowest link.  Idempotent (min)."""
        for pos, dev in enumerate(self.slave_ids):
            g = (self.hello_meta.get(dev) or {}).get("group")
            if not g:
                continue
            gbw = g.get("bandwidth_mbps")
            if gbw is None:
                continue
            cur = self.bandwidths[pos]
            self.bandwidths[pos] = gbw if cur is None else min(cur, gbw)

    def probe(self, **probe_kwargs) -> List[float]:
        """The two-level §4.1.1 probe: each sub-master re-probes its
        OWN members and answers its aggregate Eq. 1 time (compute rates
        sum), so the root's ``probe_times`` price whole groups — and a
        leaf lost inside a group surfaces here as that group's capacity
        drop, no root-tier failure involved.  Group-internal bandwidth
        bottlenecks re-fold after the base probe refreshes links."""
        times = super().probe(**probe_kwargs)
        self._fold_group_bandwidths()
        return times

    def refresh_capacity(self, **probe_kwargs) -> List[float]:
        """Re-price every group after an INNER membership change (a
        leaf died or joined): re-runs the two-level probe with the last
        (or a default) workload so the next plan's rows follow the
        groups' ACTUAL remaining capacity.  Root membership is
        untouched — that is the point: leaf churn is a number changing,
        not a topology event."""
        kw = probe_kwargs or self._probe_kwargs or dict(
            image_size=16, in_channels=3, kernel_size=3,
            num_kernels=8, batch=4, repeats=1,
        )
        return self.probe(**kw)

    # -- root-tier elasticity over whole groups ---------------------------
    def admit_group(
        self,
        spec: Union[str, GroupSpec],
        *,
        bandwidth_mbps: Optional[float] = None,
        timeout_s: float = 120.0,
        probe_time: Optional[float] = None,
    ) -> int:
        """Grow the ROOT tier by one whole group: queue the spec, ride
        the stock ``admit()`` (which starts the sub-master thread or
        subprocess, probes its aggregate capacity, and re-plans), and
        fold the newcomer's internal bandwidth.  ``spec`` may be a
        :class:`GroupSpec` or a ``"1x3"``-style topology naming ONE
        group.  Returns the sub-master's device id."""
        if isinstance(spec, str):
            parsed = parse_groups(spec)
            if len(parsed) != 1:
                raise ValueError(
                    f"admit_group takes ONE group, {spec!r} names "
                    f"{len(parsed)}"
                )
            spec = parsed[0]
        self._pending_specs.append(spec)
        try:
            dev = self.admit(
                float(spec.slowdowns[0]),
                spec.backends[0] if spec.backends else "numpy",
                bandwidth_mbps=bandwidth_mbps,
                timeout_s=timeout_s,
                probe_time=probe_time,
            )
        except Exception:
            try:
                self._pending_specs.remove(spec)
            except ValueError:
                pass  # the failed start consumed it
            raise
        self._fold_group_bandwidths()
        return dev

    def shutdown(self) -> None:
        """Stop both tiers: the base shutdown's trainOver fan-out makes
        every sub-master loop shut its own group down; any in-proc
        inner cluster is then shut again here (idempotent) so a group
        whose sub-master thread never drained cannot leak leaf
        threads/processes."""
        super().shutdown()
        for inner in self._group_by_dev.values():
            inner.shutdown()
