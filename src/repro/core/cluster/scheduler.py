"""Pipelined schedules over a cluster's scatter/gather primitives.

The per-op barrier (scatter -> compute -> gather -> ack) is replaced by
split ``scatter_*`` / ``gather_*`` halves with FIFO ordering per link.
With ``pipeline=True`` the batch is cut into microbatches and
double-buffered: the master issues the next microbatch's scatter while
the slaves' results for the current one are still in flight, and
``conv_forward_chain`` keeps slave queues non-empty across consecutive
conv layers so the master's non-conv work overlaps slave compute.

``conv_train_chain`` / ``conv_train_step`` extend the pipeline to the
WHOLE training step: the forward chain stashes each conv layer's input
and the VJP of every master-only between stage, the master computes the
loss head, and the backward chain reuses the same ``Pending`` FIFO and
microbatch machinery for the ``bwd`` op — the backward scatter of layer
k is issued while layer k+1's backward gathers (and the master's
between-VJP / head gradients) are still in flight.  Unlike the depth-2
forward chain, the train chain keeps up to ``microbatches`` ops in
flight per phase boundary (the total queued bytes still equal ONE
barrier-mode scatter of the full batch); a real flow-controlled
transport behind the channel would need a window of that many messages
— which is why ``TCPTransport`` writes through an async writer thread.

Every driver takes the cluster as its first argument and runs over
whatever transport the cluster was built on; ``HeteroCluster`` exposes
them as methods.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster.plans import LayerPlan, plan_conv


@dataclasses.dataclass
class LayerTiming:
    """Wall-clock breakdown of the cluster's work, accumulated across
    ops until ``reset_stats``; every field is seconds."""

    comm_s: float = 0.0         # scatter writes (master -> slave links)
    conv_s: float = 0.0         # conv phase: master's shard + gather
    comp_s: float = 0.0         # non-conv layers (master only)
    gather_wait_s: float = 0.0  # time the master blocked on slave results
    overlap_s: float = 0.0      # scatter->gather window minus the blocked
    #                             wait: comm/compute genuinely overlapped
    master_conv_s: float = 0.0  # master's own conv/bwd shard compute — the
    #                             denominator of its non-conv duty
    recompute_s: float = 0.0    # master time absorbing DEAD slaves' shards
    #                             (fault recovery; see cluster._recover_shard)


@dataclasses.dataclass
class TrainStepResult:
    """What one distributed training step hands back to the driver."""

    head_aux: list                 # per-microbatch head outputs (loss, ...)
    dw: List[np.ndarray]           # kernel gradient per conv layer
    dx: np.ndarray                 # gradient wrt the chain input


@dataclasses.dataclass
class Pending:
    """An in-flight scatter: the master's own shard is deferred to the
    gather so issuing the NEXT scatter never waits on local compute.

    An elastic cluster may lose a slave between this scatter and its
    gather, so a Pending carries enough to finish WITHOUT that slave:
    ``plan`` (the full split, every device's shard derivable), ``parts``
    (the participant links, frozen at scatter time — membership lists
    may have shrunk by gather time), and ``g_all`` (backward only: the
    whole microbatch gradient, so any member's slice can be recut).
    The gather reads live participants and recomputes dead ones' shards
    on the master — the step drains on the survivors."""

    op: str                       # "conv" | "bwd"
    seq: int                      # FIFO position; gathers must match
    x: np.ndarray                 # kernel mode: the broadcast input;
    #                               spatial/batch: the FULL input (the
    #                               master slices its own strip/rows at
    #                               gather)
    my_w: np.ndarray              # master's kernel shard (spatial/batch: full w)
    my_g: Optional[np.ndarray]    # bwd only: master's grad slice/strip/rows
    t_issued: float
    mode: str = "kernel"          # partition axis this op was split on
    rows: Optional[List[Tuple[int, int]]] = None
    #                               spatial: H strips [r0, r1) per device;
    #                               batch: N-axis ranges per device,
    #                               re-cut to THIS slab's batch size (a
    #                               microbatch's N differs from the
    #                               planning shape) — recovery recomputes
    #                               a dead member's rows from these
    halos: Optional[List[Tuple[int, int, int, int]]] = None
    #                               spatial: (lo, hi, pad_top, pad_bot) per device
    plan: Optional[LayerPlan] = None  # the split this op rode (recovery)
    parts: Optional[list] = None      # participant transports, scatter-time
    g_all: Optional[np.ndarray] = None  # bwd: full microbatch gradient


def microbatch_slices(cluster, batch: int) -> List[slice]:
    """The batch-axis slices the pipelined schedules will use for a
    given batch size — drivers split labels/targets identically."""
    n = cluster._n_micro(batch)
    sizes = [a.size for a in np.array_split(np.arange(batch), n)]
    out, start = [], 0
    for s in sizes:
        out.append(slice(start, start + s))
        start += s
    return out


def conv_forward(
    cluster, x: np.ndarray, w: np.ndarray, *, partition: Optional[str] = None
) -> np.ndarray:
    """Distributed convolution over the planned partition axis.
    Pipelined mode double-buffers microbatches along the batch axis
    (orthogonal to either split axis); the plan — and so the kernel
    shard each slave caches — is fixed across the microbatches."""
    x = np.asarray(x, np.float32)
    plan = plan_conv(cluster, x.shape, w, "conv", partition)
    n = cluster._n_micro(x.shape[0])
    if n == 1:
        return cluster.gather_conv(cluster._scatter_conv_planned(x, plan, True))
    parts = np.array_split(x, n, axis=0)
    outs = []
    pending = cluster._scatter_conv_planned(parts[0], plan, True)
    for nxt in parts[1:]:
        # next scatter in flight; slaves reuse the cached kernel
        nxt_pending = cluster._scatter_conv_planned(nxt, plan, False)
        outs.append(cluster.gather_conv(pending))
        pending = nxt_pending
    outs.append(cluster.gather_conv(pending))
    return np.concatenate(outs, axis=0)


def conv_backward(
    cluster, x: np.ndarray, w: np.ndarray, g: np.ndarray,
    *, partition: Optional[str] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Distributed VJP over the planned partition axis: kernel mode
    returns (partial-dX sums, concatenated dW shards); spatial mode
    seam-sums halo'd dX strips and sums full-kernel dW parts.
    Pipelined mode double-buffers microbatches; per-microbatch dW
    contributions are summed."""
    x = np.asarray(x, np.float32)
    g = np.asarray(g, np.float32)
    plan = plan_conv(cluster, x.shape, w, "bwd", partition)
    n = cluster._n_micro(x.shape[0])
    if n == 1:
        return cluster.gather_bwd(cluster._scatter_bwd_planned(x, plan, g, True))
    xs = np.array_split(x, n, axis=0)
    gs = np.array_split(g, n, axis=0)
    dxs: List[np.ndarray] = []
    dw_total: Optional[np.ndarray] = None
    pending = cluster._scatter_bwd_planned(xs[0], plan, gs[0], True)
    for xi, gi in zip(xs[1:], gs[1:]):
        nxt_pending = cluster._scatter_bwd_planned(xi, plan, gi, False)
        dx_i, dw_i = cluster.gather_bwd(pending)
        dxs.append(dx_i)
        dw_total = dw_i if dw_total is None else dw_total + dw_i
        pending = nxt_pending
    dx_i, dw_i = cluster.gather_bwd(pending)
    dxs.append(dx_i)
    dw_total = dw_i if dw_total is None else dw_total + dw_i
    return np.concatenate(dxs, axis=0), dw_total


def group_forward(cluster, x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """One tier-2 forward: what a sub-master computes when the root
    ships it a ``("conv", (x, w))`` batch-row slice — the inner
    cluster's full (pipelined, per-layer-partitioned) ``conv_forward``,
    guarded for the degenerate slices a two-level batch plan legally
    produces.  A zero-row slice (this group earned no rows of the slab)
    or a zero-kernel layer never touches the inner planner — batch
    plans require at least one row — and returns the exact
    correctly-shaped zero-size result instead."""
    x = np.asarray(x, np.float32)
    if x.shape[0] == 0 or w.shape[-1] == 0:
        return np.zeros(x.shape[:3] + (w.shape[-1],), np.float32)
    return conv_forward(cluster, x, w)


def group_backward(
    cluster, x: np.ndarray, w: np.ndarray, g: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """One tier-2 backward: the sub-master's answer to ``("bwd",
    (x, w, g))`` — the inner cluster's distributed VJP over the group's
    batch rows, returning (dX over those rows, the FULL dW summed over
    the group's members).  The root sums these per-group full dWs over
    disjoint row sets: the same exact all-reduce the flat batch axis
    proved, just with groups as the members.  Zero-row / zero-kernel
    slices short-circuit to zero arrays (a zero dW contribution is the
    correct term for a group holding no rows)."""
    x = np.asarray(x, np.float32)
    if x.shape[0] == 0 or w.shape[-1] == 0:
        return (
            np.zeros(x.shape, np.float32),
            np.zeros(w.shape, np.float32),
        )
    return conv_backward(cluster, x, w, np.asarray(g, np.float32))


def conv_forward_chain(
    cluster,
    x: np.ndarray,
    layer_weights: Sequence[np.ndarray],
    between: Optional[Sequence[Optional[Callable[[np.ndarray], np.ndarray]]]] = None,
) -> np.ndarray:
    """Run consecutive conv layers over the cluster; ``between[k]``
    is the master-only non-conv stage after layer k (ReLU/LRN/pool).

    In pipelined mode the microbatches are double-buffered through
    each layer, so the master's between-layer work for microbatch i
    overlaps the slaves' convolutions for microbatch i+1 — the
    slave queues stay non-empty across the whole chain.  In barrier
    mode every layer is scatter -> compute -> gather -> between on
    the full batch, the paper's schedule."""
    if between is None:
        between = [None] * len(layer_weights)
    assert len(between) == len(layer_weights)
    x = np.asarray(x, np.float32)
    batch = x.shape[0]
    n = cluster._n_micro(batch)
    parts: List[np.ndarray] = np.array_split(x, n, axis=0) if n > 1 else [x]
    for w, f in zip(layer_weights, between):
        # plan from the FULL batch shape: one split per layer, every
        # microbatch rides it (and the slave's cached kernel)
        plan = plan_conv(cluster, (batch,) + parts[0].shape[1:], w, "conv")
        if len(parts) == 1:
            y = cluster.gather_conv(cluster._scatter_conv_planned(parts[0], plan, True))
            parts = [cluster._master_comp(f, y) if f else y]
            continue
        outs: List[np.ndarray] = []
        pending = cluster._scatter_conv_planned(parts[0], plan, True)
        for nxt in parts[1:]:
            nxt_pending = cluster._scatter_conv_planned(nxt, plan, False)
            y = cluster.gather_conv(pending)
            outs.append(cluster._master_comp(f, y) if f else y)
            pending = nxt_pending
        y = cluster.gather_conv(pending)
        outs.append(cluster._master_comp(f, y) if f else y)
        parts = outs
    cluster._update_comp_duty()
    return np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]


def conv_train_chain(
    cluster,
    x: np.ndarray,
    layer_weights: Sequence[np.ndarray],
    between: Optional[Sequence[Optional[Callable]]] = None,
    head: Optional[Callable] = None,
) -> TrainStepResult:
    """One distributed training step over consecutive conv layers —
    forward AND backward pipelined across the cluster.

    ``between[k]`` is the master-only stage after conv layer k:
    ``f(y) -> (z, vjp)`` with ``vjp(gz) -> gy`` (None = identity).
    ``head(z, i) -> (aux, gz)`` is the master-only loss head on the
    final stage output of microbatch i (indices follow
    ``microbatch_slices``); its gradient seeds the backward chain.

    The schedule is ONE software pipeline over the phases
    ``[fwd L0 .. fwd Lk, bwd Lk .. bwd L0]``: each phase's scatters
    are issued as the previous phase's gathers complete, so the
    backward scatter of layer k goes out while layer k+1's backward
    gathers — and the master-only between-VJPs / head gradients — are
    still in flight, and the slave queues stay non-empty across the
    forward->backward turnaround.  The forward stashes each conv
    layer's input and each between stage's VJP; every phase re-sends
    its kernel shard once and microbatches after the first ride the
    slave's cached copy.  Gathers follow global scatter order, so the
    FIFO contract holds even though ``conv`` and ``bwd`` ops
    interleave on the wire.
    """
    L = len(layer_weights)
    assert L >= 1 and head is not None, "need >= 1 conv layer and a head"
    if between is None:
        between = [None] * L
    assert len(between) == L
    # split along the SAME slices drivers use for labels/targets, by
    # construction (head(z, i) pairs activations with slice i)
    x = np.asarray(x, np.float32)
    slices = microbatch_slices(cluster, x.shape[0])
    parts: List[np.ndarray] = [x[sl] for sl in slices]
    n = len(parts)

    # plans fixed for the whole step: fwd and bwd must split every
    # layer identically (comp_duty updates only at the end).  Built
    # lazily at each layer's first microbatch — spatial/auto plans
    # need the layer's ACTUAL activation shape, unknown until the
    # between stages have run.
    plans: List[Optional[LayerPlan]] = [None] * L

    def plan_for(k: int, xi: np.ndarray) -> LayerPlan:
        if plans[k] is None:
            # op="train": the plan governs BOTH sweeps, so the auto
            # axis and the comm-aware counts weigh fwd + bwd wire.
            # weight_key opts the layer into the versioned broadcast
            # cache: the backward sweep (and every microbatch after
            # the first) ships a token, never the kernel again
            plans[k] = plan_conv(
                cluster, (x.shape[0],) + xi.shape[1:], layer_weights[k],
                "train", weight_key=("train", k),
            )
        return plans[k]

    stash_x: List[List[Optional[np.ndarray]]] = [[None] * n for _ in range(L)]
    stash_vjp: List[List[Optional[Callable]]] = [[None] * n for _ in range(L)]
    head_aux: list = [None] * n

    def fwd_finish(k: int, i: int, p: Pending) -> np.ndarray:
        """Gather conv layer k / microbatch i and run the master-only
        between stage, stashing its VJP for the backward sweep."""
        y = cluster.gather_conv(p)
        f = between[k]
        if f is None:
            return y
        t0 = time.perf_counter()
        z, vjp = f(y)
        cluster.timing.comp_s += time.perf_counter() - t0
        stash_vjp[k][i] = vjp
        return z

    def bwd_through(k: int, i: int, g: np.ndarray) -> np.ndarray:
        """Pull g back through layer k's between stage (master-only)."""
        vjp = stash_vjp[k][i]
        if vjp is None:
            return g
        t0 = time.perf_counter()
        gy = vjp(g)
        cluster.timing.comp_s += time.perf_counter() - t0
        return gy

    # ---- forward phases: layer k's scatters interleave with k-1's
    # gathers (and the between stages between them)
    pend: List[Pending] = []
    for k in range(L):
        cur: List[Pending] = []
        for i in range(n):
            xi = parts[i] if k == 0 else fwd_finish(k - 1, i, pend[i])
            xi = np.asarray(xi, np.float32)
            stash_x[k][i] = xi
            cur.append(
                cluster._scatter_conv_planned(
                    xi, plan_for(k, xi), send_weights=(i == 0)
                )
            )
        pend = cur

    # ---- turnaround: finish the last fwd layer, compute the head
    # grads, and seed the backward — the bwd scatter of the last layer
    # goes out while its later fwd microbatches are still in flight
    cur = []
    for i in range(n):
        z = fwd_finish(L - 1, i, pend[i])
        t0 = time.perf_counter()
        head_aux[i], gz = head(z, i)
        cluster.timing.comp_s += time.perf_counter() - t0
        gy = bwd_through(L - 1, i, np.asarray(gz, np.float32))
        cur.append(
            cluster._scatter_bwd_planned(
                stash_x[L - 1][i], plans[L - 1], gy, send_weights=(i == 0)
            )
        )
    pend = cur

    # ---- backward phases: layer k's scatters interleave with layer
    # k+1's gathers and the between-VJPs; dW shards sum per microbatch
    dw: List[Optional[np.ndarray]] = [None] * L

    def acc_dw(k: int, dwi: np.ndarray):
        dw[k] = dwi if dw[k] is None else dw[k] + dwi

    for k in range(L - 2, -1, -1):
        cur = []
        for i in range(n):
            dx_next, dw_next = cluster.gather_bwd(pend[i])
            acc_dw(k + 1, dw_next)
            gy = bwd_through(k, i, dx_next)
            cur.append(
                cluster._scatter_bwd_planned(
                    stash_x[k][i], plans[k], gy, send_weights=(i == 0)
                )
            )
        pend = cur

    # ---- drain the first layer's backward
    dxs: List[np.ndarray] = []
    for i in range(n):
        dx_i, dw_i = cluster.gather_bwd(pend[i])
        acc_dw(0, dw_i)
        dxs.append(dx_i)
    cluster._update_comp_duty()
    return TrainStepResult(
        head_aux=head_aux,
        dw=[d for d in dw],
        dx=np.concatenate(dxs, axis=0) if n > 1 else dxs[0],
    )


class ServeChain:
    """Cross-batch pipelined forward chain for the serving lane.

    ``conv_forward_chain`` pipelines microbatches WITHIN one batch;
    a request server instead sees a stream of small, irregular batches
    and wants batch k+1's layer-0 scatter on the wire while batch k's
    final layer is still computing on the slaves.  ``push(x)`` issues
    exactly that overlap and keeps ONE batch in flight:

        push(x_k+1):  scatter L0(x_k+1)      # rides the links while ...
                      gather  L-1(x_k)       # ... batch k finishes
                      gather/scatter L1..L-1(x_k+1), leave L-1 pending
                      -> returns batch k's output (None on first push)

    Gathers stay in global scatter order, so the transport FIFO
    contract holds across batch boundaries.  Plans are rebuilt per
    push from the batch's actual shape and the CURRENT membership, so
    an ``admit()``/``evict()`` between pushes is picked up at the next
    batch — and a ``SlaveLost`` mid-batch drains on the survivors via
    the ``Pending`` recovery path, invisible here.

    Serve weights are STATIC, so every layer opts into the cluster's
    versioned weight-broadcast cache under a per-chain key: the first
    push ships each slave its kernel shard once, and every later push
    (same geometry, same membership) ships a ~24-byte version token
    instead — the per-slab broadcast that dominated serve wire bytes
    collapses to O(1) per layer.  A membership or batch-geometry
    change invalidates the token and the affected shards re-ship
    automatically.

    Args:
        cluster: the ``HeteroCluster`` to serve through.
        layer_weights: conv kernel per layer, ``(kh, kw, cin, cout)``.
        between: optional master-only stage after each layer,
            ``f(y) -> z`` (None = identity); ``between[k]`` runs after
            layer k, including the final layer (applied at the NEXT
            push, or at ``flush()``).
    """

    def __init__(
        self,
        cluster,
        layer_weights: Sequence[np.ndarray],
        between: Optional[Sequence[Optional[Callable[[np.ndarray], np.ndarray]]]] = None,
    ):
        if between is None:
            between = [None] * len(layer_weights)
        assert len(layer_weights) >= 1 and len(between) == len(layer_weights)
        self.cluster = cluster
        self.weights = [np.asarray(w, np.float32) for w in layer_weights]
        self.between = list(between)
        self._tail: Optional[Pending] = None  # previous batch's final layer

    def _finish_tail(self) -> Optional[np.ndarray]:
        """Gather the previous batch's final layer and run its between
        stage.  Returns None when no batch is in flight."""
        if self._tail is None:
            return None
        y = self.cluster.gather_conv(self._tail)
        self._tail = None
        f = self.between[-1]
        out = self.cluster._master_comp(f, y) if f else y
        self.cluster._update_comp_duty()
        return out

    def push(self, x: np.ndarray) -> Optional[np.ndarray]:
        """Feed one batch into the pipeline.

        Args:
            x: batch input ``(B, H, W, Cin)``, any float dtype.

        Returns:
            The PREVIOUS pushed batch's chain output (its final-layer
            between stage applied), or None on the first push.

        Raises:
            SlaveError: a slave raised while computing a shard (the
                batch cannot be recovered; membership faults are NOT
                errors — those drain on the survivors).
        """
        cluster, weights, between = self.cluster, self.weights, self.between
        x = np.asarray(x, np.float32)
        # batch k+1's first scatter goes out BEFORE batch k's last
        # gather: its bytes ride the links while the slaves still
        # compute batch k's final layer
        plan = plan_conv(
            cluster, x.shape, weights[0], "conv",
            weight_key=(id(self), 0),
        )
        p = cluster._scatter_conv_planned(x, plan, True)
        prev_out = self._finish_tail()
        for k in range(1, len(weights)):
            y = cluster.gather_conv(p)
            f = between[k - 1]
            y = cluster._master_comp(f, y) if f else y
            plan = plan_conv(
                cluster, y.shape, weights[k], "conv",
                weight_key=(id(self), k),
            )
            p = cluster._scatter_conv_planned(y, plan, True)
        self._tail = p
        return prev_out

    def flush(self) -> Optional[np.ndarray]:
        """Drain the pipeline: finish the in-flight batch (if any) and
        return its output, or None when the pipeline is empty."""
        return self._finish_tail()

    @property
    def in_flight(self) -> bool:
        """Whether a pushed batch is still awaiting its final gather."""
        return self._tail is not None


def conv_train_step(
    cluster,
    x: np.ndarray,
    layer_weights: Sequence[np.ndarray],
    between: Optional[Sequence[Optional[Callable]]] = None,
    head: Optional[Callable] = None,
    *,
    update: Optional[Callable[[np.ndarray, np.ndarray], np.ndarray]] = None,
) -> Tuple[List[np.ndarray], TrainStepResult]:
    """One full forward+backward ``conv_train_chain`` plus the
    optimizer step on the conv kernels: ``update(w, dw) -> new_w``
    (None leaves the weights untouched and just returns the grads)."""
    res = conv_train_chain(cluster, x, layer_weights, between=between, head=head)
    if update is None:
        return list(layer_weights), res
    return [update(w, d) for w, d in zip(layer_weights, res.dw)], res
