"""The pluggable wire-compressor stack — transport-independent
encode/decode between the scheduler and any transport.

``WireCodec`` composes per-message-class stages: WEIGHTS (kernel
shards), ACTS (activations: the x broadcast, row strips, y returns) and
GRADS (gradient slices out, ``(dX, dW)`` returns back).  Available
stages:

- ``fp32`` — no narrowing, but float64 arrays are still normalized to
  float32 so the uncompressed wire is comparable with every codec
  (nothing in the protocol computes in double precision).
- ``fp16`` / ``bf16`` — the 2-byte narrowing codecs (PR 3).
- ``int8`` — symmetric per-tensor absmax quantization: a tensor ships
  as its int8 values plus one float scale (``QuantArray``), 4x fewer
  bytes than fp32.
- ``topk:<frac>`` (grads only) — top-k sparsification of the
  master->slave gradient slices: only the largest ``frac`` of entries
  ship (``SparseGrad`` indices+values), and the master accumulates the
  dropped mass per destination as ERROR FEEDBACK, re-injecting it into
  that layer's next gradient so training stays convergent (Deep
  Gradient Compression, arXiv:1712.01887).

Every stage decodes back to float32 on the read side — only the wire
narrows.  ``wire_nbytes`` defines the repo's canonical byte accounting
for a message: arrays count their (encoded) buffer size, containers
recurse (dict KEYS count like any other scalar token), and every other
token costs 8 bytes (one double, the paper's protocol scalar).  All
transports count with the SAME function, so ``comm_bytes`` is
comparable between the in-process emulation, a real TCP wire and the
shared-memory rings.

``WeightRef`` is the versioned weight-broadcast cache's wire token: the
weight slot of an op may carry ``WeightRef(key, version, w)`` to prime
a slave's cache, or ``WeightRef(key, version, None)`` — ~24 bytes — to
say "use what you already hold" (see ``protocol.slave_loop`` /
``HeteroCluster._wire_weights``).

Import-light on purpose (numpy only): TCP/shm slave subprocesses import
this module before any heavy framework lands.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

MESSAGE_CLASSES = ("weights", "acts", "grads")

#: master->slave op grammar: which message class each payload slot is.
#: ("w" = the weight slot, which may be an ndarray, None, or WeightRef;
#: None = scalar slot, never encoded.)  Kept here, below protocol.py,
#: so the codec never imports upward.
_DOWN_SLOTS = {
    "conv": ("acts", "w"),
    "sconv": ("acts", "w", None, None),
    "bwd": ("acts", "w", "grads"),
    "sbwd": ("acts", "w", "grads", None, None),
}

_FLOATS = (np.float32, np.float64)


def resolve_wire_dtype(name: Optional[str]) -> Optional[np.dtype]:
    """Map a wire-dtype name to the numpy dtype arrays are encoded to on
    the wire; ``None``/``"fp32"`` means no narrowing (the seed wire)."""
    if name is None or name in ("fp32", "float32"):
        return None
    if name in ("fp16", "float16"):
        return np.dtype(np.float16)
    if name in ("bf16", "bfloat16"):
        try:
            import ml_dtypes
        except ImportError as e:  # pragma: no cover - ml_dtypes ships with jax
            raise ValueError(
                "wire_dtype='bf16' needs the ml_dtypes package"
            ) from e
        return np.dtype(ml_dtypes.bfloat16)
    raise ValueError(
        f"unknown wire_dtype {name!r}; use None/'fp32', 'fp16' or 'bf16'"
    )


def wire_dtype_name(dtype: Optional[np.dtype]) -> Optional[str]:
    """Inverse of ``resolve_wire_dtype`` — for shipping the codec choice
    to a slave subprocess on its command line."""
    if dtype is None:
        return None
    if dtype == np.dtype(np.float16):
        return "fp16"
    return "bf16"


def encode(obj, wire_dtype: np.dtype):
    """Compact float arrays to the wire dtype (recursive, legacy
    single-stage API — ``WireCodec`` is the grammar-aware stack)."""
    if isinstance(obj, np.ndarray) and obj.dtype in _FLOATS:
        return obj.astype(wire_dtype)
    if isinstance(obj, tuple):
        return tuple(encode(o, wire_dtype) for o in obj)
    if isinstance(obj, list):
        return [encode(o, wire_dtype) for o in obj]
    if isinstance(obj, dict):
        return {k: encode(v, wire_dtype) for k, v in obj.items()}
    return obj


def decode(obj, wire_dtype: np.dtype):
    """Widen wire-dtype arrays back to float32 at the read side (legacy
    single-stage API — ``WireCodec.decode`` handles the full stack)."""
    if isinstance(obj, np.ndarray) and obj.dtype == wire_dtype:
        return obj.astype(np.float32)
    if isinstance(obj, tuple):
        return tuple(decode(o, wire_dtype) for o in obj)
    if isinstance(obj, list):
        return [decode(o, wire_dtype) for o in obj]
    if isinstance(obj, dict):
        return {k: decode(v, wire_dtype) for k, v in obj.items()}
    return obj


class QuantArray:
    """An int8-quantized float tensor on the wire: the int8 values and
    ONE symmetric per-tensor scale (``absmax/127``).  Decodes to
    ``q.astype(float32) * scale``; costs ``q.nbytes + 8`` canonical
    bytes (the scale is one protocol scalar)."""

    __slots__ = ("q", "scale")

    def __init__(self, q: np.ndarray, scale: float):
        self.q = q
        self.scale = scale


class SparseGrad:
    """A top-k sparsified gradient on the wire: flat ``idx`` (int32),
    the surviving ``vals`` (float32) and the dense ``shape`` to scatter
    back into.  Decodes to a dense float32 tensor of zeros with
    ``vals`` at ``idx``; costs ``idx.nbytes + vals.nbytes + 8``."""

    __slots__ = ("idx", "vals", "shape")

    def __init__(self, idx: np.ndarray, vals: np.ndarray, shape):
        self.idx = idx
        self.vals = vals
        self.shape = tuple(shape)


class WeightRef:
    """The versioned weight-cache token that rides an op's weight slot.

    ``w`` is the full (encoded) kernel when the master primes or
    refreshes the slave's cache, or ``None`` when the slave already
    holds ``(key, version)`` — then the token costs ~24 bytes instead
    of the kernel re-broadcast.  The slave resolves it in
    ``protocol.slave_loop``; a miss or version mismatch is a master
    bug and raises (shipped back as ``SlaveError``)."""

    __slots__ = ("key", "version", "w")

    def __init__(self, key, version: int, w):
        self.key = key
        self.version = int(version)
        self.w = w


def map_arrays(obj, fn, leaf=np.ndarray):
    """Rebuild ``obj`` with ``fn`` applied to every ``leaf`` instance,
    descending through tuples/lists/dicts AND the codec's own marker
    classes (``QuantArray``/``SparseGrad``/``WeightRef``) — the one
    traversal both the codec stages and the shm segment packer use."""
    if isinstance(obj, leaf):
        return fn(obj)
    if isinstance(obj, tuple):
        return tuple(map_arrays(o, fn, leaf) for o in obj)
    if isinstance(obj, list):
        return [map_arrays(o, fn, leaf) for o in obj]
    if isinstance(obj, dict):
        return {k: map_arrays(v, fn, leaf) for k, v in obj.items()}
    if isinstance(obj, QuantArray):
        return QuantArray(map_arrays(obj.q, fn, leaf), obj.scale)
    if isinstance(obj, SparseGrad):
        return SparseGrad(
            map_arrays(obj.idx, fn, leaf),
            map_arrays(obj.vals, fn, leaf),
            obj.shape,
        )
    if isinstance(obj, WeightRef):
        if obj.w is None:
            return obj
        return WeightRef(obj.key, obj.version, map_arrays(obj.w, fn, leaf))
    return obj


def wire_nbytes(obj) -> int:
    """Canonical bytes-on-the-wire of a message — called AFTER encoding,
    so counters and bandwidth emulation see the codec's compacted size.
    Dict keys count at the 8-byte scalar rate like every other
    non-array token."""
    if isinstance(obj, np.ndarray):
        return obj.nbytes
    if isinstance(obj, (tuple, list)):
        return sum(wire_nbytes(o) for o in obj)
    if isinstance(obj, dict):
        return sum(
            wire_nbytes(k) + wire_nbytes(v) for k, v in obj.items()
        )
    if isinstance(obj, QuantArray):
        return obj.q.nbytes + 8  # values + one scale scalar
    if isinstance(obj, SparseGrad):
        return obj.idx.nbytes + obj.vals.nbytes + 8  # + shape token
    if isinstance(obj, WeightRef):
        body = 0 if obj.w is None else wire_nbytes(obj.w)
        return wire_nbytes(obj.key) + 8 + body  # key + version + kernel
    return 8  # flags / scalars, one double in the paper's protocol


def _quant_int8(a: np.ndarray) -> QuantArray:
    """Symmetric per-tensor absmax int8 quantization of a float array."""
    a = np.asarray(a, np.float32)
    amax = float(np.max(np.abs(a))) if a.size else 0.0
    scale = amax / 127.0 if amax > 0.0 else 1.0
    q = np.clip(np.rint(a / scale), -127, 127).astype(np.int8)
    return QuantArray(q, scale)


def _dequant_int8(qa: QuantArray) -> np.ndarray:
    """Decode ``QuantArray`` back to float32."""
    return qa.q.astype(np.float32) * np.float32(qa.scale)


def _sparsify_topk(a: np.ndarray, frac: float) -> Optional[SparseGrad]:
    """Keep the largest-|.|  ``frac`` of ``a``'s entries; ``None`` when
    the tensor is too small for sparsification to pay (ship dense)."""
    flat = np.asarray(a, np.float32).ravel()
    k = max(1, int(round(frac * flat.size)))
    if 2 * k >= flat.size:  # idx+val = 8B/entry vs 4B dense: not worth it
        return None
    idx = np.argpartition(np.abs(flat), flat.size - k)[flat.size - k:]
    idx = idx.astype(np.int32)
    return SparseGrad(idx, flat[idx], a.shape)


def _densify(sp: SparseGrad) -> np.ndarray:
    """Scatter a ``SparseGrad`` back into its dense float32 tensor."""
    out = np.zeros(int(np.prod(sp.shape)), np.float32)
    out[sp.idx] = sp.vals
    return out.reshape(sp.shape)


def _parse_stage(name: str):
    """One stage spec token -> ``None`` (fp32), a narrow np.dtype, or
    the ``"int8"`` marker.  ``topk`` is handled by the spec parser (it
    is only legal for the grads class)."""
    name = name.strip().lower()
    if name in ("", "fp32", "float32", "none"):
        return None
    if name in ("fp16", "float16", "bf16", "bfloat16"):
        return resolve_wire_dtype(name)
    if name == "int8":
        return "int8"
    raise ValueError(
        f"unknown codec stage {name!r}; use fp32, fp16, bf16, int8 "
        f"or (grads only) topk:<frac>"
    )


def _stage_name(stage) -> str:
    """Inverse of ``_parse_stage`` for the canonical spec string."""
    if stage is None:
        return "fp32"
    if stage == "int8":
        return "int8"
    return wire_dtype_name(stage)


def _stage_itemsize(stage) -> float:
    """Planner-visible bytes per float element a stage ships."""
    if stage is None:
        return 4.0
    if stage == "int8":
        return 1.0
    return float(stage.itemsize)


class WireCodec:
    """The per-link compressor stack: one stage per message class, plus
    optional top-k sparsification (with master-side error feedback) of
    the master->slave gradient slices.

    Built from a spec string (``WireCodec.from_spec``): a single stage
    name applies to all three classes (``"int8"``), or per-class pairs
    select independently (``"weights=fp16,acts=fp16,grads=topk:0.05"``).
    One instance per transport link — the error-feedback residuals are
    per-destination state.  ``encode_down`` classifies master->slave
    messages by the op grammar, ``encode_up`` classifies slave results
    by shape (a bare array is an activation, an array pair is
    ``(dX, dW)``), ``decode`` is marker-driven and direction-free.
    Heartbeats, probes, pings, hellos and errors pass through
    untouched — liveness and bandwidth measurement must not be skewed
    by compression."""

    def __init__(self, weights=None, acts=None, grads=None,
                 grad_topk: Optional[float] = None):
        self.weights = weights
        self.acts = acts
        self.grads = grads
        if grad_topk is not None and not 0.0 < grad_topk < 1.0:
            raise ValueError(f"topk fraction must be in (0, 1): {grad_topk}")
        self.grad_topk = grad_topk
        self._ef: Dict[Tuple, np.ndarray] = {}  # error-feedback residuals
        self._narrow = tuple(
            {s for s in (weights, acts, grads) if isinstance(s, np.dtype)}
        )

    # -- construction ------------------------------------------------

    @classmethod
    def from_wire_dtype(cls, wire_dtype) -> "WireCodec":
        """The legacy single-dtype wire as a stack: every class narrows
        to ``wire_dtype`` (or just fp32-normalizes when ``None``)."""
        if isinstance(wire_dtype, str):
            wire_dtype = resolve_wire_dtype(wire_dtype)
        return cls(weights=wire_dtype, acts=wire_dtype, grads=wire_dtype)

    @classmethod
    def from_spec(cls, spec: Optional[str], wire_dtype=None) -> "WireCodec":
        """Parse a ``--wire-codec`` spec; ``None`` falls back to the
        single-dtype wire (``wire_dtype``, also possibly ``None``)."""
        if spec is None or not spec.strip():
            return cls.from_wire_dtype(wire_dtype)
        parts = [p.strip() for p in spec.split(",") if p.strip()]
        if len(parts) == 1 and "=" not in parts[0]:
            stage = _parse_stage(parts[0])
            return cls(weights=stage, acts=stage, grads=stage)
        stages: Dict[str, object] = {}
        topk = None
        for part in parts:
            if "=" not in part:
                raise ValueError(
                    f"bad wire_codec entry {part!r}: expected class=stage"
                )
            k, v = (s.strip().lower() for s in part.split("=", 1))
            if k not in MESSAGE_CLASSES:
                raise ValueError(
                    f"unknown message class {k!r}; use one of "
                    f"{MESSAGE_CLASSES}"
                )
            if k in stages:
                raise ValueError(f"duplicate wire_codec class {k!r}")
            if v.startswith("topk:"):
                if k != "grads":
                    raise ValueError("topk is only valid for grads")
                topk = float(v.split(":", 1)[1])
                stages[k] = None  # sparse values ship as float32
            else:
                stages[k] = _parse_stage(v)
        return cls(
            weights=stages.get("weights"),
            acts=stages.get("acts"),
            grads=stages.get("grads"),
            grad_topk=topk,
        )

    @property
    def spec(self) -> Optional[str]:
        """Canonical spec string (CLI round-trippable); ``None`` when
        the stack is the plain fp32 wire."""
        g = (
            f"topk:{self.grad_topk:g}" if self.grad_topk is not None
            else _stage_name(self.grads)
        )
        names = (_stage_name(self.weights), _stage_name(self.acts), g)
        if names == ("fp32", "fp32", "fp32"):
            return None
        if names[0] == names[1] == names[2]:
            return names[0]
        return f"weights={names[0]},acts={names[1]},grads={names[2]}"

    def itemsize(self, message_class: str) -> float:
        """Planner-visible wire bytes per float element for one message
        class.  For sparsified grads this is the EFFECTIVE rate (frac
        of entries at 8 B each: int32 index + float32 value) — an
        approximation the Eq. 1 predictor folds into its wire terms."""
        stage = getattr(self, message_class)
        if message_class == "grads" and self.grad_topk is not None:
            return min(_stage_itemsize(stage), 8.0 * self.grad_topk)
        return _stage_itemsize(stage)

    # -- stages ------------------------------------------------------

    def _stage_arr(self, a, stage):
        """Apply one stage to one leaf array (non-float leaves pass)."""
        if not isinstance(a, np.ndarray) or a.dtype not in _FLOATS:
            return a
        if stage == "int8":
            return _quant_int8(a)
        if stage is None:
            return a.astype(np.float32) if a.dtype == np.float64 else a
        return a.astype(stage)

    def _apply(self, obj, stage):
        """One stage over a whole subtree."""
        return map_arrays(obj, lambda a: self._stage_arr(a, stage))

    def _weight_slot(self, w):
        """Encode an op's weight slot: raw kernel, ``None`` (the legacy
        per-op cache) or a ``WeightRef`` wrapping either."""
        if w is None:
            return None
        if isinstance(w, WeightRef):
            if w.w is None:
                return w
            return WeightRef(w.key, w.version, self._apply(w.w, self.weights))
        return self._apply(w, self.weights)

    def _grad_down(self, g, wkey):
        """Encode one master->slave gradient slice: top-k with error
        feedback when configured, else the dense grads stage."""
        if self.grad_topk is None:
            return self._apply(g, self.grads)
        key = (wkey, tuple(np.shape(g)))
        g_eff = np.asarray(g, np.float32)
        resid = self._ef.get(key)
        if resid is not None and resid.shape == g_eff.shape:
            g_eff = g_eff + resid
        sp = _sparsify_topk(g_eff, self.grad_topk)
        if sp is None:  # too small to pay for indices: ship dense
            self._ef.pop(key, None)
            return self._apply(g_eff, self.grads)
        self._ef[key] = g_eff - _densify(sp)
        return sp

    # -- message encode/decode ---------------------------------------

    def encode_down(self, msg):
        """Encode one master->slave message by the op grammar."""
        if (
            isinstance(msg, tuple) and len(msg) == 2
            and isinstance(msg[0], str) and msg[0] in _DOWN_SLOTS
            and isinstance(msg[1], tuple)
        ):
            op, payload = msg
            slots = _DOWN_SLOTS[op]
            if len(payload) == len(slots):
                wkey = None
                w_in = payload[slots.index("w")]
                if isinstance(w_in, WeightRef):
                    wkey = w_in.key
                out = []
                for slot, val in zip(slots, payload):
                    if slot == "acts":
                        out.append(self._apply(val, self.acts))
                    elif slot == "w":
                        out.append(self._weight_slot(val))
                    elif slot == "grads":
                        out.append(self._grad_down(val, wkey))
                    else:
                        out.append(val)
                return (op, tuple(out))
        if (
            isinstance(msg, tuple) and len(msg) == 2
            and isinstance(msg[0], str) and msg[0] == "ping"
        ):
            return msg  # bandwidth probes must measure the raw wire
        return self._apply(msg, self.acts)

    def encode_up(self, msg):
        """Encode one slave->master result: an array pair is
        ``(dX, dW)`` (grads class), anything else is activations."""
        if (
            isinstance(msg, tuple) and len(msg) == 2
            and all(isinstance(o, np.ndarray) for o in msg)
        ):
            return tuple(self._apply(o, self.grads) for o in msg)
        return self._apply(msg, self.acts)

    def decode(self, obj):
        """Widen/densify every encoded leaf back to float32 — marker
        driven, so one decoder serves both directions."""
        if isinstance(obj, QuantArray):
            return _dequant_int8(obj)
        if isinstance(obj, SparseGrad):
            return _densify(obj)
        if isinstance(obj, WeightRef):
            if obj.w is None:
                return obj
            return WeightRef(obj.key, obj.version, self.decode(obj.w))
        if isinstance(obj, np.ndarray):
            if self._narrow and obj.dtype in self._narrow:
                return obj.astype(np.float32)
            return obj
        if isinstance(obj, tuple):
            return tuple(self.decode(o) for o in obj)
        if isinstance(obj, list):
            return [self.decode(o) for o in obj]
        if isinstance(obj, dict):
            return {k: self.decode(v) for k, v in obj.items()}
        return obj
