"""The compact wire codec — a transport-independent encode/decode layer.

Float32/64 numpy arrays are ENCODED to a 2-byte dtype (fp16 or bf16)
before they reach any transport, and DECODED back to float32 on the read
side, so every device computes and accumulates in float32 — only the
wire narrows.  ``wire_nbytes`` defines the repo's canonical byte
accounting for a message: arrays count their (encoded) buffer size,
containers recurse, and every other token costs 8 bytes (one double, the
paper's protocol scalar).  Both transports count with the SAME function,
so ``comm_bytes`` is comparable between the in-process emulation and a
real TCP wire.

Import-light on purpose (numpy only): TCP slave subprocesses import this
module before any heavy framework lands.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def resolve_wire_dtype(name: Optional[str]) -> Optional[np.dtype]:
    """Map a wire-dtype name to the numpy dtype arrays are encoded to on
    the wire; ``None``/``"fp32"`` means no codec (the seed wire)."""
    if name is None or name in ("fp32", "float32"):
        return None
    if name in ("fp16", "float16"):
        return np.dtype(np.float16)
    if name in ("bf16", "bfloat16"):
        try:
            import ml_dtypes
        except ImportError as e:  # pragma: no cover - ml_dtypes ships with jax
            raise ValueError(
                "wire_dtype='bf16' needs the ml_dtypes package"
            ) from e
        return np.dtype(ml_dtypes.bfloat16)
    raise ValueError(
        f"unknown wire_dtype {name!r}; use None/'fp32', 'fp16' or 'bf16'"
    )


def wire_dtype_name(dtype: Optional[np.dtype]) -> Optional[str]:
    """Inverse of ``resolve_wire_dtype`` — for shipping the codec choice
    to a slave subprocess on its command line."""
    if dtype is None:
        return None
    if dtype == np.dtype(np.float16):
        return "fp16"
    return "bf16"


def encode(obj, wire_dtype: np.dtype):
    """Compact float arrays to the wire dtype (recursive)."""
    if isinstance(obj, np.ndarray) and obj.dtype in (np.float32, np.float64):
        return obj.astype(wire_dtype)
    if isinstance(obj, tuple):
        return tuple(encode(o, wire_dtype) for o in obj)
    if isinstance(obj, list):
        return [encode(o, wire_dtype) for o in obj]
    if isinstance(obj, dict):
        return {k: encode(v, wire_dtype) for k, v in obj.items()}
    return obj


def decode(obj, wire_dtype: np.dtype):
    """Widen wire-dtype arrays back to float32 at the read side."""
    if isinstance(obj, np.ndarray) and obj.dtype == wire_dtype:
        return obj.astype(np.float32)
    if isinstance(obj, tuple):
        return tuple(decode(o, wire_dtype) for o in obj)
    if isinstance(obj, list):
        return [decode(o, wire_dtype) for o in obj]
    if isinstance(obj, dict):
        return {k: decode(v, wire_dtype) for k, v in obj.items()}
    return obj


def wire_nbytes(obj) -> int:
    """Canonical bytes-on-the-wire of a message — called AFTER encoding,
    so counters and bandwidth emulation see the codec's compacted size."""
    if isinstance(obj, np.ndarray):
        return obj.nbytes
    if isinstance(obj, (tuple, list)):
        return sum(wire_nbytes(o) for o in obj)
    if isinstance(obj, dict):
        return sum(wire_nbytes(v) for v in obj.values())
    return 8  # flags / scalars, one double in the paper's protocol
