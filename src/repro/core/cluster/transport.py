"""Pluggable wire transports for the master/slave cluster.

A ``Transport`` is the MASTER-side handle of one master<->slave link.
The contract the whole runtime (scatter/gather, scheduler, benches,
tests) is written against:

    write_to_slave(obj)   — enqueue a message to the slave; returns
                            immediately (the NIC DMAs asynchronously)
    read_on_master()      — block for the slave's next message (FIFO)
    bytes_to_slave /      — canonical wire-byte counters per direction
    bytes_to_master         (codec.wire_nbytes of the ENCODED message,
                            identical accounting on every transport)
    close()               — release link resources

The slave side only ever needs ``send``/``recv`` — a ``slave endpoint``
— so the same protocol loop runs in a thread (in-proc) or in a spawned
OS process (TCP).

Two implementations:

``InProcTransport`` — the seed behaviour: a queue pair standing in for
the paper's socket, with optional finite-``bandwidth_mbps`` emulation
(per-direction delivery threads sleep bytes/bandwidth before handing a
message over) and the optional wire codec.  Both endpoints live in this
process; ``slave_endpoint()`` returns the view a slave thread drives.

``TCPTransport`` — a real localhost/network socket: length-prefixed
pickle frames, codec applied before pickling, TCP_NODELAY, and an async
writer thread so ``write_to_slave`` returns immediately (matching the
in-proc semantics and making the deep pipelined schedules immune to
send/recv buffer deadlock).  ``frame_bytes_*`` additionally record the
ACTUAL framed sizes (pickle + header overhead) next to the canonical
counters, and ``measure_bandwidth_mbps`` times a real echo round-trip
through the slave — the measured link the comm-aware partitioner
consumes instead of the ``bandwidth_mbps`` knob.

Liveness: ``SlaveLost`` is the transport's "this link's slave is gone"
signal — EOF/reset on the socket, a failed writer, or (with
``heartbeat_timeout_s`` set) no frame of ANY kind within the deadline.
Slave processes beat through ``TCPSlaveEndpoint.start_heartbeat``: a
daemon thread sends tiny ``(HEARTBEAT, seq)`` frames that the master's
read loop consumes silently (they count as liveness, never as protocol
traffic), so a wedged or SIGSTOPped slave is detected within the
deadline instead of hanging the scheduler forever.

Import-light on purpose (numpy + stdlib): TCP slave subprocesses import
this module before any heavy framework lands.
"""
from __future__ import annotations

import abc
import pickle
import queue
import select
import socket
import struct
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.core.cluster import codec

TRANSPORT_KINDS = ("inproc", "tcp")

HEARTBEAT = "hb"  # liveness frame tag: (HEARTBEAT, seq), never an op


def is_heartbeat(obj) -> bool:
    """Whether a received frame is a liveness beat (``(HEARTBEAT,
    seq)``) rather than an op result."""
    # the first-element type check matters: op results are tuples too,
    # and ``ndarray == str`` compares elementwise
    return (
        isinstance(obj, tuple)
        and len(obj) == 2
        and isinstance(obj[0], str)
        and obj[0] == HEARTBEAT
    )


class SlaveLost(RuntimeError):
    """The link's slave is dead or unreachable: the socket hit EOF/reset,
    the writer thread failed, or no frame (op result OR heartbeat)
    arrived within the heartbeat deadline.  A RuntimeError subclass so
    pre-elastic callers that caught RuntimeError still do — but the
    cluster's recovery path catches THIS type specifically and
    re-partitions instead of aborting the step."""


class Transport(abc.ABC):
    """Master-side contract of one master<->slave link (see module doc)."""

    wire_dtype: Optional[np.dtype] = None
    bytes_to_slave: int = 0
    bytes_to_master: int = 0
    # set (by the transport or the cluster) once the slave behind this
    # link is known dead: scatters skip it, gathers recompute its shard
    # on the master instead of reading, writes/reads raise SlaveLost
    lost: bool = False

    @abc.abstractmethod
    def write_to_slave(self, obj) -> None:
        """Queue one message toward the slave; must return without
        blocking on delivery (comm overlaps compute).  Raises
        SlaveLost/RuntimeError when the link is known down."""
        ...

    @abc.abstractmethod
    def read_on_master(self):
        """Block for the slave's next op result (heartbeats are
        filtered out).  Raises SlaveLost on EOF, writer failure, or a
        missed heartbeat deadline."""
        ...

    @property
    def total_bytes(self) -> int:
        """Bytes crossed in both directions since the last reset
        (encoded wire size, not in-memory size)."""
        return self.bytes_to_slave + self.bytes_to_master

    def reset_counters(self) -> None:
        """Zero both directions' byte counters."""
        self.bytes_to_slave = 0
        self.bytes_to_master = 0

    def close(self) -> None:
        """Release link resources; default is a no-op."""

    def measure_bandwidth_mbps(self, **_kw) -> Optional[float]:
        """Measured link speed in Mbps, or None when the link has no
        meaningful finite speed to report (in-proc unlimited queues)."""
        return None


class _InProcSlaveEndpoint:
    """The slave-thread view of an in-proc link: bare send/recv."""

    def __init__(self, link: "InProcTransport"):
        self._link = link

    def send(self, obj) -> None:
        self._link.write_to_master(obj)

    def recv(self):
        return self._link.read_on_slave()

    def close(self) -> None:  # the master side owns the queues
        ...


class InProcTransport(Transport):
    """Queue pair standing in for the paper's TCP socket; counts traffic.

    With ``bandwidth_mbps`` set, each direction gets a delivery thread
    that sleeps ``bytes * 8 / bandwidth`` before handing a message over —
    a full-duplex link of finite speed (the paper's ~5 Mbps Wi-Fi).
    Writers return immediately (the NIC DMAs asynchronously), so comm
    can genuinely overlap compute when the protocol allows it; messages
    on one direction serialize, exactly like a real link.

    With ``wire_dtype`` set (a 2-byte float numpy dtype), float32/64
    arrays are ENCODED to it on write and decoded back to float32 on
    read — the compact wire codec.  Byte counters and the bandwidth
    emulation see the encoded size, exactly like a real narrow wire."""

    def __init__(
        self,
        bandwidth_mbps: Optional[float] = None,
        wire_dtype: Optional[np.dtype] = None,
    ):
        self.to_slave: "queue.Queue" = queue.Queue()
        self.to_master: "queue.Queue" = queue.Queue()
        self.bytes_to_slave = 0
        self.bytes_to_master = 0
        self._lock = threading.Lock()
        self.bandwidth_mbps = bandwidth_mbps
        self.wire_dtype = wire_dtype
        if bandwidth_mbps is not None:
            assert bandwidth_mbps > 0
            self._stage_to_slave: "queue.Queue" = queue.Queue()
            self._stage_to_master: "queue.Queue" = queue.Queue()
            for stage, dest in (
                (self._stage_to_slave, self.to_slave),
                (self._stage_to_master, self.to_master),
            ):
                threading.Thread(
                    target=self._deliver, args=(stage, dest), daemon=True
                ).start()

    _LINK_DOWN = object()  # sentinel: stops a delivery thread

    def _deliver(self, stage: "queue.Queue", dest: "queue.Queue"):
        while True:
            item = stage.get()
            if item is InProcTransport._LINK_DOWN:
                return
            obj, nbytes = item
            # reprolint: allow=clock-injection -- bandwidth emulation IS a real delay: the sleep models wire transit time and must consume wall clock
            time.sleep(nbytes * 8.0 / (self.bandwidth_mbps * 1e6))
            dest.put(obj)

    def close(self):
        """Stop the delivery threads (queued messages drain first)."""
        if self.bandwidth_mbps is not None:
            self._stage_to_slave.put(InProcTransport._LINK_DOWN)
            self._stage_to_master.put(InProcTransport._LINK_DOWN)

    # -- legacy single-object API: both link directions -------------------
    def _nbytes(self, obj) -> int:
        return codec.wire_nbytes(obj)

    def _encode(self, obj):
        return codec.encode(obj, self.wire_dtype)

    def _decode(self, obj):
        return codec.decode(obj, self.wire_dtype)

    def write_to_slave(self, obj):
        """Count + (optionally) encode, then queue toward the slave —
        through the bandwidth-emulating stage when the link is finite."""
        if self.wire_dtype is not None:
            obj = self._encode(obj)
        n = self._nbytes(obj)
        with self._lock:
            self.bytes_to_slave += n
        if self.bandwidth_mbps is not None:
            self._stage_to_slave.put((obj, n))
        else:
            self.to_slave.put(obj)

    def write_to_master(self, obj):
        """Slave-side mirror of ``write_to_slave``."""
        if self.wire_dtype is not None:
            obj = self._encode(obj)
        n = self._nbytes(obj)
        with self._lock:
            self.bytes_to_master += n
        if self.bandwidth_mbps is not None:
            self._stage_to_master.put((obj, n))
        else:
            self.to_master.put(obj)

    def read_on_slave(self):
        """Block for the master's next message (slave side)."""
        obj = self.to_slave.get()
        return self._decode(obj) if self.wire_dtype is not None else obj

    def read_on_master(self):
        """Block for the slave's next result, decoding the wire dtype."""
        obj = self.to_master.get()
        return self._decode(obj) if self.wire_dtype is not None else obj

    def slave_endpoint(self) -> _InProcSlaveEndpoint:
        """The send/recv pair the slave thread drives."""
        return _InProcSlaveEndpoint(self)

    def measure_bandwidth_mbps(self, **_kw) -> Optional[float]:
        """The emulated knob IS the link speed; None = infinitely fast."""
        return self.bandwidth_mbps


# ---------------------------------------------------------------------------
# TCP: length-prefixed pickle frames over a real socket.
# ---------------------------------------------------------------------------

_HDR = struct.Struct(">Q")


def _send_frame(sock: socket.socket, payload: bytes) -> None:
    sock.sendall(_HDR.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise EOFError("transport connection closed")
        buf += chunk
    return bytes(buf)


def _recv_frame(sock: socket.socket) -> bytes:
    (n,) = _HDR.unpack(_recv_exact(sock, _HDR.size))
    return _recv_exact(sock, n)


def _dumps(obj) -> bytes:
    return pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)


class TCPListener:
    """The master's accept socket; slaves connect to (host, port).

    ``host`` picks the bind interface: the localhost default keeps the
    pre-elastic behaviour (only processes on this machine can join);
    ``"0.0.0.0"`` accepts slaves from genuinely remote hosts — pair it
    with the cluster auth token, the wire is pickle.  ``port=0`` (the
    default) lets the kernel pick a free port; a fixed port is what a
    remote-slave quickstart advertises to its operators."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.host, self.port = self._sock.getsockname()[:2]

    def accept(self, timeout_s: float = 60.0) -> socket.socket:
        """Block for one inbound slave connection.

        Args:
            timeout_s: seconds before ``socket.timeout`` is raised.

        Returns:
            The accepted (pre-handshake) connection socket.
        """
        self._sock.settimeout(timeout_s)
        conn, _addr = self._sock.accept()
        return conn

    def close(self) -> None:
        """Close the listening socket (accepted links live on)."""
        try:
            self._sock.close()
        except OSError:  # pragma: no cover - already closed
            pass


class TCPTransport(Transport):
    """Master-side endpoint of a real master<->slave TCP link.

    Frames are 8-byte big-endian length + pickle payload; the codec
    encodes BEFORE pickling so the real wire carries 2-byte floats.
    Writes are queued to a writer thread — ``write_to_slave`` returns
    immediately, preserving the async-NIC semantics the pipelined
    schedules assume and decoupling deep in-flight windows from the
    kernel's socket buffer sizes.  ``bytes_to_*`` count the canonical
    codec bytes (comparable with InProcTransport); ``frame_bytes_to_*``
    count what actually crossed the socket, framing included.

    ``heartbeat_timeout_s`` arms the liveness deadline: the read loop
    polls the socket (``select``, never consuming a partial frame) and
    raises ``SlaveLost`` once NO frame — result or heartbeat — has
    arrived within the deadline.  Heartbeat frames refresh the deadline
    and are consumed silently (no byte accounting: they are liveness,
    not protocol traffic).  EOF/reset raises ``SlaveLost`` immediately
    with or without a deadline — a SIGKILLed slave's kernel closes its
    socket, so crashes are detected at wire speed and only a wedged or
    SIGSTOPped slave needs the heartbeat clock."""

    _WRITER_DOWN = object()
    _POLL_S = 0.25  # deadline-check granularity while waiting for frames

    def __init__(
        self,
        conn: socket.socket,
        wire_dtype: Optional[np.dtype] = None,
        heartbeat_timeout_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._conn = conn
        self.wire_dtype = wire_dtype
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self._clock = clock
        self.last_alive = self._clock()
        self.lost = False
        self.bytes_to_slave = 0
        self.bytes_to_master = 0
        self.frame_bytes_to_slave = 0
        self.frame_bytes_to_master = 0
        self._closed = False
        self._werr: Optional[BaseException] = None
        self._wq: "queue.Queue" = queue.Queue()
        self._writer = threading.Thread(target=self._write_loop, daemon=True)
        self._writer.start()

    def _write_loop(self):
        while True:
            payload = self._wq.get()
            if payload is TCPTransport._WRITER_DOWN:
                return
            try:
                _send_frame(self._conn, payload)
            except BaseException as e:  # surface on the next master call
                self._werr = e
                return

    def _check_writer(self):
        if self._werr is not None:
            self.lost = True
            raise SlaveLost(
                f"TCP link writer failed (slave died or connection dropped): "
                f"{self._werr!r}"
            )

    def _check_lost(self):
        if self.lost:
            raise SlaveLost("TCP link already marked lost")

    def write_to_slave(self, obj):
        """Encode + frame ``obj`` and queue it to the writer thread;
        returns immediately.  Raises SlaveLost when the link is marked
        lost or the writer already failed."""
        self._check_lost()
        self._check_writer()
        if self.wire_dtype is not None:
            obj = codec.encode(obj, self.wire_dtype)
        self.bytes_to_slave += codec.wire_nbytes(obj)
        payload = _dumps(obj)
        self.frame_bytes_to_slave += len(payload) + _HDR.size
        self._wq.put(payload)

    def read_on_master(self):
        """Next non-heartbeat frame from the slave, decoded.  With a
        heartbeat deadline armed, waits in ``select`` polls so buffered
        heartbeats refresh ``last_alive`` before the deadline is judged
        (a master that was busy computing must drain the backlog, not
        declare a live slave dead on a stale clock)."""
        while True:
            self._check_lost()
            self._check_writer()
            if self.heartbeat_timeout_s is not None:
                deadline = self.last_alive + self.heartbeat_timeout_s
                wait = min(max(0.0, deadline - self._clock()), self._POLL_S)
                readable, _, _ = select.select([self._conn], [], [], wait)
                if not readable:
                    if self._clock() >= deadline:
                        self.lost = True
                        raise SlaveLost(
                            f"no frame or heartbeat from slave for "
                            f"{self.heartbeat_timeout_s:.2f}s (deadline "
                            f"exceeded): slave wedged or unreachable"
                        )
                    continue
            try:
                # with a deadline armed, the frame body is read under a
                # per-chunk socket timeout: select only promises the
                # FIRST byte, and a peer that stalls mid-frame (SIGSTOP
                # between chunks of a multi-MB result) must still trip
                # the deadline, not hang a timeout-less recv forever
                if self.heartbeat_timeout_s is not None:
                    self._conn.settimeout(self.heartbeat_timeout_s)
                payload = _recv_frame(self._conn)
            except socket.timeout as e:
                self.lost = True
                raise SlaveLost(
                    f"slave stalled mid-frame for "
                    f"{self.heartbeat_timeout_s:.2f}s (deadline "
                    f"exceeded): slave wedged or unreachable"
                ) from e
            except (EOFError, OSError) as e:
                self.lost = True
                raise SlaveLost(
                    f"TCP link to slave closed mid-protocol: {e!r}"
                ) from e
            finally:
                if self.heartbeat_timeout_s is not None:
                    try:
                        self._conn.settimeout(None)
                    except OSError:  # pragma: no cover - socket already dead
                        pass
            self.last_alive = self._clock()
            obj = pickle.loads(payload)
            if is_heartbeat(obj):
                continue  # liveness only: no byte accounting, not a result
            self.bytes_to_master += codec.wire_nbytes(obj)
            self.frame_bytes_to_master += len(payload) + _HDR.size
            return (
                codec.decode(obj, self.wire_dtype)
                if self.wire_dtype is not None
                else obj
            )

    def reset_counters(self) -> None:
        """Zero the canonical AND the on-the-wire frame byte counters."""
        super().reset_counters()
        self.frame_bytes_to_slave = 0
        self.frame_bytes_to_master = 0

    def measure_bandwidth_mbps(
        self, payload_bytes: int = 1 << 20, repeats: int = 3, **_kw
    ) -> Optional[float]:
        """Round-trip a ``payload_bytes`` echo through the slave's
        protocol loop and return the best observed Mbps (payload bytes
        moved in BOTH directions over the round-trip wall-clock) — the
        measured link the comm-aware Eq. 1 consumes.  Uses a uint8
        payload so the codec (which narrows only float arrays) does not
        skew the measurement."""
        arr = np.zeros(payload_bytes, np.uint8)
        # probes are not protocol traffic: restore EVERY counter family
        # (canonical and frame) once the measurement is done
        saved = (
            self.bytes_to_slave, self.bytes_to_master,
            self.frame_bytes_to_slave, self.frame_bytes_to_master,
        )
        best = 0.0
        try:
            for _ in range(repeats + 1):  # first round warms buffers; dropped
                t0 = time.perf_counter()
                self.write_to_slave(("ping", arr))
                echo = self.read_on_master()
                dt = time.perf_counter() - t0
                if not isinstance(echo, np.ndarray) or echo.nbytes != arr.nbytes:
                    # RuntimeError, not assert: -O must not turn a garbled
                    # echo into a nonsense Eq. 1 planning bandwidth
                    raise RuntimeError(
                        f"bandwidth probe echo mismatch: sent {arr.nbytes}B, "
                        f"got {type(echo).__name__}"
                    )
                best = max(best, 2.0 * arr.nbytes * 8.0 / (dt * 1e6))
        finally:
            (self.bytes_to_slave, self.bytes_to_master,
             self.frame_bytes_to_slave, self.frame_bytes_to_master) = saved
        return best

    def close(self) -> None:
        """Stop the writer thread and shut the socket down both ways;
        idempotent."""
        if self._closed:
            return
        self._closed = True
        self._wq.put(TCPTransport._WRITER_DOWN)
        self._writer.join(timeout=5)
        try:
            self._conn.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._conn.close()
        except OSError:  # pragma: no cover - already closed
            pass


class TCPSlaveEndpoint:
    """Slave-side endpoint: connects to the master's listener and speaks
    the same framed-pickle wire (codec included).  Drives ``slave_loop``
    inside a spawned subprocess — or a thread, for conformance tests.

    ``connect_timeout_s`` is a RETRY window, not a single attempt: a
    hand-launched remote slave may race the master's bind (two
    terminals, two hosts), so refused connections are retried with a
    short sleep until the deadline.  ``start_heartbeat`` arms the
    liveness beacon: a daemon thread sends ``(HEARTBEAT, seq)`` frames
    every interval — concurrently with the op loop's results, which is
    why every ``send`` serializes under a lock (interleaved partial
    frames would corrupt the wire)."""

    _RETRY_S = 0.25

    def __init__(
        self,
        host: str,
        port: int,
        wire_dtype: Optional[np.dtype] = None,
        connect_timeout_s: float = 30.0,
        auth_token: Optional[bytes] = None,
    ):
        # reprolint: allow=clock-injection -- slave-process side: a spawned subprocess racing a real bind has no master to inject a clock, and the retry window must measure real wall time
        deadline = time.monotonic() + connect_timeout_s
        while True:
            try:
                self._conn = socket.create_connection(
                    (host, port),
                    # reprolint: allow=clock-injection -- same real connect-retry window as above
                    timeout=max(self._RETRY_S, deadline - time.monotonic()),
                )
                break
            except OSError:
                # master not listening yet (or transient network blip):
                # retry until the window closes
                # reprolint: allow=clock-injection -- same real connect-retry window as above
                if time.monotonic() + self._RETRY_S >= deadline:
                    raise
                # reprolint: allow=clock-injection -- real backoff between real connect attempts
                time.sleep(self._RETRY_S)
        self._conn.settimeout(None)  # ops block indefinitely, like the queues
        self._conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.wire_dtype = wire_dtype
        self._send_lock = threading.Lock()
        if auth_token is not None:
            # RAW token bytes before any frame: the master refuses to
            # unpickle anything from a connection that cannot present
            # the per-cluster secret (see HeteroCluster handshake)
            self._conn.sendall(auth_token)

    def send(self, obj) -> None:
        """Encode + frame ``obj`` to the master, serialized under the
        send lock (results and heartbeats share the socket)."""
        if self.wire_dtype is not None:
            obj = codec.encode(obj, self.wire_dtype)
        payload = _dumps(obj)
        with self._send_lock:
            # reprolint: allow=blocking-under-lock -- the lock EXISTS to serialize the blocking send: heartbeats and results share one socket, and an interleaved partial frame corrupts the wire
            _send_frame(self._conn, payload)

    def recv(self):
        """Block for the master's next frame, decoded."""
        obj = pickle.loads(_recv_frame(self._conn))
        return codec.decode(obj, self.wire_dtype) if self.wire_dtype is not None else obj

    def start_heartbeat(self, interval_s: float) -> threading.Thread:
        """Beat ``(HEARTBEAT, seq)`` every ``interval_s`` from a daemon
        thread, proving liveness even while the op loop is deep in a
        long convolution.  The thread dies silently with the socket."""

        def _beat():
            seq = 0
            while True:
                # reprolint: allow=clock-injection -- the heartbeat beacon proves REAL wall-clock liveness from the slave process; a fake clock here would defeat the deadline it feeds
                time.sleep(interval_s)
                try:
                    self.send((HEARTBEAT, seq))
                except OSError:
                    return  # link gone: the op loop is exiting too
                seq += 1

        t = threading.Thread(target=_beat, daemon=True)
        t.start()
        return t

    def close(self) -> None:
        """Close the slave-side socket."""
        try:
            self._conn.close()
        except OSError:  # pragma: no cover - already closed
            pass
