"""Pluggable wire transports for the master/slave cluster.

A ``Transport`` is the MASTER-side handle of one master<->slave link.
The contract the whole runtime (scatter/gather, scheduler, benches,
tests) is written against:

    write_to_slave(obj)   — enqueue a message to the slave; returns
                            immediately (the NIC DMAs asynchronously)
    read_on_master()      — block for the slave's next message (FIFO)
    bytes_to_slave /      — canonical wire-byte counters per direction
    bytes_to_master         (codec.wire_nbytes of the ENCODED message,
                            identical accounting on every transport)
    close()               — release link resources

The slave side only ever needs ``send``/``recv`` — a ``slave endpoint``
— so the same protocol loop runs in a thread (in-proc) or in a spawned
OS process (TCP).

Three implementations:

``InProcTransport`` — the seed behaviour: a queue pair standing in for
the paper's socket, with optional finite-``bandwidth_mbps`` emulation
(per-direction delivery threads sleep bytes/bandwidth before handing a
message over) and the wire codec.  Both endpoints live in this
process; ``slave_endpoint()`` returns the view a slave thread drives.

``TCPTransport`` — a real localhost/network socket: length-prefixed
pickle frames, codec applied before pickling, TCP_NODELAY, and an async
writer thread so ``write_to_slave`` returns immediately (matching the
in-proc semantics and making the deep pipelined schedules immune to
send/recv buffer deadlock).  ``frame_bytes_*`` additionally record the
ACTUAL framed sizes (pickle + header overhead) next to the canonical
counters, and ``measure_bandwidth_mbps`` times a real echo round-trip
through the slave — the measured link the comm-aware partitioner
consumes instead of the ``bandwidth_mbps`` knob.

``ShmTransport`` — the zero-copy wire for CO-LOCATED slave
subprocesses: bulk array bytes are written ONCE into a
``multiprocessing.shared_memory`` ring buffer and mapped on the far
side; only tiny control frames (the message skeleton, with arrays
replaced by ring segment descriptors) cross a localhost socket.  No
pickling of array payloads, no per-megabyte syscalls.  It subclasses
``TCPTransport``, so auth, heartbeats, liveness deadlines, counters
and the bandwidth probe all behave identically — the probe simply
measures the ring instead of the socket.

Every transport routes messages through a per-link ``codec.WireCodec``
(the compressor stack), and counts ``codec.wire_nbytes`` of the ENCODED
message — identical canonical accounting everywhere.

Liveness: ``SlaveLost`` is the transport's "this link's slave is gone"
signal — EOF/reset on the socket, a failed writer, or (with
``heartbeat_timeout_s`` set) no frame of ANY kind within the deadline.
Slave processes beat through ``TCPSlaveEndpoint.start_heartbeat``: a
daemon thread sends tiny ``(HEARTBEAT, seq)`` frames that the master's
read loop consumes silently (they count as liveness, never as protocol
traffic), so a wedged or SIGSTOPped slave is detected within the
deadline instead of hanging the scheduler forever.

Import-light on purpose (numpy + stdlib): TCP slave subprocesses import
this module before any heavy framework lands.
"""
from __future__ import annotations

import abc
import pickle
import queue
import select
import socket
import struct
import threading
import time
from multiprocessing import shared_memory
from typing import Callable, Optional

import numpy as np

from repro.core.cluster import codec

TRANSPORT_KINDS = ("inproc", "tcp", "shm")

HEARTBEAT = "hb"  # liveness frame tag: (HEARTBEAT, seq), never an op


def is_heartbeat(obj) -> bool:
    """Whether a received frame is a liveness beat (``(HEARTBEAT,
    seq)``) rather than an op result."""
    # the first-element type check matters: op results are tuples too,
    # and ``ndarray == str`` compares elementwise
    return (
        isinstance(obj, tuple)
        and len(obj) == 2
        and isinstance(obj[0], str)
        and obj[0] == HEARTBEAT
    )


class SlaveLost(RuntimeError):
    """The link's slave is dead or unreachable: the socket hit EOF/reset,
    the writer thread failed, or no frame (op result OR heartbeat)
    arrived within the heartbeat deadline.  A RuntimeError subclass so
    pre-elastic callers that caught RuntimeError still do — but the
    cluster's recovery path catches THIS type specifically and
    re-partitions instead of aborting the step."""


class Transport(abc.ABC):
    """Master-side contract of one master<->slave link (see module doc)."""

    wire_dtype: Optional[np.dtype] = None
    bytes_to_slave: int = 0
    bytes_to_master: int = 0
    # set (by the transport or the cluster) once the slave behind this
    # link is known dead: scatters skip it, gathers recompute its shard
    # on the master instead of reading, writes/reads raise SlaveLost
    lost: bool = False

    @abc.abstractmethod
    def write_to_slave(self, obj) -> None:
        """Queue one message toward the slave; must return without
        blocking on delivery (comm overlaps compute).  Raises
        SlaveLost/RuntimeError when the link is known down."""
        ...

    @abc.abstractmethod
    def read_on_master(self):
        """Block for the slave's next op result (heartbeats are
        filtered out).  Raises SlaveLost on EOF, writer failure, or a
        missed heartbeat deadline."""
        ...

    @property
    def total_bytes(self) -> int:
        """Bytes crossed in both directions since the last reset
        (encoded wire size, not in-memory size)."""
        return self.bytes_to_slave + self.bytes_to_master

    def reset_counters(self) -> None:
        """Zero both directions' byte counters."""
        self.bytes_to_slave = 0
        self.bytes_to_master = 0

    def close(self) -> None:
        """Release link resources; default is a no-op."""

    def measure_bandwidth_mbps(self, **_kw) -> Optional[float]:
        """Measured link speed in Mbps, or None when the link has no
        meaningful finite speed to report (in-proc unlimited queues)."""
        return None


class SharedNIC:
    """One emulated network interface SHARED by every in-proc link of a
    node — the master-ingress bottleneck the two-tier hierarchy exists
    to relieve.

    Per-link ``bandwidth_mbps`` emulation models N independent wires: N
    slaves can each stream at the full link rate simultaneously, which
    is exactly the regime where a single master never saturates.  A real
    master has ONE NIC: all inbound gathers (and all outbound scatters)
    share its capacity, so six slaves returning full dW tensors serialize
    behind each other on the master's ingress.  ``SharedNIC`` models that
    with one transmit cursor per direction: each message reserves the
    next ``nbytes * 8 / bandwidth`` window after the cursor (under a
    brief lock), the cursor advances, and the link's delivery thread
    sleeps until its window's finish time.  Messages on DIFFERENT links
    therefore serialize per direction, exactly like frames sharing one
    physical port; the two directions are full-duplex and independent.

    Composes with per-link ``bandwidth_mbps`` (both delays apply — a
    slow last-hop behind a shared trunk); on its own it is the fair
    "one port on the master" model the ``hierarchy_vs_flat_gain`` bench
    uses to compare a flat 6-slave fan-in against 2 sub-master uplinks.
    """

    #: the two transmit directions, one independent cursor each
    DIRECTIONS = ("down", "up")  # down = master->slave, up = slave->master

    def __init__(self, bandwidth_mbps: float):
        if not bandwidth_mbps or bandwidth_mbps <= 0:
            raise ValueError(
                f"SharedNIC needs a positive bandwidth, got {bandwidth_mbps!r}"
            )
        self.bandwidth_mbps = float(bandwidth_mbps)
        self._lock = threading.Lock()
        self._free = {d: 0.0 for d in self.DIRECTIONS}

    def reserve(self, direction: str, nbytes: int) -> float:
        """Reserve the next transmit window on ``direction`` for a
        ``nbytes`` message and return its absolute finish time (on the
        ``time.perf_counter`` clock).  The caller sleeps until then
        OUTSIDE this call — the lock only guards the cursor arithmetic,
        never a wait."""
        transit = nbytes * 8.0 / (self.bandwidth_mbps * 1e6)
        now = time.perf_counter()
        with self._lock:
            start = max(now, self._free[direction])
            finish = start + transit
            self._free[direction] = finish
        return finish


class _InProcSlaveEndpoint:
    """The slave-thread view of an in-proc link: bare send/recv."""

    def __init__(self, link: "InProcTransport"):
        self._link = link

    def send(self, obj) -> None:
        self._link.write_to_master(obj)

    def recv(self):
        return self._link.read_on_slave()

    def close(self) -> None:  # the master side owns the queues
        ...


class InProcTransport(Transport):
    """Queue pair standing in for the paper's TCP socket; counts traffic.

    With ``bandwidth_mbps`` set, each direction gets a delivery thread
    that sleeps ``bytes * 8 / bandwidth`` before handing a message over —
    a full-duplex link of finite speed (the paper's ~5 Mbps Wi-Fi).
    Writers return immediately (the NIC DMAs asynchronously), so comm
    can genuinely overlap compute when the protocol allows it; messages
    on one direction serialize, exactly like a real link.

    Messages route through the link's ``WireCodec`` (``wire_codec``, or
    the single-``wire_dtype`` stack when only the legacy knob is given):
    float arrays are ENCODED on write and decoded back to float32 on
    read.  Byte counters and the bandwidth emulation see the encoded
    size, exactly like a real narrow wire.

    With ``nic`` (a :class:`SharedNIC`) set, the link ADDITIONALLY
    reserves a transmit window on the node's shared per-direction
    cursor for every message, so traffic on sibling links serializes
    behind this one exactly like frames sharing the master's single
    physical port."""

    def __init__(
        self,
        bandwidth_mbps: Optional[float] = None,
        wire_dtype: Optional[np.dtype] = None,
        wire_codec: Optional[codec.WireCodec] = None,
        nic: Optional[SharedNIC] = None,
    ):
        self.to_slave: "queue.Queue" = queue.Queue()
        self.to_master: "queue.Queue" = queue.Queue()
        self.bytes_to_slave = 0
        self.bytes_to_master = 0
        self._lock = threading.Lock()
        self.bandwidth_mbps = bandwidth_mbps
        self.nic = nic
        self._staged = bandwidth_mbps is not None or nic is not None
        self.wire_dtype = wire_dtype
        self._codec = (
            wire_codec if wire_codec is not None
            else codec.WireCodec.from_wire_dtype(wire_dtype)
        )
        if self._staged:
            assert bandwidth_mbps is None or bandwidth_mbps > 0
            self._stage_to_slave: "queue.Queue" = queue.Queue()
            self._stage_to_master: "queue.Queue" = queue.Queue()
            for stage, dest, direction in (
                (self._stage_to_slave, self.to_slave, "down"),
                (self._stage_to_master, self.to_master, "up"),
            ):
                threading.Thread(
                    target=self._deliver, args=(stage, dest, direction),
                    daemon=True,
                ).start()

    _LINK_DOWN = object()  # sentinel: stops a delivery thread

    def _deliver(self, stage: "queue.Queue", dest: "queue.Queue",
                 direction: str):
        while True:
            item = stage.get()
            if item is InProcTransport._LINK_DOWN:
                return
            obj, nbytes = item
            if self.bandwidth_mbps is not None:
                # reprolint: allow=clock-injection -- bandwidth emulation IS a real delay: the sleep models wire transit time and must consume wall clock
                time.sleep(nbytes * 8.0 / (self.bandwidth_mbps * 1e6))
            if self.nic is not None:
                wait = self.nic.reserve(direction, nbytes) - time.perf_counter()
                if wait > 0:
                    # reprolint: allow=clock-injection -- shared-NIC emulation: sleeping until the reserved transmit window ends IS the modeled serialization delay
                    time.sleep(wait)
            dest.put(obj)

    def close(self):
        """Stop the delivery threads (queued messages drain first)."""
        if self._staged:
            self._stage_to_slave.put(InProcTransport._LINK_DOWN)
            self._stage_to_master.put(InProcTransport._LINK_DOWN)

    # -- both link directions ---------------------------------------------
    def _nbytes(self, obj) -> int:
        return codec.wire_nbytes(obj)

    def write_to_slave(self, obj):
        """Encode + count, then queue toward the slave — through the
        bandwidth-emulating stage when the link is finite."""
        obj = self._codec.encode_down(obj)
        n = self._nbytes(obj)
        with self._lock:
            self.bytes_to_slave += n
        if self._staged:
            self._stage_to_slave.put((obj, n))
        else:
            self.to_slave.put(obj)

    def write_to_master(self, obj):
        """Slave-side mirror of ``write_to_slave``."""
        obj = self._codec.encode_up(obj)
        n = self._nbytes(obj)
        with self._lock:
            self.bytes_to_master += n
        if self._staged:
            self._stage_to_master.put((obj, n))
        else:
            self.to_master.put(obj)

    def read_on_slave(self):
        """Block for the master's next message (slave side)."""
        return self._codec.decode(self.to_slave.get())

    def read_on_master(self):
        """Block for the slave's next result, decoding the codec stack."""
        return self._codec.decode(self.to_master.get())

    def slave_endpoint(self) -> _InProcSlaveEndpoint:
        """The send/recv pair the slave thread drives."""
        return _InProcSlaveEndpoint(self)

    def measure_bandwidth_mbps(self, **_kw) -> Optional[float]:
        """The emulated knob IS the link speed; None = infinitely fast."""
        return self.bandwidth_mbps


# ---------------------------------------------------------------------------
# TCP: length-prefixed pickle frames over a real socket.
# ---------------------------------------------------------------------------

_HDR = struct.Struct(">Q")


def _send_frame(sock: socket.socket, payload: bytes) -> None:
    sock.sendall(_HDR.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise EOFError("transport connection closed")
        buf += chunk
    return bytes(buf)


def _recv_frame(sock: socket.socket) -> bytes:
    (n,) = _HDR.unpack(_recv_exact(sock, _HDR.size))
    return _recv_exact(sock, n)


def _dumps(obj) -> bytes:
    return pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)


class TCPListener:
    """The master's accept socket; slaves connect to (host, port).

    ``host`` picks the bind interface: the localhost default keeps the
    pre-elastic behaviour (only processes on this machine can join);
    ``"0.0.0.0"`` accepts slaves from genuinely remote hosts — pair it
    with the cluster auth token, the wire is pickle.  ``port=0`` (the
    default) lets the kernel pick a free port; a fixed port is what a
    remote-slave quickstart advertises to its operators."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.host, self.port = self._sock.getsockname()[:2]

    def accept(self, timeout_s: float = 60.0) -> socket.socket:
        """Block for one inbound slave connection.

        Args:
            timeout_s: seconds before ``socket.timeout`` is raised.

        Returns:
            The accepted (pre-handshake) connection socket.
        """
        self._sock.settimeout(timeout_s)
        conn, _addr = self._sock.accept()
        return conn

    def close(self) -> None:
        """Close the listening socket (accepted links live on)."""
        try:
            self._sock.close()
        except OSError:  # pragma: no cover - already closed
            pass


class TCPTransport(Transport):
    """Master-side endpoint of a real master<->slave TCP link.

    Frames are 8-byte big-endian length + pickle payload; the codec
    encodes BEFORE pickling so the real wire carries 2-byte floats.
    Writes are queued to a writer thread — ``write_to_slave`` returns
    immediately, preserving the async-NIC semantics the pipelined
    schedules assume and decoupling deep in-flight windows from the
    kernel's socket buffer sizes.  ``bytes_to_*`` count the canonical
    codec bytes (comparable with InProcTransport); ``frame_bytes_to_*``
    count what actually crossed the socket, framing included.

    ``heartbeat_timeout_s`` arms the liveness deadline: the read loop
    polls the socket (``select``, never consuming a partial frame) and
    raises ``SlaveLost`` once NO frame — result or heartbeat — has
    arrived within the deadline.  Heartbeat frames refresh the deadline
    and are consumed silently (no byte accounting: they are liveness,
    not protocol traffic).  EOF/reset raises ``SlaveLost`` immediately
    with or without a deadline — a SIGKILLed slave's kernel closes its
    socket, so crashes are detected at wire speed and only a wedged or
    SIGSTOPped slave needs the heartbeat clock."""

    _WRITER_DOWN = object()
    _POLL_S = 0.25  # deadline-check granularity while waiting for frames

    def __init__(
        self,
        conn: socket.socket,
        wire_dtype: Optional[np.dtype] = None,
        heartbeat_timeout_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
        wire_codec: Optional[codec.WireCodec] = None,
    ):
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._conn = conn
        self.wire_dtype = wire_dtype
        self._codec = (
            wire_codec if wire_codec is not None
            else codec.WireCodec.from_wire_dtype(wire_dtype)
        )
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self._clock = clock
        self.last_alive = self._clock()
        self.lost = False
        self.bytes_to_slave = 0
        self.bytes_to_master = 0
        self.frame_bytes_to_slave = 0
        self.frame_bytes_to_master = 0
        self._closed = False
        self._werr: Optional[BaseException] = None
        self._wq: "queue.Queue" = queue.Queue()
        self._writer = threading.Thread(target=self._write_loop, daemon=True)
        self._writer.start()

    def _write_loop(self):
        while True:
            item = self._wq.get()
            if item is TCPTransport._WRITER_DOWN:
                return
            try:
                if not isinstance(item, (bytes, bytearray)):
                    item = self._serialize(item)  # shm: pack in-thread
                _send_frame(self._conn, item)
            except BaseException as e:  # surface on the next master call
                self._werr = e
                return

    def _check_writer(self):
        if self._werr is not None:
            self.lost = True
            raise SlaveLost(
                f"TCP link writer failed (slave died or connection dropped): "
                f"{self._werr!r}"
            )

    def _check_lost(self):
        if self.lost:
            raise SlaveLost("TCP link already marked lost")

    def write_to_slave(self, obj):
        """Encode + frame ``obj`` and queue it to the writer thread;
        returns immediately.  Raises SlaveLost when the link is marked
        lost or the writer already failed."""
        self._check_lost()
        self._check_writer()
        obj = self._codec.encode_down(obj)
        self.bytes_to_slave += codec.wire_nbytes(obj)
        self._enqueue(obj)

    def _enqueue(self, obj) -> None:
        """Serialize the encoded message and hand it to the writer
        thread.  (``ShmTransport`` overrides: packing into the ring must
        happen IN the writer thread, so ring backpressure blocks the
        writer, never the scheduler.)"""
        payload = _dumps(obj)
        self.frame_bytes_to_slave += len(payload) + _HDR.size
        self._wq.put(payload)

    def _serialize(self, obj) -> bytes:
        """Writer-thread serialization hook for non-bytes queue items;
        only the shm subclass enqueues those."""
        raise RuntimeError(f"unserialized item on TCP writer queue: {obj!r}")

    def _loads(self, payload: bytes):
        """Deserialize one inbound frame payload (shm overrides to read
        array segments out of its ring)."""
        return pickle.loads(payload)

    def read_on_master(self):
        """Next non-heartbeat frame from the slave, decoded.  With a
        heartbeat deadline armed, waits in ``select`` polls so buffered
        heartbeats refresh ``last_alive`` before the deadline is judged
        (a master that was busy computing must drain the backlog, not
        declare a live slave dead on a stale clock)."""
        while True:
            self._check_lost()
            self._check_writer()
            if self.heartbeat_timeout_s is not None:
                deadline = self.last_alive + self.heartbeat_timeout_s
                wait = min(max(0.0, deadline - self._clock()), self._POLL_S)
                readable, _, _ = select.select([self._conn], [], [], wait)
                if not readable:
                    if self._clock() >= deadline:
                        self.lost = True
                        raise SlaveLost(
                            f"no frame or heartbeat from slave for "
                            f"{self.heartbeat_timeout_s:.2f}s (deadline "
                            f"exceeded): slave wedged or unreachable"
                        )
                    continue
            try:
                # with a deadline armed, the frame body is read under a
                # per-chunk socket timeout: select only promises the
                # FIRST byte, and a peer that stalls mid-frame (SIGSTOP
                # between chunks of a multi-MB result) must still trip
                # the deadline, not hang a timeout-less recv forever
                if self.heartbeat_timeout_s is not None:
                    self._conn.settimeout(self.heartbeat_timeout_s)
                payload = _recv_frame(self._conn)
            except socket.timeout as e:
                self.lost = True
                raise SlaveLost(
                    f"slave stalled mid-frame for "
                    f"{self.heartbeat_timeout_s:.2f}s (deadline "
                    f"exceeded): slave wedged or unreachable"
                ) from e
            except (EOFError, OSError) as e:
                self.lost = True
                raise SlaveLost(
                    f"TCP link to slave closed mid-protocol: {e!r}"
                ) from e
            finally:
                if self.heartbeat_timeout_s is not None:
                    try:
                        self._conn.settimeout(None)
                    except OSError:  # pragma: no cover - socket already dead
                        pass
            self.last_alive = self._clock()
            obj = self._loads(payload)
            if is_heartbeat(obj):
                continue  # liveness only: no byte accounting, not a result
            self.bytes_to_master += codec.wire_nbytes(obj)
            self.frame_bytes_to_master += len(payload) + _HDR.size
            return self._codec.decode(obj)

    def reset_counters(self) -> None:
        """Zero the canonical AND the on-the-wire frame byte counters."""
        super().reset_counters()
        self.frame_bytes_to_slave = 0
        self.frame_bytes_to_master = 0

    def measure_bandwidth_mbps(
        self, payload_bytes: int = 1 << 20, repeats: int = 3, **_kw
    ) -> Optional[float]:
        """Round-trip a ``payload_bytes`` echo through the slave's
        protocol loop and return the best observed Mbps (payload bytes
        moved in BOTH directions over the round-trip wall-clock) — the
        measured link the comm-aware Eq. 1 consumes.  Uses a uint8
        payload so the codec (which narrows only float arrays) does not
        skew the measurement."""
        arr = np.zeros(payload_bytes, np.uint8)
        # probes are not protocol traffic: restore EVERY counter family
        # (canonical and frame) once the measurement is done
        saved = (
            self.bytes_to_slave, self.bytes_to_master,
            self.frame_bytes_to_slave, self.frame_bytes_to_master,
        )
        best = 0.0
        try:
            for _ in range(repeats + 1):  # first round warms buffers; dropped
                t0 = time.perf_counter()
                self.write_to_slave(("ping", arr))
                echo = self.read_on_master()
                dt = time.perf_counter() - t0
                if not isinstance(echo, np.ndarray) or echo.nbytes != arr.nbytes:
                    # RuntimeError, not assert: -O must not turn a garbled
                    # echo into a nonsense Eq. 1 planning bandwidth
                    raise RuntimeError(
                        f"bandwidth probe echo mismatch: sent {arr.nbytes}B, "
                        f"got {type(echo).__name__}"
                    )
                best = max(best, 2.0 * arr.nbytes * 8.0 / (dt * 1e6))
        finally:
            (self.bytes_to_slave, self.bytes_to_master,
             self.frame_bytes_to_slave, self.frame_bytes_to_master) = saved
        return best

    def close(self) -> None:
        """Stop the writer thread and shut the socket down both ways;
        idempotent."""
        if self._closed:
            return
        self._closed = True
        self._wq.put(TCPTransport._WRITER_DOWN)
        self._writer.join(timeout=5)
        try:
            self._conn.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._conn.close()
        except OSError:  # pragma: no cover - already closed
            pass


class TCPSlaveEndpoint:
    """Slave-side endpoint: connects to the master's listener and speaks
    the same framed-pickle wire (codec included).  Drives ``slave_loop``
    inside a spawned subprocess — or a thread, for conformance tests.

    ``connect_timeout_s`` is a RETRY window, not a single attempt: a
    hand-launched remote slave may race the master's bind (two
    terminals, two hosts), so refused connections are retried with a
    short sleep until the deadline.  ``start_heartbeat`` arms the
    liveness beacon: a daemon thread sends ``(HEARTBEAT, seq)`` frames
    every interval — concurrently with the op loop's results, which is
    why every ``send`` serializes under a lock (interleaved partial
    frames would corrupt the wire)."""

    _RETRY_S = 0.25

    def __init__(
        self,
        host: str,
        port: int,
        wire_dtype: Optional[np.dtype] = None,
        connect_timeout_s: float = 30.0,
        auth_token: Optional[bytes] = None,
        wire_codec: Optional[codec.WireCodec] = None,
    ):
        self._codec = (
            wire_codec if wire_codec is not None
            else codec.WireCodec.from_wire_dtype(wire_dtype)
        )
        # reprolint: allow=clock-injection -- slave-process side: a spawned subprocess racing a real bind has no master to inject a clock, and the retry window must measure real wall time
        deadline = time.monotonic() + connect_timeout_s
        while True:
            try:
                self._conn = socket.create_connection(
                    (host, port),
                    # reprolint: allow=clock-injection -- same real connect-retry window as above
                    timeout=max(self._RETRY_S, deadline - time.monotonic()),
                )
                break
            except OSError:
                # master not listening yet (or transient network blip):
                # retry until the window closes
                # reprolint: allow=clock-injection -- same real connect-retry window as above
                if time.monotonic() + self._RETRY_S >= deadline:
                    raise
                # reprolint: allow=clock-injection -- real backoff between real connect attempts
                time.sleep(self._RETRY_S)
        self._conn.settimeout(None)  # ops block indefinitely, like the queues
        self._conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.wire_dtype = wire_dtype
        self._send_lock = threading.Lock()
        if auth_token is not None:
            # RAW token bytes before any frame: the master refuses to
            # unpickle anything from a connection that cannot present
            # the per-cluster secret (see HeteroCluster handshake)
            self._conn.sendall(auth_token)

    def send(self, obj) -> None:
        """Encode + frame ``obj`` to the master, serialized under the
        send lock (results and heartbeats share the socket)."""
        obj = self._codec.encode_up(obj)
        payload = _dumps(obj)
        with self._send_lock:
            # reprolint: allow=blocking-under-lock -- the lock EXISTS to serialize the blocking send: heartbeats and results share one socket, and an interleaved partial frame corrupts the wire
            _send_frame(self._conn, payload)

    def recv(self):
        """Block for the master's next frame, decoded."""
        return self._codec.decode(pickle.loads(_recv_frame(self._conn)))

    def start_heartbeat(self, interval_s: float) -> threading.Thread:
        """Beat ``(HEARTBEAT, seq)`` every ``interval_s`` from a daemon
        thread, proving liveness even while the op loop is deep in a
        long convolution.  The thread dies silently with the socket."""

        def _beat():
            seq = 0
            while True:
                # reprolint: allow=clock-injection -- the heartbeat beacon proves REAL wall-clock liveness from the slave process; a fake clock here would defeat the deadline it feeds
                time.sleep(interval_s)
                try:
                    self.send((HEARTBEAT, seq))
                except OSError:
                    return  # link gone: the op loop is exiting too
                seq += 1

        t = threading.Thread(target=_beat, daemon=True)
        t.start()
        return t

    def close(self) -> None:
        """Close the slave-side socket."""
        try:
            self._conn.close()
        except OSError:  # pragma: no cover - already closed
            pass


# ---------------------------------------------------------------------------
# shm: zero-copy shared-memory rings for co-located slaves; control
# frames (skeletons + segment descriptors) on a small localhost socket.
# ---------------------------------------------------------------------------

_PLAIN = b"P"     # control-frame prefix: whole message pickled inline
_SKELETON = b"S"  # control-frame prefix: arrays parked in the ring


def _shm_untrack(shm: shared_memory.SharedMemory) -> None:
    """Detach an ATTACHED segment from this process's resource tracker.

    Python < 3.13 has no ``track=False``: an attacher re-registers the
    segment, and its tracker then unlinks it behind the creator's back
    (plus a spurious "leaked shared_memory" warning at exit).  Only the
    creating ``ShmTransport`` owns unlink."""
    try:
        from multiprocessing import resource_tracker

        resource_tracker.unregister(
            getattr(shm, "_name", "/" + shm.name), "shared_memory"
        )
    except (ImportError, OSError, ValueError):  # pragma: no cover
        pass  # best-effort: worst case is one warning at interpreter exit


class _ShmRing:
    """Single-producer/single-consumer byte ring over ONE SharedMemory
    segment.

    Layout: a 16-byte header — ``released`` (u64, absolute bytes the
    consumer has finished copying out, CONSUMER-written) and
    ``capacity`` (u64, creator-written, so both sides agree even when
    the kernel page-rounds the mapping) — followed by the circular data
    area.  The producer tracks its absolute write offset locally and
    blocks (tiny sleep poll, only under backpressure) while
    ``head - released`` leaves no room.  The 8-byte aligned u64 store
    of ``released`` is a single memcpy under CPython — de-facto atomic
    on every platform this runs on; the producer additionally clamps it
    to ``head``, so a torn read can at worst delay progress, and only
    while crossing a 4 GiB counter boundary."""

    _HDR_BYTES = 16
    _POLL_S = 100e-6

    def __init__(
        self,
        name: Optional[str] = None,
        data_bytes: Optional[int] = None,
        create: bool = False,
    ):
        if create:
            if not data_bytes or data_bytes <= 0:
                raise ValueError("creating a ring needs data_bytes > 0")
            self._shm = shared_memory.SharedMemory(
                create=True, size=self._HDR_BYTES + int(data_bytes)
            )
            struct.pack_into("<Q", self._shm.buf, 8, int(data_bytes))
            self.capacity = int(data_bytes)
        else:
            self._shm = shared_memory.SharedMemory(name=name)
            _shm_untrack(self._shm)
            self.capacity = struct.unpack_from("<Q", self._shm.buf, 8)[0]
        self._head = 0  # producer-local absolute write offset
        self._aborted = False

    @property
    def name(self) -> str:
        """OS name of the segment — what the setup frame advertises."""
        return self._shm.name

    def abort(self) -> None:
        """Unblock a producer parked on ring backpressure (link death /
        close): its wait loop raises instead of spinning forever."""
        self._aborted = True

    def release(self, upto: int) -> None:
        """Consumer: mark every byte below absolute offset ``upto`` as
        copied out and reusable."""
        struct.pack_into("<Q", self._shm.buf, 0, upto)

    def _released(self) -> int:
        return struct.unpack_from("<Q", self._shm.buf, 0)[0]

    def write_array(self, a: np.ndarray) -> int:
        """Producer: park one array's bytes in the ring (wrapping), and
        return its absolute offset.  Blocks while the consumer lags by
        more than ``capacity - a.nbytes``."""
        a = np.ascontiguousarray(a)
        n = a.nbytes
        while self.capacity - (self._head - min(self._released(), self._head)) < n:
            if self._aborted:
                raise OSError("shm ring aborted (link closed) mid-write")
            # reprolint: allow=clock-injection -- ring backpressure IS real flow control: the producer must yield real wall time until the consumer frees space
            time.sleep(self._POLL_S)
        pos = self._head % self.capacity
        flat = a.reshape(-1).view(np.uint8)
        first = min(n, self.capacity - pos)
        h = self._HDR_BYTES
        self._shm.buf[h + pos:h + pos + first] = flat[:first]
        if n > first:
            self._shm.buf[h:h + n - first] = flat[first:]
        off = self._head
        self._head += n
        return off

    def read_array(self, off: int, nbytes: int, dtype, shape) -> np.ndarray:
        """Consumer: copy one parked array back out of the ring.  The
        ONE copy on the whole path — the producer's write is the only
        other touch of the bytes."""
        out = np.empty(nbytes, np.uint8)
        pos = off % self.capacity
        first = min(nbytes, self.capacity - pos)
        h = self._HDR_BYTES
        out[:first] = np.frombuffer(self._shm.buf, np.uint8, first, h + pos)
        if nbytes > first:
            out[first:] = np.frombuffer(
                self._shm.buf, np.uint8, nbytes - first, h
            )
        return out.view(dtype).reshape(shape)

    def close(self) -> None:
        """Detach this process's mapping (idempotent)."""
        self._aborted = True
        try:
            self._shm.close()
        except (OSError, BufferError):  # pragma: no cover - best effort
            pass

    def unlink(self) -> None:
        """Remove the OS segment — creator side only, after close()."""
        try:
            self._shm.unlink()
        except (OSError, FileNotFoundError):  # pragma: no cover
            pass


class _ShmSeg:
    """Control-frame descriptor of one array parked in the ring: where
    its bytes sit and how to view them.  Pickles tiny."""

    __slots__ = ("off", "nbytes", "dtype", "shape")

    def __init__(self, off: int, nbytes: int, dtype, shape):
        self.off = off
        self.nbytes = nbytes
        self.dtype = dtype
        self.shape = shape

    def __getstate__(self):
        return (self.off, self.nbytes, self.dtype, self.shape)

    def __setstate__(self, state):
        self.off, self.nbytes, self.dtype, self.shape = state


def _shm_pack(obj, ring: _ShmRing) -> bytes:
    """Build one control-frame payload: every array in ``obj`` is parked
    in the ring and replaced by a ``_ShmSeg``; the skeleton pickles
    small.  Degenerate or ring-overflowing arrays stay inline (the
    canonical byte accounting happened before any of this)."""

    def park(a: np.ndarray):
        if a.nbytes == 0 or a.nbytes > ring.capacity:
            return a
        off = ring.write_array(a)
        return _ShmSeg(off, a.nbytes, a.dtype, a.shape)

    return _SKELETON + _dumps(codec.map_arrays(obj, park))


def _shm_unpack(payload: bytes, ring: Optional[_ShmRing]):
    """Inverse of ``_shm_pack``: rebuild the message, copying each
    segment's bytes out of the ring, then release them for reuse."""
    kind, obj = payload[:1], pickle.loads(payload[1:])
    if kind != _SKELETON:
        return obj
    end = 0

    def fetch(seg: _ShmSeg) -> np.ndarray:
        nonlocal end
        arr = ring.read_array(seg.off, seg.nbytes, seg.dtype, seg.shape)
        end = max(end, seg.off + seg.nbytes)
        return arr

    out = codec.map_arrays(obj, fetch, leaf=_ShmSeg)
    if end:
        ring.release(end)
    return out


class ShmListener(TCPListener):
    """Listener for the shm transport's CONTROL channel.  Identical to
    ``TCPListener`` — what it accepts only ever carries the handshake,
    heartbeats and tiny skeleton frames; bulk arrays ride the
    shared-memory rings the accepted ``ShmTransport`` creates."""


class ShmTransport(TCPTransport):
    """Master-side endpoint of a zero-copy shared-memory link.

    Construction creates TWO rings (one per direction) and advertises
    their names to the slave in a ``("shm-setup", tx, rx)`` control
    frame — guaranteed first on the wire, the writer queue is empty at
    that point.  After setup, every frame is either ``_PLAIN`` (whole
    message inline: pre-setup handshake) or ``_SKELETON`` (arrays
    parked in the ring, descriptors on the socket): array bytes are
    written once by the producer and copied out once by the consumer —
    no pickling of bulk data, no per-megabyte socket syscalls.

    Everything else — auth-before-unpickle, the async writer, heartbeat
    deadlines, ``SlaveLost``, canonical + frame byte counters, and
    ``measure_bandwidth_mbps`` (which now times the RING, feeding Eq. 1
    the speed the plans will actually see) — is inherited from
    ``TCPTransport`` unchanged.  Ring packing happens in the writer
    thread, so ring backpressure blocks the writer, never the
    scheduler.  The master owns both segments: ``close()`` detaches AND
    unlinks them (slave endpoints only detach)."""

    DEFAULT_RING_BYTES = 64 << 20  # per direction; overflow falls inline

    def __init__(
        self,
        conn: socket.socket,
        wire_dtype: Optional[np.dtype] = None,
        heartbeat_timeout_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
        wire_codec: Optional[codec.WireCodec] = None,
        ring_bytes: int = DEFAULT_RING_BYTES,
    ):
        self._tx = _ShmRing(data_bytes=ring_bytes, create=True)  # to slave
        self._rx = _ShmRing(data_bytes=ring_bytes, create=True)  # to master
        try:
            super().__init__(
                conn, wire_dtype, heartbeat_timeout_s, clock,
                wire_codec=wire_codec,
            )
        except BaseException:
            for ring in (self._tx, self._rx):
                ring.close()
                ring.unlink()
            raise
        self._wq.put(
            _PLAIN + _dumps(("shm-setup", self._tx.name, self._rx.name))
        )

    def _enqueue(self, obj) -> None:
        """Defer serialization to the writer thread (see class doc)."""
        self._wq.put(obj)

    def _serialize(self, obj) -> bytes:
        """Writer thread: park arrays in the tx ring, frame the skeleton."""
        payload = _shm_pack(obj, self._tx)
        self.frame_bytes_to_slave += len(payload) + _HDR.size
        return payload

    def _loads(self, payload: bytes):
        """Rebuild one inbound frame from the rx ring."""
        return _shm_unpack(payload, self._rx)

    def close(self) -> None:
        """Stop the writer (aborting any ring wait), close the control
        socket, then detach and unlink both rings; idempotent."""
        if self._closed:
            return
        self._tx.abort()  # a writer parked on backpressure must exit
        self._rx.abort()
        super().close()
        for ring in (self._tx, self._rx):
            ring.close()
            ring.unlink()


class ShmSlaveEndpoint(TCPSlaveEndpoint):
    """Slave-side endpoint of the shm link: connects to the control
    socket like a TCP slave (auth token and all), then attaches the two
    rings named by the master's ``shm-setup`` frame — transparently,
    inside ``recv``, so ``slave_loop`` needs no changes.  Sends pack
    under the send lock (results and heartbeats share one ring: single
    producer).  Detaches on close; the master owns unlink."""

    def __init__(
        self,
        host: str,
        port: int,
        wire_dtype: Optional[np.dtype] = None,
        connect_timeout_s: float = 30.0,
        auth_token: Optional[bytes] = None,
        wire_codec: Optional[codec.WireCodec] = None,
    ):
        super().__init__(
            host, port, wire_dtype, connect_timeout_s, auth_token,
            wire_codec=wire_codec,
        )
        self._tx_ring: Optional[_ShmRing] = None  # slave -> master
        self._rx_ring: Optional[_ShmRing] = None  # master -> slave

    def send(self, obj) -> None:
        """Encode, park arrays in the tx ring, frame the skeleton —
        all under the send lock (the ring is single-producer and the
        socket must carry whole frames)."""
        obj = self._codec.encode_up(obj)
        with self._send_lock:
            if self._tx_ring is not None:
                # reprolint: allow=blocking-under-lock -- single-producer ring + shared socket: both the ring write and the frame send MUST serialize under this lock or frames interleave
                payload = _shm_pack(obj, self._tx_ring)
            else:
                payload = _PLAIN + _dumps(obj)  # pre-setup (hello)
            # reprolint: allow=blocking-under-lock -- same single-producer serialization as above
            _send_frame(self._conn, payload)

    def recv(self):
        """Block for the master's next frame, consuming ``shm-setup``
        internally (ring attach) and decoding everything else."""
        while True:
            payload = _recv_frame(self._conn)
            obj = _shm_unpack(payload, self._rx_ring)
            if (
                isinstance(obj, tuple) and len(obj) == 3
                and isinstance(obj[0], str) and obj[0] == "shm-setup"
            ):
                self._rx_ring = _ShmRing(name=obj[1])
                self._tx_ring = _ShmRing(name=obj[2])
                continue
            return self._codec.decode(obj)

    def close(self) -> None:
        """Detach both ring mappings and close the control socket."""
        for ring in (self._tx_ring, self._rx_ring):
            if ring is not None:
                ring.close()
        super().close()
