"""The master/slave message protocol and the slave loop (Algorithm 2).

Transport-agnostic: a slave drives any endpoint exposing ``send``/
``recv`` — the in-proc queue view of ``InProcTransport`` when the slave
is a thread, or a ``TCPSlaveEndpoint`` when the slave is a real OS
process.  Message grammar on the wire:

    ("probe", {probe_kwargs})          -> float seconds
    ("ping", payload)                  -> payload echoed (bandwidth probe)
    ("conv", (x, W))                   -> y
    ("bwd",  (x, W, g))                -> (dx, dw)
    ("sconv", (x_halo, W, pt, pb))     -> y strip (spatial mode)
    ("sbwd", (x_halo, W, g, pt, pb))   -> (dx_halo, dw) (spatial)
    "trainOver"                        -> slave loop exits

The weight slot ``W`` is one of three things.  A raw kernel array is
cached per op; ``None`` means "reuse the kernel you cached for this
op" — the pipelined schedules pay the weight traffic once per layer.
A ``codec.WeightRef(key, version, w)`` is the VERSIONED weight cache:
with ``w`` attached the slave stores it under ``(key, version)``; with
``w=None`` the slave must already hold that exact version (a miss or a
version mismatch is a master bug and raises).  The versioned cache is
what lets a serve master ship a ~24-byte token instead of
re-broadcasting static kernels on every slab.  A compute exception
ships back as a ``SlaveError`` (the master re-raises it at the
matching gather) so a broken backend fails loudly instead of hanging
the protocol.

Two serve loops share the grammar: ``slave_loop`` computes each op on
ONE backend (a leaf device), while ``sub_master_loop`` computes it over
a whole inner ``HeteroCluster`` — the two-tier hierarchy's middle node,
a slave upward and a master downward (``--group-slowdowns`` on the
CLI; see ``core/cluster/hierarchy.py``).

Run as a module, this file IS the TCP slave process — spawned by the
master on this host, or hand-launched on ANY host that can reach the
master's listener:

    python -m repro.core.cluster.protocol --host H --port P \
        [--device I] [--slowdown 1.5] [--backend numpy] \
        [--transport tcp|shm] [--wire-dtype fp16] [--wire-codec SPEC] \
        [--heartbeat-s 0.5] \
        [--auth-env REPRO_CLUSTER_AUTH] [--connect-timeout-s 60]

It connects back to the master's listener (retrying while the master is
still binding), presents the cluster auth token (read from the env var
named by ``--auth-env``), identifies itself with a
``("hello", device, {"backend", "slowdown"})`` frame, and waits for the
master's ``("welcome", assigned_device)`` — the master owns device
numbering, so a hand-launched slave may omit ``--device`` entirely and
take whatever slot the cluster assigns.  With ``--heartbeat-s`` it
beats liveness frames from a side thread so a master with a heartbeat
deadline can tell "busy convolving" from "dead".  It then serves ops
until "trainOver" or EOF and leaves via ``os._exit`` so native runtime
threads (XLA) can never hang the interpreter at exit.  Imports stay
numpy-light until the first op needs a compute backend, keeping
subprocess spawn fast for numpy/sim slaves.
"""
from __future__ import annotations

import time
import traceback
from typing import Tuple

import numpy as np

from repro.core.cluster.codec import WeightRef

TRAIN_OVER = "trainOver"


class SlaveError:
    """A slave's exception, shipped to the master instead of silently
    killing the slave (which would hang the master's gather)."""

    def __init__(self, device: int, tb: str):
        self.device = device
        self.tb = tb


def conv_shard(backend, x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Backend conv with the 0-kernel and 0-batch fast paths: comp-aware
    shares (or a very slow device) may legally allocate 0 kernels — or,
    on the batch axis, 0 rows — which not every backend kernel tolerates
    (pallas grid math divides by cout; sim flops scale with N)."""
    if w.shape[-1] == 0 or x.shape[0] == 0:
        return np.zeros(x.shape[:-1] + (w.shape[-1],), np.float32)
    return backend.conv(x, w)


def bwd_shard(backend, x, w, g) -> Tuple[np.ndarray, np.ndarray]:
    """Backend conv_vjp with the 0-kernel/0-batch fast paths (see
    conv_shard).  An empty batch slice contributes a zero dW, which the
    master's batch-axis all-reduce sums away."""
    if w.shape[-1] == 0 or x.shape[0] == 0:
        return np.zeros(x.shape, np.float32), np.zeros(w.shape, np.float32)
    return backend.conv_vjp(x, w, g)


def _resolve_weights(w, op: str, cached_w: dict, wcache: dict):
    """Resolve an op's weight slot against both slave-side caches: the
    legacy per-op slot (raw array / ``None``) and the versioned
    ``WeightRef`` cache (one kernel per key — memory stays bounded by
    the number of live layers)."""
    if isinstance(w, WeightRef):
        if w.w is not None:
            wcache[w.key] = (w.version, w.w)
            return w.w
        hit = wcache.get(w.key)
        if hit is None:
            raise RuntimeError(
                f"weight-cache miss: no kernel cached for key {w.key!r} "
                f"(master sent a bare version token first)"
            )
        version, kernel = hit
        if version != w.version:
            raise RuntimeError(
                f"weight-cache version mismatch for key {w.key!r}: "
                f"cached v{version}, master referenced v{w.version}"
            )
        return kernel
    if w is None:
        return cached_w[op]
    cached_w[op] = w
    return w


def slave_loop(endpoint, slowdown: float, backend_name: str, device: int):
    """Algorithm 2, asynchronous: drain ops in FIFO order — read
    inputs/kernels, convolve with this device's backend, write outputs.
    No per-op ack: the master may queue several ops ahead (the pipeline);
    results stream back in issue order.  Returns on "trainOver" or when
    the master's side of the link goes away (EOF)."""
    backend = None
    cached_w = {}  # last kernel shard per op: pipelined microbatches after
    #                the first send w=None instead of retransmitting it
    wcache = {}  # versioned weight cache: key -> (version, kernel)
    while True:
        try:
            msg = endpoint.recv()
        except (EOFError, OSError):
            return  # master gone: nothing left to serve
        if isinstance(msg, str) and msg == TRAIN_OVER:
            return
        op, payload = msg
        if op == "ping":  # bandwidth probe: echo, no compute, no slowdown
            endpoint.send(payload)
            continue
        try:
            if backend is None:
                from repro.core.backends import get_backend

                backend = get_backend(backend_name)
            if op == "probe":
                from repro.core.backends import probe_conv_time

                endpoint.send(
                    probe_conv_time(backend, slowdown=slowdown, **payload)
                )
                continue
            t0 = time.perf_counter()
            if op == "conv":
                x, w = payload
                w = _resolve_weights(w, op, cached_w, wcache)
                out = conv_shard(backend, x, w)
            elif op == "bwd":
                x, w, g = payload
                w = _resolve_weights(w, op, cached_w, wcache)
                out = bwd_shard(backend, x, w, g)
            elif op == "sconv":  # spatial: a height strip + halo, full kernel
                from repro.core.backends import strip_conv

                xh, w, pt, pb = payload
                w = _resolve_weights(w, op, cached_w, wcache)
                out = strip_conv(backend, xh, w, pt, pb)
            elif op == "sbwd":  # spatial backward: halo dX + full-kernel dW
                from repro.core.backends import strip_conv_vjp

                xh, w, g, pt, pb = payload
                w = _resolve_weights(w, op, cached_w, wcache)
                out = strip_conv_vjp(backend, xh, w, g, pt, pb)
            else:  # pragma: no cover
                raise ValueError(f"unknown op {op}")
            elapsed = time.perf_counter() - t0
            if slowdown > 1.0:
                # reprolint: allow=clock-injection -- slowdown emulation IS a real delay: it stretches measured compute to the emulated device's speed
                time.sleep(elapsed * (slowdown - 1.0))
        except Exception:
            endpoint.send(SlaveError(device, traceback.format_exc()))
            continue
        endpoint.send(out)


def sub_master_loop(endpoint, cluster, device: int):
    """The TWO-TIER serve loop: Algorithm 2's grammar toward the root,
    a full ``HeteroCluster`` master toward the group.  A sub-master is
    a protocol node that answers the SAME wire ops as ``slave_loop``
    but computes each one over its inner cluster — per-layer
    kernel/spatial/batch/auto partitioning, pipelining, and the group's
    own fault tolerance all live behind this seam, invisible to the
    root except as capacity changes.

    Op semantics at this tier:

    * ``("probe", kw)`` re-probes every GROUP member and answers the
      aggregate Eq. 1 time (``plans.group_aggregate_time``: member
      compute rates sum) — the root prices the whole group as one
      device, and a member lost inside the group shows up here as a
      capacity drop the root re-plans on.
    * ``("conv", ...)`` / ``("bwd", ...)`` run the scheduler's
      ``group_forward`` / ``group_backward`` over the inner cluster —
      zero-row slices from the root's batch plan short-circuit, and
      the bwd answer is (dX rows, the group's FULL summed dW), the
      term the root's exact all-reduce sums.
    * ``("sconv", ...)`` / ``("sbwd", ...)`` fall back to the inner
      MASTER's backend (strip ops don't decompose over batch groups);
      a hierarchy root plans the batch axis, so these only arrive from
      legacy drivers.
    * ``"trainOver"`` / EOF shut the inner cluster down and return.

    The weight slot resolves through the same per-op + versioned caches
    as a leaf slave, so the root's ~24-byte ``WeightRef`` tokens work
    unchanged one tier down."""
    from repro.core.backends import strip_conv, strip_conv_vjp
    from repro.core.cluster.plans import group_aggregate_time
    from repro.core.cluster.scheduler import group_backward, group_forward

    cached_w = {}
    wcache = {}

    def ensure_probed():
        # A root that pins its own probe_times never forwards ("probe",
        # kw) down here, but the inner planner still needs member times
        # before its first share split — self-probe once with the stock
        # admit workload.
        if cluster.probe_times is None:
            cluster.probe(
                image_size=16, in_channels=3, kernel_size=3,
                num_kernels=8, batch=4, repeats=1,
            )

    try:
        while True:
            try:
                msg = endpoint.recv()
            except (EOFError, OSError):
                return  # root gone: the group follows it down
            if isinstance(msg, str) and msg == TRAIN_OVER:
                return
            op, payload = msg
            if op == "ping":  # root bandwidth probe: echo, never forwarded
                endpoint.send(payload)
                continue
            try:
                if op == "probe":
                    endpoint.send(group_aggregate_time(cluster.probe(**payload)))
                    continue
                if op == "conv":
                    x, w = payload
                    w = _resolve_weights(w, op, cached_w, wcache)
                    ensure_probed()
                    out = group_forward(cluster, x, w)
                elif op == "bwd":
                    x, w, g = payload
                    w = _resolve_weights(w, op, cached_w, wcache)
                    ensure_probed()
                    out = group_backward(cluster, x, w, g)
                elif op == "sconv":
                    xh, w, pt, pb = payload
                    w = _resolve_weights(w, op, cached_w, wcache)
                    out = strip_conv(cluster._master_backend, xh, w, pt, pb)
                elif op == "sbwd":
                    xh, w, g, pt, pb = payload
                    w = _resolve_weights(w, op, cached_w, wcache)
                    out = strip_conv_vjp(
                        cluster._master_backend, xh, w, g, pt, pb
                    )
                else:  # pragma: no cover
                    raise ValueError(f"unknown op {op}")
            except Exception:
                endpoint.send(SlaveError(device, traceback.format_exc()))
                continue
            endpoint.send(out)
    finally:
        cluster.shutdown()


def hello_frame(
    device: int, backend: str, slowdown: float, extra: dict = None
) -> tuple:
    """The join handshake: requested device slot (-1 = let the master
    assign one) plus the metadata the master records for membership —
    what an externally-launched slave brings that a spawned one was
    configured with.  ``extra`` extends the open meta dict without
    touching the grammar: a sub-master adds ``{"group": {"size": n,
    "bandwidth_mbps": min_internal}}`` so the root can fold the group's
    internal bottleneck into its uplink pricing."""
    meta = {"backend": backend, "slowdown": slowdown}
    if extra:
        meta.update(extra)
    return ("hello", device, meta)


def parse_hello(frame) -> Tuple[int, dict]:
    """(requested_device, meta) from a hello frame; raises RuntimeError
    (never assert: -O strips those) on anything else."""
    if (
        isinstance(frame, tuple)
        and len(frame) == 3
        and frame[0] == "hello"
        and isinstance(frame[2], dict)
    ):
        return int(frame[1]), dict(frame[2])
    raise RuntimeError(f"bad slave handshake frame {frame!r}")


def main(argv=None):
    """TCP slave process entry — see module docstring."""
    import argparse
    import os

    from repro.core.cluster.codec import WireCodec
    from repro.core.cluster.transport import ShmSlaveEndpoint, TCPSlaveEndpoint

    ap = argparse.ArgumentParser(description="master/slave TCP slave process")
    ap.add_argument("--host", required=True)
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--transport", default="tcp", choices=["tcp", "shm"],
                    help="wire to the master: a plain TCP socket, or "
                         "shared-memory rings with a TCP control channel "
                         "(co-located masters only)")
    ap.add_argument("--device", type=int, default=-1,
                    help="requested device slot; -1 (default) lets the "
                         "master assign the next free one — what a "
                         "hand-launched remote slave should use")
    ap.add_argument("--slowdown", type=float, default=1.0)
    ap.add_argument("--backend", default="numpy")
    ap.add_argument("--wire-dtype", default=None)
    ap.add_argument("--wire-codec", default=None,
                    help="compressor-stack spec, e.g. 'int8' or "
                         "'weights=fp16,acts=fp16,grads=topk:0.05'; "
                         "must match the master's")
    ap.add_argument("--heartbeat-s", type=float, default=0.0,
                    help="send a liveness frame every this many seconds "
                         "(0 = off); masters with a heartbeat deadline "
                         "need it to tell busy from dead")
    ap.add_argument("--auth-env", default="REPRO_CLUSTER_AUTH",
                    help="name of the env var holding the cluster auth "
                         "token (hex); the secret rides the environment, "
                         "never argv (visible in ps)")
    ap.add_argument("--connect-timeout-s", type=float, default=60.0,
                    help="keep retrying the connect for this long — a "
                         "hand-launched slave may legally start before "
                         "the master binds its listener")
    # -- sub-master mode: this process is a whole GROUP -------------------
    ap.add_argument("--group-slowdowns", default=None,
                    help="comma-separated slowdowns of the group's devices "
                         "(first = this sub-master's own compute).  Setting "
                         "this turns the process into a SUB-MASTER: a slave "
                         "to the root on the wire above, a full "
                         "HeteroCluster master to an inner in-proc group")
    ap.add_argument("--group-backends", default=None,
                    help="comma-separated backends of the group's devices "
                         "(default: numpy for all)")
    ap.add_argument("--group-partition", default="auto",
                    help="the INNER per-layer partition axis "
                         "(kernel|spatial|batch|auto)")
    ap.add_argument("--group-microbatches", type=int, default=4)
    ap.add_argument("--group-no-pipeline", action="store_true",
                    help="disable the inner cluster's microbatch pipeline")
    ap.add_argument("--group-bandwidth-mbps", type=float, default=None,
                    help="emulated per-link bandwidth INSIDE the group")
    ap.add_argument("--group-nic-mbps", type=float, default=None,
                    help="emulated shared NIC for the sub-master's own "
                         "in-proc links (see transport.SharedNIC)")
    args = ap.parse_args(argv)

    token_hex = os.environ.get(args.auth_env)
    endpoint_cls = (
        ShmSlaveEndpoint if args.transport == "shm" else TCPSlaveEndpoint
    )
    endpoint = endpoint_cls(
        args.host, args.port,
        connect_timeout_s=args.connect_timeout_s,
        auth_token=bytes.fromhex(token_hex) if token_hex else None,
        wire_codec=WireCodec.from_spec(args.wire_codec, args.wire_dtype),
    )
    code = 0
    inner = None
    try:
        extra = None
        if args.group_slowdowns:
            # Lazy on purpose: hierarchy -> cluster pulls the full
            # master-side stack; plain leaf slaves must stay jax-free
            # and numpy-light at import time.
            from repro.core.cluster.hierarchy import (
                GroupSpec,
                build_group_cluster,
                group_hello_meta,
            )

            sds = [float(s) for s in args.group_slowdowns.split(",")]
            bks = (
                args.group_backends.split(",")
                if args.group_backends else None
            )
            inner = build_group_cluster(GroupSpec(
                slowdowns=sds,
                backends=bks,
                partition=args.group_partition,
                pipeline=not args.group_no_pipeline,
                microbatches=args.group_microbatches,
                bandwidth_mbps=args.group_bandwidth_mbps,
                nic_mbps=args.group_nic_mbps,
            ))
            extra = {"group": group_hello_meta(inner)}
        endpoint.send(
            hello_frame(args.device, args.backend, args.slowdown, extra)
        )
        reply = endpoint.recv()
        if (
            not isinstance(reply, tuple) or len(reply) != 2
            or reply[0] != "welcome"
        ):
            raise RuntimeError(f"bad master welcome frame {reply!r}")
        device = int(reply[1])
        if args.heartbeat_s > 0:
            endpoint.start_heartbeat(args.heartbeat_s)
        if inner is not None:
            sub_master_loop(endpoint, inner, device)  # shuts inner down
        else:
            slave_loop(endpoint, args.slowdown, args.backend, device)
    except Exception:  # pragma: no cover - surfaced via the exit code
        traceback.print_exc()
        code = 1
    finally:
        if inner is not None:
            inner.shutdown()  # idempotent; normally done by the loop
        endpoint.close()
        # _exit, not exit: an xla/pallas backend leaves native runtime
        # threads behind that can deadlock CPython finalization (the
        # ROADMAP hang); a slave has nothing to finalize.
        os._exit(code)


if __name__ == "__main__":
    # Re-enter through the properly-imported module: under ``-m`` this
    # file IS ``__main__``, and a SlaveError pickled from here would
    # unpickle as ``__main__.SlaveError`` on the master (whose __main__
    # is pytest / the CLI) and fail to resolve.
    from repro.core.cluster import protocol as _protocol

    _protocol.main()
