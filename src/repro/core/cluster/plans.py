"""Per-layer partition plans: which axis a conv layer splits on, and how.

The paper splits only the output-channel ("kernel") axis; the hybrid
runtime can also split the HEIGHT axis ("spatial": row strips + a
``kh//2`` halo), the BATCH axis ("batch": replicate the kernel, split
the N axis, sum the per-slave dW — an exact all-reduce), or pick the
cheapest axis per layer ("auto") from the comm-extended Eq. 1
prediction.  This module holds the pure planning math — strip/halo
geometry, batch-row ranges, per-unit wire bytes, the wall-clock
predictor and the axis resolver — over a duck-typed ``cluster`` that
supplies device state (``_effective_times``, ``shares_for``,
``bandwidths``, ``probe_flops``, ``_wire_itemsize``, ``partition``,
``partition_choices``).  No transport, no threads, numpy only.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

PARTITION_MODES = ("kernel", "spatial", "batch", "auto")


class BoundedDict(dict):
    """A dict with a FIFO size bound: inserting past ``maxsize`` evicts
    the oldest key.  Backs ``partition_choices`` and the auto-mode memo
    so serve-lane dynamic batching (a new key per slab batch size)
    cannot grow the planner's caches without bound."""

    def __init__(self, maxsize: int = 128):
        super().__init__()
        self.maxsize = int(maxsize)

    def __setitem__(self, key, value):
        if key in self:
            del self[key]  # re-insert at the back so live keys survive
        super().__setitem__(key, value)
        while len(self) > self.maxsize:
            del self[next(iter(self))]


def strip_plan(
    h: int, kh: int, counts: Sequence[int]
) -> Tuple[List[Tuple[int, int]], List[Tuple[int, int, int, int]]]:
    """Cut H output rows into per-device strips sized by ``counts`` and
    derive each strip's halo'd input window: rows [lo, hi) of the input
    plus (pad_top, pad_bot) zero rows that restore the clipped SAME
    padding at the image border.  Empty strips get empty windows."""
    ph, pb = kh // 2, kh - 1 - (kh // 2)
    rows: List[Tuple[int, int]] = []
    halos: List[Tuple[int, int, int, int]] = []
    r0 = 0
    for c in counts:
        r1 = r0 + int(c)
        if r1 == r0:
            rows.append((r0, r0))
            halos.append((r0, r0, 0, 0))
            continue
        lo, hi = max(0, r0 - ph), min(h, r1 + pb)
        halos.append((lo, hi, ph - (r0 - lo), pb - (hi - r1)))
        rows.append((r0, r1))
        r0 = r1
    assert r0 == h, "strip counts must sum to H"
    return rows, halos


def batch_ranges(counts: Sequence[int], b: int) -> List[Tuple[int, int]]:
    """Per-device ``[r0, r1)`` batch-row ranges for a slab of ``b``
    rows, proportional to ``counts`` (largest-remainder rounding,
    deterministic).  A batch plan is built from the FULL batch shape
    but each microbatch scatter moves a slice whose N differs — the
    plan's proportions are re-cut to the actual slab here, so the
    device shares hold at every pipeline depth.  When ``b`` equals
    ``sum(counts)`` the ranges reproduce ``counts`` exactly.  Devices
    with a zero share get empty ranges (and ship zero rows)."""
    c = np.asarray(counts, dtype=np.float64)
    total = float(c.sum())
    assert total > 0, "batch plan must cover at least one row"
    ideal = c * (b / total)
    base = np.floor(ideal).astype(np.int64)
    rem = int(b - base.sum())
    order = np.argsort(-(ideal - np.floor(ideal)), kind="stable")
    for j in range(rem):
        base[order[j % len(base)]] += 1
    out: List[Tuple[int, int]] = []
    r0 = 0
    for cc in base:
        out.append((r0, r0 + int(cc)))
        r0 += int(cc)
    assert r0 == b, "batch ranges must tile the slab"
    return out


@dataclasses.dataclass
class LayerPlan:
    """How ONE conv layer is split over the devices — fixed for every
    microbatch of the layer (the slave caches one kernel shard per op,
    so the split must not drift between microbatches).

    ``member_ids`` pins the membership the plan was built for: the
    stable slave ids behind ``counts[1:]``, in order.  An elastic
    cluster may lose a slave while a plan is still live (later
    microbatches, the backward sweep) — scatters resolve shard k to
    member ``member_ids[k-1]``, never to "whatever the k-th live slave
    is now", and the master absorbs shards of members that died."""

    mode: str                     # "kernel" | "spatial" | "batch" (auto is resolved)
    counts: np.ndarray            # kernels / H rows / batch rows per device
    shards: Optional[List[np.ndarray]] = None  # kernel mode: w split per device
    w: Optional[np.ndarray] = None             # spatial+batch: the full kernel
    rows: Optional[List[Tuple[int, int]]] = None  # H strips or batch ranges
    halos: Optional[List[Tuple[int, int, int, int]]] = None
    member_ids: Optional[Tuple[int, ...]] = None  # slave ids behind counts[1:]
    # versioned weight-broadcast cache: the stable key this layer's
    # kernel is cached under on the slaves (None = legacy per-op cache)
    # and the version frozen when the plan was built — scatters ship a
    # WeightRef token instead of the kernel when a slave already holds
    # (wkey, wversion) with this plan's geometry
    wkey: Optional[object] = None
    wversion: int = 0


def split_kernels(w: np.ndarray, counts: np.ndarray) -> List[np.ndarray]:
    """Split the kernel's output-channel axis into per-device shards."""
    edges = np.cumsum(counts)[:-1]
    return np.split(w, edges, axis=-1)


def unit_bytes(
    x_shape, w_shape, mode: str, op: str, itemsize: float,
    w_itemsize: Optional[float] = None, g_itemsize: Optional[float] = None,
    w_cached: bool = False,
) -> float:
    """Share-proportional wire bytes per allocation unit — one KERNEL
    (w column out + feature-map column back, plus the gradient slice
    and dW column for bwd), one H ROW (x row out + y row back, plus
    the g row and dX row for bwd), or one BATCH ROW (one sample's x
    out + y back; bwd adds the sample's g out and dX back).
    ``op="train"`` is one forward plus one backward, what a
    train-chain plan governs.  Fixed per-slave costs (the x broadcast,
    the halo, the full kernel, the kernel-mode backward's full-dX
    return, the batch-mode backward's full-dW return) do not move the
    optimal split and are left to the mode predictor.

    Byte prediction sees the codec and the weight cache: ``itemsize``
    prices activation elements, ``w_itemsize``/``g_itemsize`` (default:
    same) price weight/gradient elements, and ``w_cached=True`` zeroes
    the weight-shipping terms — a versioned-cache hit means the slaves
    already hold this layer's kernel."""
    w_item = itemsize if w_itemsize is None else w_itemsize
    g_item = itemsize if g_itemsize is None else g_itemsize
    b, h, wd, cin = x_shape
    kh, kw, _, cout = w_shape
    if mode == "kernel":
        w_col = kh * kw * cin
        y_col = b * h * wd
        w_ship = 0.0 if w_cached else w_col * w_item
        conv = w_ship + y_col * itemsize   # w col out + y col back
        # bwd: w col + g col out, dW col back; the full-dX return is
        # a FIXED per-slave cost, excluded by this contract
        bwd = w_ship + y_col * g_item + w_col * g_item
    elif mode == "batch":
        x_smp = h * wd * cin
        y_smp = h * wd * cout
        conv = (x_smp + y_smp) * itemsize  # x sample out + y sample back
        # x + g samples out, dX sample back; the full-dW return is a
        # FIXED per-slave cost, excluded by this contract
        bwd = x_smp * itemsize + (y_smp + x_smp) * g_item
    else:
        x_row = b * wd * cin
        y_row = b * wd * cout
        conv = (x_row + y_row) * itemsize  # x row out + y row back
        # x + g rows out, dX row back
        bwd = x_row * itemsize + (y_row + x_row) * g_item
    if op == "conv":
        return conv
    if op == "bwd":
        return bwd
    return conv + bwd              # "train"


def predict_partition_seconds(
    cluster, x_shape, w_shape, op: str = "conv",
    weights_cached: bool = False,
) -> Dict[str, float]:
    """Predicted per-layer wall-clock of each partition axis: every
    slave's wire bytes over its OWN link plus its balanced compute
    share (absolute once a real ``probe()`` has calibrated
    ``probe_flops``; otherwise the comm term alone decides — the
    compute splits near-identically on both axes).  ``op`` is what
    the plan will govern: ``"conv"`` (forward only), ``"bwd"``, or
    ``"train"`` (one forward + one backward) — the backward's wire
    differs by axis (kernel mode re-broadcasts x AND returns a
    full-size dX per slave; spatial ships strips both ways; batch
    ships row slices both ways but returns a FULL dW per slave, the
    all-reduce cost that sinks data parallelism on thin links), so a
    train-step plan must weigh both directions.  The prediction sees
    the codec (per-class wire itemsizes — batch's dW return is priced
    at the grads itemsize, so ``grads=topk`` + error feedback
    discounts the all-reduce per slave) and the versioned weight
    cache (``weights_cached=True`` zeroes the kernel-shipping terms,
    which makes batch's replica broadcast nearly free after step 1)."""
    b, h, wd, cin = x_shape
    kh, kw, _, cout = w_shape
    item = cluster._wire_itemsize
    item_w = getattr(cluster, "_wire_itemsize_w", item)
    item_g = getattr(cluster, "_wire_itemsize_g", item)
    x_e = float(b * h * wd * cin)    # activation elements
    y_e = float(b * h * wd * cout)   # output / gradient-slice elements
    w_e = float(kh * kw * cin * cout)
    x_b, y_b, w_b = x_e * item, y_e * item, w_e * item
    w_ship = 0.0 if weights_cached else w_e * item_w
    times = cluster._effective_times()
    layer_flops = 2.0 * b * h * wd * kh * kw * cin * cout
    # the backward (dX + dW) costs ~2x the forward's flops
    flops_mult = {"conv": 1.0, "bwd": 2.0, "train": 3.0}[op]
    scale = (layer_flops / cluster.probe_flops) if cluster.probe_flops else None
    out: Dict[str, float] = {}
    for mode in ("kernel", "spatial", "batch"):
        n_units = {"kernel": cout, "spatial": h, "batch": b}[mode]
        counts = cluster.shares_for(
            n_units,
            unit_bytes=unit_bytes(
                x_shape, w_shape, mode, op, item,
                w_itemsize=item_w, g_itemsize=item_g,
                w_cached=weights_cached,
            ),
            layer_flops=flops_mult * layer_flops,
        )
        worst = 0.0
        for i, c in enumerate(counts):
            bw = None if i == 0 else cluster.bandwidths[i - 1]
            frac = float(c) / n_units if n_units else 0.0
            halo = min(kh - 1, h) if c > 0 else 0
            if mode == "kernel":
                fwd_wire = x_b + frac * (w_ship + y_b)
                # x re-broadcast + g slice out; full dX + dW cols back
                bwd_wire = (
                    x_b + x_e * item_g
                    + frac * (w_ship + y_e * item_g)
                )
                comp_frac = frac
                active = i > 0
            elif mode == "batch":
                # x rows + full kernel out; y rows back
                fwd_wire = frac * (x_b + y_b) + w_ship
                # x + g rows out; dX rows + the FULL dW back per slave
                # (the exact all-reduce — its cost is constant in the
                # batch share, priced at the grads itemsize)
                bwd_wire = (
                    frac * (x_b + x_e * item_g + y_e * item_g)
                    + w_ship + w_e * item_g
                )
                comp_frac = frac
                active = i > 0 and c > 0
            else:
                hfrac = (c + halo) / h
                fwd_wire = hfrac * x_b + w_ship + frac * y_b
                # x strip + g strip out; dX halo strip + full dW back
                bwd_wire = (
                    hfrac * (x_b + x_e * item_g)
                    + w_ship + w_e * item_g
                    + frac * y_e * item_g
                )
                comp_frac = hfrac
                active = i > 0 and c > 0
            wire = {
                "conv": fwd_wire,
                "bwd": bwd_wire,
                "train": fwd_wire + bwd_wire,
            }[op] if active else 0.0
            t_comm = wire * 8.0 / (bw * 1e6) if bw is not None else 0.0
            t_comp = (
                times[i] * scale * comp_frac * flops_mult if scale else 0.0
            )
            worst = max(worst, t_comm + t_comp)
        out[mode] = worst
    return out


def resolve_mode(
    cluster, x_shape, w_shape, override: Optional[str], op: str = "conv",
    weights_cached: bool = False,
) -> str:
    """The partition axis for one layer; ``"auto"`` resolves against
    the predicted wall-clock of ``op`` and records its pick in
    ``cluster.partition_choices``.

    The decision key includes the batch dimension (it rides in
    ``x_shape``: batch mode's unit count and every mode's bytes scale
    with N), ``op`` and the weight-cache state — serve-lane dynamic
    batching re-resolves per slab size deliberately, but through the
    cluster's bounded ``_mode_cache`` memo so repeated slab sizes skip
    the predictor and the caches stay bounded.  Ties break toward the
    paper's order (kernel, then spatial, then batch): a challenger
    axis must be strictly faster to displace the incumbent."""
    mode = override or cluster.partition
    if mode not in PARTITION_MODES:
        raise ValueError(
            f"partition must be one of {PARTITION_MODES}, got {mode!r}"
        )
    if mode != "auto":
        return mode
    shape_key = (tuple(x_shape), tuple(w_shape))
    memo = getattr(cluster, "_mode_cache", None)
    memo_key = shape_key + (op, bool(weights_cached))
    if memo is not None and memo_key in memo:
        choice = memo[memo_key]
        cluster.partition_choices[shape_key] = choice
        return choice
    if all(bw is None for bw in cluster.bandwidths):
        # free links: the paper's kernel axis, no halo / all-reduce
        # overhead to pay back
        choice = "kernel"
    else:
        pred = predict_partition_seconds(
            cluster, x_shape, w_shape, op, weights_cached=weights_cached
        )
        choice = "kernel"
        for challenger in ("spatial", "batch"):
            if pred[challenger] < pred[choice]:
                choice = challenger
    if memo is not None:
        memo[memo_key] = choice
    cluster.partition_choices[shape_key] = choice
    return choice


def plan_conv(
    cluster, x_shape, w: np.ndarray, op: str = "conv",
    partition: Optional[str] = None, weight_key=None,
) -> LayerPlan:
    """Freeze how one conv layer splits over the devices: the axis
    (resolving ``"auto"`` against what the plan will govern — ``op``
    is ``"conv"``, ``"bwd"`` or ``"train"``), the Eq. 1(+comm) unit
    counts, the per-device kernel shards or row strips, and the
    membership snapshot (``member_ids``) the split binds to.  One
    plan serves every microbatch of the layer — the slave caches ONE
    kernel shard per op, so the split must not drift within a
    layer.

    ``weight_key`` opts the layer into the versioned weight-broadcast
    cache: the cluster's version store decides whether this kernel
    object is ALREADY current on the slaves (same array identity as
    the version it last shipped), and a current version both discounts
    the weight terms in the byte prediction and lets scatters ship a
    ~24-byte ``WeightRef`` token instead of the kernel."""
    wkey = weight_key if getattr(cluster, "weight_cache", False) else None
    wversion, wcached = 0, False
    if wkey is not None:
        wversion, wcached = cluster._weight_version(wkey, w)
    mode = resolve_mode(
        cluster, tuple(x_shape), tuple(w.shape), partition, op,
        weights_cached=wcached,
    )
    b, h, wd, cin = x_shape
    kh, kw, _, cout = w.shape
    layer_flops = 2.0 * b * h * wd * kh * kw * cin * cout
    item = cluster._wire_itemsize
    ub = unit_bytes(
        x_shape, w.shape, mode, op, item,
        w_itemsize=getattr(cluster, "_wire_itemsize_w", item),
        g_itemsize=getattr(cluster, "_wire_itemsize_g", item),
        w_cached=wcached,
    )
    members = getattr(cluster, "slave_ids", None)
    members = tuple(members) if members is not None else None
    if mode == "kernel":
        counts = cluster.shares_for(
            cout, unit_bytes=ub, layer_flops=layer_flops
        )
        return LayerPlan(
            "kernel", counts, shards=split_kernels(w, counts),
            member_ids=members, wkey=wkey, wversion=wversion,
        )
    if mode == "batch":
        # replicate the kernel, split the N axis; each microbatch
        # scatter re-cuts ``counts`` to its slab via ``batch_ranges``
        counts = cluster.shares_for(b, unit_bytes=ub, layer_flops=layer_flops)
        return LayerPlan(
            "batch", counts, w=np.asarray(w, np.float32),
            rows=batch_ranges(counts, int(b)),
            member_ids=members, wkey=wkey, wversion=wversion,
        )
    counts = cluster.shares_for(h, unit_bytes=ub, layer_flops=layer_flops)
    rows, halos = strip_plan(h, kh, counts)
    return LayerPlan(
        "spatial", counts, w=np.asarray(w, np.float32), rows=rows,
        halos=halos, member_ids=members, wkey=wkey, wversion=wversion,
    )


def group_aggregate_time(times: Sequence[float]) -> float:
    """Aggregate Eq. 1 probe time of a GROUP of devices working in
    parallel: member compute RATES add, so the group's time per probe
    workload is the harmonic combination ``1 / sum(1 / t_i)`` — always
    positive, and degenerate topologies stay well-defined (a one-member
    group is just that member's time; equal members divide it by the
    member count).  This is the single number a sub-master reports
    upward so the root can price a whole group as one Eq. 1 device.

    Raises:
        ValueError: on an empty group or a non-positive member time
            (a zero time would divide by zero AND claim infinite
            capacity — a probe that fast is a bug, not a device).
    """
    ts = [float(t) for t in times]
    if not ts:
        raise ValueError("group_aggregate_time needs at least one member")
    if any(t <= 0.0 for t in ts):
        raise ValueError(f"member probe times must be positive, got {ts}")
    return 1.0 / sum(1.0 / t for t in ts)


def group_capacity(
    times: Sequence[float], bandwidths: Sequence[Optional[float]]
) -> Tuple[float, Optional[float]]:
    """A group's (aggregate probe time, internal bandwidth) as ONE
    Eq. 1 device: compute rates SUM (``group_aggregate_time``), while
    the internal bandwidth is the MIN of the members' finite link
    speeds — a chain is as fast as its narrowest hop, and the root
    folds this into the group's uplink so rows are never priced faster
    than the group can internally redistribute them.  ``None`` entries
    mean an unmetered (in-proc) link and are skipped; all-``None``
    yields ``None`` (no finite internal bottleneck to report)."""
    finite = [float(b) for b in bandwidths if b is not None]
    return group_aggregate_time(times), (min(finite) if finite else None)


def check_plan(plan: LayerPlan, n_units: int, n_devices: int) -> None:
    """Invariants every live plan must satisfy — what the re-partition
    conformance tests assert after an evict/admit: unit counts cover the
    layer exactly once over exactly the current membership, spatial
    strips tile [0, n_units) with in-bounds halo windows, and batch
    ranges tile the batch.  Raises AssertionError with a named
    reason."""
    assert len(plan.counts) == n_devices, (
        f"plan covers {len(plan.counts)} devices, membership has {n_devices}"
    )
    assert int(np.sum(plan.counts)) == n_units, (
        f"plan units sum to {int(np.sum(plan.counts))}, layer has {n_units}"
    )
    if plan.member_ids is not None:
        assert len(plan.member_ids) == n_devices - 1, "one member id per slave"
    if plan.mode == "kernel":
        assert plan.shards is not None and len(plan.shards) == n_devices
        assert sum(s.shape[-1] for s in plan.shards) == n_units
        return
    if plan.mode == "batch":
        assert plan.w is not None, "batch plan carries the full kernel"
        assert plan.rows is not None and len(plan.rows) == n_devices
        r_prev = 0
        for r0, r1 in plan.rows:
            assert r1 >= r0, "batch range non-negative"
            if r1 > r0:
                assert r0 == r_prev, "batch ranges tile in order"
                r_prev = r1
        assert r_prev == n_units, "batch ranges cover every row"
        return
    assert plan.rows is not None and plan.halos is not None
    r_prev = 0
    for (r0, r1), (lo, hi, pt, pb) in zip(plan.rows, plan.halos):
        assert r0 == (r_prev if r1 > r0 else r0), "strips tile in order"
        if r1 > r0:
            r_prev = r1
        assert 0 <= lo <= hi <= n_units, "halo window inside the image"
        assert pt >= 0 and pb >= 0, "halo pads non-negative"
    assert r_prev == n_units, "strips cover every output row"
