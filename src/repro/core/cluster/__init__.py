"""The master/slave cluster runtime, layered bottom-up:

    transport.py — the wire: ``InProcTransport`` (queues + emulated
                   bandwidth, slave threads) and ``TCPTransport`` (real
                   framed sockets, subprocess slaves)
    codec.py     — the fp16/bf16 compact wire codec + canonical byte
                   accounting, independent of any transport
    protocol.py  — message grammar + the slave loop (Algorithm 2);
                   doubles as the TCP slave process entry (``-m``)
    plans.py     — per-layer partition plans: kernel/spatial/auto axis
                   resolution, Eq. 1(+comm) unit counts, strip/halo math
    scheduler.py — the pipelined schedules (microbatch double-buffering,
                   forward chain, fwd+bwd train chain) over any transport
    cluster.py   — ``HeteroCluster`` (the master, Algorithm 1) wiring it
                   all together, plus ``make_distributed_conv``
    hierarchy.py — the two-tier composition: ``HierarchicalCluster``
                   (a batch-axis root over sub-master groups) and
                   ``GroupSpec``/``parse_groups`` topology parsing

Attribute access is lazy (PEP 562) so that TCP slave subprocesses —
which import ``repro.core.cluster.protocol`` — never pay for jax or the
master-side stack.  ``repro.core.master_slave`` remains the stable
import surface; it re-exports everything from here.
"""
from __future__ import annotations

from repro.lazy import lazy_exports

_EXPORTS = {
    "HeteroCluster": ".cluster",
    "make_distributed_conv": ".cluster",
    "HierarchicalCluster": ".hierarchy",
    "GroupSpec": ".hierarchy",
    "parse_groups": ".hierarchy",
    "Transport": ".transport",
    "InProcTransport": ".transport",
    "SharedNIC": ".transport",
    "TCPTransport": ".transport",
    "TCPSlaveEndpoint": ".transport",
    "TCPListener": ".transport",
    "TRANSPORT_KINDS": ".transport",
    "SlaveLost": ".transport",
    "HEARTBEAT": ".transport",
    "is_heartbeat": ".transport",
    "resolve_wire_dtype": ".codec",
    "wire_nbytes": ".codec",
    "TRAIN_OVER": ".protocol",
    "SlaveError": ".protocol",
    "slave_loop": ".protocol",
    "PARTITION_MODES": ".plans",
    "LayerPlan": ".plans",
    "strip_plan": ".plans",
    "check_plan": ".plans",
    "LayerTiming": ".scheduler",
    "TrainStepResult": ".scheduler",
    "Pending": ".scheduler",
}

__all__ = sorted(_EXPORTS)

__getattr__, __dir__ = lazy_exports(__name__, globals(), _EXPORTS)
