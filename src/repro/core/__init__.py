"""The paper's primary contribution: heterogeneity-aware kernel-sharded
model parallelism for convolutional layers (Marques, Falcao, Alexandre,
2017), plus its TPU-mesh generalisation."""
from repro.core.costmodel import (  # noqa: F401
    ConvLayerSpec,
    comm_time_s,
    paper_network,
    predict_step_time,
    upload_bytes,
    upload_elements,
    upload_elements_nodes,
)
from repro.core.backends import (  # noqa: F401
    available_backends,
    get_backend,
    probe_conv_time,
    register_backend,
)
from repro.core.master_slave import HeteroCluster, make_distributed_conv  # noqa: F401
from repro.core.partitioner import (  # noqa: F401
    allocate_kernels,
    predicted_conv_time,
    probe_device,
    speedup,
    workload_shares,
)
from repro.core.conv_shard import make_sharded_conv  # noqa: F401
