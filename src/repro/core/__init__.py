"""The paper's primary contribution: heterogeneity-aware kernel-sharded
model parallelism for convolutional layers (Marques, Falcao, Alexandre,
2017), plus its TPU-mesh generalisation.

Attribute access is lazy (PEP 562): ``from repro.core import
HeteroCluster`` works as before, but merely importing ``repro.core``
no longer drags in jax — TCP slave subprocesses
(``-m repro.core.cluster.protocol``) stay numpy-light at spawn.
"""
from __future__ import annotations

from repro.lazy import lazy_exports

_EXPORTS = {
    # costmodel
    "ConvLayerSpec": "repro.core.costmodel",
    "comm_time_s": "repro.core.costmodel",
    "paper_network": "repro.core.costmodel",
    "predict_step_time": "repro.core.costmodel",
    "upload_bytes": "repro.core.costmodel",
    "upload_elements": "repro.core.costmodel",
    "upload_elements_nodes": "repro.core.costmodel",
    # backends
    "available_backends": "repro.core.backends",
    "get_backend": "repro.core.backends",
    "probe_conv_time": "repro.core.backends",
    "register_backend": "repro.core.backends",
    # master/slave cluster (core/cluster/ package behind the shim)
    "HeteroCluster": "repro.core.master_slave",
    "make_distributed_conv": "repro.core.master_slave",
    # partitioner
    "allocate_kernels": "repro.core.partitioner",
    "effective_times": "repro.core.partitioner",
    "predicted_conv_time": "repro.core.partitioner",
    "probe_device": "repro.core.partitioner",
    "speedup": "repro.core.partitioner",
    "workload_shares": "repro.core.partitioner",
    # mesh sharding
    "make_sharded_conv": "repro.core.conv_shard",
}

__all__ = sorted(_EXPORTS)

__getattr__, __dir__ = lazy_exports(__name__, globals(), _EXPORTS)
