"""Kernel-sharded convolution on the TPU mesh — the paper's distribution
expressed as GSPMD shardings.

"Broadcast the inputs" = activations replicated over ``model``;
"scatter the kernels"  = HWIO weights sharded on the output-channel axis;
"gather the feature maps" = the all-gather GSPMD inserts when gather-mode
rules pin the conv output back to replicated (the sharded/megatron rules
keep feature maps channel-sharded through ReLU/LRN/pool instead — the
§Perf lever, since LRN and pooling are channel-local up to a 2-channel
halo).

On a homogeneous mesh the Eq. 1 shares degenerate to the uniform split
(its fixed point) — GSPMD shards are even by construction; the uneven
heterogeneous allocation is exercised by core/master_slave.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.conv import apply_conv
from repro.sharding.axes import AxisRules
from repro.sharding.partitioning import constrain


def make_sharded_conv(rules: AxisRules):
    """conv_fn for models/cnn.py running under a mesh: the kernel axis is
    sharded over `model`, the output layout follows the rule mode."""

    def conv_fn(params, x, padding: str = "SAME"):
        y = apply_conv(params, x, padding=padding)
        # column layout right after the convolution (every mode)
        y = constrain(y, rules, "batch", None, None, "act_conv_col")
        # gather mode: force the paper's all-gather; sharded mode: keep
        y = constrain(y, rules, "batch", None, None, "act_conv")
        return y

    return conv_fn
