"""Heterogeneity-aware workload partitioner — the paper's Eq. 1.

Given per-device probe times ``t_i`` (seconds to run the same reference
workload), the workload share of device i is

    w_i = (max(t) / t_i) / sum_j (max(t) / t_j)                    (Eq. 1)

i.e. shares proportional to measured throughput.  ``allocate_kernels``
turns the fractional shares into an integer number of kernels per device
with the largest-remainder method, preserving the total and guaranteeing
every device at least ``min_per_device`` kernels (0 allowed).

The allocator is axis-agnostic: the same Eq. 1 shares split output
kernels (partition="kernel"), image rows (partition="spatial"), or
batch samples (partition="batch") — only the unit and its per-unit
wire bytes change (cluster/plans.py:unit_bytes).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


def workload_shares(times: Sequence[float]) -> np.ndarray:
    """Eq. 1.  times[i] > 0 is device i's probe time; returns shares
    summing to 1, inversely proportional to time."""
    t = np.asarray(times, dtype=np.float64)
    if t.ndim != 1 or t.size == 0:
        raise ValueError("times must be a non-empty 1-D sequence")
    if np.any(t <= 0) or not np.all(np.isfinite(t)):
        raise ValueError("probe times must be positive and finite")
    perf = t.max() / t  # max(t)/t_i — the paper's performance values
    return perf / perf.sum()


def allocate_kernels(
    num_kernels: int, times: Sequence[float], *, min_per_device: int = 0
) -> np.ndarray:
    """Integer kernel counts per device via largest-remainder rounding of
    the Eq. 1 shares.  sum == num_kernels always holds."""
    if num_kernels < 0:
        raise ValueError("num_kernels must be >= 0")
    shares = workload_shares(times)
    n = shares.size
    if num_kernels < n * min_per_device:
        raise ValueError("num_kernels too small for min_per_device")
    ideal = shares * num_kernels
    base = np.floor(ideal).astype(np.int64)
    base = np.maximum(base, min_per_device)
    # distribute the remainder to the largest fractional parts
    while base.sum() > num_kernels:  # over-allocated due to min clamp
        i = int(np.argmax(base - ideal))
        if base[i] <= min_per_device:
            candidates = np.where(base > min_per_device)[0]
            i = candidates[int(np.argmax((base - ideal)[candidates]))]
        base[i] -= 1
    rem = num_kernels - base.sum()
    if rem > 0:
        frac = ideal - np.floor(ideal)
        order = np.argsort(-frac, kind="stable")
        for j in range(int(rem)):
            base[order[j % n]] += 1
    return base


_MAX_COMP_DUTY = 0.95  # clamp: a duty of 1.0 would zero the device out


def effective_times(
    times: Sequence[float],
    *,
    comp_duties=None,
    wire_bytes: Optional[Sequence[float]] = None,
    bandwidths_mbps: Optional[Sequence[Optional[float]]] = None,
) -> np.ndarray:
    """THE parameterized Eq. 1 input: probe times adjusted for every
    modelled effect, in one place.

    Two orthogonal adjustments (either may be omitted):

    * **non-conv duty** (multiplicative): a device that spends fraction
      ``d`` of its busy time on master-only non-conv layers has only
      ``1 - d`` of its throughput left for its conv shard, so its probe
      time inflates to ``t / (1 - d)`` (clamped at ``_MAX_COMP_DUTY``).
      ``comp_duties`` is a mapping ``{device: duty}`` or a per-device
      sequence.
    * **link comm** (additive): ``wire_bytes[i]`` is the bytes device i
      would move over its link if it took the WHOLE workload
      (share-proportional traffic only — fixed broadcast costs do not
      move the optimal split); ``bandwidths_mbps[i]`` its measured link
      (None/inf = no link, e.g. the master).  Both terms scale linearly
      with the share, so Eq. 1 over the sums minimizes the predicted
      wall-clock, not just the compute makespan.

    ``comp_aware_times`` / ``link_aware_times`` / ``profiles_to_shares``
    and ``HeteroCluster.shares_for`` are all thin parameterizations of
    this one path."""
    t = np.asarray(times, dtype=np.float64).copy()
    if comp_duties is not None:
        items = (
            comp_duties.items()
            if hasattr(comp_duties, "items")
            else enumerate(comp_duties)
        )
        for i, duty in items:
            d = min(float(duty), _MAX_COMP_DUTY)
            if d > 0.0:
                t[i] = t[i] / (1.0 - d)
    if wire_bytes is not None:
        if bandwidths_mbps is None or not (
            len(wire_bytes) == len(bandwidths_mbps) == t.size
        ):
            raise ValueError("times, wire_bytes, bandwidths must align")
        for i, (b, bw) in enumerate(zip(wire_bytes, bandwidths_mbps)):
            if bw is not None and np.isfinite(bw):
                if bw <= 0:
                    raise ValueError("bandwidths must be positive")
                t[i] += float(b) * 8.0 / (bw * 1e6)
    return t


def comp_aware_times(
    times: Sequence[float], comp_duty: float, *, device: int = 0
) -> np.ndarray:
    """One device's Eq. 1 share discounted by its non-conv duty — the
    single-device parameterization of ``effective_times``."""
    return effective_times(times, comp_duties={device: comp_duty})


def link_aware_times(
    times: Sequence[float],
    wire_bytes: Sequence[float],
    bandwidths_mbps: Sequence[Optional[float]],
) -> np.ndarray:
    """Eq. 1 extension: each device's COMM term added to its probe time
    — the links-only parameterization of ``effective_times``."""
    return effective_times(
        times, wire_bytes=wire_bytes, bandwidths_mbps=bandwidths_mbps
    )


def comm_aware_allocate(
    num_units: int,
    times: Sequence[float],
    wire_bytes: Sequence[float],
    bandwidths_mbps: Sequence[Optional[float]],
    *,
    min_per_device: int = 0,
) -> np.ndarray:
    """Integer unit counts (kernels, image rows, or batch samples) from
    the comm-extended Eq. 1: shares inversely proportional to compute +
    wire time."""
    return allocate_kernels(
        num_units,
        link_aware_times(times, wire_bytes, bandwidths_mbps),
        min_per_device=min_per_device,
    )


def predicted_conv_time(
    times: Sequence[float], kernels: Sequence[int], num_kernels: int
) -> float:
    """Time for the slowest device to finish its kernel share, given that
    device i convolves `num_kernels` kernels in `times[i]` seconds
    (linear-in-kernels model, the paper's assumption)."""
    t = np.asarray(times, dtype=np.float64)
    k = np.asarray(kernels, dtype=np.float64)
    return float(np.max(t * k / num_kernels))


def speedup(times: Sequence[float], kernels: Sequence[int], num_kernels: int,
            *, baseline_device: int = 0) -> float:
    """Speedup of the distributed conv phase vs the baseline device doing
    all kernels alone (the paper compares against a single device)."""
    t = np.asarray(times, dtype=np.float64)
    return float(t[baseline_device] / predicted_conv_time(times, kernels, num_kernels))


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """A device's measured capability, as the paper's probe reports it."""

    name: str
    conv_time: float  # seconds for the reference conv workload
    bandwidth_mbps: float = 5.0  # link to the master (paper: ~5 Mbps Wi-Fi)
    backend: str = "numpy"  # conv compute backend the device runs (core/backends.py)
    comp_duty: float = 0.0  # measured fraction of busy time spent on the
    #                         master-only non-conv layers (LayerTiming.comp_s
    #                         over comp_s + master_conv_s); 0 for slaves

    @property
    def gflops(self) -> float:
        # informational only; the partitioner uses times, not FLOPs
        return 1.0 / self.conv_time

    @property
    def effective_conv_time(self) -> float:
        """Probe time inflated by the non-conv duty — the Eq. 1 input for
        a device that cannot devote its whole throughput to conv."""
        return float(
            effective_times([self.conv_time], comp_duties=[self.comp_duty])[0]
        )

    def with_comp_duty(self, comp_duty: float) -> "DeviceProfile":
        """Record a measured non-conv duty (e.g. from a cluster's
        ``LayerTiming``) on an otherwise identical profile."""
        return dataclasses.replace(self, comp_duty=float(comp_duty))


def probe_device(
    name: str,
    backend: str = "numpy",
    *,
    slowdown: float = 1.0,
    bandwidth_mbps: float = 5.0,
    **probe_kwargs,
) -> DeviceProfile:
    """Run the §4.1.1 reference convolution on the named compute backend
    and return the resulting profile.  Probing the backend a device will
    actually run keeps the Eq. 1 shares exact for mixed-backend clusters
    (probe_kwargs: image_size, in_channels, kernel_size, num_kernels,
    batch, repeats, seed — see core/backends.py)."""
    from repro.core.backends import probe_conv_time

    t = probe_conv_time(backend, slowdown=slowdown, **probe_kwargs)
    return DeviceProfile(name, t, bandwidth_mbps, backend)


def profiles_to_shares(
    profiles: Sequence[DeviceProfile],
    *,
    wire_bytes: Optional[Sequence[float]] = None,
) -> np.ndarray:
    """Eq. 1 over a probed device set, comp-aware: each profile's
    non-conv duty discounts its share.  With ``wire_bytes`` (the bytes
    device i would move if it took the whole layer) the shares also
    weigh each profile's measured link — the comm-extended Eq. 1.  One
    ``effective_times`` call applies both adjustments."""
    return workload_shares(
        effective_times(
            [p.conv_time for p in profiles],
            comp_duties=[p.comp_duty for p in profiles],
            wire_bytes=wire_bytes,
            bandwidths_mbps=(
                [p.bandwidth_mbps for p in profiles]
                if wire_bytes is not None
                else None
            ),
        )
    )
