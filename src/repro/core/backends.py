"""Pluggable conv compute backends for the distributed engine.

The paper distributes ONE operation — the stride-1 SAME convolution over
the output-channel ("kernel") axis — so every device in the cluster only
ever needs two primitives:

    conv(x, w)        -> y                (Algorithm 2's `convn`)
    conv_vjp(x, w, g) -> (dx, dw)         (the backward shard)

``ConvBackend`` pins that contract; the registry maps a name to an
implementation so a heterogeneous cluster can mix devices running
different kernels (the paper's CPU/GPU scenario):

    numpy   — serial im2col, callback- and thread-safe everywhere; the
              master's default since it runs inside jax host callbacks
              where re-entering jit dispatch can deadlock the runtime.
    xla     — ``jax.lax.conv_general_dilated`` jitted per shape (jit's
              own cache keys on shapes/dtypes).
    pallas  — the MXU direct-conv kernel (kernels/conv2d.py) forward and
              the Pallas dX/dW backward; interpret mode off-TPU.

All primitives take and return **numpy** arrays: the master/slave
protocol moves serialized host buffers (the emulated sockets), and numpy
is the one currency every backend speaks.  ``probe_conv_time`` times the
SAME code a device will run for the real workload, so the Eq. 1 shares
computed from probe times are exact per backend.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


class ConvBackend:
    """The per-device compute contract of the distributed conv engine."""

    name: str = "base"

    def conv(self, x: np.ndarray, w: np.ndarray) -> np.ndarray:
        """NHWC x HWIO -> NHWC, SAME padding, stride 1."""
        raise NotImplementedError

    def conv_vjp(
        self, x: np.ndarray, w: np.ndarray, g: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(dx, dw) of sum(conv(x, w) * g)."""
        raise NotImplementedError


_REGISTRY: Dict[str, Callable[..., ConvBackend]] = {}
_INSTANCES: Dict[str, ConvBackend] = {}


def register_backend(name: str):
    """Class decorator: ``@register_backend("mine")`` adds a factory."""

    def deco(factory: Callable[..., ConvBackend]):
        _REGISTRY[name] = factory
        return factory

    return deco


def get_backend(name: str) -> ConvBackend:
    """Resolve (and cache) a backend instance by registry name.

    Names may carry a parameter after a colon — ``"sim:5e9"`` is a sim
    device at 5 GFLOP/s, ``"pallas:interpret"`` forces interpret mode —
    so one cluster can mix several instances of the same backend at
    different speeds without the per-device ``slowdown`` workaround.
    Each parameterized name caches its OWN instance."""
    if name not in _INSTANCES:
        base, _, param = name.partition(":")
        if base not in _REGISTRY:
            raise KeyError(
                f"unknown conv backend {name!r}; available: {available_backends()}"
            )
        try:
            _INSTANCES[name] = _REGISTRY[base](param) if param else _REGISTRY[base]()
        except (TypeError, ValueError) as e:
            raise ValueError(
                f"backend {base!r} rejected parameter {param!r}: {e}"
            ) from e
    return _INSTANCES[name]


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# numpy: serial im2col — the seed implementation, kept as the reference
# and as the only backend safe inside jax host callbacks.
# ---------------------------------------------------------------------------


def _conv_windows(x: np.ndarray, kh: int, kw: int) -> np.ndarray:
    """SAME-padded sliding windows as a zero-copy strided VIEW.
    x: (B,H,W,C) -> view (B,H,W,C,kh,kw)."""
    ph, pw = kh // 2, kw // 2
    xp = np.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    return np.lib.stride_tricks.sliding_window_view(xp, (kh, kw), axis=(1, 2))


def _im2col(x: np.ndarray, kh: int, kw: int) -> np.ndarray:
    """SAME-padded im2col.  x: (B,H,W,C) -> (B,H,W, kh*kw*C).

    Materializes a contiguous copy of the windows — kept ONLY where the
    reshape-to-matrix genuinely requires it: for kh,kw > 1 the single
    large BLAS GEMM it enables beats every measured copy-free
    formulation (tensordot/einsum on the strided view re-materialize the
    same copy internally; per-tap shifted GEMMs lose to the strided
    accumulate), and the VJP's ``cols.T @ g`` has no matrix without it.
    The 1x1 forward skips the lowering entirely (see ``numpy_conv``)."""
    b, h, w, c = x.shape
    win = _conv_windows(x, kh, kw).transpose(0, 1, 2, 4, 5, 3)
    return np.ascontiguousarray(win).reshape(b, h, w, kh * kw * c)


def numpy_conv(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """NHWC x HWIO SAME conv, stride 1 (the slave's `convn`).

    1x1 kernels take the lowering-free hot path: one GEMM on a FREE
    reshape of the contiguous input — no pad, no window copy (1.4-17x
    measured, ``numpy_fwd_1x1_nocopy`` in bench_kernels).  Larger
    kernels keep the im2col copy the GEMM genuinely needs (see
    ``_im2col``)."""
    kh, kw, cin, cout = w.shape
    x = np.asarray(x, np.float32)
    if kh == 1 and kw == 1:
        b, h, wd, _ = x.shape
        return (x.reshape(-1, cin) @ w[0, 0]).reshape(b, h, wd, cout)
    cols = _im2col(x, kh, kw)
    y = cols.reshape(-1, kh * kw * cin) @ w.reshape(kh * kw * cin, cout)
    return y.reshape(x.shape[0], x.shape[1], x.shape[2], cout)


def numpy_conv_vjp(x: np.ndarray, w: np.ndarray, g: np.ndarray):
    """Returns (dx, dw) of sum(conv(x, w) * g)."""
    x = np.asarray(x, np.float32)
    g = np.asarray(g, np.float32)
    kh, kw, cin, cout = w.shape
    if cout == 0:  # legal: a device allocated 0 kernels contributes nothing
        return np.zeros(x.shape, np.float32), np.zeros(w.shape, np.float32)
    b, h, wd, _ = x.shape
    cols = _im2col(x, kh, kw).reshape(-1, kh * kw * cin)
    dw = (cols.T @ g.reshape(-1, cout)).reshape(kh, kw, cin, cout)
    # dx: scatter the columns of dG @ W^T back into the padded image
    dcols = (g.reshape(-1, cout) @ w.reshape(kh * kw * cin, cout).T).reshape(
        b, h, wd, kh, kw, cin
    )
    ph, pw = kh // 2, kw // 2
    dxp = np.zeros((b, h + kh - 1, wd + kw - 1, cin), np.float32)
    for di in range(kh):
        for dj in range(kw):
            dxp[:, di : di + h, dj : dj + wd, :] += dcols[:, :, :, di, dj, :]
    dx = dxp[:, ph : ph + h, pw : pw + wd, :]
    return dx, dw


@register_backend("numpy")
class NumpyBackend(ConvBackend):
    name = "numpy"

    def conv(self, x, w):
        return numpy_conv(x, w)

    def conv_vjp(self, x, w, g):
        return numpy_conv_vjp(x, w, g)


# ---------------------------------------------------------------------------
# height-strip (spatial) partitioning helpers — shared by the master and
# every slave, on top of ANY backend's plain SAME conv primitives.
# ---------------------------------------------------------------------------


def strip_conv(
    backend: ConvBackend,
    x_halo: np.ndarray,
    w: np.ndarray,
    pad_top: int,
    pad_bot: int,
) -> np.ndarray:
    """Forward of one height strip of a SAME stride-1 conv.

    ``x_halo`` holds the strip's input rows plus the ``kh//2`` halo rows
    on each side, CLIPPED at the image border; ``pad_top``/``pad_bot``
    zero-rows restore what the clip removed, so the padded strip carries
    exactly the receptive field of the strip's output rows (the zeros
    coincide with the global SAME padding).  Runs the backend's ordinary
    SAME conv on the padded strip and slices out the interior rows —
    every backend works unchanged.  Assumes odd ``kh`` (the repo's
    ``kh//2``-low padding convention; even kernels differ per backend).
    Returns the strip's output rows: (B, strip_h, W, cout)."""
    kh = w.shape[0]
    ph = kh // 2
    strip_h = x_halo.shape[1] + pad_top + pad_bot - (kh - 1)
    if strip_h <= 0:  # a device legally allocated 0 rows
        return np.zeros(
            (x_halo.shape[0], 0, x_halo.shape[2], w.shape[-1]), np.float32
        )
    xp = np.pad(x_halo, ((0, 0), (pad_top, pad_bot), (0, 0), (0, 0)))
    y = backend.conv(xp, w)
    return np.asarray(y[:, ph : ph + strip_h], np.float32)


def strip_conv_vjp(
    backend: ConvBackend,
    x_halo: np.ndarray,
    w: np.ndarray,
    g_strip: np.ndarray,
    pad_top: int,
    pad_bot: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Backward of one height strip: ``(dx_halo, dw_partial)``.

    ``dx_halo`` covers the strip PLUS its halo rows — contributions of
    this strip's output-gradient rows to neighbouring strips' inputs —
    so the master must overlap-ADD the seams when reassembling the full
    dX.  ``dw_partial`` is this strip's contribution to the FULL kernel
    gradient (strips see every output channel); the master sums it."""
    kh = w.shape[0]
    ph = kh // 2
    strip_h = g_strip.shape[1]
    if strip_h == 0 or x_halo.shape[1] == 0:
        return (
            np.zeros(x_halo.shape, np.float32),
            np.zeros(w.shape, np.float32),
        )
    xp = np.pad(x_halo, ((0, 0), (pad_top, pad_bot), (0, 0), (0, 0)))
    gp = np.zeros(xp.shape[:-1] + (w.shape[-1],), np.float32)
    gp[:, ph : ph + strip_h] = g_strip
    dxp, dw = backend.conv_vjp(xp, w, gp)
    dx_halo = dxp[:, pad_top : pad_top + x_halo.shape[1]]
    return np.asarray(dx_halo, np.float32), np.asarray(dw, np.float32)


# ---------------------------------------------------------------------------
# xla: jax.lax.conv_general_dilated, jitted per shape.
# ---------------------------------------------------------------------------


@register_backend("xla")
class XlaBackend(ConvBackend):
    name = "xla"

    def __init__(self):
        import jax

        def _conv(x, w):
            return jax.lax.conv_general_dilated(
                x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
            )

        def _vjp(x, w, g):
            _, pullback = jax.vjp(_conv, x, w)
            return pullback(g)

        # jit caches per (shape, dtype), so every shard shape compiles once
        self._conv = jax.jit(_conv)
        self._vjp = jax.jit(_vjp)

    def conv(self, x, w):
        return np.asarray(self._conv(np.asarray(x), np.asarray(w)))

    def conv_vjp(self, x, w, g):
        dx, dw = self._vjp(np.asarray(x), np.asarray(w), np.asarray(g))
        return np.asarray(dx), np.asarray(dw)


# ---------------------------------------------------------------------------
# pallas: the MXU direct-conv kernel + the Pallas dX/dW backward.
# ---------------------------------------------------------------------------


@register_backend("pallas")
class PallasBackend(ConvBackend):
    """Runs kernels/conv2d.py.  Off-TPU the kernels execute in Pallas
    interpret mode — bit-accurate but slow, meant for CI parity tests."""

    name = "pallas"

    def __init__(self, interpret=None):
        import jax

        if isinstance(interpret, str):  # registry parameter, e.g. "pallas:interpret"
            if interpret not in ("interpret", "compiled"):
                raise ValueError(
                    f"pallas parameter must be 'interpret' or 'compiled', got {interpret!r}"
                )
            interpret = interpret == "interpret"
        if interpret is None:
            interpret = jax.devices()[0].platform != "tpu"
        self.interpret = bool(interpret)

    def conv(self, x, w):
        import jax.numpy as jnp

        from repro.kernels.conv2d import conv2d_pallas

        return np.asarray(
            conv2d_pallas(jnp.asarray(x), jnp.asarray(w), interpret=self.interpret)
        )

    def conv_vjp(self, x, w, g):
        import jax.numpy as jnp

        from repro.kernels.conv2d import conv2d_dw_pallas, conv2d_dx_pallas

        kh, kw = w.shape[0], w.shape[1]
        dx = conv2d_dx_pallas(jnp.asarray(g), jnp.asarray(w), interpret=self.interpret)
        dw = conv2d_dw_pallas(
            jnp.asarray(x), jnp.asarray(g), kh, kw, interpret=self.interpret
        )
        return np.asarray(dx), np.asarray(dw)


# ---------------------------------------------------------------------------
# sim: a deterministic virtual device for protocol/scheduling studies.
# ---------------------------------------------------------------------------


@register_backend("sim")
class SimBackend(ConvBackend):
    """Sleeps exactly ``flops / flops_per_s`` and returns ZEROS of the
    right shape.  Wall-clock behaves like a device of known speed with
    none of the host's compute noise — for benchmarking the master/slave
    protocol schedule (bench_master_slave.py), NEVER for numerics."""

    name = "sim"

    def __init__(self, flops_per_s=1e9):
        # accepts the registry parameter string: "sim:5e9" = 5 GFLOP/s
        self.flops_per_s = float(flops_per_s)
        if self.flops_per_s <= 0:
            raise ValueError("sim flops_per_s must be positive")

    def _flops(self, x, w) -> float:
        b, h, wd, _ = x.shape
        kh, kw, cin, cout = w.shape
        return 2.0 * b * h * wd * kh * kw * cin * cout

    def conv(self, x, w):
        time.sleep(self._flops(x, w) / self.flops_per_s)
        return np.zeros(x.shape[:-1] + (w.shape[-1],), np.float32)

    def conv_vjp(self, x, w, g):
        # backward is ~2x the forward cost (dX + dW)
        time.sleep(2.0 * self._flops(x, w) / self.flops_per_s)
        return np.zeros(x.shape, np.float32), np.zeros(w.shape, np.float32)


# ---------------------------------------------------------------------------
# probing — §4.1.1, generalized so each device times its OWN backend.
# ---------------------------------------------------------------------------


def probe_conv_time(
    backend,
    *,
    image_size: int,
    in_channels: int,
    kernel_size: int,
    num_kernels: int,
    batch: int,
    repeats: int = 3,
    slowdown: float = 1.0,
    seed: int = 0,
) -> float:
    """The paper's probe: median wall-clock of the reference convolution
    on the given backend (name or instance), scaled by the emulated
    slowdown — in BOTH directions: ``slowdown < 1.0`` emulates a FASTER
    device and must scale too, or its Eq. 1 share would be computed from
    the unscaled host time.  (HeteroCluster rejects sub-1 slowdowns —
    its op-level emulation can only sleep — but standalone Eq. 1 inputs
    for genuinely faster remote devices need the scaling, as do
    parameterized sim backends.)  Probing the backend a device actually
    runs keeps the Eq. 1 ratios exact for mixed-backend clusters."""
    if slowdown <= 0:
        raise ValueError(f"slowdown must be positive, got {slowdown}")
    if isinstance(backend, str):
        backend = get_backend(backend)
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(batch, image_size, image_size, in_channels)).astype(np.float32)
    w = rng.normal(
        size=(kernel_size, kernel_size, in_channels, num_kernels)
    ).astype(np.float32)
    backend.conv(x, w)  # warm caches / jit
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        backend.conv(x, w)
        times.append(time.perf_counter() - t0)
    measured = float(np.median(times))
    return measured * slowdown


# ---------------------------------------------------------------------------
# jax-level conv_fn factory — threads a backend choice into models/cnn.py
# (single-process path; the cluster path lives in core/master_slave.py).
# ---------------------------------------------------------------------------


def make_conv_fn(name: str, *, interpret: Optional[bool] = None):
    """Return a ``conv_fn(params, x)`` for ``cnn_forward`` that computes
    the convolution with the named backend, differentiable end to end."""
    import jax

    if name == "xla":
        from repro.layers.conv import apply_conv

        return apply_conv

    if name == "pallas":
        from repro.kernels.conv2d import (
            conv2d_dw_pallas,
            conv2d_dx_pallas,
            conv2d_pallas,
        )

        interp = (
            jax.devices()[0].platform != "tpu" if interpret is None else bool(interpret)
        )

        @jax.custom_vjp
        def pconv(x, w):
            return conv2d_pallas(x, w, interpret=interp)

        def pconv_fwd(x, w):
            return pconv(x, w), (x, w)

        def pconv_bwd(res, g):
            x, w = res
            dx = conv2d_dx_pallas(g, w, interpret=interp)
            dw = conv2d_dw_pallas(x, g, w.shape[0], w.shape[1], interpret=interp)
            return dx, dw.astype(w.dtype)

        pconv.defvjp(pconv_fwd, pconv_bwd)

        def conv_fn(params, x, padding: str = "SAME"):
            y = pconv(x, params["kernel"].astype(x.dtype))
            return y + params["bias"].astype(y.dtype)[None, None, None, :]

        return conv_fn

    if name == "numpy":
        backend = get_backend("numpy")

        @jax.custom_vjp
        def nconv(x, w):
            return _np_callback_conv(x, w)

        def _np_callback_conv(x, w):
            out_shape = jax.ShapeDtypeStruct(x.shape[:-1] + (w.shape[-1],), x.dtype)
            return jax.pure_callback(
                lambda xx, ww: backend.conv(np.asarray(xx), np.asarray(ww)).astype(
                    xx.dtype
                ),
                out_shape, x, w,
            )

        def nconv_fwd(x, w):
            return _np_callback_conv(x, w), (x, w)

        def nconv_bwd(res, g):
            x, w = res
            out_shape = (
                jax.ShapeDtypeStruct(x.shape, x.dtype),
                jax.ShapeDtypeStruct(w.shape, w.dtype),
            )
            return jax.pure_callback(
                lambda xx, ww, gg: tuple(
                    np.asarray(o, xx.dtype)
                    for o in backend.conv_vjp(
                        np.asarray(xx), np.asarray(ww), np.asarray(gg)
                    )
                ),
                out_shape, x, w, g,
            )

        nconv.defvjp(nconv_fwd, nconv_bwd)

        def conv_fn(params, x, padding: str = "SAME"):
            y = nconv(x, params["kernel"].astype(x.dtype))
            return y + params["bias"].astype(y.dtype)[None, None, None, :]

        return conv_fn

    raise KeyError(f"no conv_fn for backend {name!r}; available: {available_backends()}")
