"""Algorithms 1 & 2 — the master/slave distributed convolution protocol.

Faithful in-process emulation of the paper's socket cluster: every slave
is a thread, every socket a pair of queues, every ``writeSocket`` /
``readSocket`` moves serialized numpy buffers and counts the bytes (so
Eq. 2 can be validated against the actual traffic, see
tests/test_costmodel.py).  Heterogeneity is emulated with per-slave
*slowdown factors*: after computing, a slave sleeps (slowdown-1) x the
measured compute time, appearing exactly like a proportionally slower
machine to both the probe and the training loop.

The protocol per convolutional layer (Algorithm 1 lines 6-23):
  * master broadcasts the SAME inputs to every slave,
  * master scatters a DIFFERENT kernel shard to each slave, sized by the
    Eq. 1 partitioner from probe times,
  * every node (master included) convolves its shard,
  * master gathers the output feature maps and concatenates them,
  * master computes every non-convolutional layer alone.

Beyond the seed implementation, two orthogonal upgrades:

**Per-device compute backends** (core/backends.py): each device — the
master and every slave — picks a conv backend by name (``numpy`` im2col,
``xla`` jitted lax conv, ``pallas`` MXU kernels), so a cluster can mix
numpy-CPU and pallas-TPU nodes, the paper's actual heterogeneous
scenario.  The probe times the backend a device really runs, keeping the
Eq. 1 shares exact.  NOTE: when the cluster is driven through
``make_distributed_conv`` (jax host callbacks), the *master's* backend
should stay ``numpy`` — re-entering jit dispatch on the runtime thread
can deadlock — and slaves should avoid ``pallas`` in INTERPRET mode
(interpret re-enters jax from the slave thread and can deadlock against
the blocked callback; compiled TPU pallas and ``xla`` slaves are fine,
as is any backend under direct ``conv_forward``/``conv_backward`` calls).

**Asynchronous, pipelined scatter/gather**: the per-op barrier (scatter
-> compute -> gather -> ack) is replaced by split ``scatter_*`` /
``gather_*`` halves with FIFO ordering per socket.  With
``pipeline=True`` the batch is cut into microbatches and double-buffered:
the master issues the next microbatch's scatter while the slaves' results
for the current one are still in flight, and ``conv_forward_chain`` keeps
slave queues non-empty across consecutive conv layers so the master's
non-conv work overlaps slave compute.  ``LayerTiming`` accounts the
overlap window.

Backward propagation is distributed the same way ("forward and backward
propagation included", §1): each slave computes the VJP of its own kernel
shard — dW for its shard and its partial dX — and the master sums the
partial dX contributions (the gather of the backward pass).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backends import get_backend, numpy_conv, numpy_conv_vjp, probe_conv_time
from repro.core.partitioner import allocate_kernels

_TRAIN_OVER = "trainOver"


class _Socket:
    """Queue pair standing in for the paper's TCP socket; counts traffic.

    With ``bandwidth_mbps`` set, each direction gets a delivery thread
    that sleeps ``bytes * 8 / bandwidth`` before handing a message over —
    a full-duplex link of finite speed (the paper's ~5 Mbps Wi-Fi).
    Writers return immediately (the NIC DMAs asynchronously), so comm
    can genuinely overlap compute when the protocol allows it; messages
    on one direction serialize, exactly like a real link."""

    def __init__(self, bandwidth_mbps: Optional[float] = None):
        self.to_slave: "queue.Queue" = queue.Queue()
        self.to_master: "queue.Queue" = queue.Queue()
        self.bytes_to_slave = 0
        self.bytes_to_master = 0
        self._lock = threading.Lock()
        self.bandwidth_mbps = bandwidth_mbps
        if bandwidth_mbps is not None:
            assert bandwidth_mbps > 0
            self._stage_to_slave: "queue.Queue" = queue.Queue()
            self._stage_to_master: "queue.Queue" = queue.Queue()
            for stage, dest in (
                (self._stage_to_slave, self.to_slave),
                (self._stage_to_master, self.to_master),
            ):
                threading.Thread(
                    target=self._deliver, args=(stage, dest), daemon=True
                ).start()

    _LINK_DOWN = object()  # sentinel: stops a delivery thread

    def _deliver(self, stage: "queue.Queue", dest: "queue.Queue"):
        while True:
            item = stage.get()
            if item is _Socket._LINK_DOWN:
                return
            obj, nbytes = item
            time.sleep(nbytes * 8.0 / (self.bandwidth_mbps * 1e6))
            dest.put(obj)

    def close(self):
        """Stop the delivery threads (queued messages drain first)."""
        if self.bandwidth_mbps is not None:
            self._stage_to_slave.put(_Socket._LINK_DOWN)
            self._stage_to_master.put(_Socket._LINK_DOWN)

    def _nbytes(self, obj) -> int:
        if isinstance(obj, np.ndarray):
            return obj.nbytes
        if isinstance(obj, (tuple, list)):
            return sum(self._nbytes(o) for o in obj)
        if isinstance(obj, dict):
            return sum(self._nbytes(v) for v in obj.values())
        return 8  # flags / scalars, one double in the paper's protocol

    def write_to_slave(self, obj):
        n = self._nbytes(obj)
        with self._lock:
            self.bytes_to_slave += n
        if self.bandwidth_mbps is not None:
            self._stage_to_slave.put((obj, n))
        else:
            self.to_slave.put(obj)

    def write_to_master(self, obj):
        n = self._nbytes(obj)
        with self._lock:
            self.bytes_to_master += n
        if self.bandwidth_mbps is not None:
            self._stage_to_master.put((obj, n))
        else:
            self.to_master.put(obj)

    def read_on_slave(self):
        return self.to_slave.get()

    def read_on_master(self):
        return self.to_master.get()

    @property
    def total_bytes(self) -> int:
        return self.bytes_to_slave + self.bytes_to_master


# Seed-compatible aliases: the numpy im2col conv now lives in
# core/backends.py as the `numpy` backend (callback- and thread-safe).
_conv = numpy_conv
_conv_vjp = numpy_conv_vjp


def _np_probe(*, slowdown: float = 1.0, **probe_kwargs) -> float:
    """The paper's §4.1.1 probe on the numpy backend (seed behaviour)."""
    return probe_conv_time("numpy", slowdown=slowdown, **probe_kwargs)


def _slave_loop(sock: _Socket, slowdown: float, backend_name: str):
    """Algorithm 2, asynchronous: drain ops in FIFO order — read
    inputs/kernels, convolve with this device's backend, write outputs.
    No per-op ack: the master may queue several ops ahead (the pipeline);
    results stream back in issue order."""
    backend = None
    cached_w = {}  # last kernel shard per op: pipelined microbatches after
    #                the first send w=None instead of retransmitting it
    while True:
        msg = sock.read_on_slave()
        if msg == _TRAIN_OVER:
            return
        op, payload = msg
        if backend is None:
            backend = get_backend(backend_name)
        if op == "probe":
            sock.write_to_master(probe_conv_time(backend, slowdown=slowdown, **payload))
            continue
        t0 = time.perf_counter()
        if op == "conv":
            x, w = payload
            w = cached_w[op] if w is None else w
            cached_w[op] = w
            out = backend.conv(x, w)
        elif op == "bwd":
            x, w, g = payload
            w = cached_w[op] if w is None else w
            cached_w[op] = w
            out = backend.conv_vjp(x, w, g)
        else:  # pragma: no cover
            raise ValueError(f"unknown op {op}")
        elapsed = time.perf_counter() - t0
        if slowdown > 1.0:
            time.sleep(elapsed * (slowdown - 1.0))
        sock.write_to_master(out)


@dataclasses.dataclass
class LayerTiming:
    comm_s: float = 0.0         # scatter writes (master -> slave sockets)
    conv_s: float = 0.0         # conv phase: master's shard + gather
    comp_s: float = 0.0         # non-conv layers (master only)
    gather_wait_s: float = 0.0  # time the master blocked on slave results
    overlap_s: float = 0.0      # scatter->gather window minus the blocked
    #                             wait: comm/compute genuinely overlapped


@dataclasses.dataclass
class _Pending:
    """An in-flight scatter: the master's own shard is deferred to the
    gather so issuing the NEXT scatter never waits on local compute."""

    op: str                       # "conv" | "bwd"
    seq: int                      # FIFO position; gathers must match
    x: np.ndarray
    my_w: np.ndarray              # master's kernel shard
    my_g: Optional[np.ndarray]    # bwd only: master's grad slice
    t_issued: float


class HeteroCluster:
    """The master node (Algorithm 1) plus ``n_slaves`` slave threads.

    Device 0 is the master itself (it convolves its own shard while the
    slaves work).  ``slowdowns[i]`` emulates device i's relative speed
    (1.0 = this host's full speed); slowdowns[0] applies to the master.

    ``backends[i]`` names device i's conv backend (core/backends.py);
    defaults to ``numpy`` everywhere, the seed behaviour.

    ``pipeline=True`` enables the double-buffered microbatch protocol:
    ``conv_forward``/``conv_backward`` split the batch into up to
    ``microbatches`` slices and keep one scatter in flight ahead of every
    gather.  With ``pipeline=False`` (default) every call is a single
    scatter -> compute -> gather barrier, the paper's Algorithm 1.

    ``bandwidth_mbps`` emulates finite master<->slave links (the paper's
    ~5 Mbps Wi-Fi): message delivery is delayed by bytes/bandwidth on an
    async delivery thread, so the pipelined protocol can hide transfer
    time behind compute while the barrier protocol pays it serially.
    Default ``None`` = infinitely fast links (the seed behaviour).
    """

    def __init__(
        self,
        slowdowns: Sequence[float],
        backends: Optional[Sequence[str]] = None,
        *,
        pipeline: bool = False,
        microbatches: int = 4,
        bandwidth_mbps: Optional[float] = None,
    ):
        assert len(slowdowns) >= 1
        self.slowdowns = list(slowdowns)
        self.n_slaves = len(slowdowns) - 1
        if backends is None:
            backends = ["numpy"] * len(self.slowdowns)
        assert len(backends) == len(self.slowdowns), "one backend per device"
        self.backends = list(backends)
        # resolve every name NOW: an unknown backend must raise here, not
        # kill a slave thread later and leave the master blocked forever
        for name in self.backends:
            get_backend(name)
        self._master_backend = get_backend(self.backends[0])
        self.pipeline = bool(pipeline)
        self.microbatches = int(microbatches)
        self.sockets = [_Socket(bandwidth_mbps) for _ in range(self.n_slaves)]
        self.threads = [
            threading.Thread(
                target=_slave_loop, args=(s, sd, bk), daemon=True
            )
            for s, sd, bk in zip(self.sockets, self.slowdowns[1:], self.backends[1:])
        ]
        for t in self.threads:
            t.start()
        self.probe_times: Optional[List[float]] = None
        self.timing = LayerTiming()
        self._seq_issued = 0
        self._seq_gathered = 0

    # -- §4.1.1 pre-processing -------------------------------------------
    def probe(self, **probe_kwargs) -> List[float]:
        """Every device runs the timed reference convolution on its OWN
        backend — sequential so the 1-core host's timings do not
        interfere."""
        master_t = probe_conv_time(
            self._master_backend, slowdown=self.slowdowns[0], **probe_kwargs
        )
        slave_ts = []
        for s in self.sockets:
            s.write_to_slave(("probe", probe_kwargs))
            slave_ts.append(s.read_on_master())
        self.probe_times = [master_t] + slave_ts
        return self.probe_times

    def shares_for(self, num_kernels: int) -> np.ndarray:
        assert self.probe_times is not None, "run probe() first"
        return allocate_kernels(num_kernels, self.probe_times)

    # -- async scatter/gather halves -------------------------------------
    def _split(self, w: np.ndarray, counts: np.ndarray) -> List[np.ndarray]:
        edges = np.cumsum(counts)[:-1]
        return np.split(w, edges, axis=-1)

    def scatter_conv(self, x: np.ndarray, w: np.ndarray) -> _Pending:
        """Broadcast x + scatter kernel shards to the slaves; returns a
        handle.  The master's own shard runs at gather time."""
        shards = self._split(w, self.shares_for(w.shape[-1]))
        return self._scatter_conv_shards(x, shards, send_weights=True)

    def _scatter_conv_shards(
        self, x: np.ndarray, shards: List[np.ndarray], send_weights: bool
    ) -> _Pending:
        """send_weights=False sends w=None: the slave reuses its cached
        shard, so pipelined microbatches pay the weight traffic once."""
        t0 = time.perf_counter()
        for sock, shard in zip(self.sockets, shards[1:]):
            sock.write_to_slave(("conv", (x, shard if send_weights else None)))
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        return _Pending("conv", self._seq_issued, x, shards[0], None, now)

    def gather_conv(self, p: _Pending) -> np.ndarray:
        """Compute the master's shard, collect the slaves' feature maps
        (FIFO: gathers must be issued in scatter order), concatenate."""
        self._check_order(p, "conv")
        t0 = time.perf_counter()
        my_out = self._master_compute(lambda: self._master_backend.conv(p.x, p.my_w))
        outs = [my_out]
        t_wait = time.perf_counter()
        for sock in self.sockets:
            outs.append(sock.read_on_master())
        t1 = time.perf_counter()
        self._account_gather(p, t0, t_wait, t1)
        return np.concatenate(outs, axis=-1)

    def scatter_bwd(self, x: np.ndarray, w: np.ndarray, g: np.ndarray) -> _Pending:
        counts = self.shares_for(w.shape[-1])
        return self._scatter_bwd_shards(
            x, self._split(w, counts), g, counts, send_weights=True
        )

    def _scatter_bwd_shards(
        self,
        x: np.ndarray,
        w_shards: List[np.ndarray],
        g: np.ndarray,
        counts: np.ndarray,
        send_weights: bool,
    ) -> _Pending:
        g_shards = self._split(g, counts)
        t0 = time.perf_counter()
        for sock, ws, gs in zip(self.sockets, w_shards[1:], g_shards[1:]):
            sock.write_to_slave(("bwd", (x, ws if send_weights else None, gs)))
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        return _Pending("bwd", self._seq_issued, x, w_shards[0], g_shards[0], now)

    def gather_bwd(self, p: _Pending) -> Tuple[np.ndarray, np.ndarray]:
        """Master's shard VJP + gather: sum partial dX, concat dW shards."""
        self._check_order(p, "bwd")
        t0 = time.perf_counter()
        dx, dw0 = self._master_compute(
            lambda: self._master_backend.conv_vjp(p.x, p.my_w, p.my_g)
        )
        dws = [dw0]
        t_wait = time.perf_counter()
        for sock in self.sockets:
            dxi, dwi = sock.read_on_master()
            dx = dx + dxi
            dws.append(dwi)
        t1 = time.perf_counter()
        self._account_gather(p, t0, t_wait, t1)
        return dx, np.concatenate(dws, axis=-1)

    def _check_order(self, p: _Pending, op: str):
        # real exceptions, not asserts: an out-of-order gather would pair
        # one scatter's master shard with another's slave outputs and
        # return silently corrupted feature maps (and -O strips asserts)
        if p.op != op:
            raise RuntimeError(f"pending is a {p.op!r} op, gathered as {op!r}")
        if p.seq != self._seq_gathered + 1:
            raise RuntimeError(
                "gathers must follow scatter order (FIFO sockets): "
                f"expected seq {self._seq_gathered + 1}, got {p.seq}"
            )
        self._seq_gathered = p.seq

    def _master_compute(self, fn: Callable):
        t0 = time.perf_counter()
        out = fn()
        el = time.perf_counter() - t0
        if self.slowdowns[0] > 1.0:
            time.sleep(el * (self.slowdowns[0] - 1.0))
        return out

    def _account_gather(self, p: _Pending, t0: float, t_wait: float, t1: float):
        self.timing.conv_s += t1 - t0
        self.timing.gather_wait_s += t1 - t_wait
        # in-flight window minus the time the master actually blocked:
        # the comm/compute overlap the pipeline buys
        self.timing.overlap_s += max(0.0, (t_wait - p.t_issued))

    # -- Algorithm 1, the conv layer loop --------------------------------
    def _n_micro(self, batch: int) -> int:
        if not self.pipeline:
            return 1
        return max(1, min(self.microbatches, batch))

    def conv_forward(self, x: np.ndarray, w: np.ndarray) -> np.ndarray:
        """Distributed convolution: broadcast x, scatter kernel shards,
        gather and concatenate feature maps.  Pipelined mode double-
        buffers microbatches along the batch axis."""
        n = self._n_micro(x.shape[0])
        if n == 1:
            return self.gather_conv(self.scatter_conv(x, w))
        parts = np.array_split(x, n, axis=0)
        shards = self._split(w, self.shares_for(w.shape[-1]))
        outs = []
        pending = self._scatter_conv_shards(parts[0], shards, True)
        for nxt in parts[1:]:
            # next scatter in flight; slaves reuse the cached shard
            nxt_pending = self._scatter_conv_shards(nxt, shards, False)
            outs.append(self.gather_conv(pending))
            pending = nxt_pending
        outs.append(self.gather_conv(pending))
        return np.concatenate(outs, axis=0)

    def conv_backward(
        self, x: np.ndarray, w: np.ndarray, g: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Distributed VJP: each node takes the output-gradient slice of
        its own kernels, returns (partial dX, its dW shard); the master
        sums dX and concatenates dW.  Pipelined mode double-buffers
        microbatches; per-microbatch dW shards are summed."""
        n = self._n_micro(x.shape[0])
        if n == 1:
            return self.gather_bwd(self.scatter_bwd(x, w, g))
        xs = np.array_split(x, n, axis=0)
        gs = np.array_split(g, n, axis=0)
        counts = self.shares_for(w.shape[-1])
        w_shards = self._split(w, counts)
        dxs: List[np.ndarray] = []
        dw_total: Optional[np.ndarray] = None
        pending = self._scatter_bwd_shards(xs[0], w_shards, gs[0], counts, True)
        for xi, gi in zip(xs[1:], gs[1:]):
            nxt_pending = self._scatter_bwd_shards(xi, w_shards, gi, counts, False)
            dx_i, dw_i = self.gather_bwd(pending)
            dxs.append(dx_i)
            dw_total = dw_i if dw_total is None else dw_total + dw_i
            pending = nxt_pending
        dx_i, dw_i = self.gather_bwd(pending)
        dxs.append(dx_i)
        dw_total = dw_i if dw_total is None else dw_total + dw_i
        return np.concatenate(dxs, axis=0), dw_total

    def conv_forward_chain(
        self,
        x: np.ndarray,
        layer_weights: Sequence[np.ndarray],
        between: Optional[Sequence[Optional[Callable[[np.ndarray], np.ndarray]]]] = None,
    ) -> np.ndarray:
        """Run consecutive conv layers over the cluster; ``between[k]``
        is the master-only non-conv stage after layer k (ReLU/LRN/pool).

        In pipelined mode the microbatches are double-buffered through
        each layer, so the master's between-layer work for microbatch i
        overlaps the slaves' convolutions for microbatch i+1 — the
        slave queues stay non-empty across the whole chain.  In barrier
        mode every layer is scatter -> compute -> gather -> between on
        the full batch, the paper's schedule."""
        if between is None:
            between = [None] * len(layer_weights)
        assert len(between) == len(layer_weights)
        n = self._n_micro(x.shape[0])
        parts: List[np.ndarray] = np.array_split(x, n, axis=0) if n > 1 else [x]
        for w, f in zip(layer_weights, between):
            if len(parts) == 1:
                y = self.gather_conv(self.scatter_conv(parts[0], w))
                parts = [self._master_comp(f, y) if f else y]
                continue
            shards = self._split(w, self.shares_for(w.shape[-1]))
            outs: List[np.ndarray] = []
            pending = self._scatter_conv_shards(parts[0], shards, True)
            for nxt in parts[1:]:
                nxt_pending = self._scatter_conv_shards(nxt, shards, False)
                y = self.gather_conv(pending)
                outs.append(self._master_comp(f, y) if f else y)
                pending = nxt_pending
            y = self.gather_conv(pending)
            outs.append(self._master_comp(f, y) if f else y)
            parts = outs
        return np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]

    def _master_comp(self, f: Callable, y: np.ndarray) -> np.ndarray:
        t0 = time.perf_counter()
        out = f(y)
        self.timing.comp_s += time.perf_counter() - t0
        return out

    # ---------------------------------------------------------------------
    @property
    def comm_bytes(self) -> int:
        return sum(s.total_bytes for s in self.sockets)

    def reset_stats(self):
        self.timing = LayerTiming()
        for s in self.sockets:
            s.bytes_to_slave = 0
            s.bytes_to_master = 0

    def shutdown(self):
        for s in self.sockets:
            s.write_to_slave(_TRAIN_OVER)
        for t in self.threads:
            t.join(timeout=10)
        for s in self.sockets:
            s.close()


def make_distributed_conv(cluster: HeteroCluster):
    """A drop-in ``conv_fn`` for models/cnn.py: jax custom-VJP convolution
    whose forward and backward run over the cluster via callbacks.  If the
    cluster is pipelined, every conv call is internally microbatched and
    double-buffered; keep the master's backend ``numpy`` here (see module
    docstring)."""

    @jax.custom_vjp
    def dconv(x, w, b):
        y = _call_fwd(x, w)
        return y + b[None, None, None, :]

    def fwd(x, w, b):
        y = _call_fwd(x, w)
        return y + b[None, None, None, :], (x, w)

    def bwd(res, g):
        x, w = res
        dx, dw = _call_bwd(x, w, g)
        db = jnp.sum(g, axis=(0, 1, 2))
        return dx, dw, db

    def _call_fwd(x, w):
        out_shape = jax.ShapeDtypeStruct(x.shape[:-1] + (w.shape[-1],), x.dtype)
        return jax.pure_callback(
            lambda xx, ww: cluster.conv_forward(np.asarray(xx), np.asarray(ww)),
            out_shape, x, w,
        )

    def _call_bwd(x, w, g):
        out_shape = (
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
        )
        return jax.pure_callback(
            lambda xx, ww, gg: cluster.conv_backward(
                np.asarray(xx), np.asarray(ww), np.asarray(gg)
            ),
            out_shape, x, w, g,
        )

    dconv.defvjp(fwd, bwd)

    def conv_fn(params, x, padding: str = "SAME"):
        return dconv(x, params["kernel"], params["bias"])

    return conv_fn
