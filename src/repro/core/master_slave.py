"""Algorithms 1 & 2 — the master/slave distributed convolution protocol.

Faithful in-process emulation of the paper's socket cluster: every slave
is a thread, every socket a pair of queues, every ``writeSocket`` /
``readSocket`` moves serialized numpy buffers and counts the bytes (so
Eq. 2 can be validated against the actual traffic, see
tests/test_costmodel.py).  Heterogeneity is emulated with per-slave
*slowdown factors*: after computing, a slave sleeps (slowdown-1) x the
measured compute time, appearing exactly like a proportionally slower
machine to both the probe and the training loop.

The protocol per convolutional layer (Algorithm 1 lines 6-23):
  * master broadcasts the SAME inputs to every slave,
  * master scatters a DIFFERENT kernel shard to each slave, sized by the
    Eq. 1 partitioner from probe times,
  * every node (master included) convolves its shard,
  * master gathers the output feature maps and concatenates them,
  * master computes every non-convolutional layer alone.

Beyond the seed implementation, two orthogonal upgrades:

**Per-device compute backends** (core/backends.py): each device — the
master and every slave — picks a conv backend by name (``numpy`` im2col,
``xla`` jitted lax conv, ``pallas`` MXU kernels), so a cluster can mix
numpy-CPU and pallas-TPU nodes, the paper's actual heterogeneous
scenario.  The probe times the backend a device really runs, keeping the
Eq. 1 shares exact.  NOTE: when the cluster is driven through
``make_distributed_conv`` (jax host callbacks), the *master's* backend
should stay ``numpy`` — re-entering jit dispatch on the runtime thread
can deadlock — and slaves should avoid ``pallas`` in INTERPRET mode
(interpret re-enters jax from the slave thread and can deadlock against
the blocked callback; compiled TPU pallas and ``xla`` slaves are fine,
as is any backend under direct ``conv_forward``/``conv_backward`` calls).

**Asynchronous, pipelined scatter/gather**: the per-op barrier (scatter
-> compute -> gather -> ack) is replaced by split ``scatter_*`` /
``gather_*`` halves with FIFO ordering per socket.  With
``pipeline=True`` the batch is cut into microbatches and double-buffered:
the master issues the next microbatch's scatter while the slaves' results
for the current one are still in flight, and ``conv_forward_chain`` keeps
slave queues non-empty across consecutive conv layers so the master's
non-conv work overlaps slave compute.  ``LayerTiming`` accounts the
overlap window.

Backward propagation is distributed the same way ("forward and backward
propagation included", §1): each slave computes the VJP of its own kernel
shard — dW for its shard and its partial dX — and the master sums the
partial dX contributions (the gather of the backward pass).

``conv_train_chain`` / ``conv_train_step`` extend the pipeline to the
WHOLE training step: the forward chain stashes each conv layer's input
and the VJP of every master-only between stage, the master computes the
loss head, and the backward chain reuses the same ``_Pending`` FIFO and
microbatch machinery for the ``bwd`` op — the backward scatter of layer
k is issued while layer k+1's backward gathers (and the master's
between-VJP / head gradients) are still in flight, so a real training
step hides the per-layer barrier cost, not just the forward.  Unlike
the depth-2 ``conv_forward_chain``, the train chain keeps up to
``microbatches`` ops in flight per phase boundary (the total queued
bytes still equal ONE barrier-mode scatter of the full batch); a real
flow-controlled transport behind ``_Socket`` would need a window of
that many messages.

The cluster is also *comp-aware* (``comp_aware=True``): the master's
measured non-conv duty (``LayerTiming.comp_s`` vs its own conv time)
automatically discounts its Eq. 1 share, since a master busy with
ReLU/LRN/pool/fc work has proportionally less throughput left for its
conv shard.

**Hybrid spatial x kernel partitioning** (``partition=``): the paper
splits only the output-channel ("kernel") axis, which forces the master
to broadcast the FULL input activation to every slave — scatter bytes
grow with ``n_slaves x activation_bytes`` and throttle speedup on slow
links.  ``partition="spatial"`` splits the HEIGHT axis instead: each
device receives only its Eq. 1 share of input rows plus a ``kh//2``
halo (and the full kernel, once per layer), convolves its strip
(backends.strip_conv), and returns its output rows; the backward
overlap-ADDS the dX halo seams on the master (backends.strip_conv_vjp).
``partition="auto"`` picks the cheaper axis PER LAYER from the
predicted wall-clock — the comm-extended Eq. 1
(partitioner.link_aware_times): compute share + wire bytes over each
device's measured link.  Shares themselves are comm-aware too once a
real ``probe()`` has run (probe_flops known) and links are finite.

**Compact wire codec** (``wire_dtype="fp16"|"bf16"``): float arrays are
encoded to the 2-byte dtype at the ``_Socket`` boundary and decoded back
to float32 on read, halving wire bytes in either partition mode;
``_nbytes``/``LayerTiming``/``comm_bytes`` account the ENCODED size.
Master-side arithmetic (shard compute, dX seam sums, dW sums) stays in
float32 — only the wire narrows.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import traceback
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backends import (
    get_backend,
    numpy_conv,
    numpy_conv_vjp,
    probe_conv_time,
    strip_conv,
    strip_conv_vjp,
)
from repro.core.partitioner import (
    allocate_kernels,
    comp_aware_times,
    link_aware_times,
)

_TRAIN_OVER = "trainOver"

PARTITION_MODES = ("kernel", "spatial", "auto")


def resolve_wire_dtype(name: Optional[str]) -> Optional[np.dtype]:
    """Map a wire-dtype name to the numpy dtype arrays are encoded to on
    the sockets; ``None``/``"fp32"`` means no codec (the seed wire)."""
    if name is None or name in ("fp32", "float32"):
        return None
    if name in ("fp16", "float16"):
        return np.dtype(np.float16)
    if name in ("bf16", "bfloat16"):
        try:
            import ml_dtypes
        except ImportError as e:  # pragma: no cover - ml_dtypes ships with jax
            raise ValueError(
                "wire_dtype='bf16' needs the ml_dtypes package"
            ) from e
        return np.dtype(ml_dtypes.bfloat16)
    raise ValueError(
        f"unknown wire_dtype {name!r}; use None/'fp32', 'fp16' or 'bf16'"
    )


class _Socket:
    """Queue pair standing in for the paper's TCP socket; counts traffic.

    With ``bandwidth_mbps`` set, each direction gets a delivery thread
    that sleeps ``bytes * 8 / bandwidth`` before handing a message over —
    a full-duplex link of finite speed (the paper's ~5 Mbps Wi-Fi).
    Writers return immediately (the NIC DMAs asynchronously), so comm
    can genuinely overlap compute when the protocol allows it; messages
    on one direction serialize, exactly like a real link.

    With ``wire_dtype`` set (a 2-byte float numpy dtype), float32/64
    arrays are ENCODED to it on write and decoded back to float32 on
    read — the compact wire codec.  Byte counters and the bandwidth
    emulation see the encoded size, exactly like a real narrow wire."""

    def __init__(
        self,
        bandwidth_mbps: Optional[float] = None,
        wire_dtype: Optional[np.dtype] = None,
    ):
        self.to_slave: "queue.Queue" = queue.Queue()
        self.to_master: "queue.Queue" = queue.Queue()
        self.bytes_to_slave = 0
        self.bytes_to_master = 0
        self._lock = threading.Lock()
        self.bandwidth_mbps = bandwidth_mbps
        self.wire_dtype = wire_dtype
        if bandwidth_mbps is not None:
            assert bandwidth_mbps > 0
            self._stage_to_slave: "queue.Queue" = queue.Queue()
            self._stage_to_master: "queue.Queue" = queue.Queue()
            for stage, dest in (
                (self._stage_to_slave, self.to_slave),
                (self._stage_to_master, self.to_master),
            ):
                threading.Thread(
                    target=self._deliver, args=(stage, dest), daemon=True
                ).start()

    _LINK_DOWN = object()  # sentinel: stops a delivery thread

    def _deliver(self, stage: "queue.Queue", dest: "queue.Queue"):
        while True:
            item = stage.get()
            if item is _Socket._LINK_DOWN:
                return
            obj, nbytes = item
            time.sleep(nbytes * 8.0 / (self.bandwidth_mbps * 1e6))
            dest.put(obj)

    def close(self):
        """Stop the delivery threads (queued messages drain first)."""
        if self.bandwidth_mbps is not None:
            self._stage_to_slave.put(_Socket._LINK_DOWN)
            self._stage_to_master.put(_Socket._LINK_DOWN)

    def _nbytes(self, obj) -> int:
        """Bytes on the wire — called AFTER encoding, so the counters and
        the bandwidth emulation see the codec's compacted size."""
        if isinstance(obj, np.ndarray):
            return obj.nbytes
        if isinstance(obj, (tuple, list)):
            return sum(self._nbytes(o) for o in obj)
        if isinstance(obj, dict):
            return sum(self._nbytes(v) for v in obj.values())
        return 8  # flags / scalars, one double in the paper's protocol

    def _encode(self, obj):
        """Compact float arrays to the wire dtype (recursive)."""
        if isinstance(obj, np.ndarray) and obj.dtype in (np.float32, np.float64):
            return obj.astype(self.wire_dtype)
        if isinstance(obj, tuple):
            return tuple(self._encode(o) for o in obj)
        if isinstance(obj, list):
            return [self._encode(o) for o in obj]
        if isinstance(obj, dict):
            return {k: self._encode(v) for k, v in obj.items()}
        return obj

    def _decode(self, obj):
        """Widen wire-dtype arrays back to float32 at the read side, so
        every device COMPUTES and ACCUMULATES in float32."""
        if isinstance(obj, np.ndarray) and obj.dtype == self.wire_dtype:
            return obj.astype(np.float32)
        if isinstance(obj, tuple):
            return tuple(self._decode(o) for o in obj)
        if isinstance(obj, list):
            return [self._decode(o) for o in obj]
        if isinstance(obj, dict):
            return {k: self._decode(v) for k, v in obj.items()}
        return obj

    def write_to_slave(self, obj):
        if self.wire_dtype is not None:
            obj = self._encode(obj)
        n = self._nbytes(obj)
        with self._lock:
            self.bytes_to_slave += n
        if self.bandwidth_mbps is not None:
            self._stage_to_slave.put((obj, n))
        else:
            self.to_slave.put(obj)

    def write_to_master(self, obj):
        if self.wire_dtype is not None:
            obj = self._encode(obj)
        n = self._nbytes(obj)
        with self._lock:
            self.bytes_to_master += n
        if self.bandwidth_mbps is not None:
            self._stage_to_master.put((obj, n))
        else:
            self.to_master.put(obj)

    def read_on_slave(self):
        obj = self.to_slave.get()
        return self._decode(obj) if self.wire_dtype is not None else obj

    def read_on_master(self):
        obj = self.to_master.get()
        return self._decode(obj) if self.wire_dtype is not None else obj

    @property
    def total_bytes(self) -> int:
        return self.bytes_to_slave + self.bytes_to_master


# Seed-compatible aliases: the numpy im2col conv now lives in
# core/backends.py as the `numpy` backend (callback- and thread-safe).
_conv = numpy_conv
_conv_vjp = numpy_conv_vjp


def _np_probe(*, slowdown: float = 1.0, **probe_kwargs) -> float:
    """The paper's §4.1.1 probe on the numpy backend (seed behaviour)."""
    return probe_conv_time("numpy", slowdown=slowdown, **probe_kwargs)


class _SlaveError:
    """A slave's exception, shipped to the master instead of silently
    killing the slave thread (which would hang the master's gather)."""

    def __init__(self, device: int, tb: str):
        self.device = device
        self.tb = tb


def _conv_shard(backend, x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Backend conv with the 0-kernel fast path: comp-aware shares (or a
    very slow device) may legally allocate 0 kernels, which not every
    backend kernel tolerates (pallas grid math divides by cout)."""
    if w.shape[-1] == 0:
        return np.zeros(x.shape[:-1] + (0,), np.float32)
    return backend.conv(x, w)


def _bwd_shard(backend, x, w, g) -> Tuple[np.ndarray, np.ndarray]:
    """Backend conv_vjp with the 0-kernel fast path (see _conv_shard)."""
    if w.shape[-1] == 0:
        return np.zeros(x.shape, np.float32), np.zeros(w.shape, np.float32)
    return backend.conv_vjp(x, w, g)


def _slave_loop(sock: _Socket, slowdown: float, backend_name: str, device: int):
    """Algorithm 2, asynchronous: drain ops in FIFO order — read
    inputs/kernels, convolve with this device's backend, write outputs.
    No per-op ack: the master may queue several ops ahead (the pipeline);
    results stream back in issue order.  A compute exception is shipped
    back as a _SlaveError (the master raises it at the matching gather)
    so a broken backend fails loudly instead of hanging the protocol."""
    backend = None
    cached_w = {}  # last kernel shard per op: pipelined microbatches after
    #                the first send w=None instead of retransmitting it
    while True:
        msg = sock.read_on_slave()
        if msg == _TRAIN_OVER:
            return
        op, payload = msg
        try:
            if backend is None:
                backend = get_backend(backend_name)
            if op == "probe":
                sock.write_to_master(
                    probe_conv_time(backend, slowdown=slowdown, **payload)
                )
                continue
            t0 = time.perf_counter()
            if op == "conv":
                x, w = payload
                w = cached_w[op] if w is None else w
                cached_w[op] = w
                out = _conv_shard(backend, x, w)
            elif op == "bwd":
                x, w, g = payload
                w = cached_w[op] if w is None else w
                cached_w[op] = w
                out = _bwd_shard(backend, x, w, g)
            elif op == "sconv":  # spatial: a height strip + halo, full kernel
                xh, w, pt, pb = payload
                w = cached_w[op] if w is None else w
                cached_w[op] = w
                out = strip_conv(backend, xh, w, pt, pb)
            elif op == "sbwd":  # spatial backward: halo dX + full-kernel dW
                xh, w, g, pt, pb = payload
                w = cached_w[op] if w is None else w
                cached_w[op] = w
                out = strip_conv_vjp(backend, xh, w, g, pt, pb)
            else:  # pragma: no cover
                raise ValueError(f"unknown op {op}")
            elapsed = time.perf_counter() - t0
            if slowdown > 1.0:
                time.sleep(elapsed * (slowdown - 1.0))
        except Exception:
            sock.write_to_master(_SlaveError(device, traceback.format_exc()))
            continue
        sock.write_to_master(out)


@dataclasses.dataclass
class LayerTiming:
    comm_s: float = 0.0         # scatter writes (master -> slave sockets)
    conv_s: float = 0.0         # conv phase: master's shard + gather
    comp_s: float = 0.0         # non-conv layers (master only)
    gather_wait_s: float = 0.0  # time the master blocked on slave results
    overlap_s: float = 0.0      # scatter->gather window minus the blocked
    #                             wait: comm/compute genuinely overlapped
    master_conv_s: float = 0.0  # master's own conv/bwd shard compute — the
    #                             denominator of its non-conv duty


@dataclasses.dataclass
class TrainStepResult:
    """What one distributed training step hands back to the driver."""

    head_aux: list                 # per-microbatch head outputs (loss, ...)
    dw: List[np.ndarray]           # kernel gradient per conv layer
    dx: np.ndarray                 # gradient wrt the chain input


@dataclasses.dataclass
class _Pending:
    """An in-flight scatter: the master's own shard is deferred to the
    gather so issuing the NEXT scatter never waits on local compute."""

    op: str                       # "conv" | "bwd"
    seq: int                      # FIFO position; gathers must match
    x: np.ndarray                 # kernel mode: the broadcast input;
    #                               spatial mode: the FULL input (the
    #                               master slices its own strip at gather)
    my_w: np.ndarray              # master's kernel shard (spatial: full w)
    my_g: Optional[np.ndarray]    # bwd only: master's grad slice/strip
    t_issued: float
    mode: str = "kernel"          # partition axis this op was split on
    rows: Optional[List[Tuple[int, int]]] = None      # spatial: [r0, r1) per device
    halos: Optional[List[Tuple[int, int, int, int]]] = None
    #                               spatial: (lo, hi, pad_top, pad_bot) per device


def _strip_plan(
    h: int, kh: int, counts: Sequence[int]
) -> Tuple[List[Tuple[int, int]], List[Tuple[int, int, int, int]]]:
    """Cut H output rows into per-device strips sized by ``counts`` and
    derive each strip's halo'd input window: rows [lo, hi) of the input
    plus (pad_top, pad_bot) zero rows that restore the clipped SAME
    padding at the image border.  Empty strips get empty windows."""
    ph, pb = kh // 2, kh - 1 - (kh // 2)
    rows: List[Tuple[int, int]] = []
    halos: List[Tuple[int, int, int, int]] = []
    r0 = 0
    for c in counts:
        r1 = r0 + int(c)
        if r1 == r0:
            rows.append((r0, r0))
            halos.append((r0, r0, 0, 0))
            continue
        lo, hi = max(0, r0 - ph), min(h, r1 + pb)
        halos.append((lo, hi, ph - (r0 - lo), pb - (hi - r1)))
        rows.append((r0, r1))
        r0 = r1
    assert r0 == h, "strip counts must sum to H"
    return rows, halos


@dataclasses.dataclass
class _LayerPlan:
    """How ONE conv layer is split over the devices — fixed for every
    microbatch of the layer (the slave caches one kernel shard per op,
    so the split must not drift between microbatches)."""

    mode: str                     # "kernel" | "spatial" (auto is resolved)
    counts: np.ndarray            # kernels (kernel) or rows (spatial) per device
    shards: Optional[List[np.ndarray]] = None  # kernel mode: w split per device
    w: Optional[np.ndarray] = None             # spatial mode: the full kernel
    rows: Optional[List[Tuple[int, int]]] = None
    halos: Optional[List[Tuple[int, int, int, int]]] = None


class HeteroCluster:
    """The master node (Algorithm 1) plus ``n_slaves`` slave threads.

    Device 0 is the master itself (it convolves its own shard while the
    slaves work).  ``slowdowns[i]`` emulates device i's relative speed
    (1.0 = this host's full speed); slowdowns[0] applies to the master.

    ``backends[i]`` names device i's conv backend (core/backends.py);
    defaults to ``numpy`` everywhere, the seed behaviour.

    ``pipeline=True`` enables the double-buffered microbatch protocol:
    ``conv_forward``/``conv_backward`` split the batch into up to
    ``microbatches`` slices and keep one scatter in flight ahead of every
    gather.  With ``pipeline=False`` (default) every call is a single
    scatter -> compute -> gather barrier, the paper's Algorithm 1.

    ``bandwidth_mbps`` emulates finite master<->slave links (the paper's
    ~5 Mbps Wi-Fi): message delivery is delayed by bytes/bandwidth on an
    async delivery thread, so the pipelined protocol can hide transfer
    time behind compute while the barrier protocol pays it serially.
    Default ``None`` = infinitely fast links (the seed behaviour).

    ``comp_aware=True`` (default) makes the Eq. 1 shares discount the
    master's measured non-conv duty: once ``conv_forward_chain`` or
    ``conv_train_chain`` has observed master-only between/head work
    (``LayerTiming.comp_s`` vs ``master_conv_s``), ``shares_for`` inflates
    the master's probe time by ``1/(1-duty)`` automatically — the share
    bench_master_slave used to pin by hand.

    ``partition`` picks the conv split axis: ``"kernel"`` (the paper,
    default), ``"spatial"`` (height strips + halo exchange — each slave
    gets only its rows instead of the full activation), or ``"auto"``
    (per layer, the axis with the smaller predicted wall-clock over the
    measured links).  ``bandwidth_mbps`` may be a single float or one
    value PER SLAVE (heterogeneous links); with a real ``probe()`` the
    Eq. 1 shares then include each device's comm term.  ``wire_dtype``
    ("fp16"/"bf16") turns on the compact wire codec.
    """

    def __init__(
        self,
        slowdowns: Sequence[float],
        backends: Optional[Sequence[str]] = None,
        *,
        pipeline: bool = False,
        microbatches: int = 4,
        bandwidth_mbps: Union[None, float, Sequence[Optional[float]]] = None,
        comp_aware: bool = True,
        partition: str = "kernel",
        wire_dtype: Optional[str] = None,
    ):
        assert len(slowdowns) >= 1
        if any(sd < 1.0 for sd in slowdowns):
            # the op-level emulation can only SLEEP (slowdown-1)x the
            # measured compute — it cannot make the host faster — so a
            # sub-1 slowdown would probe fast (probe_conv_time scales
            # both directions) yet compute at 1.0x, and Eq. 1 would
            # overfeed the device.  Emulate faster devices with a
            # parameterized sim backend instead.
            raise ValueError(
                f"slowdowns must be >= 1.0 (got {list(slowdowns)}): the "
                f"cluster emulates slower devices by sleeping; for a "
                f"FASTER virtual device use a parameterized sim backend, "
                f"e.g. backends=['sim:5e9', ...]"
            )
        self.slowdowns = list(slowdowns)
        self.n_slaves = len(slowdowns) - 1
        if backends is None:
            backends = ["numpy"] * len(self.slowdowns)
        assert len(backends) == len(self.slowdowns), "one backend per device"
        self.backends = list(backends)
        # resolve every name NOW: an unknown backend must raise here, not
        # kill a slave thread later and leave the master blocked forever
        for name in self.backends:
            get_backend(name)
        self._master_backend = get_backend(self.backends[0])
        self.pipeline = bool(pipeline)
        self.microbatches = int(microbatches)
        if partition not in PARTITION_MODES:
            raise ValueError(
                f"partition must be one of {PARTITION_MODES}, got {partition!r}"
            )
        self.partition = partition
        self.partition_choices: Dict[tuple, str] = {}  # auto's per-layer picks
        self.wire_dtype = wire_dtype
        self._wire_np_dtype = resolve_wire_dtype(wire_dtype)
        self._wire_itemsize = (
            self._wire_np_dtype.itemsize if self._wire_np_dtype is not None else 4
        )
        if bandwidth_mbps is None or isinstance(bandwidth_mbps, (int, float)):
            self.bandwidths: List[Optional[float]] = (
                [bandwidth_mbps] * self.n_slaves
            )
        else:
            self.bandwidths = list(bandwidth_mbps)
            assert len(self.bandwidths) == self.n_slaves, "one bandwidth per slave"
        self.sockets = [
            _Socket(bw, self._wire_np_dtype) for bw in self.bandwidths
        ]
        self.threads = [
            threading.Thread(
                target=_slave_loop, args=(s, sd, bk, i), daemon=True
            )
            for i, (s, sd, bk) in enumerate(
                zip(self.sockets, self.slowdowns[1:], self.backends[1:]), start=1
            )
        ]
        for t in self.threads:
            t.start()
        self.probe_times: Optional[List[float]] = None
        self.probe_flops: Optional[float] = None  # flops of the probe workload
        self.timing = LayerTiming()
        self.comp_aware = bool(comp_aware)
        self.comp_duty = 0.0  # measured master non-conv duty (see shares_for)
        self._duty_mark = (0.0, 0.0)  # (comp_s, master_conv_s) at last update
        self._seq_issued = 0
        self._seq_gathered = 0

    # -- §4.1.1 pre-processing -------------------------------------------
    def probe(self, **probe_kwargs) -> List[float]:
        """Every device runs the timed reference convolution on its OWN
        backend — sequential so the 1-core host's timings do not
        interfere.  Also records the probe workload's FLOPs, the scale
        factor that lets the comm-aware partitioner and the auto axis
        chooser turn probe times into absolute per-layer predictions."""
        master_t = probe_conv_time(
            self._master_backend, slowdown=self.slowdowns[0], **probe_kwargs
        )
        slave_ts = []
        for s in self.sockets:
            s.write_to_slave(("probe", probe_kwargs))
            slave_ts.append(self._check_result(s.read_on_master()))
        self.probe_times = [master_t] + slave_ts
        self.probe_flops = (
            2.0
            * probe_kwargs["batch"]
            * probe_kwargs["image_size"] ** 2
            * probe_kwargs["kernel_size"] ** 2
            * probe_kwargs["in_channels"]
            * probe_kwargs["num_kernels"]
        )
        return self.probe_times

    def _effective_times(self) -> List[float]:
        """Probe times with the comp-aware master discount applied."""
        assert self.probe_times is not None, "run probe() first"
        times = self.probe_times
        if self.comp_aware and self.comp_duty > 0.0:
            times = comp_aware_times(times, self.comp_duty)
        return list(times)

    def shares_for(
        self,
        num_kernels: int,
        *,
        unit_bytes: float = 0.0,
        layer_flops: Optional[float] = None,
    ) -> np.ndarray:
        """Eq. 1 unit counts (kernels or rows) from the probe times; with
        ``comp_aware`` the master's measured non-conv duty discounts its
        share.  When the layer's wire cost is known (``unit_bytes`` per
        unit, ``layer_flops`` to scale probe times to this layer) and the
        links are finite, each slave's comm term joins its compute term —
        the comm-extended Eq. 1 (partitioner.link_aware_times)."""
        times = self._effective_times()
        if (
            unit_bytes > 0.0
            and layer_flops
            and self.probe_flops
            and any(bw is not None for bw in self.bandwidths)
        ):
            scale = layer_flops / self.probe_flops
            wire = [0.0] + [
                float(num_kernels) * unit_bytes if bw is not None else 0.0
                for bw in self.bandwidths
            ]
            times = link_aware_times(
                [t * scale for t in times], wire, [None] + list(self.bandwidths)
            )
        return allocate_kernels(num_kernels, times)

    def _update_comp_duty(self):
        """Refresh the measured non-conv duty — the fraction of the
        master's busy time spent OUTSIDE its conv shard — from the window
        since the LAST update (deltas, not cumulative): a one-off cost in
        an early step (jit compilation of the master-only stages, cold
        caches) then mis-shapes at most the next step's shares before the
        first clean window corrects it."""
        t = self.timing
        dc = t.comp_s - self._duty_mark[0]
        dm = t.master_conv_s - self._duty_mark[1]
        self._duty_mark = (t.comp_s, t.master_conv_s)
        if dc + dm > 0.0:
            self.comp_duty = dc / (dc + dm)

    # -- hybrid spatial x kernel partitioning: per-layer plans ------------
    def _unit_bytes(self, x_shape, w_shape, mode: str, op: str) -> float:
        """Share-proportional wire bytes per allocation unit — one KERNEL
        (w column out + feature-map column back, plus the gradient slice
        and dW column for bwd) or one ROW (x row out + y row back, plus
        the g row and dX row for bwd).  ``op="train"`` is one forward
        plus one backward, what a train-chain plan governs.  Fixed
        per-slave costs (the x broadcast, the halo, the full kernel, the
        kernel-mode backward's full-dX return) do not move the optimal
        split and are left to the mode predictor."""
        b, h, wd, cin = x_shape
        kh, kw, _, cout = w_shape
        item = self._wire_itemsize
        if mode == "kernel":
            w_col = kh * kw * cin * item
            y_col = b * h * wd * item
            conv = w_col + y_col       # w col out + y col back
            # bwd: w col + g col out, dW col back; the full-dX return is
            # a FIXED per-slave cost, excluded by this contract
            bwd = 2 * w_col + y_col
        else:
            x_row = b * wd * cin * item
            y_row = b * wd * cout * item
            conv = x_row + y_row       # x row out + y row back
            bwd = 2 * x_row + y_row    # x + g rows out, dX row back
        if op == "conv":
            return conv
        if op == "bwd":
            return bwd
        return conv + bwd              # "train"

    def predict_partition_seconds(
        self, x_shape, w_shape, op: str = "conv"
    ) -> Dict[str, float]:
        """Predicted per-layer wall-clock of each partition axis: every
        slave's wire bytes over its OWN link plus its balanced compute
        share (absolute once a real ``probe()`` has calibrated
        ``probe_flops``; otherwise the comm term alone decides — the
        compute splits near-identically on both axes).  ``op`` is what
        the plan will govern: ``"conv"`` (forward only), ``"bwd"``, or
        ``"train"`` (one forward + one backward) — the backward's wire
        differs by axis (kernel mode re-broadcasts x AND returns a
        full-size dX per slave; spatial ships strips both ways), so a
        train-step plan must weigh both directions."""
        b, h, wd, cin = x_shape
        kh, kw, _, cout = w_shape
        item = self._wire_itemsize
        x_b = float(b * h * wd * cin * item)
        y_b = float(b * h * wd * cout * item)
        w_b = float(kh * kw * cin * cout * item)
        times = self._effective_times()
        layer_flops = 2.0 * b * h * wd * kh * kw * cin * cout
        # the backward (dX + dW) costs ~2x the forward's flops
        flops_mult = {"conv": 1.0, "bwd": 2.0, "train": 3.0}[op]
        scale = (layer_flops / self.probe_flops) if self.probe_flops else None
        out: Dict[str, float] = {}
        for mode in ("kernel", "spatial"):
            n_units = cout if mode == "kernel" else h
            counts = self.shares_for(
                n_units,
                unit_bytes=self._unit_bytes(x_shape, w_shape, mode, op),
                layer_flops=flops_mult * layer_flops,
            )
            worst = 0.0
            for i, c in enumerate(counts):
                bw = None if i == 0 else self.bandwidths[i - 1]
                frac = float(c) / n_units if n_units else 0.0
                halo = min(kh - 1, h) if c > 0 else 0
                if mode == "kernel":
                    fwd_wire = x_b + frac * (w_b + y_b)
                    # x re-broadcast + g slice out; full dX + dW cols back
                    bwd_wire = 2.0 * x_b + frac * (w_b + y_b)
                    comp_frac = frac
                    active = i > 0
                else:
                    hfrac = (c + halo) / h
                    fwd_wire = hfrac * x_b + w_b + frac * y_b
                    # x strip + g strip out; dX halo strip + full dW back
                    bwd_wire = 2.0 * hfrac * x_b + 2.0 * w_b + frac * y_b
                    comp_frac = hfrac
                    active = i > 0 and c > 0
                wire = {
                    "conv": fwd_wire,
                    "bwd": bwd_wire,
                    "train": fwd_wire + bwd_wire,
                }[op] if active else 0.0
                t_comm = wire * 8.0 / (bw * 1e6) if bw is not None else 0.0
                t_comp = (
                    times[i] * scale * comp_frac * flops_mult if scale else 0.0
                )
                worst = max(worst, t_comm + t_comp)
            out[mode] = worst
        return out

    def _resolve_mode(
        self, x_shape, w_shape, override: Optional[str], op: str = "conv"
    ) -> str:
        """The partition axis for one layer; ``"auto"`` resolves against
        the predicted wall-clock of ``op`` and records its pick."""
        mode = override or self.partition
        if mode not in PARTITION_MODES:
            raise ValueError(
                f"partition must be one of {PARTITION_MODES}, got {mode!r}"
            )
        if mode != "auto":
            return mode
        if all(bw is None for bw in self.bandwidths):
            # free links: the paper's kernel axis, no halo overhead
            choice = "kernel"
        else:
            pred = self.predict_partition_seconds(x_shape, w_shape, op)
            choice = "spatial" if pred["spatial"] < pred["kernel"] else "kernel"
        self.partition_choices[(tuple(x_shape), tuple(w_shape))] = choice
        return choice

    def plan_conv(
        self, x_shape, w: np.ndarray, op: str = "conv",
        partition: Optional[str] = None,
    ) -> _LayerPlan:
        """Freeze how one conv layer splits over the devices: the axis
        (resolving ``"auto"`` against what the plan will govern — ``op``
        is ``"conv"``, ``"bwd"`` or ``"train"``), the Eq. 1(+comm) unit
        counts, and the per-device kernel shards or row strips.  One
        plan serves every microbatch of the layer — the slave caches ONE
        kernel shard per op, so the split must not drift within a
        layer."""
        mode = self._resolve_mode(tuple(x_shape), tuple(w.shape), partition, op)
        b, h, wd, cin = x_shape
        kh, kw, _, cout = w.shape
        layer_flops = 2.0 * b * h * wd * kh * kw * cin * cout
        unit_bytes = self._unit_bytes(x_shape, w.shape, mode, op)
        if mode == "kernel":
            counts = self.shares_for(
                cout, unit_bytes=unit_bytes, layer_flops=layer_flops
            )
            return _LayerPlan("kernel", counts, shards=self._split(w, counts))
        counts = self.shares_for(h, unit_bytes=unit_bytes, layer_flops=layer_flops)
        rows, halos = _strip_plan(h, kh, counts)
        return _LayerPlan(
            "spatial", counts, w=np.asarray(w, np.float32), rows=rows, halos=halos
        )

    # -- async scatter/gather halves -------------------------------------
    def _split(self, w: np.ndarray, counts: np.ndarray) -> List[np.ndarray]:
        edges = np.cumsum(counts)[:-1]
        return np.split(w, edges, axis=-1)

    def scatter_conv(
        self, x: np.ndarray, w: np.ndarray, *, partition: Optional[str] = None
    ) -> _Pending:
        """Scatter one conv: broadcast x + kernel shards (kernel mode) or
        height strips + the full kernel (spatial mode); returns a handle.
        The master's own shard runs at gather time."""
        x = np.asarray(x, np.float32)
        plan = self.plan_conv(x.shape, w, "conv", partition)
        return self._scatter_conv_planned(x, plan, send_weights=True)

    def _scatter_conv_planned(
        self, x: np.ndarray, plan: _LayerPlan, send_weights: bool
    ) -> _Pending:
        if plan.mode == "kernel":
            return self._scatter_conv_shards(x, plan.shards, send_weights)
        t0 = time.perf_counter()
        for sock, (lo, hi, pt, pb) in zip(self.sockets, plan.halos[1:]):
            sock.write_to_slave(
                ("sconv", (x[:, lo:hi], plan.w if send_weights else None, pt, pb))
            )
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        return _Pending(
            "conv", self._seq_issued, x, plan.w, None, now,
            mode="spatial", rows=plan.rows, halos=plan.halos,
        )

    def _scatter_conv_shards(
        self, x: np.ndarray, shards: List[np.ndarray], send_weights: bool
    ) -> _Pending:
        """send_weights=False sends w=None: the slave reuses its cached
        shard, so pipelined microbatches pay the weight traffic once."""
        t0 = time.perf_counter()
        for sock, shard in zip(self.sockets, shards[1:]):
            sock.write_to_slave(("conv", (x, shard if send_weights else None)))
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        return _Pending("conv", self._seq_issued, x, shards[0], None, now)

    def gather_conv(self, p: _Pending) -> np.ndarray:
        """Compute the master's shard, collect the slaves' feature maps
        (FIFO: gathers must be issued in scatter order), concatenate —
        along channels (kernel mode) or height (spatial strips)."""
        self._check_order(p, "conv")
        t0 = time.perf_counter()
        if p.mode == "spatial":
            lo, hi, pt, pb = p.halos[0]
            my_out = self._master_compute(
                lambda: strip_conv(self._master_backend, p.x[:, lo:hi], p.my_w, pt, pb)
            )
            axis = 1
        else:
            my_out = self._master_compute(
                lambda: _conv_shard(self._master_backend, p.x, p.my_w)
            )
            axis = -1
        outs = [my_out]
        t_wait = time.perf_counter()
        for sock in self.sockets:
            outs.append(self._check_result(sock.read_on_master()))
        t1 = time.perf_counter()
        self._account_gather(p, t0, t_wait, t1)
        return np.concatenate(outs, axis=axis)

    def scatter_bwd(
        self, x: np.ndarray, w: np.ndarray, g: np.ndarray,
        *, partition: Optional[str] = None,
    ) -> _Pending:
        x = np.asarray(x, np.float32)
        g = np.asarray(g, np.float32)
        plan = self.plan_conv(x.shape, w, "bwd", partition)
        return self._scatter_bwd_planned(x, plan, g, send_weights=True)

    def _scatter_bwd_planned(
        self, x: np.ndarray, plan: _LayerPlan, g: np.ndarray, send_weights: bool
    ) -> _Pending:
        if plan.mode == "kernel":
            return self._scatter_bwd_shards(
                x, plan.shards, g, plan.counts, send_weights
            )
        t0 = time.perf_counter()
        for sock, (r0, r1), (lo, hi, pt, pb) in zip(
            self.sockets, plan.rows[1:], plan.halos[1:]
        ):
            sock.write_to_slave(
                ("sbwd", (
                    x[:, lo:hi], plan.w if send_weights else None,
                    g[:, r0:r1], pt, pb,
                ))
            )
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        r0, r1 = plan.rows[0]
        return _Pending(
            "bwd", self._seq_issued, x, plan.w, g[:, r0:r1], now,
            mode="spatial", rows=plan.rows, halos=plan.halos,
        )

    def _scatter_bwd_shards(
        self,
        x: np.ndarray,
        w_shards: List[np.ndarray],
        g: np.ndarray,
        counts: np.ndarray,
        send_weights: bool,
    ) -> _Pending:
        g_shards = self._split(g, counts)
        t0 = time.perf_counter()
        for sock, ws, gs in zip(self.sockets, w_shards[1:], g_shards[1:]):
            sock.write_to_slave(("bwd", (x, ws if send_weights else None, gs)))
        now = time.perf_counter()
        self.timing.comm_s += now - t0
        self._seq_issued += 1
        return _Pending("bwd", self._seq_issued, x, w_shards[0], g_shards[0], now)

    def gather_bwd(self, p: _Pending) -> Tuple[np.ndarray, np.ndarray]:
        """Master's shard VJP + gather.  Kernel mode: sum partial dX,
        concat dW shards.  Spatial mode: overlap-ADD each device's halo'd
        dX rows into the full dX (the seam sums) and SUM the full-kernel
        dW contributions."""
        self._check_order(p, "bwd")
        t0 = time.perf_counter()
        if p.mode == "spatial":
            lo, hi, pt, pb = p.halos[0]
            dxh, dw = self._master_compute(
                lambda: strip_conv_vjp(
                    self._master_backend, p.x[:, lo:hi], p.my_w, p.my_g, pt, pb
                )
            )
            dx = np.zeros(p.x.shape, np.float32)
            dx[:, lo:hi] += dxh
            t_wait = time.perf_counter()
            for sock, (lo_i, hi_i, _pt, _pb) in zip(self.sockets, p.halos[1:]):
                dxh_i, dw_i = self._check_result(sock.read_on_master())
                dx[:, lo_i:hi_i] += dxh_i  # the halo seams overlap-sum here
                dw = dw + dw_i
            t1 = time.perf_counter()
            self._account_gather(p, t0, t_wait, t1)
            return dx, dw
        dx, dw0 = self._master_compute(
            lambda: _bwd_shard(self._master_backend, p.x, p.my_w, p.my_g)
        )
        dws = [dw0]
        t_wait = time.perf_counter()
        for sock in self.sockets:
            dxi, dwi = self._check_result(sock.read_on_master())
            dx = dx + dxi
            dws.append(dwi)
        t1 = time.perf_counter()
        self._account_gather(p, t0, t_wait, t1)
        return dx, np.concatenate(dws, axis=-1)

    def _check_result(self, out):
        """Re-raise a slave's shipped exception at the gather that would
        otherwise consume its (missing) result."""
        if isinstance(out, _SlaveError):
            raise RuntimeError(
                f"slave device {out.device} failed while computing its "
                f"shard:\n{out.tb}"
            )
        return out

    def _check_order(self, p: _Pending, op: str):
        # real exceptions, not asserts: an out-of-order gather would pair
        # one scatter's master shard with another's slave outputs and
        # return silently corrupted feature maps (and -O strips asserts)
        if p.op != op:
            raise RuntimeError(f"pending is a {p.op!r} op, gathered as {op!r}")
        if p.seq != self._seq_gathered + 1:
            raise RuntimeError(
                "gathers must follow scatter order (FIFO sockets): "
                f"expected seq {self._seq_gathered + 1}, got {p.seq}"
            )
        self._seq_gathered = p.seq

    def _master_compute(self, fn: Callable):
        t0 = time.perf_counter()
        out = fn()
        el = time.perf_counter() - t0
        if self.slowdowns[0] > 1.0:
            time.sleep(el * (self.slowdowns[0] - 1.0))
        self.timing.master_conv_s += time.perf_counter() - t0
        return out

    def _account_gather(self, p: _Pending, t0: float, t_wait: float, t1: float):
        self.timing.conv_s += t1 - t0
        self.timing.gather_wait_s += t1 - t_wait
        # in-flight window minus the time the master actually blocked:
        # the comm/compute overlap the pipeline buys
        self.timing.overlap_s += max(0.0, (t_wait - p.t_issued))

    # -- Algorithm 1, the conv layer loop --------------------------------
    def _n_micro(self, batch: int) -> int:
        if not self.pipeline:
            return 1
        return max(1, min(self.microbatches, batch))

    def conv_forward(
        self, x: np.ndarray, w: np.ndarray, *, partition: Optional[str] = None
    ) -> np.ndarray:
        """Distributed convolution over the planned partition axis.
        Pipelined mode double-buffers microbatches along the batch axis
        (orthogonal to either split axis); the plan — and so the kernel
        shard each slave caches — is fixed across the microbatches."""
        x = np.asarray(x, np.float32)
        plan = self.plan_conv(x.shape, w, "conv", partition)
        n = self._n_micro(x.shape[0])
        if n == 1:
            return self.gather_conv(self._scatter_conv_planned(x, plan, True))
        parts = np.array_split(x, n, axis=0)
        outs = []
        pending = self._scatter_conv_planned(parts[0], plan, True)
        for nxt in parts[1:]:
            # next scatter in flight; slaves reuse the cached kernel
            nxt_pending = self._scatter_conv_planned(nxt, plan, False)
            outs.append(self.gather_conv(pending))
            pending = nxt_pending
        outs.append(self.gather_conv(pending))
        return np.concatenate(outs, axis=0)

    def conv_backward(
        self, x: np.ndarray, w: np.ndarray, g: np.ndarray,
        *, partition: Optional[str] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Distributed VJP over the planned partition axis: kernel mode
        returns (partial-dX sums, concatenated dW shards); spatial mode
        seam-sums halo'd dX strips and sums full-kernel dW parts.
        Pipelined mode double-buffers microbatches; per-microbatch dW
        contributions are summed."""
        x = np.asarray(x, np.float32)
        g = np.asarray(g, np.float32)
        plan = self.plan_conv(x.shape, w, "bwd", partition)
        n = self._n_micro(x.shape[0])
        if n == 1:
            return self.gather_bwd(self._scatter_bwd_planned(x, plan, g, True))
        xs = np.array_split(x, n, axis=0)
        gs = np.array_split(g, n, axis=0)
        dxs: List[np.ndarray] = []
        dw_total: Optional[np.ndarray] = None
        pending = self._scatter_bwd_planned(xs[0], plan, gs[0], True)
        for xi, gi in zip(xs[1:], gs[1:]):
            nxt_pending = self._scatter_bwd_planned(xi, plan, gi, False)
            dx_i, dw_i = self.gather_bwd(pending)
            dxs.append(dx_i)
            dw_total = dw_i if dw_total is None else dw_total + dw_i
            pending = nxt_pending
        dx_i, dw_i = self.gather_bwd(pending)
        dxs.append(dx_i)
        dw_total = dw_i if dw_total is None else dw_total + dw_i
        return np.concatenate(dxs, axis=0), dw_total

    def conv_forward_chain(
        self,
        x: np.ndarray,
        layer_weights: Sequence[np.ndarray],
        between: Optional[Sequence[Optional[Callable[[np.ndarray], np.ndarray]]]] = None,
    ) -> np.ndarray:
        """Run consecutive conv layers over the cluster; ``between[k]``
        is the master-only non-conv stage after layer k (ReLU/LRN/pool).

        In pipelined mode the microbatches are double-buffered through
        each layer, so the master's between-layer work for microbatch i
        overlaps the slaves' convolutions for microbatch i+1 — the
        slave queues stay non-empty across the whole chain.  In barrier
        mode every layer is scatter -> compute -> gather -> between on
        the full batch, the paper's schedule."""
        if between is None:
            between = [None] * len(layer_weights)
        assert len(between) == len(layer_weights)
        x = np.asarray(x, np.float32)
        batch = x.shape[0]
        n = self._n_micro(batch)
        parts: List[np.ndarray] = np.array_split(x, n, axis=0) if n > 1 else [x]
        for w, f in zip(layer_weights, between):
            # plan from the FULL batch shape: one split per layer, every
            # microbatch rides it (and the slave's cached kernel)
            plan = self.plan_conv((batch,) + parts[0].shape[1:], w, "conv")
            if len(parts) == 1:
                y = self.gather_conv(self._scatter_conv_planned(parts[0], plan, True))
                parts = [self._master_comp(f, y) if f else y]
                continue
            outs: List[np.ndarray] = []
            pending = self._scatter_conv_planned(parts[0], plan, True)
            for nxt in parts[1:]:
                nxt_pending = self._scatter_conv_planned(nxt, plan, False)
                y = self.gather_conv(pending)
                outs.append(self._master_comp(f, y) if f else y)
                pending = nxt_pending
            y = self.gather_conv(pending)
            outs.append(self._master_comp(f, y) if f else y)
            parts = outs
        self._update_comp_duty()
        return np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]

    def _master_comp(self, f: Callable, y: np.ndarray) -> np.ndarray:
        t0 = time.perf_counter()
        out = f(y)
        self.timing.comp_s += time.perf_counter() - t0
        return out

    # -- the full training step, pipelined (fwd + bwd, Algorithm 1 whole) --
    def microbatch_slices(self, batch: int) -> List[slice]:
        """The batch-axis slices the pipelined schedules will use for a
        given batch size — drivers split labels/targets identically."""
        n = self._n_micro(batch)
        sizes = [a.size for a in np.array_split(np.arange(batch), n)]
        out, start = [], 0
        for s in sizes:
            out.append(slice(start, start + s))
            start += s
        return out

    def conv_train_chain(
        self,
        x: np.ndarray,
        layer_weights: Sequence[np.ndarray],
        between: Optional[Sequence[Optional[Callable]]] = None,
        head: Optional[Callable] = None,
    ) -> TrainStepResult:
        """One distributed training step over consecutive conv layers —
        forward AND backward pipelined across the cluster.

        ``between[k]`` is the master-only stage after conv layer k:
        ``f(y) -> (z, vjp)`` with ``vjp(gz) -> gy`` (None = identity).
        ``head(z, i) -> (aux, gz)`` is the master-only loss head on the
        final stage output of microbatch i (indices follow
        ``microbatch_slices``); its gradient seeds the backward chain.

        The schedule is ONE software pipeline over the phases
        ``[fwd L0 .. fwd Lk, bwd Lk .. bwd L0]``: each phase's scatters
        are issued as the previous phase's gathers complete, so the
        backward scatter of layer k goes out while layer k+1's backward
        gathers — and the master-only between-VJPs / head gradients — are
        still in flight, and the slave queues stay non-empty across the
        forward->backward turnaround.  Pipeline depth is the microbatch
        count (the first phase fills the pipe; total queued bytes match
        one barrier-mode full-batch scatter), deeper than the depth-2
        ``conv_forward_chain``.  The forward stashes each conv
        layer's input and each between stage's VJP; every phase re-sends
        its kernel shard once and microbatches after the first ride the
        slave's cached copy.  Gathers follow global scatter order, so the
        FIFO-socket contract holds even though ``conv`` and ``bwd`` ops
        interleave on the wire.
        """
        L = len(layer_weights)
        assert L >= 1 and head is not None, "need >= 1 conv layer and a head"
        if between is None:
            between = [None] * L
        assert len(between) == L
        # split along the SAME slices drivers use for labels/targets, by
        # construction (head(z, i) pairs activations with slice i)
        x = np.asarray(x, np.float32)
        slices = self.microbatch_slices(x.shape[0])
        parts: List[np.ndarray] = [x[sl] for sl in slices]
        n = len(parts)

        # plans fixed for the whole step: fwd and bwd must split every
        # layer identically (comp_duty updates only at the end).  Built
        # lazily at each layer's first microbatch — spatial/auto plans
        # need the layer's ACTUAL activation shape, unknown until the
        # between stages have run.
        plans: List[Optional[_LayerPlan]] = [None] * L

        def plan_for(k: int, xi: np.ndarray) -> _LayerPlan:
            if plans[k] is None:
                # op="train": the plan governs BOTH sweeps, so the auto
                # axis and the comm-aware counts weigh fwd + bwd wire
                plans[k] = self.plan_conv(
                    (x.shape[0],) + xi.shape[1:], layer_weights[k], "train"
                )
            return plans[k]

        stash_x: List[List[Optional[np.ndarray]]] = [[None] * n for _ in range(L)]
        stash_vjp: List[List[Optional[Callable]]] = [[None] * n for _ in range(L)]
        head_aux: list = [None] * n

        def fwd_finish(k: int, i: int, p: _Pending) -> np.ndarray:
            """Gather conv layer k / microbatch i and run the master-only
            between stage, stashing its VJP for the backward sweep."""
            y = self.gather_conv(p)
            f = between[k]
            if f is None:
                return y
            t0 = time.perf_counter()
            z, vjp = f(y)
            self.timing.comp_s += time.perf_counter() - t0
            stash_vjp[k][i] = vjp
            return z

        def bwd_through(k: int, i: int, g: np.ndarray) -> np.ndarray:
            """Pull g back through layer k's between stage (master-only)."""
            vjp = stash_vjp[k][i]
            if vjp is None:
                return g
            t0 = time.perf_counter()
            gy = vjp(g)
            self.timing.comp_s += time.perf_counter() - t0
            return gy

        # ---- forward phases: layer k's scatters interleave with k-1's
        # gathers (and the between stages between them)
        pend: List[_Pending] = []
        for k in range(L):
            cur: List[_Pending] = []
            for i in range(n):
                xi = parts[i] if k == 0 else fwd_finish(k - 1, i, pend[i])
                xi = np.asarray(xi, np.float32)
                stash_x[k][i] = xi
                cur.append(
                    self._scatter_conv_planned(
                        xi, plan_for(k, xi), send_weights=(i == 0)
                    )
                )
            pend = cur

        # ---- turnaround: finish the last fwd layer, compute the head
        # grads, and seed the backward — the bwd scatter of the last layer
        # goes out while its later fwd microbatches are still in flight
        cur = []
        for i in range(n):
            z = fwd_finish(L - 1, i, pend[i])
            t0 = time.perf_counter()
            head_aux[i], gz = head(z, i)
            self.timing.comp_s += time.perf_counter() - t0
            gy = bwd_through(L - 1, i, np.asarray(gz, np.float32))
            cur.append(
                self._scatter_bwd_planned(
                    stash_x[L - 1][i], plans[L - 1], gy, send_weights=(i == 0)
                )
            )
        pend = cur

        # ---- backward phases: layer k's scatters interleave with layer
        # k+1's gathers and the between-VJPs; dW shards sum per microbatch
        dw: List[Optional[np.ndarray]] = [None] * L

        def acc_dw(k: int, dwi: np.ndarray):
            dw[k] = dwi if dw[k] is None else dw[k] + dwi

        for k in range(L - 2, -1, -1):
            cur = []
            for i in range(n):
                dx_next, dw_next = self.gather_bwd(pend[i])
                acc_dw(k + 1, dw_next)
                gy = bwd_through(k, i, dx_next)
                cur.append(
                    self._scatter_bwd_planned(
                        stash_x[k][i], plans[k], gy, send_weights=(i == 0)
                    )
                )
            pend = cur

        # ---- drain the first layer's backward
        dxs: List[np.ndarray] = []
        for i in range(n):
            dx_i, dw_i = self.gather_bwd(pend[i])
            acc_dw(0, dw_i)
            dxs.append(dx_i)
        self._update_comp_duty()
        return TrainStepResult(
            head_aux=head_aux,
            dw=[d for d in dw],
            dx=np.concatenate(dxs, axis=0) if n > 1 else dxs[0],
        )

    def conv_train_step(
        self,
        x: np.ndarray,
        layer_weights: Sequence[np.ndarray],
        between: Optional[Sequence[Optional[Callable]]] = None,
        head: Optional[Callable] = None,
        *,
        update: Optional[Callable[[np.ndarray, np.ndarray], np.ndarray]] = None,
    ) -> Tuple[List[np.ndarray], TrainStepResult]:
        """One full forward+backward ``conv_train_chain`` plus the
        optimizer step on the conv kernels: ``update(w, dw) -> new_w``
        (None leaves the weights untouched and just returns the grads)."""
        res = self.conv_train_chain(x, layer_weights, between=between, head=head)
        if update is None:
            return list(layer_weights), res
        return [update(w, d) for w, d in zip(layer_weights, res.dw)], res

    # ---------------------------------------------------------------------
    @property
    def comm_bytes(self) -> int:
        return sum(s.total_bytes for s in self.sockets)

    def reset_stats(self):
        self.timing = LayerTiming()
        self._duty_mark = (0.0, 0.0)
        for s in self.sockets:
            s.bytes_to_slave = 0
            s.bytes_to_master = 0

    def shutdown(self):
        for s in self.sockets:
            s.write_to_slave(_TRAIN_OVER)
        for t in self.threads:
            t.join(timeout=10)
        for s in self.sockets:
            s.close()


def make_distributed_conv(cluster: HeteroCluster):
    """A drop-in ``conv_fn`` for models/cnn.py: jax custom-VJP convolution
    whose forward and backward run over the cluster via callbacks.  If the
    cluster is pipelined, every conv call is internally microbatched and
    double-buffered; keep the master's backend ``numpy`` here (see module
    docstring)."""
    # Fail fast on the documented deadlock instead of hanging at 0% CPU:
    # the callbacks below block the jax runtime thread while the master
    # computes its shard, so any master backend that re-enters jit
    # dispatch — everything but numpy — deadlocks, as does a pallas slave
    # in interpret mode (interpret re-enters jax from the slave thread
    # against the blocked callback).
    if cluster.backends[0] != "numpy":
        raise RuntimeError(
            f"make_distributed_conv drives the cluster through jax host "
            f"callbacks; the master (device 0) backend must be 'numpy', got "
            f"{cluster.backends[0]!r}: re-entering jax from inside "
            f"pure_callback deadlocks the runtime thread.  Use the direct "
            f"conv_train_step / conv_forward drivers (no callbacks) for a "
            f"non-numpy master."
        )
    interp_pallas = [
        i for i, b in enumerate(cluster.backends)
        if i > 0 and b.partition(":")[0] == "pallas"
        and getattr(get_backend(b), "interpret", False)
    ]
    if interp_pallas:
        raise RuntimeError(
            f"slave device(s) {interp_pallas} run the 'pallas' backend in "
            f"interpret mode, which re-enters jax from the slave thread and "
            f"can deadlock against a blocked make_distributed_conv callback. "
            f"Use compiled TPU pallas, 'xla', or 'numpy' slaves here, or "
            f"drive the cluster directly via conv_train_step."
        )

    @jax.custom_vjp
    def dconv(x, w, b):
        y = _call_fwd(x, w)
        return y + b[None, None, None, :]

    def fwd(x, w, b):
        y = _call_fwd(x, w)
        return y + b[None, None, None, :], (x, w)

    def bwd(res, g):
        x, w = res
        dx, dw = _call_bwd(x, w, g)
        db = jnp.sum(g, axis=(0, 1, 2))
        return dx, dw, db

    def _call_fwd(x, w):
        out_shape = jax.ShapeDtypeStruct(x.shape[:-1] + (w.shape[-1],), x.dtype)
        return jax.pure_callback(
            lambda xx, ww: cluster.conv_forward(np.asarray(xx), np.asarray(ww)),
            out_shape, x, w,
        )

    def _call_bwd(x, w, g):
        out_shape = (
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
        )
        return jax.pure_callback(
            lambda xx, ww, gg: cluster.conv_backward(
                np.asarray(xx), np.asarray(ww), np.asarray(gg)
            ),
            out_shape, x, w, g,
        )

    dconv.defvjp(fwd, bwd)

    def conv_fn(params, x, padding: str = "SAME"):
        return dconv(x, params["kernel"], params["bias"])

    return conv_fn
