"""Compat shim — the master/slave runtime now lives in ``core/cluster/``.

PR 4 decomposed the former 1363-line monolith into a layered package
(transport -> codec -> protocol -> plans -> scheduler -> cluster); see
``repro/core/cluster/__init__.py`` for the map.  Everything the repo —
tests, benches, examples, the ``launch/hetero.py`` CLI — ever imported
from this module keeps working through the re-exports below, including
the seed-era private names.  New code should import from
``repro.core.cluster`` directly.

Two transports ride behind the same ``HeteroCluster`` API:
``transport="inproc"`` (the seed behaviour: slave threads, queue pairs,
emulated bandwidth) and ``transport="tcp"`` (real OS subprocess slaves
over framed localhost sockets with measured link bandwidth).
"""
from __future__ import annotations

from repro.core.backends import (  # noqa: F401  (seed-compatible aliases)
    numpy_conv as _conv,
    numpy_conv_vjp as _conv_vjp,
)
from repro.core.cluster.cluster import (  # noqa: F401
    HeteroCluster,
    _np_probe,
    make_distributed_conv,
)
from repro.core.cluster.codec import resolve_wire_dtype  # noqa: F401
from repro.core.cluster.plans import (  # noqa: F401
    PARTITION_MODES,
    LayerPlan as _LayerPlan,
    strip_plan as _strip_plan,
)
from repro.core.cluster.protocol import (  # noqa: F401
    TRAIN_OVER as _TRAIN_OVER,
    SlaveError as _SlaveError,
    bwd_shard as _bwd_shard,
    conv_shard as _conv_shard,
    slave_loop,
)
from repro.core.cluster.scheduler import (  # noqa: F401
    LayerTiming,
    Pending as _Pending,
    TrainStepResult,
)
from repro.core.cluster.transport import (  # noqa: F401
    InProcTransport as _Socket,
    SlaveLost,
    TCPListener,
    TCPSlaveEndpoint,
    TCPTransport,
    Transport,
)


def _slave_loop(sock, slowdown: float, backend_name: str, device: int):
    """Seed-signature wrapper: drive the protocol loop from a legacy
    ``_Socket`` (an ``InProcTransport``) instead of a bare endpoint."""
    return slave_loop(sock.slave_endpoint(), slowdown, backend_name, device)


__all__ = [
    "HeteroCluster",
    "make_distributed_conv",
    "LayerTiming",
    "TrainStepResult",
    "PARTITION_MODES",
    "resolve_wire_dtype",
    "Transport",
    "TCPTransport",
    "TCPSlaveEndpoint",
    "TCPListener",
    "SlaveLost",
]
