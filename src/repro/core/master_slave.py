"""Algorithms 1 & 2 — the master/slave distributed convolution protocol.

Faithful in-process emulation of the paper's socket cluster: every slave
is a thread, every socket a pair of queues, every ``writeSocket`` /
``readSocket`` moves serialized numpy buffers and counts the bytes (so
Eq. 2 can be validated against the actual traffic, see
tests/test_costmodel.py).  Heterogeneity is emulated with per-slave
*slowdown factors*: after computing, a slave sleeps (slowdown-1) x the
measured compute time, appearing exactly like a proportionally slower
machine to both the probe and the training loop.

The protocol per convolutional layer (Algorithm 1 lines 6-23):
  * master broadcasts the SAME inputs to every slave,
  * master scatters a DIFFERENT kernel shard to each slave, sized by the
    Eq. 1 partitioner from probe times,
  * every node (master included) convolves its shard,
  * master gathers the output feature maps and concatenates them,
  * master computes every non-convolutional layer alone.

Backward propagation is distributed the same way ("forward and backward
propagation included", §1): each slave computes the VJP of its own kernel
shard — dW for its shard and its partial dX — and the master sums the
partial dX contributions (the gather of the backward pass).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partitioner import allocate_kernels

_TRAIN_OVER = "trainOver"
_ALL_OK = "allOk"


class _Socket:
    """Queue pair standing in for the paper's TCP socket; counts traffic."""

    def __init__(self):
        self.to_slave: "queue.Queue" = queue.Queue()
        self.to_master: "queue.Queue" = queue.Queue()
        self.bytes_to_slave = 0
        self.bytes_to_master = 0
        self._lock = threading.Lock()

    def _nbytes(self, obj) -> int:
        if isinstance(obj, np.ndarray):
            return obj.nbytes
        if isinstance(obj, (tuple, list)):
            return sum(self._nbytes(o) for o in obj)
        if isinstance(obj, dict):
            return sum(self._nbytes(v) for v in obj.values())
        return 8  # flags / scalars, one double in the paper's protocol

    def write_to_slave(self, obj):
        with self._lock:
            self.bytes_to_slave += self._nbytes(obj)
        self.to_slave.put(obj)

    def write_to_master(self, obj):
        with self._lock:
            self.bytes_to_master += self._nbytes(obj)
        self.to_master.put(obj)

    def read_on_slave(self):
        return self.to_slave.get()

    def read_on_master(self):
        return self.to_master.get()

    @property
    def total_bytes(self) -> int:
        return self.bytes_to_slave + self.bytes_to_master


# The node compute is pure NumPy (im2col): the master's side runs inside
# jax host callbacks, where re-entering jax (jit dispatch) can deadlock
# the runtime thread — numpy is callback-safe and thread-safe.


def _im2col(x: np.ndarray, kh: int, kw: int) -> np.ndarray:
    """SAME-padded im2col.  x: (B,H,W,C) -> (B,H,W, kh*kw*C)."""
    b, h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = np.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    win = np.lib.stride_tricks.sliding_window_view(xp, (kh, kw), axis=(1, 2))
    # win: (B, H, W, C, kh, kw) -> (B, H, W, kh, kw, C)
    win = win.transpose(0, 1, 2, 4, 5, 3)
    return np.ascontiguousarray(win).reshape(b, h, w, kh * kw * c)


def _conv(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """NHWC x HWIO SAME conv, stride 1 (the slave's `convn`)."""
    kh, kw, cin, cout = w.shape
    cols = _im2col(np.asarray(x, np.float32), kh, kw)
    y = cols.reshape(-1, kh * kw * cin) @ w.reshape(kh * kw * cin, cout)
    return y.reshape(x.shape[0], x.shape[1], x.shape[2], cout)


def _conv_vjp(x: np.ndarray, w: np.ndarray, g: np.ndarray):
    """Returns (dx, dw) of sum(conv(x, w) * g)."""
    x = np.asarray(x, np.float32)
    g = np.asarray(g, np.float32)
    kh, kw, cin, cout = w.shape
    b, h, wd, _ = x.shape
    cols = _im2col(x, kh, kw).reshape(-1, kh * kw * cin)
    dw = (cols.T @ g.reshape(-1, cout)).reshape(kh, kw, cin, cout)
    # dx: scatter the columns of dG @ W^T back into the padded image
    dcols = (g.reshape(-1, cout) @ w.reshape(kh * kw * cin, cout).T).reshape(
        b, h, wd, kh, kw, cin
    )
    ph, pw = kh // 2, kw // 2
    dxp = np.zeros((b, h + kh - 1, wd + kw - 1, cin), np.float32)
    for di in range(kh):
        for dj in range(kw):
            dxp[:, di : di + h, dj : dj + wd, :] += dcols[:, :, :, di, dj, :]
    dx = dxp[:, ph : ph + h, pw : pw + wd, :]
    return dx, dw


def _np_probe(*, image_size: int, in_channels: int, kernel_size: int,
              num_kernels: int, batch: int, repeats: int = 3,
              slowdown: float = 1.0, seed: int = 0) -> float:
    """The paper's §4.1.1 probe with the SAME kernel the nodes use for the
    real workload (numpy im2col conv), so Eq. 1 ratios are exact."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(batch, image_size, image_size, in_channels)).astype(np.float32)
    w = rng.normal(size=(kernel_size, kernel_size, in_channels, num_kernels)).astype(np.float32)
    _conv(x, w)  # warm caches
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _conv(x, w)
        times.append(time.perf_counter() - t0)
    measured = float(np.median(times))
    return measured * slowdown if slowdown > 1.0 else measured


def _slave_loop(sock: _Socket, slowdown: float):
    """Algorithm 2: read inputs/kernels, convolve, write outputs, repeat."""
    while True:
        msg = sock.read_on_slave()
        if msg == _TRAIN_OVER:
            return
        op, payload = msg
        t0 = time.perf_counter()
        if op == "conv":
            x, w = payload
            out = _conv(x, w)
        elif op == "bwd":
            x, w, g = payload
            out = _conv_vjp(x, w, g)
        elif op == "probe":
            kwargs = payload
            out = _np_probe(slowdown=slowdown, **kwargs)
            sock.write_to_master(out)
            continue
        else:  # pragma: no cover
            raise ValueError(f"unknown op {op}")
        elapsed = time.perf_counter() - t0
        if slowdown > 1.0:
            time.sleep(elapsed * (slowdown - 1.0))
        sock.write_to_master(out)
        ack = sock.read_on_slave()
        assert ack == _ALL_OK


@dataclasses.dataclass
class LayerTiming:
    comm_s: float = 0.0
    conv_s: float = 0.0
    comp_s: float = 0.0  # non-conv layers (master only)


class HeteroCluster:
    """The master node (Algorithm 1) plus ``n_slaves`` slave threads.

    Device 0 is the master itself (it convolves its own shard while the
    slaves work).  ``slowdowns[i]`` emulates device i's relative speed
    (1.0 = this host's full speed); slowdowns[0] applies to the master.
    """

    def __init__(self, slowdowns: Sequence[float]):
        assert len(slowdowns) >= 1
        self.slowdowns = list(slowdowns)
        self.n_slaves = len(slowdowns) - 1
        self.sockets = [_Socket() for _ in range(self.n_slaves)]
        self.threads = [
            threading.Thread(
                target=_slave_loop, args=(s, sd), daemon=True
            )
            for s, sd in zip(self.sockets, self.slowdowns[1:])
        ]
        for t in self.threads:
            t.start()
        self.probe_times: Optional[List[float]] = None
        self.timing = LayerTiming()

    # -- §4.1.1 pre-processing -------------------------------------------
    def probe(self, **probe_kwargs) -> List[float]:
        """Every device runs the timed reference convolution — sequential
        so the 1-core host's timings do not interfere."""
        master_t = _np_probe(slowdown=self.slowdowns[0], **probe_kwargs)
        slave_ts = []
        for s in self.sockets:
            s.write_to_slave(("probe", probe_kwargs))
            slave_ts.append(s.read_on_master())
        self.probe_times = [master_t] + slave_ts
        return self.probe_times

    def shares_for(self, num_kernels: int) -> np.ndarray:
        assert self.probe_times is not None, "run probe() first"
        return allocate_kernels(num_kernels, self.probe_times)

    # -- Algorithm 1, the conv layer loop --------------------------------
    def _split(self, w: np.ndarray, counts: np.ndarray) -> List[np.ndarray]:
        edges = np.cumsum(counts)[:-1]
        return np.split(w, edges, axis=-1)

    def conv_forward(self, x: np.ndarray, w: np.ndarray) -> np.ndarray:
        """Distributed convolution: broadcast x, scatter kernel shards,
        gather and concatenate feature maps."""
        counts = self.shares_for(w.shape[-1])
        shards = self._split(w, counts)
        t0 = time.perf_counter()
        for sock, shard in zip(self.sockets, shards[1:]):
            sock.write_to_slave(("conv", (x, shard)))
        self.timing.comm_s += time.perf_counter() - t0

        t0 = time.perf_counter()
        my_out = _conv(x, shards[0])
        el = time.perf_counter() - t0
        if self.slowdowns[0] > 1.0:
            time.sleep(el * (self.slowdowns[0] - 1.0))
        outs = [my_out]
        for sock in self.sockets:
            outs.append(sock.read_on_master())
            sock.write_to_slave(_ALL_OK)
        self.timing.conv_s += time.perf_counter() - t0
        return np.concatenate(outs, axis=-1)

    def conv_backward(
        self, x: np.ndarray, w: np.ndarray, g: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Distributed VJP: each node takes the output-gradient slice of
        its own kernels, returns (partial dX, its dW shard); the master
        sums dX and concatenates dW."""
        counts = self.shares_for(w.shape[-1])
        w_shards = self._split(w, counts)
        g_shards = self._split(g, counts)
        t0 = time.perf_counter()
        for sock, ws, gs in zip(self.sockets, w_shards[1:], g_shards[1:]):
            sock.write_to_slave(("bwd", (x, ws, gs)))
        self.timing.comm_s += time.perf_counter() - t0

        t0 = time.perf_counter()
        dx, dw0 = _conv_vjp(x, w_shards[0], g_shards[0])
        el = time.perf_counter() - t0
        if self.slowdowns[0] > 1.0:
            time.sleep(el * (self.slowdowns[0] - 1.0))
        dws = [dw0]
        for sock in self.sockets:
            dxi, dwi = sock.read_on_master()
            dx = dx + dxi
            dws.append(dwi)
            sock.write_to_slave(_ALL_OK)
        self.timing.conv_s += time.perf_counter() - t0
        return dx, np.concatenate(dws, axis=-1)

    # ---------------------------------------------------------------------
    @property
    def comm_bytes(self) -> int:
        return sum(s.total_bytes for s in self.sockets)

    def reset_stats(self):
        self.timing = LayerTiming()
        for s in self.sockets:
            s.bytes_to_slave = 0
            s.bytes_to_master = 0

    def shutdown(self):
        for s in self.sockets:
            s.write_to_slave(_TRAIN_OVER)
        for t in self.threads:
            t.join(timeout=10)


def make_distributed_conv(cluster: HeteroCluster):
    """A drop-in ``conv_fn`` for models/cnn.py: jax custom-VJP convolution
    whose forward and backward run over the cluster via callbacks."""

    @jax.custom_vjp
    def dconv(x, w, b):
        y = _call_fwd(x, w)
        return y + b[None, None, None, :]

    def fwd(x, w, b):
        y = _call_fwd(x, w)
        return y + b[None, None, None, :], (x, w)

    def bwd(res, g):
        x, w = res
        dx, dw = _call_bwd(x, w, g)
        db = jnp.sum(g, axis=(0, 1, 2))
        return dx, dw, db

    def _call_fwd(x, w):
        out_shape = jax.ShapeDtypeStruct(x.shape[:-1] + (w.shape[-1],), x.dtype)
        return jax.pure_callback(
            lambda xx, ww: cluster.conv_forward(np.asarray(xx), np.asarray(ww)),
            out_shape, x, w,
        )

    def _call_bwd(x, w, g):
        out_shape = (
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
        )
        return jax.pure_callback(
            lambda xx, ww, gg: cluster.conv_backward(
                np.asarray(xx), np.asarray(ww), np.asarray(gg)
            ),
            out_shape, x, w, g,
        )

    dconv.defvjp(fwd, bwd)

    def conv_fn(params, x, padding: str = "SAME"):
        return dconv(x, params["kernel"], params["bias"])

    return conv_fn
