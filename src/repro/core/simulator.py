"""Cluster simulator — reproduces the paper's scalability studies
(Figs 9-13, and the prediction side of Tables 4/5) from the Eq. 1
partitioner + Eq. 2 cost model.

"By understanding these details, it is possible to accurately predict new
communication times when more nodes are added, as well as convolution
times and therefore the total processing time." (§5.3.4)

The simulator is calibrated with (a) per-device conv throughputs — either
measured by the probe on this host or the paper's device classes — and
(b) a link bandwidth (the paper measured ~5 Mbps Wi-Fi).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.costmodel import (
    ConvLayerSpec,
    StepTimePrediction,
    comm_time_s,
    paper_network,
    predict_step_time,
)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A simulated heterogeneous cluster.

    ``device_conv_times[i]``: seconds for device i to convolve the whole
    network alone (batch included).  ``master_comp_time``: seconds the
    master spends on the non-conv layers (they are never distributed).
    """

    device_conv_times: Sequence[float]
    master_comp_time: float
    bandwidth_mbps: float
    layers: Sequence[ConvLayerSpec]
    batch: int
    #: False = the paper's Eq. 2 (inputs counted once); True = physical
    #: per-slave input broadcast (see costmodel.upload_elements_nodes)
    broadcast_inputs: bool = False


def simulate(spec: ClusterSpec, n_nodes: int) -> StepTimePrediction:
    """Step-time for the first ``n_nodes`` devices of the cluster."""
    return predict_step_time(
        layers=spec.layers,
        batch=spec.batch,
        device_conv_times=list(spec.device_conv_times[:n_nodes]),
        master_comp_time=spec.master_comp_time,
        bandwidth_mbps=spec.bandwidth_mbps,
        broadcast_inputs=spec.broadcast_inputs,
    )


def speedup_curve(spec: ClusterSpec, max_nodes: Optional[int] = None) -> np.ndarray:
    """Speedups vs the single (master) device, for 1..max_nodes devices —
    the paper's Figures 5/7/9/10 quantity."""
    max_nodes = max_nodes or len(spec.device_conv_times)
    base = simulate(spec, 1).total
    return np.array([base / simulate(spec, n).total for n in range(1, max_nodes + 1)])


def amdahl_ceiling(spec: ClusterSpec) -> float:
    """Theoretical max speedup: conv time -> 0, comm -> 0 (§5.3.1 computes
    7.76x for the largest network at 13% comp share)."""
    one = simulate(spec, 1)
    return one.total / spec.master_comp_time


def gaussian_cluster(
    *,
    n_nodes: int,
    base_conv_time: float,
    rel_speed_low: float,
    rel_speed_high: float,
    master_comp_time: float,
    bandwidth_mbps: float,
    layers: Sequence[ConvLayerSpec],
    batch: int,
    seed: int = 0,
    broadcast_inputs: bool = False,
) -> ClusterSpec:
    """The paper's Figs 9-13 setup: nodes drawn with Gaussian-distributed
    performance between the worst and best measured device."""
    rng = np.random.default_rng(seed)
    mid = 0.5 * (rel_speed_low + rel_speed_high)
    sigma = (rel_speed_high - rel_speed_low) / 4.0
    speeds = np.clip(
        rng.normal(mid, sigma, size=n_nodes), rel_speed_low, rel_speed_high
    )
    speeds[0] = 1.0  # the master is the reference device
    times = base_conv_time / speeds
    return ClusterSpec(
        device_conv_times=list(times),
        master_comp_time=master_comp_time,
        bandwidth_mbps=bandwidth_mbps,
        layers=layers,
        batch=batch,
        broadcast_inputs=broadcast_inputs,
    )


# ---------------------------------------------------------------------------
# calibration against the paper's experiment (Tables 4/5)
#
# The paper reports speedups and time *ratios* but not absolute step
# times, and Eq. 2's volume at a literal 5 Mbps would dwarf any conv time
# (doubles of a 1024-image batch are ~GBs) — the measured comm times in
# Figs 6/8 are far smaller, so the effective comm-to-conv ratio must be
# calibrated.  We fit one scalar per table row:
#     beta = 1 / (bandwidth_bytes_per_s x single_device_step_s)
# (and for GPUs also the non-conv fraction, which the CPU table pins at
# §5.3.1's reported values) by least squares against Tables 4/5, then
# validate the *shape* of the model (speedup vs nodes / batch / kernels).


#: Table 4 (best speedups, CPU) and Table 5 (GPU) from the paper.
PAPER_TABLE4_CPU = {
    (50, 500): (1.40, 1.51, 1.56),
    (150, 800): (1.68, 1.93, 2.10),
    (300, 1000): (1.69, 1.93, 2.33),
    (500, 1500): (1.98, 2.74, 3.28),
}
PAPER_TABLE5_GPU = {
    (50, 500): (1.96, 2.45),
    (150, 800): (1.89, 2.23),
    (300, 1000): (1.78, 2.09),
    (500, 1500): (1.66, 2.00),
}


def predict_speedups(
    c1: int, c2: int, batch: int, *, speeds: Sequence[float],
    comp_fraction: float, beta: float, n_list: Sequence[int],
) -> np.ndarray:
    """Speedup vs a single device for each n in n_list, with comm time
    beta * Eq2_bytes (beta folds bandwidth and absolute step scale)."""
    layers = paper_network(c1, c2)
    out = []
    for n in n_list:
        t = 1.0 / np.asarray(speeds[:n])
        shares = (1.0 / t) / np.sum(1.0 / t)
        vol_bytes = upload_elements_nodes_bytes(layers, batch, shares[1:])
        # (paper's Eq. 2: inputs counted once — the calibration regime)
        conv = (1 - comp_fraction) / np.sum(np.asarray(speeds[:n]))
        out.append(1.0 / (vol_bytes * beta + conv + comp_fraction))
    return np.array(out)


def upload_elements_nodes_bytes(layers, batch, slave_shares,
                                broadcast_inputs: bool = False) -> float:
    from repro.core.costmodel import BYTES_PER_ELEMENT, upload_elements_nodes

    return (
        upload_elements_nodes(
            layers, batch, slave_shares, broadcast_inputs=broadcast_inputs
        )
        * BYTES_PER_ELEMENT
    )


def bandwidth_from_beta(beta: float) -> float:
    """Convert a fitted beta (s per byte at unit step time) to the
    equivalent ClusterSpec bandwidth in Mbps (8 bits/byte)."""
    return 8.0 / (beta * 1e6)


def fit_paper_row(
    c1: int, c2: int, reported: Sequence[float], *, device: str = "cpu",
    batch: int = 1024,
) -> dict:
    """Least-squares fit of beta (and comp_fraction for GPUs) to one row
    of Table 4/5.  Returns {beta, comp_fraction, predicted, reported,
    max_rel_err}."""
    speeds = PAPER_CPU_SPEEDS if device == "cpu" else PAPER_GPU_SPEEDS
    n_list = list(range(2, 2 + len(reported)))
    cf_grid = (
        [PAPER_COMP_FRACTION[(c1, c2)]]
        if device == "cpu"
        else list(np.linspace(0.01, 0.40, 40))
    )
    best = None
    for cf in cf_grid:
        for beta in np.logspace(-16, -9, 240):
            pred = predict_speedups(
                c1, c2, batch, speeds=speeds, comp_fraction=cf, beta=beta,
                n_list=n_list,
            )
            err = float(np.sum((pred - np.asarray(reported)) ** 2))
            if best is None or err < best["err"]:
                best = {"beta": float(beta), "comp_fraction": float(cf),
                        "err": err, "predicted": pred}
    rel = np.abs(best["predicted"] - np.asarray(reported)) / np.asarray(reported)
    best["reported"] = tuple(reported)
    best["max_rel_err"] = float(rel.max())
    return best


#: Relative CPU speeds fitted to the paper's Table 4 (PC1 i5-3210M is the
#: 1.0 reference/master; PC2 i7-4700HQ, PC3 i7-5500U, PC4 i7-6700HQ).
PAPER_CPU_SPEEDS = (1.0, 1.55, 1.25, 1.9)
#: Relative GPU speeds (PC2 GeForce 840M master ref; PC3 940M, PC4 GTX 950M).
PAPER_GPU_SPEEDS = (1.0, 1.15, 1.85)

#: Fraction of single-device step time spent OUTSIDE convolutions, per
#: network size (paper §5.3.1: 25% for the smallest, 13% for the largest).
PAPER_COMP_FRACTION = {
    (50, 500): 0.25,
    (150, 800): 0.19,
    (300, 1000): 0.16,
    (500, 1500): 0.13,
}


def paper_cluster(
    c1: int,
    c2: int,
    batch: int,
    *,
    device: str = "cpu",
    single_device_step_s: Optional[float] = None,
    bandwidth_mbps: float = 5.0,
    seconds_per_kernel_unit: float = 2.4e-4,
) -> ClusterSpec:
    """Build a ClusterSpec matching the paper's testbed for network
    (c1:c2) at the given batch size.

    ``single_device_step_s`` calibrates absolute scale; when None a
    simple linear-in-(kernels x batch) model is used (the constant is per
    CPU; GPUs are ~8x faster on convolutions at batch 1024)."""
    layers = paper_network(c1, c2)
    comp_frac = PAPER_COMP_FRACTION[(c1, c2)]
    speeds = PAPER_CPU_SPEEDS if device == "cpu" else PAPER_GPU_SPEEDS
    if single_device_step_s is None:
        work = sum(
            l.out_size ** 2 * l.kernel_size ** 2 * l.in_channels * l.num_kernels
            for l in layers
        )
        conv_time = work * batch / 1024 * seconds_per_kernel_unit / 1e3
        if device == "gpu":
            conv_time /= 8.0
        single_device_step_s = conv_time / (1 - comp_frac)
    conv1 = single_device_step_s * (1 - comp_frac)
    comp = single_device_step_s * comp_frac
    times = [conv1 * speeds[0] / s for s in speeds]
    return ClusterSpec(
        device_conv_times=times,
        master_comp_time=comp,
        bandwidth_mbps=bandwidth_mbps,
        layers=layers,
        batch=batch,
    )
