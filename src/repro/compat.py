"""Version-compat helpers for the pinned jax.

The global-mesh context manager has been renamed twice across jax
releases: ``jax.set_mesh`` (0.6+), ``jax.sharding.use_mesh`` (0.5.x),
and before that ``Mesh`` itself was the context manager.  ``shard_map``
moved from ``jax.experimental.shard_map`` (with ``check_rep``) to
``jax.shard_map`` (with ``check_vma``).  Every caller goes through this
module so the repo runs unmodified on whichever API the installed jax
exposes.
"""
from __future__ import annotations

import jax


def mesh_context(mesh):
    """Return a context manager that activates ``mesh`` for the enclosed
    region, across jax versions:

        jax.set_mesh(mesh)            # jax >= 0.6
        jax.sharding.use_mesh(mesh)   # jax 0.5.x
        with mesh: ...                # jax <= 0.4.x (Mesh.__enter__)

    Usage: ``with mesh_context(mesh): ...``
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh  # Mesh is itself a context manager on the legacy API


def get_active_mesh():
    """The mesh activated by :func:`mesh_context` for the current thread,
    or ``None``.  Uses ``jax.sharding.get_abstract_mesh`` where it exists;
    the legacy fallback reads the thread-local physical mesh that
    ``Mesh.__enter__`` installs.  Either way the result has ``axis_names``
    and ``axis_sizes``."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        mesh = getter()
    else:
        from jax._src import mesh as _mesh_lib

        mesh = _mesh_lib.thread_resources.env.physical_mesh
    if mesh is None or getattr(mesh, "empty", True):
        return None
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` (0.6+, ``check_vma``) falling back to
    ``jax.experimental.shard_map.shard_map`` (``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
