from repro.data.pipeline import (  # noqa: F401
    synthetic_cifar_batches,
    synthetic_token_batches,
    make_global_batch,
)
