"""Data pipelines.

Synthetic generators (deterministic per step) for CIFAR-like images and
LM token streams: the training examples need real gradient flow and
shuffled batches, not real labels, so the pipeline synthesizes a *learnable*
task — images whose label is a linear probe of the pixels, and token
streams from a fixed-random bigram chain — letting the e2e examples show
loss ACTUALLY decreasing while staying dependency-free and offline.

``make_global_batch`` builds host-sharded global arrays for a mesh
(jax.make_array_from_callback) so the same pipeline feeds single-process
CPU tests and the multi-pod launcher.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def synthetic_cifar_batches(
    batch: int, *, seed: int = 0, image_size: int = 32, channels: int = 3,
    num_classes: int = 10,
) -> Iterator[Dict[str, np.ndarray]]:
    """CIFAR-shaped stream whose label is a SPATIALLY SMOOTH class
    template (coarse random pattern upsampled) plus noise — local
    receptive fields + pooling can actually extract it, so a real CNN
    fits it in a few dozen steps."""
    rng = np.random.default_rng(seed)
    coarse = rng.normal(size=(num_classes, image_size // 4, image_size // 4, channels))
    probes = coarse.repeat(4, axis=1).repeat(4, axis=2)  # low-frequency templates
    probes /= np.sqrt((probes ** 2).mean(axis=(1, 2, 3), keepdims=True))
    while True:
        labels = rng.integers(0, num_classes, size=batch)
        images = (
            rng.normal(size=(batch, image_size, image_size, channels)) * 0.5
            + probes[labels]
        )
        yield {
            "images": images.astype(np.float32),
            "labels": labels.astype(np.int32),
        }


def synthetic_token_batches(
    batch: int, seq_len: int, vocab_size: int, *, seed: int = 0,
    stream_seed: Optional[int] = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Bigram-chain token stream: next token = perm[token] with noise, so
    an LM can drive loss well below uniform.  ``seed`` fixes the TASK
    (the permutation); ``stream_seed`` varies the samples — use the same
    seed with a different stream_seed for held-out eval data."""
    task_rng = np.random.default_rng(seed)
    perm = task_rng.permutation(vocab_size)
    rng = np.random.default_rng(stream_seed if stream_seed is not None else seed + 1)
    while True:
        toks = np.empty((batch, seq_len + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, vocab_size, size=batch)
        noise = rng.random((batch, seq_len)) < 0.1
        randoms = rng.integers(0, vocab_size, size=(batch, seq_len))
        for t in range(seq_len):
            nxt = perm[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], randoms[:, t], nxt)
        yield {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


def make_global_batch(
    host_batch: Dict[str, np.ndarray], mesh: Mesh, batch_axes=("pod", "data")
) -> Dict[str, jax.Array]:
    """Host numpy batch -> global jax.Arrays sharded on the batch axes.

    Each host provides its slice via callback; in this single-process
    container all shards come from the same buffer, but the code path is
    the real multi-host one (make_array_from_callback)."""
    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def one(x: np.ndarray) -> jax.Array:
        spec = PartitionSpec(axes if axes else None)
        sharding = NamedSharding(mesh, spec)
        return jax.make_array_from_callback(
            x.shape, sharding, lambda idx: x[idx]
        )

    return {k: one(v) for k, v in host_batch.items()}
