"""llava-next (v1.6) mistral-7b backbone [hf:llava-hf/llava-v1.6-mistral-7b-hf].

VLM: mistral-7B language model + anyres tiling vision frontend.  The
SigLIP/CLIP vision tower is a STUB per the assignment carve-out —
input_specs() provides (B, 2880, 1024) patch embeddings (5 anyres tiles x
576 patches); the 2-layer MLP projector and the full LM backbone are real.
Mistral's native sliding-window attention (4096) makes long_500k runnable.
"""
from repro.configs.base import ModelConfig, VisionStubConfig

CONFIG = ModelConfig(
    arch_id="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    sliding_window=4096,
    vision=VisionStubConfig(vision_dim=1024, num_image_tokens=2880,
                            projector_hidden=4096),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
