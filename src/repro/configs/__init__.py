"""Config registry: ``get_config("--arch id")`` + input shapes + specs."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    CNNConfig,
    InputShape,
    INPUT_SHAPES,
    ModelConfig,
    MoEConfig,
    RunConfig,
    SSMConfig,
    reduced_for_smoke,
)

_ARCH_MODULES = {
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "whisper-medium": "whisper_medium",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "hymba-1.5b": "hymba_1_5b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "minicpm-2b": "minicpm_2b",
    "mamba2-370m": "mamba2_370m",
    "yi-6b": "yi_6b",
    "nemotron-4-340b": "nemotron_4_340b",
    "mixtral-8x22b": "mixtral_8x22b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id.startswith("cifar_cnn"):
        from repro.configs.cifar_cnn import CONFIGS

        return CONFIGS[arch_id]
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


from repro.configs.input_specs import input_specs, shapes_for_arch  # noqa: E402,F401
