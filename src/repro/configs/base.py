"""Config dataclasses: model architecture, input shapes, run settings."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    expert_d_ff: int
    # router
    router_jitter: float = 0.0
    load_balance_loss_weight: float = 0.01
    # capacity factor for dropped-token dispatch path (dense path ignores it)
    capacity_factor: float = 1.25
    # combine schedule: "psum" = the paper-faithful scheme (tokens
    # replicated over `model`, expert outputs psum-gathered — Alg.1's
    # broadcast+gather); "alltoall" = beyond-paper: tokens sharded over
    # `model` too, capacity buffers exchanged with two all-to-alls (only
    # routed tokens move).  Falls back to psum when shapes do not divide.
    dispatch: str = "psum"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class VisionStubConfig:
    """Modality frontend stub (VLM): input_specs() provides patch embeddings."""

    vision_dim: int = 1024
    num_image_tokens: int = 2880  # llava-next anyres: 5 tiles x 576 patches
    projector_hidden: int = 4096


@dataclasses.dataclass(frozen=True)
class AudioStubConfig:
    """Modality frontend stub (audio): input_specs() provides frame embeddings
    as produced by the conv frontend (mel 3000 frames -> stride-2 conv -> 1500)."""

    num_frames: int = 1500
    frame_dim: int = 1024


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | cnn
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    activation: str = "silu"  # silu | gelu | squared_relu
    gated_mlp: bool = True
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None  # SWA width; None = full attention
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    vision: Optional[VisionStubConfig] = None
    audio: Optional[AudioStubConfig] = None
    num_encoder_layers: int = 0  # >0 => encoder-decoder
    logit_softcap: Optional[float] = None
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # source citation (from the public pool assignment)
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """sub-quadratic decode path exists (SSM state or sliding window)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """The paper's CIFAR-10 network: conv(5x5,c1) -> norm -> pool/2 ->
    conv(5x5,c2) -> norm -> pool/2 -> FC -> softmax."""

    arch_id: str
    c1_kernels: int
    c2_kernels: int
    kernel_size: int = 5
    image_size: int = 32
    image_channels: int = 3
    num_classes: int = 10
    pool_stride: int = 2
    dtype: str = "float32"
    family: str = "cnn"


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Distribution / training-loop knobs, orthogonal to the architecture."""

    tp_mode: str = "megatron"  # gather (paper-faithful) | megatron (optimised)
    fsdp: bool = True
    remat: str = "full"  # none | full | dots
    grad_accum: int = 1  # microbatch count (lax.scan over microbatches)
    optimizer: str = "adam"  # sgd | adam | adafactor
    learning_rate: float = 3e-4
    schedule: str = "cosine"  # constant | cosine | wsd
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.0
    max_grad_norm: Optional[float] = 1.0
    seed: int = 0

    def with_(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def reduced_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests
    (2 layers, d_model<=512, <=4 experts)."""
    d_model = min(cfg.d_model, 256)
    # keep head structure valid (attention-free archs keep 0 heads)
    if cfg.num_heads > 0:
        num_heads = min(cfg.num_heads, 4)
        num_kv_heads = max(1, min(cfg.num_kv_heads, num_heads))
        while num_heads % num_kv_heads:
            num_kv_heads -= 1
        head_dim = max(8, d_model // num_heads)
    else:
        num_heads = num_kv_heads = 0
        head_dim = 32
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(
            moe,
            num_experts=min(moe.num_experts, 4),
            experts_per_token=min(moe.experts_per_token, 2),
            expert_d_ff=min(moe.expert_d_ff, 128),
        )
    ssm = cfg.ssm
    if ssm is not None:
        ssm = dataclasses.replace(
            ssm, d_state=min(ssm.d_state, 16), head_dim=32, chunk_size=32
        )
    vision = cfg.vision
    if vision is not None:
        vision = dataclasses.replace(
            vision, vision_dim=64, num_image_tokens=8, projector_hidden=64
        )
    audio = cfg.audio
    if audio is not None:
        audio = dataclasses.replace(audio, num_frames=16, frame_dim=d_model)
    return cfg.with_(
        num_layers=2,
        num_encoder_layers=2 if cfg.num_encoder_layers else 0,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv_heads,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) or cfg.d_ff,
        vocab_size=min(cfg.vocab_size, 512),
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else None,
        moe=moe,
        ssm=ssm,
        vision=vision,
        audio=audio,
        dtype="float32",
        param_dtype="float32",
    )
