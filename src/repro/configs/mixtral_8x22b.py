"""mixtral-8x22b [arXiv:2401.04088].

56 layers, 8 experts top-2 with per-expert d_ff 16384, GQA 48/8
(head_dim 128), sliding-window attention per the pool assignment ->
long_500k runnable.  8 experts < 16-way model axis, so the MoE layer
shards each expert's d_ff instead (per-expert tensor parallelism) — the
same psum-combine code path (layers/moe.py).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,              # per-expert hidden dim
    vocab_size=32768,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, experts_per_token=2, expert_d_ff=16384),
    source="arXiv:2401.04088",
)
