"""whisper-medium transformer backbone [arXiv:2212.04356].

Encoder-decoder; the mel-spectrogram + conv1d frontend is a STUB per the
assignment carve-out — input_specs() provides (B, 1500, 1024) frame
embeddings as the stride-2 conv stack emits them.  LayerNorm + GELU
(non-gated) per the paper; decoder embedding tied with the logits head.
RoPE replaces whisper's learned absolute positions (DESIGN.md backbone
adaptation note).
"""
from repro.configs.base import AudioStubConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium",
    family="encdec",
    num_layers=24,           # decoder layers
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,         # MHA
    d_ff=4096,
    vocab_size=51865,
    activation="gelu",
    gated_mlp=False,
    norm="layernorm",
    tie_embeddings=True,
    audio=AudioStubConfig(num_frames=1500, frame_dim=1024),
    source="arXiv:2212.04356",
)
