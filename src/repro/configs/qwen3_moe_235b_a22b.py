"""qwen3-moe 235B-A22B [hf:Qwen/Qwen3-30B-A3B family scaling].

94 layers, 128 experts top-8, per-expert d_ff 1536, GQA 64 q heads /
4 kv heads at head_dim 128.  Every layer is MoE; expert parallelism
shards the 128 experts over the 16-way model axis (8 per device) — the
paper's kernel-sharding with experts as the kernel sets.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,               # per-expert hidden dim
    vocab_size=151936,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, experts_per_token=8, expert_d_ff=1536),
    source="hf:Qwen/Qwen3-30B-A3B",
)
