"""yi-6b [arXiv:2403.04652] — llama-arch GQA (32 q heads / 4 kv heads)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
)
