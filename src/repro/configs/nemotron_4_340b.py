"""nemotron-4-340b [arXiv:2402.16819].

96 layers at d_model 18432, GQA 96/8 (head_dim 192), squared-ReLU
non-gated MLP with d_ff 73728, vocab 256000.  The scale forces the
beyond-paper memory regime: FSDP over pod/data + TP over model, Adafactor
(factored second moments), full remat, grad accumulation — see DESIGN.md
§4 and the dry-run memory analysis.  Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    activation="squared_relu",
    gated_mlp=False,
    norm="layernorm",
    source="arXiv:2402.16819",
)
