"""minicpm-2b [arXiv:2404.06395].

Llama-like dense arch with MHA (36 heads = 36 kv heads, head_dim 64),
tied embeddings, trained with the WSD schedule (optim/schedule.py; the
train launcher selects schedule="wsd" for this arch).  Full attention,
no sub-quadratic variant -> long_500k skipped (DESIGN.md policy).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2404.06395",
)
