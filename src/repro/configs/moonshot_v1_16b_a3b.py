"""moonshot-v1 16B-A3B (Moonlight) [hf:moonshotai/Moonlight-16B-A3B].

The pool tags this [dense] but specifies "MoE 64e top-6" — we implement
the MoE per the numbers (DESIGN.md §Arch-applicability note): 48 layers,
64 experts top-6 with per-expert d_ff 1408, MHA 16 heads (kv=16).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,               # per-expert hidden dim
    vocab_size=163840,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    rope_theta=50_000.0,
    moe=MoEConfig(num_experts=64, experts_per_token=6, expert_d_ff=1408),
    source="hf:moonshotai/Moonlight-16B-A3B",
)
