"""mamba2-370m [arXiv:2405.21060] — SSD (state-space duality).

Attention-free: 48 pure mamba2 blocks, d_model 1024, d_state 128,
head_dim 64 (expand 2 -> d_inner 2048 -> 32 SSD heads).  The paper's
technique has no attention axis here; the SSD *head* axis is the
output-feature analogue sharded over `model` (DESIGN.md
§Arch-applicability).  O(1) recurrent state -> long_500k native.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,             # attention-free
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    norm="rmsnorm",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    source="arXiv:2405.21060",
)
