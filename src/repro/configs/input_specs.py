"""ShapeDtypeStruct stand-ins for every model input — the dry-run's
weak-type-correct, shardable, zero-allocation input description.

``input_specs(cfg, shape)`` returns the kwargs pytree the corresponding
step function is lowered with:

* train / prefill: {"tokens", "labels"} (+ "patches" for VLM, "frames"
  for audio) — the modality stubs ARE the carve-out: precomputed
  patch/frame embeddings of the frontend's output shape.
* decode: {"tokens": (B, 1)} + the cache pytree from the model's
  ``init_cache`` under ``jax.eval_shape`` (full-length KV for dense,
  window ring for SWA, O(1) state for SSM).
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def shapes_for_arch(cfg: ModelConfig) -> List[str]:
    """Which of the four input shapes this arch runs (long_500k only with
    a sub-quadratic decode path — DESIGN.md policy)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        out.append("long_500k")
    return out


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {"tokens": _sds((b, s), jnp.int32)}
        if shape.kind == "train":
            specs["labels"] = _sds((b, s), jnp.int32)
        if cfg.vision is not None:
            v = cfg.vision
            specs["patches"] = _sds((b, v.num_image_tokens, v.vision_dim), jnp.float32)
        if cfg.audio is not None:
            a = cfg.audio
            specs["frames"] = _sds((b, a.num_frames, a.frame_dim), jnp.float32)
        return specs

    # decode: one token against a seq_len-sized context
    from repro.models.registry import build_model  # lazy: avoids import cycle

    api = build_model(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(b, s))
    return {"tokens": _sds((b, 1), jnp.int32), "cache": cache}
