"""The paper's own CIFAR-10 CNN configs (§5.2), four sizes:
(C1:C2) kernels = 50:500, 150:800, 300:1000, 500:1500."""
from repro.configs.base import CNNConfig

CONFIGS = {
    f"cifar_cnn_{c1}_{c2}": CNNConfig(
        arch_id=f"cifar_cnn_{c1}_{c2}", c1_kernels=c1, c2_kernels=c2
    )
    for c1, c2 in [(50, 500), (150, 800), (300, 1000), (500, 1500)]
}
CONFIG = CONFIGS["cifar_cnn_500_1500"]  # the paper's largest (headline) net
