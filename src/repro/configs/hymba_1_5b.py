"""hymba-1.5b [arXiv:2411.13676].

Hybrid-head architecture: every block runs attention heads and mamba
(SSM) heads IN PARALLEL on the same input and fuses the outputs — here by
averaging after each branch (the paper uses learned per-branch output
norms; averaging is the fusion the smoke oracle checks).  25 query heads /
5 kv heads at head_dim 64; sliding-window attention (1024) in the global
config makes long_500k runnable together with the O(1) SSM state.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    sliding_window=1024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    source="arXiv:2411.13676",
)
