import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The paper's OWN architecture on the production mesh: the CIFAR-10 CNN
# with kernel-sharded convolutions (core/conv_shard.py), lowered and
# compiled at batch 1024 (the paper's largest), comparing the faithful
# gather schedule against the channel-sharded (beyond-paper) one.

import argparse  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.compat import mesh_context  # noqa: E402
from repro.configs.base import InputShape  # noqa: E402
from repro.configs.cifar_cnn import CONFIGS  # noqa: E402
from repro.core.conv_shard import make_sharded_conv  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_name  # noqa: E402
from repro.models.cnn import cnn_axes, cnn_loss, init_cnn  # noqa: E402
from repro.models.registry import rules_for_mode  # noqa: E402
from repro.roofline.analysis import RooflineReport  # noqa: E402
from repro.roofline.hlo_parse import analyze_hlo  # noqa: E402
from repro.sharding.partitioning import param_sharding_for_tree, spec_for_shape  # noqa: E402


def dryrun_cnn(arch: str, batch: int, tp_mode: str, multi_pod: bool = False):
    cfg = CONFIGS[arch]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for_mode(tp_mode)
    conv_fn = make_sharded_conv(rules)

    abstract = jax.eval_shape(lambda: init_cnn(jax.random.key(0), cfg))
    param_sh = param_sharding_for_tree(mesh, cnn_axes(), rules, abstract)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    img_sh = jax.NamedSharding(
        mesh, spec_for_shape(rules, (batch, 32, 32, 3), ("batch", None, None, None), sizes)
    )
    lbl_sh = jax.NamedSharding(
        mesh, spec_for_shape(rules, (batch,), ("batch",), sizes)
    )

    def train_step(params, images, labels):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: cnn_loss(p, images, labels, cfg=cfg, conv_fn=conv_fn),
            has_aux=True,
        )(params)
        new = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
        return new, loss, acc

    jitted = jax.jit(
        train_step,
        in_shardings=(param_sh, img_sh, lbl_sh),
        out_shardings=(param_sh, None, None),
    )
    with mesh_context(mesh):
        lowered = jitted.lower(
            abstract,
            jax.ShapeDtypeStruct((batch, 32, 32, 3), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        )
        compiled = lowered.compile()
    chips = mesh.devices.size
    hc = analyze_hlo(compiled.as_text(), num_partitions=chips)
    mem = compiled.memory_analysis()
    hbm = mem.temp_size_in_bytes + mem.argument_size_in_bytes
    rec = {
        "arch_id": arch, "shape": f"train_b{batch}", "mesh": mesh_name(mesh),
        "tp_mode": tp_mode, "chips": chips,
        "compute_s": hc.flops / 197e12,
        "memory_s": hc.memory_bytes / 819e9,
        "collective_s": hc.collective_bytes / 50e9,
        "collective_breakdown": hc.by_kind,
        "hbm_bytes_per_device": int(hbm),
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: rec[k])
    print(
        f"{arch:22s} b={batch:5d} {tp_mode:9s} "
        f"C={rec['compute_s']:.2e} M={rec['memory_s']:.2e} "
        f"X={rec['collective_s']:.2e} dom={dom.split('_')[0]:10s} "
        f"hbm/dev={hbm/2**20:8.1f}MiB", flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    recs = []
    for arch in CONFIGS:
        for mode in ("gather", "megatron"):
            recs.append(dryrun_cnn(arch, args.batch, mode))
    if args.out:
        with open(args.out, "a") as f:
            for r in recs:
                f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
