"""Launch the paper's CNN over the emulated heterogeneous cluster.

The one CLI that wires the whole stack together: per-device compute
backends (core/backends.py), Eq. 1 probing/partitioning, and the
asynchronous pipelined scatter/gather protocol (core/master_slave.py),
driving real training steps of the CIFAR CNN (models/cnn.py).

    PYTHONPATH=src python -m repro.launch.hetero \
        --slowdowns 1.0,1.5,3.0 --backends numpy,xla,numpy \
        --pipeline --microbatches 4 --steps 2

Device 0 is the master; keep its backend ``numpy`` (the training loop
drives the cluster through jax host callbacks — see master_slave.py).

``--train-pipeline`` switches to the activation-stashing full-step
schedule (``conv_train_step``): forward AND backward of every conv layer
are pipelined across the cluster and the master-only stages overlap
slave compute.  It drives the cluster directly (no jax callbacks), so
any master backend is safe, and the comp-aware partitioner discounts the
master's measured non-conv duty automatically:

    PYTHONPATH=src python -m repro.launch.hetero \
        --slowdowns 1.0,1.5,3.0 --train-pipeline --microbatches 4 --steps 4

``--partition`` picks the conv split axis — ``kernel`` (the paper),
``spatial`` (height strips + halo exchange: each slave receives only its
rows instead of the full activation), ``batch`` (data parallelism:
replicate the kernel, split the batch's N axis, sum per-slave dW — wins
on fat links), or ``auto`` (per layer, the axis with the smallest
predicted wall-clock over the emulated links) — and
``--wire-dtype fp16|bf16`` turns on the compact wire codec.  Both need
``--bandwidth-mbps`` to matter (with infinitely fast links the wire is
free and auto sticks to the paper's kernel axis):

    PYTHONPATH=src python -m repro.launch.hetero \
        --slowdowns 1.0,1.5,3.0 --train-pipeline --bandwidth-mbps 50 \
        --partition auto --wire-dtype fp16 --steps 4

``--transport tcp`` runs every slave as a REAL OS process connected over
localhost sockets (core/cluster/transport.py): comm, serialization and
slave compute are measured, not emulated, and the probe feeds each
link's measured bandwidth to the comm-aware partitioner:

    PYTHONPATH=src python -m repro.launch.hetero \
        --transport tcp --train-pipeline --slowdowns 1.0,1.5 --steps 2

``--transport shm`` keeps the OS-subprocess slaves but moves the bulk
array bytes through zero-copy shared-memory rings (same host only;
control frames stay on a localhost socket).  ``--wire-codec`` layers
the pluggable compressor stack over any transport with a per-message-
class spec, and the versioned weight-broadcast cache is on by default
(``--no-weight-cache`` to disable):

    PYTHONPATH=src python -m repro.launch.hetero \
        --transport shm --train-pipeline --slowdowns 1.0,1.5 \
        --wire-codec "weights=fp16,acts=int8,grads=topk:0.05" --steps 2

``--groups GxM`` trades the flat topology for the TWO-TIER hierarchy
(core/cluster/hierarchy.py): G sub-master groups of M devices each,
the root planning disjoint batch rows across groups (exact dW
all-reduce) while each group partitions its rows internally on
``--group-partition``.  ``--slowdowns`` then carries 1 + G*M entries
(root first, then group devices chunked M per group) or just the root;
``--master-nic-mbps`` emulates one shared master port serialized
across all root links (inproc only) — the regime where two tiers beat
flat, because the root's ingress carries G summed group gradients
instead of G*M:

    PYTHONPATH=src python -m repro.launch.hetero \
        --groups 2x3 --train-pipeline --master-nic-mbps 200 --steps 4

``--expected-slaves N`` makes the master WAIT for N hand-launched
slaves instead of spawning them — the remote-host path.  Pass only the
master's ``--slowdowns`` entry, bind with ``--listen-host``/
``--listen-port``, export the same REPRO_CLUSTER_AUTH hex token in
both environments, and start each slave (any reachable host) with:

    python -m repro.core.cluster.protocol --host MASTER --port P \
        --backend numpy --heartbeat-s 0.5

``--heartbeat-s`` arms liveness on tcp: slaves beat small frames and
the master declares a silent link dead after 3x the interval, evicts
it, absorbs its in-flight shards, and re-partitions the next step over
the survivors (core/cluster/cluster.py, the elastic runtime).

The CLI always leaves through ``os._exit`` after flushing its output:
an ``xla`` slave (or any backend with native runtime threads) used to
complete its steps and then hang the interpreter at exit (XLA runtime
thread vs CPython finalization, the ROADMAP pre-existing bug).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.master_slave import HeteroCluster, make_distributed_conv
from repro.core.partitioner import workload_shares
from repro.models.cnn import (
    cnn_loss,
    init_cnn,
    make_cluster_train_step,
    make_cnn_config,
)


def run_hetero(
    slowdowns,
    backends=None,
    *,
    pipeline: bool = False,
    train_pipeline: bool = False,
    microbatches: int = 4,
    c1: int = 8,
    c2: int = 16,
    batch: int = 8,
    steps: int = 2,
    lr: float = 0.05,
    partition: str = "kernel",
    wire_dtype=None,
    wire_codec=None,
    weight_cache: bool = True,
    bandwidth_mbps=None,
    transport: str = "inproc",
    expected_slaves=None,
    listen_host: str = "127.0.0.1",
    listen_port: int = 0,
    heartbeat_s=None,
    groups=None,
    group_partition: str = "auto",
    master_nic_mbps=None,
) -> dict:
    if not train_pipeline and backends is not None and backends[0] != "numpy":
        # the callback training loop re-enters jax on the blocked runtime
        # thread with a non-numpy master and can deadlock — fail fast
        # (make_distributed_conv raises too; this gives the CLI message)
        raise SystemExit(
            f"device 0 (the master) must use the 'numpy' backend with "
            f"callback-driven training, got {backends[0]!r}; slaves may "
            f"use any backend.  --train-pipeline drives the cluster "
            f"directly and lifts this restriction."
        )
    cfg = make_cnn_config(c1, c2)
    if groups is not None:
        from repro.core.cluster.hierarchy import (
            HierarchicalCluster,
            parse_groups,
        )

        if expected_slaves is not None:
            raise SystemExit(
                "--groups spawns its own sub-masters; --expected-slaves "
                "(hand-launched joins) is a flat-cluster feature"
            )
        gspecs = parse_groups(
            groups,
            slowdowns=slowdowns[1:] if len(slowdowns) > 1 else None,
            backends=backends[1:] if backends and len(backends) > 1 else None,
            partition=group_partition,
            pipeline=pipeline or train_pipeline,
            microbatches=microbatches,
        )
        cluster = HierarchicalCluster(
            gspecs,
            master_slowdown=slowdowns[0],
            master_backend=backends[0] if backends else "numpy",
            pipeline=pipeline or train_pipeline, microbatches=microbatches,
            wire_dtype=wire_dtype, wire_codec=wire_codec,
            weight_cache=weight_cache, bandwidth_mbps=bandwidth_mbps,
            master_nic_mbps=master_nic_mbps, transport=transport,
            heartbeat_s=heartbeat_s,
        )
        partition = "batch"  # the root's inter-group axis, by construction
    else:
        cluster = HeteroCluster(
            slowdowns, backends,
            pipeline=pipeline or train_pipeline, microbatches=microbatches,
            partition=partition, wire_dtype=wire_dtype,
            wire_codec=wire_codec, weight_cache=weight_cache,
            bandwidth_mbps=bandwidth_mbps, transport=transport,
            expected_slaves=expected_slaves,
            listen_host=listen_host, listen_port=listen_port,
            heartbeat_s=heartbeat_s,
            master_nic_mbps=master_nic_mbps,
        )
    try:
        probe = cluster.probe(
            image_size=cfg.image_size, in_channels=cfg.image_channels,
            kernel_size=cfg.kernel_size, num_kernels=max(8, c1), batch=batch,
        )
        shares = workload_shares(probe)
        print(f"devices: slowdowns={list(cluster.slowdowns)} "
              f"backends={cluster.backends} transport={transport}"
              + (f" topology={groups} (groups plan rows internally on "
                 f"'{group_partition}')" if groups else ""))
        print(f"probe times: {np.round(probe, 4).tolist()}")
        if transport in ("tcp", "shm"):
            print(f"measured link bandwidth (Mbps): "
                  f"{[None if b is None else round(b, 1) for b in cluster.measured_bandwidths]}")
        print(f"Eq.1 shares: {np.round(shares, 3).tolist()} -> "
              f"c2 kernels {cluster.shares_for(c2).tolist()}")

        params = init_cnn(jax.random.key(0), cfg)
        imgs = jax.random.normal(jax.random.key(1), (batch, 32, 32, 3))
        labels = jnp.arange(batch) % cfg.num_classes

        if train_pipeline:
            # full-step pipeline: fwd + bwd distributed, direct driver
            cluster_step = make_cluster_train_step(cluster, cfg, lr=lr)

            def train_step(p):
                p, loss, _acc = cluster_step(p, imgs, labels)
                return p, loss
        else:
            # seed path: jax custom-VJP conv via host callbacks
            conv_fn = make_distributed_conv(cluster)

            def train_step(p):
                (loss, acc), grads = jax.value_and_grad(
                    lambda q: cnn_loss(q, imgs, labels, cfg=cfg, conv_fn=conv_fn),
                    has_aux=True,
                )(p)
                return jax.tree.map(lambda a, g: a - lr * g, p, grads), loss

        cluster.reset_stats()
        t0 = time.perf_counter()
        losses = []
        for _ in range(steps):
            params, loss = train_step(params)
            losses.append(float(loss))
        wall = time.perf_counter() - t0

        t = cluster.timing
        rec = {
            "protocol": (
                "trainstep-pipelined" if train_pipeline
                else "pipelined" if pipeline else "barrier"
            ),
            "transport": transport,
            "topology": groups or "flat",
            "group_partition": group_partition if groups else None,
            "master_nic_mbps": master_nic_mbps,
            "measured_bandwidth_mbps": list(cluster.measured_bandwidths),
            "microbatches": microbatches if (pipeline or train_pipeline) else 1,
            "partition": partition,
            "partition_choices": {
                str(k): v for k, v in cluster.partition_choices.items()
            },
            "wire_dtype": wire_dtype or "fp32",
            "wire_codec": cluster._codec_cfg.spec,
            "weight_cache": weight_cache,
            "bandwidth_mbps": bandwidth_mbps,
            "heartbeat_s": heartbeat_s,
            "slave_ids": list(cluster.slave_ids),
            "failures": list(cluster.failures),
            "comp_duty": cluster.comp_duty,
            "backends": list(cluster.backends),
            "probe_s": [float(x) for x in probe],
            "losses": losses,
            "wall_s": wall,
            "comm_mb": cluster.comm_bytes / 2 ** 20,
            "timing": dataclasses.asdict(t),
        }
        print(f"{steps} steps in {wall:.2f}s  losses={np.round(losses, 4).tolist()}")
        print(f"comm={rec['comm_mb']:.1f}MiB  scatter={t.comm_s:.3f}s "
              f"conv={t.conv_s:.3f}s wait={t.gather_wait_s:.3f}s "
              f"overlap={t.overlap_s:.3f}s")
        if train_pipeline:
            print(f"comp-aware: master non-conv duty={cluster.comp_duty:.2f} -> "
                  f"c2 kernels now {cluster.shares_for(c2).tolist()}")
        if partition == "auto" and cluster.partition_choices:
            print(f"auto partition picks: {rec['partition_choices']}")
        return rec
    finally:
        cluster.shutdown()


def run_serve(
    slowdowns,
    backends=None,
    *,
    microbatches: int = 4,
    c1: int = 8,
    c2: int = 16,
    requests: int = 20,
    deadline_s=30.0,
    max_batch: int = 4,
    image_size: int = 16,
    partition: str = "kernel",
    wire_dtype=None,
    wire_codec=None,
    weight_cache: bool = True,
    bandwidth_mbps=None,
    transport: str = "inproc",
    expected_slaves=None,
    listen_host: str = "127.0.0.1",
    listen_port: int = 0,
    heartbeat_s=None,
    seed: int = 0,
) -> dict:
    """Serve ``requests`` synthetic conv-chain requests through a
    ``ClusterServer`` (continuous batching over the pipelined cluster)
    and report throughput + tail latency.  Doubles as the CI
    serve-smoke: the returned record carries ``all_ok`` and the CLI
    exits nonzero unless every request completed under its deadline."""
    from repro.serve.server import ClusterServer

    rng = np.random.default_rng(seed)
    k = 5
    weights = [
        rng.standard_normal((k, k, 3, c1)).astype(np.float32) * 0.1,
        rng.standard_normal((k, k, c1, c2)).astype(np.float32) * 0.1,
    ]

    def _relu_pool(y):
        """Master-only stage after each conv: ReLU + 2x2 max-pool
        (numpy — the serve loop drives the cluster directly)."""
        y = np.maximum(y, 0.0)
        b, h, w, c = y.shape
        return y.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))

    feat = image_size // 4
    fc = rng.standard_normal((feat * feat * c2, 10)).astype(np.float32) * 0.01

    def _head(z):
        return z.reshape(z.shape[0], -1) @ fc

    cluster = HeteroCluster(
        slowdowns, backends,
        pipeline=True, microbatches=microbatches,
        partition=partition, wire_dtype=wire_dtype,
        wire_codec=wire_codec, weight_cache=weight_cache,
        bandwidth_mbps=bandwidth_mbps, transport=transport,
        expected_slaves=expected_slaves,
        listen_host=listen_host, listen_port=listen_port,
        heartbeat_s=heartbeat_s,
    )
    try:
        cluster.probe(image_size=image_size, in_channels=3, kernel_size=k,
                      num_kernels=max(8, c1), batch=max_batch)
        print(f"serving: slowdowns={list(cluster.slowdowns)} "
              f"backends={cluster.backends} transport={transport} "
              f"max_batch={max_batch} deadline_s={deadline_s}")
        server = ClusterServer(
            cluster, weights, between=[_relu_pool, _relu_pool], head=_head,
            max_batch=max_batch, max_queue=max(2 * requests, 16),
            default_deadline_s=deadline_s,
        )
        t0 = time.perf_counter()
        with server:
            futs = [
                server.submit(
                    rng.standard_normal((image_size, image_size, 3))
                    .astype(np.float32)
                )
                for _ in range(requests)
            ]
            resps = [f.result(timeout=600.0) for f in futs]
        wall = time.perf_counter() - t0
        stats = server.stats()
        statuses = sorted({r.status for r in resps})
        all_ok = all(r.status == "ok" for r in resps)
        rec = {
            "mode": "serve",
            "transport": transport,
            "wire_codec": cluster._codec_cfg.spec,
            "weight_cache": weight_cache,
            "requests": requests,
            "max_batch": max_batch,
            "deadline_s": deadline_s,
            "statuses": statuses,
            "all_ok": all_ok,
            "retries": sum(r.retries for r in resps),
            "failures": list(cluster.failures),
            "wall_s": wall,
            "throughput_rps": requests / wall,
            "p50_ms": stats["p50_ms"],
            "p99_ms": stats["p99_ms"],
            "comm_mb": cluster.comm_bytes / 2 ** 20,
        }
        print(f"{requests} requests in {wall:.2f}s -> "
              f"{rec['throughput_rps']:.1f} req/s  "
              f"p50={stats['p50_ms']:.1f}ms p99={stats['p99_ms']:.1f}ms  "
              f"statuses={statuses} retries={rec['retries']}")
        return rec
    finally:
        cluster.shutdown()


def _clean_exit(code: int) -> None:
    """Flush and leave through ``os._exit``: the ROADMAP pre-existing
    hang — an ``xla`` slave completes its steps, prints results, then
    the interpreter never exits (XLA runtime threads vs CPython
    finalization) — cannot bite a process that skips finalization.
    Everything user-visible (stdout/stderr, --out JSONL) is already
    written and flushed by the time this runs, so nothing is lost."""
    sys.stdout.flush()
    sys.stderr.flush()
    os._exit(code)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--slowdowns", default=None,
                    help="comma list; device 0 is the master (default "
                         "1.0,1.5,3.0 flat; with --groups GxM pass 1 + G*M "
                         "entries — root then group devices chunked M per "
                         "group — or just the root, group devices default "
                         "to 1.0)")
    ap.add_argument("--groups", default=None, metavar="GxM",
                    help="two-tier topology: G sub-master groups of M "
                         "devices each (e.g. 2x3); the root plans disjoint "
                         "batch rows across groups (exact dW all-reduce), "
                         "each group re-partitions its rows internally on "
                         "--group-partition.  With --transport tcp each "
                         "sub-master is a real OS process")
    ap.add_argument("--group-partition", default="auto",
                    choices=["kernel", "spatial", "batch", "auto"],
                    help="conv split axis INSIDE each group (the root's "
                         "inter-group axis is always batch)")
    ap.add_argument("--master-nic-mbps", type=float, default=None,
                    help="emulate ONE shared master port of this speed "
                         "serialized across all root links (inproc only) — "
                         "the master-ingress-bound regime where the "
                         "hierarchy beats a flat cluster")
    ap.add_argument("--backends", default=None,
                    help="comma list of conv backends per device "
                         "(numpy|xla|pallas|sim), default numpy everywhere; "
                         "in callback mode (no --train-pipeline) the master "
                         "(device 0) must stay numpy")
    ap.add_argument("--pipeline", action="store_true",
                    help="double-buffered microbatch scatter/gather")
    ap.add_argument("--train-pipeline", action="store_true",
                    help="pipeline the FULL training step (forward + "
                         "backward) with the activation-stashing "
                         "conv_train_step schedule; implies --pipeline and "
                         "allows any master backend (direct driver)")
    ap.add_argument("--partition", default="kernel",
                    choices=["kernel", "spatial", "batch", "auto"],
                    help="conv split axis: output channels (kernel, the "
                         "paper), height strips + halo exchange (spatial), "
                         "batch rows + replicated kernel + dW all-reduce "
                         "(batch), or per-layer predicted-wall-clock pick "
                         "(auto)")
    ap.add_argument("--wire-dtype", default=None,
                    choices=["fp32", "fp16", "bf16"],
                    help="compact wire codec at the socket boundary; "
                         "master-side accumulation stays float32")
    ap.add_argument("--wire-codec", default=None,
                    help="full compressor stack, superseding --wire-dtype: "
                         "one stage for everything ('fp16', 'int8') or "
                         "per message class, e.g. "
                         "'weights=fp16,acts=int8,grads=topk:0.05' "
                         "(top-k applies to gradients only, with "
                         "master-side error feedback)")
    ap.add_argument("--no-weight-cache", action="store_true",
                    help="disable the versioned weight-broadcast cache "
                         "(slaves then receive kernels every slab/"
                         "microbatch — the pre-cache wire, for A/B runs)")
    ap.add_argument("--bandwidth-mbps", type=float, default=None,
                    help="emulated master<->slave link speed (the paper's "
                         "~5 Mbps Wi-Fi); default: infinitely fast links. "
                         "With --transport tcp this only overrides the "
                         "measured planning bandwidth")
    ap.add_argument("--transport", default="inproc",
                    choices=["inproc", "tcp", "shm"],
                    help="the wire: in-process queue emulation (threads, "
                         "seed behaviour), real localhost TCP sockets "
                         "with one OS subprocess per slave, or shm — "
                         "subprocess slaves with bulk arrays on zero-copy "
                         "shared-memory rings (co-located only)")
    ap.add_argument("--expected-slaves", type=int, default=None,
                    help="wait for this many HAND-LAUNCHED slaves to "
                         "join the listener instead of spawning any "
                         "(implies --transport tcp; pass only the "
                         "master's --slowdowns entry and export "
                         "REPRO_CLUSTER_AUTH in both environments)")
    ap.add_argument("--listen-host", default="127.0.0.1",
                    help="TCP listener bind interface; 0.0.0.0 accepts "
                         "slaves from remote hosts")
    ap.add_argument("--listen-port", type=int, default=0,
                    help="TCP listener port (0 = kernel-assigned); fix "
                         "it so remote slaves know where to connect")
    ap.add_argument("--heartbeat-s", type=float, default=None,
                    help="slave liveness interval: spawned slaves beat "
                         "every this many seconds and the master "
                         "declares a silent link dead after 3x (tcp "
                         "only); hand-launched slaves must pass the "
                         "same --heartbeat-s themselves")
    ap.add_argument("--serve", action="store_true",
                    help="serve a stream of forward-pass requests through "
                         "the continuous-batching ClusterServer instead of "
                         "training (see docs/serving.md); exits nonzero "
                         "unless every request completes under deadline")
    ap.add_argument("--requests", type=int, default=20,
                    help="synthetic requests to serve with --serve")
    ap.add_argument("--deadline-s", type=float, default=30.0,
                    help="per-request deadline for --serve")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="dynamic-batching slot count for --serve")
    ap.add_argument("--image-size", type=int, default=16,
                    help="request image height/width for --serve")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--c1", type=int, default=8)
    ap.add_argument("--c2", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--out", default=None, help="append the record as JSONL")
    args = ap.parse_args()

    # the flat default topology makes no sense under --groups: there the
    # default is "just the root", group devices filling in at 1.0
    slowdowns_s = args.slowdowns or ("1.0" if args.groups else "1.0,1.5,3.0")
    slowdowns = [float(s) for s in slowdowns_s.split(",")]
    backends = args.backends.split(",") if args.backends else None
    transport = args.transport
    if args.expected_slaves is not None:
        transport = "tcp"  # external joins only exist on the real wire
    try:
        if args.serve:
            rec = run_serve(
                slowdowns, backends,
                microbatches=args.microbatches, c1=args.c1, c2=args.c2,
                requests=args.requests, deadline_s=args.deadline_s,
                max_batch=args.max_batch, image_size=args.image_size,
                partition=args.partition, wire_dtype=args.wire_dtype,
                wire_codec=args.wire_codec,
                weight_cache=not args.no_weight_cache,
                bandwidth_mbps=args.bandwidth_mbps, transport=transport,
                expected_slaves=args.expected_slaves,
                listen_host=args.listen_host, listen_port=args.listen_port,
                heartbeat_s=args.heartbeat_s,
            )
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
            _clean_exit(0 if rec["all_ok"] else 1)
        rec = run_hetero(
            slowdowns, backends, pipeline=args.pipeline,
            train_pipeline=args.train_pipeline,
            microbatches=args.microbatches, c1=args.c1, c2=args.c2,
            batch=args.batch, steps=args.steps,
            partition=args.partition, wire_dtype=args.wire_dtype,
            wire_codec=args.wire_codec,
            weight_cache=not args.no_weight_cache,
            bandwidth_mbps=args.bandwidth_mbps, transport=transport,
            expected_slaves=args.expected_slaves,
            listen_host=args.listen_host, listen_port=args.listen_port,
            heartbeat_s=args.heartbeat_s,
            groups=args.groups, group_partition=args.group_partition,
            master_nic_mbps=args.master_nic_mbps,
        )
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    except SystemExit:
        raise  # config validation: no cluster (and no xla threads) yet
    except BaseException:
        traceback.print_exc()
        _clean_exit(1)
    _clean_exit(0)


if __name__ == "__main__":
    main()
