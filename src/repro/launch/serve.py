"""Serving launcher: batched prefill + greedy/sampled decode.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --batch 4 \
        --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, get_config, reduced_for_smoke
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.registry import build_model
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--tp-mode", default="megatron", choices=["megatron", "gather"])
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_for_smoke(cfg)
    api = build_model(cfg)
    run = RunConfig(tp_mode=args.tp_mode)
    mesh = make_production_mesh() if args.full else None

    params = api.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
            jnp.int32,
        )
    }
    if cfg.vision is not None:
        v = cfg.vision
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, v.num_image_tokens, v.vision_dim)),
            jnp.float32,
        )
    if cfg.audio is not None:
        a = cfg.audio
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, a.num_frames, a.frame_dim)), jnp.float32
        )

    engine = ServeEngine(api=api, run=run, params=params, mesh=mesh)
    t0 = time.time()
    out = engine.generate(
        batch,
        max_new_tokens=args.max_new,
        sample=args.sample,
        temperature=args.temperature,
        seed=args.seed,
    )
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"generated {out.shape} in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    print(np.asarray(out[:2]))


if __name__ == "__main__":
    main()
