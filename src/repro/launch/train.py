"""Training launcher.

Runs real optimization steps with the synthetic pipeline.  On this CPU
host the full configs do not fit, so ``--reduced`` (default) trains the
smoke-scale variant of the chosen arch; on a TPU pod the same launcher
runs the full config over ``make_production_mesh()`` — the code path
(mesh, shardings, host-sharded data, checkpointing) is identical.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import save_checkpoint
from repro.compat import mesh_context
from repro.configs import INPUT_SHAPES, RunConfig, get_config, reduced_for_smoke
from repro.data.pipeline import make_global_batch, synthetic_token_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.registry import build_model, rules_for_mode
from repro.sharding.partitioning import param_sharding_for_tree
from repro.train.step import init_train_state, make_train_step, train_state_axes


def add_modalities(batch, cfg, rng):
    if cfg.vision is not None:
        v = cfg.vision
        batch["patches"] = rng.normal(
            size=(batch["tokens"].shape[0], v.num_image_tokens, v.vision_dim)
        ).astype(np.float32)
    if cfg.audio is not None:
        a = cfg.audio
        batch["frames"] = rng.normal(
            size=(batch["tokens"].shape[0], a.num_frames, a.frame_dim)
        ).astype(np.float32)
    return batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--tp-mode", default="megatron", choices=["megatron", "gather"])
    ap.add_argument("--optimizer", default="adam")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="full config on the production mesh (TPU pods)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_for_smoke(cfg)
    api = build_model(cfg)
    run = RunConfig(
        tp_mode=args.tp_mode,
        optimizer=args.optimizer,
        learning_rate=args.lr,
        grad_accum=args.grad_accum,
        schedule="wsd" if args.arch == "minicpm-2b" else "cosine",
        total_steps=args.steps,
        warmup_steps=max(1, args.steps // 10),
        remat="full" if args.full else "none",
    )
    mesh = make_production_mesh() if args.full else make_host_mesh()
    rules = rules_for_mode(run.tp_mode)

    state = init_train_state(jax.random.key(args.seed), api, run)
    abstract = jax.eval_shape(lambda: state)
    axes = train_state_axes(api, run, abstract.params)
    shardings = param_sharding_for_tree(mesh, axes, rules, abstract)
    state = jax.device_put(state, shardings)

    step_fn = jax.jit(
        make_train_step(api, run, mesh=mesh),
        in_shardings=(shardings, None),
        out_shardings=(shardings, None),
        donate_argnums=(0,),
    )

    it = synthetic_token_batches(args.batch, args.seq, cfg.vocab_size, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    with mesh_context(mesh):
        for i in range(args.steps):
            host = add_modalities(next(it), cfg, rng)
            batch = make_global_batch(host, mesh)
            state, metrics = step_fn(state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                m = jax.device_get(metrics)
                print(
                    f"step {i:5d} loss={float(m['loss']):.4f} "
                    f"aux={float(m['aux_loss']):.4f} lr={float(m['lr']):.2e} "
                    f"({(time.time()-t0):.1f}s)",
                    flush=True,
                )
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps, state.params)
        print(f"saved params to {path}")


if __name__ == "__main__":
    main()
