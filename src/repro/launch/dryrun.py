import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import: jax locks the
# device count at first initialisation, and the production dry-run needs
# 512 placeholder host devices to build the 2x16x16 multi-pod mesh.

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import functools  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.compat import mesh_context  # noqa: E402
from repro.configs import (  # noqa: E402
    ARCH_IDS,
    INPUT_SHAPES,
    RunConfig,
    get_config,
    input_specs,
    shapes_for_arch,
)
from repro.configs.base import InputShape, ModelConfig  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_name  # noqa: E402
from repro.models.registry import build_model, rules_for_mode  # noqa: E402
from repro.models.unroll import scan_unroll  # noqa: E402
from repro.roofline.analysis import analyze_compiled  # noqa: E402
from repro.serve.engine import make_serve_step  # noqa: E402
from repro.sharding.partitioning import param_sharding_for_tree, spec_for_shape  # noqa: E402
from repro.train.step import init_train_state, make_train_step, train_state_axes  # noqa: E402


def run_config_for(cfg: ModelConfig, tp_mode: str, remat: str = "full") -> RunConfig:
    """Per-arch run settings: the >20B archs need the beyond-paper memory
    regime (adafactor + full remat); minicpm trains with WSD."""
    api = build_model(cfg)
    n_params = sum(
        x.size for x in jax.tree.leaves(jax.eval_shape(lambda: api.init(jax.random.key(0))))
    )
    big = n_params > 20e9
    return RunConfig(
        tp_mode=tp_mode,
        optimizer="adafactor" if big else "adam",
        remat=remat,
        schedule="wsd" if cfg.arch_id == "minicpm-2b" else "cosine",
        grad_accum=1,
    )


def _batch_logical_axes(specs: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in specs.items():
        if k in ("tokens", "labels"):
            out[k] = ("batch", None)
        elif k in ("patches", "frames"):
            out[k] = ("batch", None, None)
        else:
            raise KeyError(k)
    return out


def _shardings_for(mesh, rules, axes_tree, shape_tree):
    return param_sharding_for_tree(mesh, axes_tree, rules, shape_tree)


def lower_train(cfg: ModelConfig, shape: InputShape, mesh, tp_mode: str,
                remat: str = "full"):
    api = build_model(cfg)
    run = run_config_for(cfg, tp_mode, remat)
    rules = rules_for_mode(tp_mode)

    abstract_state = jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), api, run)
    )
    state_axes = train_state_axes(api, run, abstract_state.params)
    state_sh = _shardings_for(mesh, rules, state_axes, abstract_state)

    specs = input_specs(cfg, shape)
    batch_axes = _batch_logical_axes(specs)
    batch_sh = _shardings_for(mesh, rules, batch_axes, specs)

    train_step = make_train_step(api, run, mesh=mesh)
    jitted = jax.jit(
        train_step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
    with mesh_context(mesh):
        lowered = jitted.lower(abstract_state, specs)
        compiled = lowered.compile()
    return lowered, compiled


def lower_decode(cfg: ModelConfig, shape: InputShape, mesh, tp_mode: str):
    api = build_model(cfg)
    run = run_config_for(cfg, tp_mode)
    rules = rules_for_mode(tp_mode)

    abstract_params = jax.eval_shape(lambda: api.init(jax.random.key(0)))
    param_sh = _shardings_for(mesh, rules, api.param_axes(), abstract_params)

    specs = input_specs(cfg, shape)
    cache = specs["cache"]
    cache_sh = _shardings_for(mesh, rules, api.cache_axes(), cache)
    tok_sh = _shardings_for(
        mesh, rules, {"tokens": ("batch", None)}, {"tokens": specs["tokens"]}
    )["tokens"]

    serve_step = make_serve_step(api, run, mesh=mesh)

    def step(params, cache, tokens):
        nxt, logits, new_cache = serve_step(params, cache, tokens)
        return nxt, new_cache

    jitted = jax.jit(
        step,
        in_shardings=(param_sh, cache_sh, tok_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    )
    with mesh_context(mesh):
        lowered = jitted.lower(abstract_params, cache, specs["tokens"])
        compiled = lowered.compile()
    return lowered, compiled


def lower_prefill(cfg: ModelConfig, shape: InputShape, mesh, tp_mode: str):
    api = build_model(cfg)
    run = run_config_for(cfg, tp_mode)
    rules = rules_for_mode(tp_mode)

    abstract_params = jax.eval_shape(lambda: api.init(jax.random.key(0)))
    param_sh = _shardings_for(mesh, rules, api.param_axes(), abstract_params)

    specs = input_specs(cfg, shape)
    batch_axes = _batch_logical_axes(specs)
    batch_sh = _shardings_for(mesh, rules, batch_axes, specs)

    def prefill(params, batch):
        return api.prefill(params, batch, rules=rules, mesh=mesh, remat="dots")

    jitted = jax.jit(prefill, in_shardings=(param_sh, batch_sh))
    with mesh_context(mesh):
        lowered = jitted.lower(abstract_params, specs)
        compiled = lowered.compile()
    return lowered, compiled


def dryrun_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    tp_mode: str = "megatron",
    remat: str = "full",
    moe_dispatch: str = None,
    verbose: bool = True,
) -> dict:
    """Lower + compile one (arch x shape x mesh x mode); return the record
    (roofline terms, memory analysis, timings)."""
    cfg = get_config(arch)
    if moe_dispatch and cfg.moe is not None:
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, dispatch=moe_dispatch))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    t0 = time.time()
    # ROLLED layer scans: fast compiles and the true production artifact.
    # FLOPs/collectives are counted by roofline/hlo_parse.py, which weights
    # while bodies by their trip count (XLA's cost_analysis counts them
    # once); memory_analysis is only meaningful on the rolled module.
    if shape.kind == "train":
        lowered, compiled = lower_train(cfg, shape, mesh, tp_mode, remat)
    elif shape.kind == "prefill":
        lowered, compiled = lower_prefill(cfg, shape, mesh, tp_mode)
    else:
        lowered, compiled = lower_decode(cfg, shape, mesh, tp_mode)
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    report = analyze_compiled(
        compiled, cfg=cfg, shape=shape, mesh_name=mesh_name(mesh),
        tp_mode=tp_mode, chips=chips,
    )
    rec = report.to_dict()
    rec["remat"] = remat
    rec["moe_dispatch"] = moe_dispatch or (cfg.moe.dispatch if cfg.moe else None)
    rec["compile_s"] = compile_s
    rec["memory_analysis"] = {
        k: int(getattr(mem, k, 0))
        for k in (
            "temp_size_in_bytes",
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        )
    }
    per_dev_hbm = (
        rec["memory_analysis"]["temp_size_in_bytes"]
        + rec["memory_analysis"]["argument_size_in_bytes"]
    )
    rec["hbm_bytes_per_device"] = per_dev_hbm
    if verbose:
        print(report.row(), f"hbm/dev={per_dev_hbm/2**30:7.2f}GiB compile={compile_s:6.1f}s",
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="input shape or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--tp-mode", default="megatron", choices=["megatron", "gather", "fsdp", "zero1", "both"])
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--moe-dispatch", default=None, choices=["psum", "alltoall"])
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    modes = ["megatron", "gather"] if args.tp_mode == "both" else [args.tp_mode]

    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        allowed = shapes_for_arch(cfg)
        if args.shape == "all":
            shapes = allowed
        else:
            # respect the long_500k skip policy even with an explicit shape
            shapes = [args.shape] if args.shape in allowed else []
        for shape_name in shapes:
            for multi_pod in meshes:
                for mode in modes:
                    try:
                        rec = dryrun_one(
                            arch, shape_name, multi_pod=multi_pod, tp_mode=mode,
                            remat=args.remat, moe_dispatch=args.moe_dispatch,
                        )
                        n_ok += 1
                        if args.out:
                            with open(args.out, "a") as f:
                                f.write(json.dumps(rec) + "\n")
                    except Exception:
                        n_fail += 1
                        print(f"FAIL {arch} {shape_name} multi_pod={multi_pod} {mode}")
                        traceback.print_exc()
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
