"""Production mesh definition.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run must
set XLA_FLAGS before the first jax initialisation.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") —
the "pod" axis extends the batch/FSDP dimension across the DCN/ICI
boundary; "model" stays inside a pod (tensor/expert shards never cross
pods).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke tests (same axis names as production,
    sizes 1 — every sharding rule degenerates to replication)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
