from repro.sharding.axes import (
    LOGICAL_RULES_GATHER,
    LOGICAL_RULES_MEGATRON,
    AxisRules,
    logical_to_mesh_spec,
)
from repro.sharding.partitioning import (
    constrain,
    named_sharding,
    param_sharding_for_tree,
)

__all__ = [
    "AxisRules",
    "LOGICAL_RULES_GATHER",
    "LOGICAL_RULES_MEGATRON",
    "logical_to_mesh_spec",
    "constrain",
    "named_sharding",
    "param_sharding_for_tree",
]
