"""Helpers to apply logical-axis shardings to arrays and pytrees."""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.sharding.axes import AxisRules


def named_sharding(mesh: Mesh, spec: PartitionSpec) -> NamedSharding:
    return NamedSharding(mesh, spec)


def constrain(
    x: jax.Array, rules: AxisRules, *logical_axes: Optional[str]
) -> jax.Array:
    """with_sharding_constraint by logical axis names.

    Safe to call outside a mesh context (becomes a no-op) so that layer
    code runs unchanged in single-device tests.  Shape-aware: mesh axes
    that do not evenly divide the corresponding dim are dropped.
    """
    return constrain_shaped(x, rules, *logical_axes)


def filter_spec_for_mesh(mesh_axis_names: Sequence[str], spec: PartitionSpec) -> PartitionSpec:
    """Drop mesh axes not present on this mesh from a PartitionSpec."""
    names = set(mesh_axis_names)

    def _filter(entry):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    return PartitionSpec(*[_filter(e) for e in spec])


def spec_for_shape(
    rules: AxisRules,
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    mesh_axis_sizes: dict,
) -> PartitionSpec:
    """Shape-aware logical->mesh spec.

    Walks the dims of a concrete shape and maps each logical axis to its
    mesh axes, *dropping* any mesh axis that (a) is not on the mesh,
    (b) was already consumed by an earlier dim, or (c) does not evenly
    divide the dim size.  This keeps every spec GSPMD-legal for
    architectures whose head/expert/vocab counts do not divide the mesh
    (e.g. hymba's 25 heads, mixtral's 8 experts on a 16-way model axis).
    """
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    out = []
    seen: set = set()
    for dim, ax in zip(shape, logical_axes):
        if ax is None or ax not in rules.rules:
            out.append(None)
            continue
        mesh_ax = rules.rules[ax]
        if mesh_ax is None:
            out.append(None)
            continue
        cands = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
        kept = []
        prod = 1
        for m in cands:
            if m not in mesh_axis_sizes or m in seen:
                continue
            if dim % (prod * mesh_axis_sizes[m]) != 0:
                continue
            kept.append(m)
            prod *= mesh_axis_sizes[m]
        seen.update(kept)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def constrain_shaped(
    x: jax.Array, rules: AxisRules, *logical_axes: Optional[str]
) -> jax.Array:
    """Shape-aware with_sharding_constraint (divisibility-safe constrain)."""
    from repro.compat import get_active_mesh

    mesh = get_active_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    spec = spec_for_shape(rules, x.shape, logical_axes, sizes)
    return jax.lax.with_sharding_constraint(x, spec)


def param_sharding_for_tree(
    mesh: Mesh, logical_tree: Any, rules: AxisRules, shape_tree: Any = None
) -> Any:
    """Map a pytree of logical-axis tuples to NamedShardings.

    ``logical_tree`` mirrors the parameter pytree but holds tuples of
    logical axis names (or None) per array dim.  If ``shape_tree`` (a
    matching pytree of arrays / ShapeDtypeStructs) is given, specs are
    shape-aware (divisibility-checked).
    """
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    is_leaf = lambda x: isinstance(x, tuple) or x is None

    if shape_tree is None:
        def _one(axes):
            spec = rules.spec(*axes)
            spec = filter_spec_for_mesh(mesh.axis_names, spec)
            return NamedSharding(mesh, spec)

        return jax.tree.map(_one, logical_tree, is_leaf=is_leaf)

    def _one_shaped(axes, arr):
        axes = axes if axes is not None else (None,) * len(arr.shape)
        spec = spec_for_shape(rules, arr.shape, axes, sizes)
        return NamedSharding(mesh, spec)

    return jax.tree.map(_one_shaped, logical_tree, shape_tree, is_leaf=is_leaf)


def constrain_logical_tree(tree: Any, rules: AxisRules, axes_tree: Any) -> Any:
    """with_sharding_constraint over a pytree guided by a logical-axes
    tree (tuple leaves).  Used to pin gradient shardings to the parameter
    layout so GSPMD reduce-scatters instead of all-reducing."""
    is_leaf = lambda n: isinstance(n, tuple) or n is None

    def one(axes, x):
        axes = axes if axes is not None else (None,) * x.ndim
        return constrain_shaped(x, rules, *axes)

    return jax.tree.map(one, axes_tree, tree, is_leaf=is_leaf)
