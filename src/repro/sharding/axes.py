"""Logical-axis -> mesh-axis rule system (MaxText-style).

Every array in the framework is annotated with *logical* axis names
("batch", "embed", "heads", "mlp", "experts", ...).  A rule table maps each
logical axis to zero or more physical mesh axes.  Two rule tables are
shipped:

* ``LOGICAL_RULES_GATHER`` — the *paper-faithful* scheme: weights of the
  compute-dominant layer are sharded along their output-feature axis, all
  activations are replicated (the "master gathers every layer output"
  protocol of Algorithms 1 & 2 expressed as GSPMD shardings).

* ``LOGICAL_RULES_MEGATRON`` — the beyond-paper optimised scheme:
  column/row-parallel pairing plus sequence-parallel activations and FSDP
  parameter sharding along the data axis.

The distinction is the framework's main §Perf lever, see EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple, Union

from jax.sharding import PartitionSpec

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Immutable logical->mesh axis mapping."""

    rules: Mapping[str, MeshAxes]
    name: str = "custom"

    def spec(self, *logical_axes: Optional[str]) -> PartitionSpec:
        """Build a PartitionSpec for an array whose dims carry the given
        logical names (``None`` = unsharded dim)."""
        out = []
        seen: set = set()
        for ax in logical_axes:
            if ax is None:
                out.append(None)
                continue
            if ax not in self.rules:
                out.append(None)
                continue
            mesh_ax = self.rules[ax]
            # A mesh axis may be consumed at most once per spec; later
            # logical axes that map to an already-used mesh axis fall back
            # to replication (GSPMD requirement).
            if mesh_ax is None:
                out.append(None)
            elif isinstance(mesh_ax, tuple):
                free = tuple(m for m in mesh_ax if m not in seen)
                seen.update(free)
                out.append(free if free else None)
            else:
                if mesh_ax in seen:
                    out.append(None)
                else:
                    seen.add(mesh_ax)
                    out.append(mesh_ax)
        # Trim trailing Nones (canonical form).
        while out and out[-1] is None:
            out.pop()
        return PartitionSpec(*out)

    def replace(self, **updates: MeshAxes) -> "AxisRules":
        new = dict(self.rules)
        new.update(updates)
        return AxisRules(rules=new, name=self.name + "+")


def _rules(d: Mapping[str, MeshAxes], name: str) -> AxisRules:
    return AxisRules(rules=dict(d), name=name)


# Logical axes used across the framework:
#   batch         global batch dim of activations
#   seq           sequence dim of activations
#   embed         d_model dim of activations / weights
#   heads         attention query-head dim
#   kv_heads      attention kv-head dim
#   head_dim      per-head feature dim
#   mlp           FFN hidden dim
#   vocab         vocabulary dim
#   experts       MoE expert dim
#   expert_mlp    per-expert FFN hidden dim
#   ssm_heads     mamba head dim
#   ssm_state     mamba state dim (never sharded)
#   conv_out      conv output-channel dim (the paper's kernel axis)
#   conv_in       conv input-channel dim
#   layers        stacked-layer dim of scanned params (never sharded)
#   fsdp_embed    embed dim of *parameters* when FSDP shards them on data

# Paper-faithful ("gather"): the weights of each compute-dominant matmul
# (the "kernel sets") are sharded along their *output-feature* axis over
# `model`; the matmul runs sharded ("slaves convolve their kernels"); its
# output is immediately all-gathered ("the master receives all feature
# maps", Alg. 1 l.19-22); every downstream op runs replicated (= the
# master computing the rest of the network serially -- the Amdahl
# bottleneck the paper reports).  The batch dim stays sharded over
# pod/data, matching the paper keeping the batch local.
#
# Axis pairs:  "act_*_col" pins the layout right after the column matmul
# (sharded in BOTH modes -- the distributed compute); "act_*" pins the
# layout handed to downstream ops (gather mode: None => forced all-gather;
# megatron mode: "model" => stays sharded, consumed row-parallel).
LOGICAL_RULES_GATHER = _rules(
    {
        "batch": ("pod", "data"),
        "seq": None,
        "embed": None,
        "act_embed": None,       # residual stream: replicated (master-held)
        "act_seq": None,
        "act_mlp_col": "model",  # column-matmul output: sharded...
        "act_mlp": None,         # ...then gathered (paper's Alg.1 gather)
        "act_heads_col": "model",
        "act_heads": None,
        "heads": "model",        # weight out-feature axes: the kernel shards
        "kv_heads": "model",
        "head_dim": None,
        "cache_seq": None,       # decode cache held replicated (master)
        "heads_in": None,        # wo consumed replicated (master computes it)
        "mlp": "model",
        "mlp_in": None,          # w_out consumed replicated
        "vocab": None,           # FC/loss layers on the master: replicated
        "experts": "model",
        "expert_mlp": None,
        "ssm_heads": "model",
        "ssm_inner": "model",
        "ssm_state": None,
        "conv_out": "model",
        "conv_in": None,
        "act_conv_col": "model",
        "act_conv": None,        # feature maps gathered to the master
        "layers": None,
        "fsdp_embed": None,      # no FSDP in the faithful scheme
        "opt_embed": None,
    },
    name="gather",
)

# Beyond-paper ("megatron"): column->row parallel pairing (one all-reduce/
# reduce-scatter per sublayer instead of two all-gathers), sequence-
# parallel residual stream, FSDP parameter sharding over pod/data, and a
# model-sharded vocab/logits head.
LOGICAL_RULES_MEGATRON = _rules(
    {
        "batch": ("pod", "data"),
        "seq": None,
        "embed": None,
        "act_embed": None,
        "act_seq": "model",      # sequence-parallel residual stream
        "act_mlp_col": "model",
        "act_mlp": "model",      # stays sharded -> row-parallel w_out
        "act_heads_col": "model",
        "act_heads": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "cache_seq": "model",
        "heads_in": "model",     # wo row-parallel
        "mlp": "model",
        "mlp_in": "model",       # w_out row-parallel
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "ssm_heads": "model",
        "ssm_inner": "model",
        "ssm_state": None,
        "conv_out": "model",
        "conv_in": None,
        "act_conv_col": "model",
        "act_conv": "model",     # feature maps stay channel-sharded
        "layers": None,
        "fsdp_embed": ("pod", "data"),  # ZeRO-3 style param sharding
        "opt_embed": ("pod", "data"),
    },
    name="megatron",
)


# Beyond-paper ("fsdp"): NO tensor parallelism — the model axis is folded
# into the batch/FSDP dimension (512-way data parallel + ZeRO-3).  For
# models whose per-layer weights fit one chip (<~7B dense) this removes
# every activation collective; the only comm left is the per-layer
# parameter all-gather + gradient reduce-scatter.  The SS Perf lever for
# collective-bound small-dense pairs (yi-6b, minicpm-2b).
LOGICAL_RULES_FSDP = _rules(
    {
        "batch": ("pod", "data", "model"),
        "seq": None,
        "embed": None,
        "act_embed": None,
        "act_seq": None,
        "act_mlp_col": None,
        "act_mlp": None,
        "act_heads_col": None,
        "act_heads": None,
        "heads": None,
        "kv_heads": None,
        "head_dim": None,
        "cache_seq": "model",    # decode cache slots sharded over model
        "heads_in": None,
        "mlp": None,
        "mlp_in": None,
        "vocab": None,
        "experts": "model",      # MoE still needs expert parallelism
        "expert_mlp": None,
        "ssm_heads": None,
        "ssm_inner": None,
        "ssm_state": None,
        "conv_out": None,
        "conv_in": None,
        "act_conv_col": None,
        "act_conv": None,
        "layers": None,
        "fsdp_embed": ("pod", "data", "model"),  # ZeRO-3 over every chip
        "opt_embed": ("pod", "data", "model"),
    },
    name="fsdp",
)

# Beyond-paper ("zero1"): parameters REPLICATED (no per-layer all-gather
# at all), optimizer state sharded over every chip.  For dense models
# whose bf16 params fit HBM (<~7B) this leaves only the gradient
# reduction as communication — the cheapest schedule on the menu.
LOGICAL_RULES_ZERO1 = AxisRules(
    rules={**LOGICAL_RULES_FSDP.rules,
           "fsdp_embed": None,
           "opt_embed": ("pod", "data", "model")},
    name="zero1",
)


def logical_to_mesh_spec(
    rules: AxisRules, logical_axes: Sequence[Optional[str]]
) -> PartitionSpec:
    return rules.spec(*logical_axes)
