"""Post-SPMD HLO text analyzer with while-loop trip-count weighting.

Why not ``compiled.cost_analysis()``: XLA counts a ``while`` body ONCE
(not x trip count), so a scanned-over-layers model under-reports FLOPs
and collectives by ~num_layers; and the CPU backend reports un-fused
"bytes accessed" (every op's operands+outputs), inflating the memory term
~20x vs what a fused TPU executable touches in HBM.

This parser walks the scheduled module instead:

* computations are parsed into per-instruction records with a local
  symbol table (every ``%name = type[...] op(...)`` line);
* ``while`` trip counts come from the integer constant in the loop's
  condition computation (scan lengths are compile-time constants);
* cost(comp) = own dots/collectives + called computations (fusion/call),
  with while bodies multiplied by their trip count — memoized;
* FLOPs: 2 x |result| x |contracted dims| per dot (batch dims are already
  in the result product);
* memory bytes (fused estimate): dot operands+results + collective
  payloads + entry arguments + entry outputs — elementwise chains are
  assumed fused (free), matching TPU executables;
* collective payload per device: all-gather = result; all-reduce =
  2 x result (RS+AG phases); reduce-scatter = result x group_size;
  all-to-all / collective-permute = result.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_SHAPE_RE = re.compile(r"^\(?\s*(\w+)\[([\d,]*)\]")
_TUPLE_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"\}?\s*([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_CALLS_RE = re.compile(r"(?:calls|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_size_bytes(type_txt: str) -> int:
    """Bytes of a (possibly tuple) result type string."""
    total = 0
    for m in _TUPLE_SHAPE_RE.finditer(type_txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_txt: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.match(type_txt.strip())
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    type_txt: str
    op: str
    rest: str  # everything after '=' (for attribute parsing)


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr]
    symbols: Dict[str, str]  # instr name -> type text


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                cur = Computation(
                    name=m.group(2), is_entry=bool(m.group(1)), instrs=[], symbols={}
                )
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        type_txt, op, rem = _split_type_op(rhs)
        cur.instrs.append(Instr(name=name, type_txt=type_txt, op=op, rest=rem))
        cur.symbols[name] = type_txt
    return comps


def _split_type_op(rhs: str):
    """Split '<type> <op>(<operands>), attrs' — the type may be a
    parenthesized tuple, and layouts may contain nested parens/braces."""
    i = len(rhs)
    depth = 0
    for j, ch in enumerate(rhs):
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        elif ch == " " and depth == 0:
            i = j
            break
    # a tuple type "(a, b)" begins with '(' and ends when depth returns to 0
    if rhs.startswith("("):
        depth = 0
        for j, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    i = j + 1
                    break
    type_txt = rhs[:i]
    rem = rhs[i:].lstrip()
    m = re.match(r"([\w\-]+)\(", rem)
    return type_txt, (m.group(1) if m else ""), rem


def _trip_count(cond: Computation) -> int:
    """Max integer constant in the condition computation ~ trip count."""
    best = 1
    for ins in cond.instrs:
        for m in _CONST_RE.finditer(ins.rest):
            best = max(best, int(m.group(1)))
    return best


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_EXPLICIT_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    return default


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    memory_bytes: float = 0.0
    collective_bytes: float = 0.0
    by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS}
    )

    def __iadd__(self, o: "HloCost"):
        self.flops += o.flops
        self.memory_bytes += o.memory_bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.by_kind.items():
            self.by_kind[k] += v
        return self

    def scaled(self, f: float) -> "HloCost":
        return HloCost(
            flops=self.flops * f,
            memory_bytes=self.memory_bytes * f,
            collective_bytes=self.collective_bytes * f,
            by_kind={k: v * f for k, v in self.by_kind.items()},
        )


def _operand_names(rest: str) -> List[str]:
    m = _OPERANDS_RE.search(rest[rest.find("("):] if "(" in rest else rest)
    if not m:
        return []
    return re.findall(r"%([\w.\-]+)", m.group(1))


def analyze_hlo(text: str, *, num_partitions: int = 1) -> HloCost:
    comps = parse_computations(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # pragma: no cover
        raise ValueError("no ENTRY computation found")
    memo: Dict[str, HloCost] = {}

    def cost_of(comp: Computation) -> HloCost:
        if comp.name in memo:
            return memo[comp.name]
        total = HloCost()
        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                _, rdims = _shape_dims(ins.type_txt)
                rbytes = _shape_size_bytes(ins.type_txt)
                import numpy as _np

                rsize = float(_np.prod(rdims)) if rdims else 1.0
                # contraction size from lhs shape + contracting dims
                ops = _operand_names(ins.rest)
                csize = 1.0
                cm = _CONTRACT_RE.search(ins.rest)
                lhs_bytes = rhs_bytes = 0.0
                if ops:
                    lhs_t = comp.symbols.get(ops[0], "")
                    _, ldims = _shape_dims(lhs_t)
                    lhs_bytes = _shape_size_bytes(lhs_t)
                    if cm and ldims:
                        for d in cm.group(1).split(","):
                            if d and int(d) < len(ldims):
                                csize *= ldims[int(d)]
                if len(ops) > 1:
                    rhs_t = comp.symbols.get(ops[1], "")
                    rhs_bytes = _shape_size_bytes(rhs_t)
                total.flops += 2.0 * rsize * csize
                total.memory_bytes += rbytes + lhs_bytes + rhs_bytes
            elif op == "convolution":
                _, rdims = _shape_dims(ins.type_txt)
                import numpy as _np

                rsize = float(_np.prod(rdims)) if rdims else 1.0
                ops = _operand_names(ins.rest)
                ksize = 1.0
                if len(ops) > 1:
                    _, kdims = _shape_dims(comp.symbols.get(ops[1], ""))
                    if len(kdims) >= 3:
                        ksize = float(_np.prod(kdims[:-1]))  # kh*kw*cin
                total.flops += 2.0 * rsize * ksize
                total.memory_bytes += _shape_size_bytes(ins.type_txt)
            elif any(op.startswith(k) for k in COLLECTIVE_KINDS):
                kind = next(k for k in COLLECTIVE_KINDS if op.startswith(k))
                if op.endswith("-done"):
                    continue
                rbytes = _shape_size_bytes(ins.type_txt)
                if kind == "all-gather":
                    payload = rbytes
                elif kind == "all-reduce":
                    payload = 2.0 * rbytes
                elif kind == "reduce-scatter":
                    payload = rbytes * _group_size(ins.rest, num_partitions)
                else:
                    payload = rbytes
                total.by_kind[kind] += payload
                total.collective_bytes += payload
                total.memory_bytes += rbytes
            if op == "while":
                body_m = _CALLS_RE.search(ins.rest)
                cond_m = _COND_RE.search(ins.rest)
                if body_m and body_m.group(1) in comps:
                    trips = 1
                    if cond_m and cond_m.group(1) in comps:
                        trips = _trip_count(comps[cond_m.group(1)])
                    total += cost_of(comps[body_m.group(1)]).scaled(trips)
            elif op in ("fusion", "call", "conditional", "custom-call"):
                for m in re.finditer(r"(?:calls|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?", ins.rest):
                    for name in re.findall(r"[\w.\-]+", m.group(1)):
                        if name in comps:
                            total += cost_of(comps[name])
        memo[comp.name] = total
        return total

    total = cost_of(entry)
    # entry argument + result traffic (params read, outputs written)
    for ins in entry.instrs:
        if ins.op == "parameter":
            total.memory_bytes += _shape_size_bytes(ins.type_txt)
    # outputs: ROOT instruction result size
    root = entry.instrs[-1] if entry.instrs else None
    if root is not None:
        total.memory_bytes += _shape_size_bytes(root.type_txt)
    return total
