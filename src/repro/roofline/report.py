"""Aggregate dry-run JSONL records into the EXPERIMENTS.md tables."""
from __future__ import annotations

import argparse
import json
from typing import Dict, List


def load(path: str) -> List[dict]:
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x >= 0.01:
        return f"{x:.2f}"
    return f"{x:.1e}"


def markdown_table(recs: List[dict]) -> str:
    header = (
        "| arch | shape | C (s) | M (s) | X (s) | dominant | useful | "
        "mfu<= | HBM/dev | note |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for r in recs:
        hbm = r["hbm_bytes_per_device"] / 2 ** 30
        fits = "" if hbm <= 16 else "**>16G**"
        rows.append(
            f"| {r['arch_id']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.1%} | "
            f"{r['mfu_upper_bound']:.1%} | {hbm:.1f}G | {fits} |"
        )
    return header + "\n".join(rows) + "\n"


def one_liner_per_pair(recs: List[dict]) -> str:
    """The required 'what would move the dominant term down' sentence."""
    out = []
    for r in recs:
        dom = r["dominant"]
        if dom == "collective":
            kinds = r["collective_breakdown"]
            top = max(kinds, key=kinds.get)
            hint = {
                "all-gather": "keep activations sharded through the tail "
                "(megatron pairing) or reduce TP degree for this size",
                "all-reduce": "replace the gather+replicated-tail with a "
                "row-parallel reduce-scatter, or fold model into the data axis",
                "reduce-scatter": "already paired; next lever is TP degree",
                "all-to-all": "larger expert capacity granularity / fewer "
                "expert shards per token batch",
                "collective-permute": "reorder the mesh so the sharded axis "
                "is ICI-contiguous",
            }.get(top, "reduce TP degree")
            out.append(f"- {r['arch_id']}/{r['shape']}: collective-bound "
                       f"({top}); {hint}.")
        elif dom == "memory":
            out.append(
                f"- {r['arch_id']}/{r['shape']}: memory-bound; shard the "
                "dominant resident tensor further (FSDP the params/opt state, "
                "shard the KV cache over batch/heads) or raise arithmetic "
                "intensity (fuse, larger per-device batch)."
            )
        else:
            out.append(
                f"- {r['arch_id']}/{r['shape']}: compute-bound; reduce "
                "redundant FLOPs (remat policy, replicated tail) — "
                f"useful ratio {r['useful_flops_ratio']:.1%}."
            )
    return "\n".join(out) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl")
    ap.add_argument("--hints", action="store_true")
    args = ap.parse_args()
    recs = load(args.jsonl)
    print(markdown_table(recs))
    if args.hints:
        print(one_liner_per_pair(recs))


if __name__ == "__main__":
    main()
