from repro.roofline.analysis import (  # noqa: F401
    HW,
    RooflineReport,
    analyze_compiled,
    model_flops,
    parse_collective_bytes,
)
