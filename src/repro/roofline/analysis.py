"""Three-term roofline analysis from the compiled dry-run artifact.

    compute term    = HLO_FLOPs   / (chips x peak_FLOP/s)
    memory term     = HLO_bytes   / (chips x HBM_bw)
    collective term = coll_bytes  / (chips x link_bw)

``cost_analysis()`` yields per-device FLOPs/bytes of the post-SPMD module
(global = per-device x chips, so the division by chips cancels — both
views are reported).  Collective bytes are NOT in cost_analysis: we parse
the post-SPMD HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, weighted
by the op's ring-traffic factor.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import InputShape, ModelConfig


@dataclasses.dataclass(frozen=True)
class HWSpec:
    peak_flops: float = 197e12   # bf16 FLOP/s per chip
    hbm_bw: float = 819e9        # bytes/s per chip
    ici_bw: float = 50e9         # bytes/s per link


HW = HWSpec()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# Ring traffic per device ~ (n-1)/n x payload ~ payload for large rings.
# Payload source per op: the *larger* side of the transfer —
#   all-gather: the gathered RESULT; reduce-scatter/all-to-all/permute:
#   the full OPERAND; all-reduce: 2 x operand (RS + AG phases).
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+([a-z0-9\[\],{}() ]*?)\s*(all-gather|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute)(-start|-done)?\(([^)]*)\)",
)


def _shape_bytes(txt: str) -> int:
    """Sum byte sizes of every dtype[shape] group in ``txt`` (handles
    tuple-shaped results of variadic collectives)."""
    total = 0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Per-device collective traffic (bytes) from post-SPMD HLO text.
    Returns (total, breakdown by op kind)."""
    by_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind, suffix = m.group(2), m.group(3)
        if suffix == "-done":  # async pair: count only the -start
            continue
        result_txt, operand_txt = m.group(1), m.group(4)
        if kind == "all-gather":
            payload = _shape_bytes(result_txt)
            if suffix == "-start":
                # -start result is the (operand, output) tuple
                payload -= _shape_bytes(operand_txt)
        elif kind == "all-reduce":
            payload = 2 * _shape_bytes(operand_txt)
        else:
            payload = _shape_bytes(operand_txt)
        by_kind[kind] += payload
    return sum(by_kind.values()), by_kind


@dataclasses.dataclass
class RooflineReport:
    arch_id: str
    shape: str
    mesh: str
    tp_mode: str
    chips: int
    # per-device quantities from the compiled module
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, float]
    # three terms in seconds
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    # "useful compute" accounting
    model_flops: float = 0.0
    bytes_per_device_peak: float = 0.0  # from memory_analysis (HBM footprint)

    def __post_init__(self):
        self.compute_s = self.flops_per_device / HW.peak_flops
        self.memory_s = self.bytes_per_device / HW.hbm_bw
        self.collective_s = self.collective_bytes_per_device / HW.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs — catches remat/redundant
        compute (gather-mode replication shows up here)."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_upper_bound(self) -> float:
        """MODEL_FLOPS / (chips x peak x bound_s): the MFU this config
        could at best reach if the dominant term were perfectly hidden."""
        denom = self.chips * HW.peak_flops * self.bound_s
        return self.model_flops / denom if denom else 0.0

    def row(self) -> str:
        return (
            f"{self.arch_id:24s} {self.shape:12s} {self.mesh:9s} {self.tp_mode:8s} "
            f"C={self.compute_s:9.3e} M={self.memory_s:9.3e} "
            f"X={self.collective_s:9.3e} dom={self.dominant:10s} "
            f"useful={self.useful_flops_ratio:6.1%} mfu<={self.mfu_upper_bound:6.1%}"
        )

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            compute_s=self.compute_s, memory_s=self.memory_s,
            collective_s=self.collective_s, dominant=self.dominant,
            useful_flops_ratio=self.useful_flops_ratio,
            mfu_upper_bound=self.mfu_upper_bound,
        )
        return d


def active_params(cfg: ModelConfig) -> float:
    """Parameter count touched per token (MoE: top-k experts only)."""
    import jax

    from repro.models.registry import build_model

    api = build_model(cfg)
    abstract = jax.eval_shape(lambda: api.init(jax.random.key(0)))
    flat = jax.tree_util.tree_flatten_with_path(abstract)[0]
    total = 0.0
    for path, leaf in flat:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        size = float(np.prod(leaf.shape))
        if cfg.moe is not None and any("moe" == k for k in keys) and any(
            k in ("w_in", "w_gate", "w_out") for k in keys
        ):
            size *= cfg.moe.experts_per_token / cfg.moe.num_experts
        total += size
    return total


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """The 6·N·D / 2·N·D "useful model FLOPs" yardstick (N = active
    params, D = tokens processed).  train: fwd+bwd = 6·N·D; prefill:
    2·N·D; decode: 2·N·B (one token per sequence)."""
    n = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        if cfg.audio is not None:
            d += shape.global_batch * cfg.audio.num_frames
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one new token


def analyze_compiled(
    compiled,
    *,
    cfg: ModelConfig,
    shape: InputShape,
    mesh_name: str,
    tp_mode: str,
    chips: int,
    hlo_text: Optional[str] = None,
) -> RooflineReport:
    from repro.roofline.hlo_parse import analyze_hlo

    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = analyze_hlo(text, num_partitions=chips)
    flops = hc.flops
    byts = hc.memory_bytes
    coll, breakdown = hc.collective_bytes, dict(hc.by_kind)
    try:
        mem = compiled.memory_analysis()
        peak = float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        )
    except Exception:
        peak = 0.0
    return RooflineReport(
        arch_id=cfg.arch_id,
        shape=shape.name,
        mesh=mesh_name,
        tp_mode=tp_mode,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=coll,
        collective_breakdown=breakdown,
        model_flops=model_flops(cfg, shape),
        bytes_per_device_peak=peak,
    )
