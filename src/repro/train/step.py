"""Train-step factory: loss + grad + optimizer update, with gradient
accumulation (lax.scan over microbatches), global-norm clipping, and the
remat policy threaded into the model forward.

The returned ``train_step(state, batch)`` is what launch/dryrun.py lowers
for every (architecture x input shape) on the production mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.registry import ModelApi, rules_for_mode
from repro.sharding.partitioning import constrain_logical_tree
from repro.optim.optimizers import make_optimizer, optimizer_state_axes
from repro.optim.schedule import make_schedule
from repro.train.loss import softmax_cross_entropy


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: jax.Array
    params: Any
    opt_state: Any


def init_train_state(key, api: ModelApi, run: RunConfig) -> TrainState:
    params = api.init(key)
    opt = make_optimizer(run.optimizer, weight_decay=run.weight_decay)
    return TrainState(
        step=jnp.zeros((), jnp.int32), params=params, opt_state=opt.init(params)
    )


def train_state_axes(api: ModelApi, run: RunConfig, abstract_params) -> TrainState:
    """Logical-axes pytree matching TrainState (for the launcher)."""
    p_axes = api.param_axes()
    return TrainState(
        step=None,
        params=p_axes,
        opt_state=optimizer_state_axes(run.optimizer, p_axes, abstract_params),
    )


def _clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def make_train_step(
    api: ModelApi,
    run: RunConfig,
    *,
    mesh=None,
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict[str, jax.Array]]]:
    """Build the jittable train step for this model + run config.

    ``batch``: {"tokens": (B, S) int32, "labels": (B, S) int32, + optional
    modality inputs ("patches" / "frames")}.
    """
    rules = rules_for_mode(run.tp_mode)
    opt = make_optimizer(run.optimizer, weight_decay=run.weight_decay)
    schedule = make_schedule(
        run.schedule,
        learning_rate=run.learning_rate,
        warmup_steps=run.warmup_steps,
        total_steps=run.total_steps,
    )

    def loss_fn(params, micro):
        logits, aux = api.forward(
            params, micro, rules=rules, mesh=mesh, remat=run.remat
        )
        loss = softmax_cross_entropy(logits, micro["labels"])
        return loss + aux, (loss, aux)

    grad_fn = jax.grad(loss_fn, has_aux=True)

    def microbatch_split(batch):
        n = run.grad_accum
        def split(x):
            b = x.shape[0]
            assert b % n == 0, (b, n)
            return x.reshape(n, b // n, *x.shape[1:])
        return jax.tree.map(split, batch)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, jax.Array]]:
        if run.grad_accum > 1:
            micros = microbatch_split(batch)

            def accum(carry, micro):
                g_acc, l_acc, a_acc = carry
                g, (l, a) = grad_fn(state.params, micro)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l, a_acc + a), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (grads, loss, aux), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros(()), jnp.zeros(())), micros
            )
            inv = 1.0 / run.grad_accum
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss, aux = loss * inv, aux * inv
        else:
            grads, (loss, aux) = grad_fn(state.params, batch)

        # pin gradient layout to the parameter sharding: GSPMD then emits
        # a reduce-scatter for FSDP gradients instead of an all-reduce
        # (half the ring traffic) — SS Perf iteration B2
        grads = constrain_logical_tree(grads, rules, api.param_axes())

        metrics = {"loss": loss, "aux_loss": aux}
        if run.max_grad_norm is not None:
            grads, gnorm = _clip_by_global_norm(grads, run.max_grad_norm)
            metrics["grad_norm"] = gnorm
        lr = schedule(state.step)
        metrics["lr"] = lr
        new_params, new_opt = opt.update(grads, state.opt_state, state.params, lr)
        return TrainState(step=state.step + 1, params=new_params, opt_state=new_opt), metrics

    return train_step
