"""Loss functions."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def softmax_cross_entropy(
    logits: jax.Array,
    labels: jax.Array,
    *,
    mask: Optional[jax.Array] = None,
    z_loss: float = 0.0,
) -> jax.Array:
    """Mean next-token cross-entropy.  logits (B, S, V) fp-any; labels
    (B, S) int32.  ``z_loss`` adds the log-normaliser penalty (stabilises
    large-vocab training; used by the 340B run config).

    Computed in fp32 with the gather trick (no (B,S,V) one-hot), which
    keeps the sharded-vocab case a single cross-shard gather.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)  # (B, S)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is not None:
        denom = jnp.maximum(mask.sum(), 1.0)
        return jnp.sum(nll * mask) / denom
    return jnp.mean(nll)
