from repro.train.loss import softmax_cross_entropy  # noqa: F401
from repro.train.step import TrainState, make_train_step, init_train_state  # noqa: F401
