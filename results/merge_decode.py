"""Merge the post-iteration-D decode/long re-runs into the sweep JSONLs
(replace matching (arch, shape) records), then re-splice EXPERIMENTS.md."""
import json
import subprocess
import sys

PAIRS = [
    ("results/redo_decode_gather_single.jsonl", "results/dryrun_gather_single.jsonl"),
    ("results/redo_decode_megatron_single.jsonl", "results/dryrun_megatron_single.jsonl"),
    ("results/redo_decode_fsdp_single.jsonl", "results/dryrun_fsdp_single.jsonl"),
    ("results/redo_decode_gather_multi.jsonl", "results/dryrun_gather_multi.jsonl"),
    ("results/redo_decode_megatron_multi.jsonl", "results/dryrun_megatron_multi.jsonl"),
]


def load(path):
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


for redo_path, sweep_path in PAIRS:
    redo = {(r["arch_id"], r["shape"]): r for r in load(redo_path)}
    out = []
    for r in load(sweep_path):
        out.append(redo.pop((r["arch_id"], r["shape"]), r))
    out.extend(redo.values())
    with open(sweep_path, "w") as f:
        for r in out:
            f.write(json.dumps(r) + "\n")
    print(f"merged {redo_path} -> {sweep_path} ({len(out)} records)")

subprocess.run([sys.executable, "results/splice_tables.py"], check=True)
