"""Splice the generated roofline tables into EXPERIMENTS.md."""
import subprocess
import sys

MARK = "<!-- ROOFLINE TABLES SPLICED HERE BY results/splice_tables.py -->"

SECTIONS = [
    ("### gather (paper-faithful), 16x16", "results/dryrun_gather_single.jsonl"),
    ("### megatron (optimised), 16x16", "results/dryrun_megatron_single.jsonl"),
    ("### fsdp (beyond-paper), 16x16", "results/dryrun_fsdp_single.jsonl"),
    ("### gather, 2x16x16 multi-pod", "results/dryrun_gather_multi.jsonl"),
    ("### megatron, 2x16x16 multi-pod", "results/dryrun_megatron_multi.jsonl"),
]


def main():
    blocks = [MARK]
    for title, path in SECTIONS:
        out = subprocess.run(
            [sys.executable, "-m", "repro.roofline.report", path],
            capture_output=True, text=True, check=True,
        ).stdout
        blocks.append(f"{title}\n\n{out.strip()}\n")
    # per-pair "what would move the dominant term down" (megatron table)
    hints = subprocess.run(
        [sys.executable, "-m", "repro.roofline.report",
         "results/dryrun_megatron_single.jsonl", "--hints"],
        capture_output=True, text=True, check=True,
    ).stdout.split("\n\n", 1)[1]
    blocks.append("### What would move each dominant term down (megatron table)\n\n"
                  + hints.strip() + "\n")

    text = open("EXPERIMENTS.md").read()
    start = text.index(MARK)
    end = text.index("### Reading the baselines")
    new = text[:start] + "\n\n".join(blocks) + "\n\n" + text[end:]
    open("EXPERIMENTS.md", "w").write(new)
    print("spliced", len(blocks) - 1, "tables")


if __name__ == "__main__":
    main()
