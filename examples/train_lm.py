"""End-to-end driver: train a ~100M-parameter decoder-only LM for a few
hundred steps on the synthetic bigram task, with checkpointing and
eval — the (b) deliverable's training end of the spectrum.

The config is a scaled-down yi-style dense transformer (~100M params);
the same script trains any ``--arch`` at reduced scale.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import restore_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig, RunConfig
from repro.data.pipeline import synthetic_token_batches
from repro.models.registry import build_model, rules_for_mode
from repro.train.loss import softmax_cross_entropy
from repro.train.step import init_train_state, make_train_step

LM_100M = ModelConfig(
    arch_id="lm-100m", family="dense", num_layers=12, d_model=640,
    num_heads=10, num_kv_heads=5, d_ff=2560, vocab_size=8192,
    head_dim=64, dtype="float32", param_dtype="float32",
    source="scaled-down yi-6b [arXiv:2403.04652]",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = LM_100M
    api = build_model(cfg)
    n_params = sum(
        x.size for x in jax.tree.leaves(jax.eval_shape(lambda: api.init(jax.random.key(0))))
    )
    print(f"{cfg.arch_id}: {n_params/1e6:.1f}M params")

    run = RunConfig(
        optimizer="adam", learning_rate=args.lr, schedule="cosine",
        warmup_steps=args.steps // 20, total_steps=args.steps,
        remat="none", grad_accum=1, tp_mode="megatron",
    )
    state = init_train_state(jax.random.key(0), api, run)
    step = jax.jit(make_train_step(api, run), donate_argnums=(0,))

    train_it = synthetic_token_batches(args.batch, args.seq, cfg.vocab_size, seed=0)
    # held-out samples from the SAME task (same bigram permutation)
    eval_it = synthetic_token_batches(
        args.batch, args.seq, cfg.vocab_size, seed=0, stream_seed=999
    )
    rules = rules_for_mode(run.tp_mode)

    @jax.jit
    def eval_loss(params, batch):
        logits, _ = api.forward(params, batch, rules=rules)
        return softmax_cross_entropy(logits, batch["labels"])

    t0 = time.time()
    tokens_seen = 0
    first_loss = None
    for i in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in next(train_it).items()}
        state, m = step(state, b)
        tokens_seen += args.batch * args.seq
        if i % 25 == 0 or i == args.steps - 1:
            eb = {k: jnp.asarray(v) for k, v in next(eval_it).items()}
            ev = float(eval_loss(state.params, eb))
            first_loss = first_loss if first_loss is not None else float(m["loss"])
            tps = tokens_seen / (time.time() - t0)
            print(
                f"step {i:4d} train={float(m['loss']):.3f} eval={ev:.3f} "
                f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f} "
                f"({tps:,.0f} tok/s)", flush=True,
            )
    path = save_checkpoint(args.ckpt_dir, args.steps, state.params)
    print(f"checkpoint -> {path}")

    restored = restore_checkpoint(args.ckpt_dir)
    eb = {k: jnp.asarray(v) for k, v in next(eval_it).items()}
    ev = float(eval_loss(restored, eb))
    print(f"restored-checkpoint eval loss {ev:.3f}")
    assert ev < first_loss - 1.0, "model did not learn"
    print("OK: loss dropped by "
          f"{first_loss - ev:.2f} nats over {args.steps} steps.")


if __name__ == "__main__":
    main()
