"""Quickstart: train the paper's CIFAR-10 CNN, first locally, then with
the convolutional layers distributed over an emulated heterogeneous
cluster (Algorithms 1 & 2) — verifying identical losses, i.e. the
paper's claim that distribution does not affect classification
performance.

    PYTHONPATH=src python examples/quickstart.py [--steps 30]
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.master_slave import HeteroCluster, make_distributed_conv
from repro.core.partitioner import workload_shares
from repro.data.pipeline import synthetic_cifar_batches
from repro.models.cnn import cnn_loss, init_cnn, make_cnn_config


def sgd_update(params, grads, lr):
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)


def train(cfg, conv_fn, steps, lr=0.05, seed=0, jit=True):
    params = init_cnn(jax.random.key(seed), cfg)
    it = synthetic_cifar_batches(64, seed=seed)

    def step(params, images, labels):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: cnn_loss(p, images, labels, cfg=cfg, conv_fn=conv_fn),
            has_aux=True,
        )(params)
        return sgd_update(params, grads, lr), loss, acc

    if jit:
        step = jax.jit(step)
    losses, accs = [], []
    for i in range(steps):
        b = next(it)
        params, loss, acc = step(
            params, jnp.asarray(b["images"]), jnp.asarray(b["labels"])
        )
        losses.append(float(loss))
        accs.append(float(acc))
        if i % 5 == 0:
            print(f"  step {i:3d} loss={losses[-1]:.3f} acc={accs[-1]:.2f}")
    return np.array(losses), np.array(accs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--c1", type=int, default=16)
    ap.add_argument("--c2", type=int, default=32)
    args = ap.parse_args()

    cfg = make_cnn_config(args.c1, args.c2)
    print(f"== local training ({cfg.arch_id}) ==")
    t0 = time.time()
    loss_local, acc_local = train(cfg, None or __import__(
        "repro.layers.conv", fromlist=["apply_conv"]).apply_conv, args.steps)
    print(f"local: {time.time()-t0:.1f}s, final acc {acc_local[-5:].mean():.2f}")

    print("\n== distributed training (master + 2 slaves, one 2x slower) ==")
    cluster = HeteroCluster([1.0, 1.0, 2.0])
    try:
        times = cluster.probe(
            image_size=32, in_channels=3, kernel_size=5,
            num_kernels=args.c1, batch=64,
        )
        print(f"probe times: {np.round(times, 4).tolist()}")
        print(f"Eq.1 shares: {np.round(workload_shares(times), 3).tolist()}")
        t0 = time.time()
        loss_dist, acc_dist = train(
            cfg, make_distributed_conv(cluster), args.steps, jit=False
        )
        print(f"distributed: {time.time()-t0:.1f}s, final acc {acc_dist[-5:].mean():.2f}")
        print(f"comm volume: {cluster.comm_bytes/2**20:.1f} MiB")
    finally:
        cluster.shutdown()

    drift = np.max(np.abs(loss_local - loss_dist))
    print(f"\nmax |loss_local - loss_distributed| over training = {drift:.2e}")
    assert drift < 1e-2, "distribution changed the training trajectory!"
    assert loss_local[-5:].mean() < loss_local[:5].mean() - 0.1, \
        "CNN loss did not decrease"
    print("OK: loss decreases AND distribution does not affect the "
          "training trajectory (the paper's §5.3 classification claim).")


if __name__ == "__main__":
    import os
    import traceback

    # a jit+host-callback session can leave the XLA runtime wedged at
    # interpreter shutdown on the CPU backend; exit hard once done
    code = 0
    try:
        main()
    except BaseException:
        traceback.print_exc()
        code = 1
    sys.stdout.flush()
    sys.stderr.flush()
    os._exit(code)
