"""Batched serving demo: prefill a batch of prompts and decode with the
KV-cache engine across three cache regimes — full attention (yi-style),
sliding-window ring buffer (mistral-style), and O(1) SSM state
(mamba2) — printing cache memory per sequence to show the long-context
scaling the decode shapes (decode_32k / long_500k) rely on.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, SSMConfig
from repro.models.registry import build_model
from repro.serve.engine import ServeEngine


def cache_bytes(cache) -> int:
    return sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(cache)
        if hasattr(x, "size")
    )


def demo(name: str, cfg: ModelConfig, batch=4, prompt=32, new=24):
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(batch, prompt)), jnp.int32)
    engine = ServeEngine(api=api, run=RunConfig(), params=params)

    t0 = time.time()
    out = engine.generate({"tokens": toks}, max_new_tokens=new, sample=True,
                          temperature=0.8, seed=1)
    dt = time.time() - t0

    cache = jax.eval_shape(lambda: api.init_cache(batch, prompt + new))
    per_seq = cache_bytes(cache) / batch
    print(f"{name:28s} {batch*new/dt:7.1f} tok/s  cache/seq={per_seq/2**10:8.1f} KiB"
          f"  sample: {np.asarray(out[0, :8]).tolist()}")


def main():
    base = dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                d_ff=512, vocab_size=1024, head_dim=64,
                dtype="float32", param_dtype="float32")
    demo("full-attention (yi-style)", ModelConfig(
        arch_id="serve-dense", family="dense", **base))
    demo("sliding-window (mistral)", ModelConfig(
        arch_id="serve-swa", family="dense", sliding_window=16, **base))
    ssm_base = dict(base, num_heads=0, num_kv_heads=0, d_ff=0)
    demo("SSM O(1) state (mamba2)", ModelConfig(
        arch_id="serve-ssm", family="ssm",
        ssm=SSMConfig(d_state=16, head_dim=32, chunk_size=16), **ssm_base))
    print("\nNote the cache scaling: full grows with context, SWA is capped "
          "at the window, SSM is constant — the long_500k enabler.")


if __name__ == "__main__":
    main()
