"""Continuous-batching serving demo: a ``ClusterServer`` routing conv
forward passes through a 2-slave in-process ``HeteroCluster``.

A burst of single-image requests is submitted while the server packs
them into slots (dynamic batching), pipelines each slab's scatter
against the previous slab's gather (``ServeChain``), and resolves one
future per request — then the same burst is replayed one-request-at-
a-time to show what the batching bought.  See docs/serving.md for the
knobs (deadlines, autoscaling, failure semantics).

    PYTHONPATH=src python examples/serve_cluster.py
"""
import time

import numpy as np

from repro.core.master_slave import HeteroCluster
from repro.serve.server import ClusterServer

C1, C2 = 8, 16
SIZE = 16  # request images are (SIZE, SIZE, 3)


def relu_pool(y):
    """Master-only stage after each conv: ReLU + 2x2 max-pool."""
    y = np.maximum(y, 0.0)
    b, h, w, c = y.shape
    return y.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def main():
    rng = np.random.default_rng(0)
    weights = [
        rng.standard_normal((5, 5, 3, C1)).astype(np.float32) * 0.1,
        rng.standard_normal((5, 5, C1, C2)).astype(np.float32) * 0.1,
    ]
    fc = rng.standard_normal(((SIZE // 4) ** 2 * C2, 10)).astype(np.float32) * 0.01

    def head(z):
        return z.reshape(z.shape[0], -1) @ fc

    # master + 2 slaves, one of them 1.5x slower: Eq. 1 still balances
    # the per-layer split, the serving lane rides the same plans
    cluster = HeteroCluster([1.0, 1.0, 1.5], pipeline=True, microbatches=2)
    try:
        cluster.probe(image_size=SIZE, in_channels=3, kernel_size=5,
                      num_kernels=C1, batch=4)
        images = [rng.standard_normal((SIZE, SIZE, 3)).astype(np.float32)
                  for _ in range(16)]

        def burst(max_batch, sequential):
            server = ClusterServer(
                cluster, weights, between=[relu_pool, relu_pool], head=head,
                max_batch=max_batch, default_deadline_s=30.0,
            )
            t0 = time.perf_counter()
            with server:
                if sequential:
                    resps = [server.submit(x).result(timeout=60.0)
                             for x in images]
                else:
                    futs = [server.submit(x) for x in images]
                    resps = [f.result(timeout=60.0) for f in futs]
            wall = time.perf_counter() - t0
            assert all(r.status == "ok" for r in resps)
            return wall, resps, server.stats()

        wall_b, resps, stats = burst(max_batch=4, sequential=False)
        print(f"dynamic batching (max_batch=4): {len(images)} requests in "
              f"{wall_b:.3f}s -> {len(images) / wall_b:.0f} req/s  "
              f"p50={stats['p50_ms']:.1f}ms p99={stats['p99_ms']:.1f}ms")
        print(f"  first logits: {np.round(resps[0].output, 3).tolist()}")

        wall_s, _, _ = burst(max_batch=1, sequential=True)
        print(f"one-at-a-time baseline: {wall_s:.3f}s "
              f"({wall_s / wall_b:.1f}x slower)")
    finally:
        cluster.shutdown()


if __name__ == "__main__":
    main()
