"""Heterogeneous-cluster wall-clock study — the paper's §4.1.1 experiment
on this host: distributed convolution over emulated devices of different
speeds, comparing

  1. single device (the baseline),
  2. naive equal kernel split (what the paper argues against),
  3. the Eq. 1 balanced split.

Real threads, real convolutions, real wall-clock.  The Eq. 1 split must
beat the equal split whenever the cluster is heterogeneous, because the
equal split waits for the slowest device (the paper's Device-1/Device-2
example).

    PYTHONPATH=src python examples/hetero_cluster.py
"""
import time

import numpy as np

from repro.core.master_slave import HeteroCluster
from repro.core.partitioner import workload_shares


def time_forward(cluster, x, w, reps=4):
    cluster.conv_forward(x, w)  # warm the jit caches for these shard shapes
    t0 = time.perf_counter()
    for _ in range(reps):
        cluster.conv_forward(x, w)
    return (time.perf_counter() - t0) / reps


def main():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(32, 32, 32, 3)).astype(np.float32)
    w = rng.normal(size=(5, 5, 3, 240)).astype(np.float32)

    print("== single device baseline ==")
    single = HeteroCluster([1.0])
    single.probe_times = [1.0]
    t_single = time_forward(single, x, w)
    single.shutdown()
    print(f"single-device conv: {t_single*1e3:.1f} ms")

    print("\n== heterogeneous cluster: master + slave(1x) + slave(3x slower) ==")
    cluster = HeteroCluster([1.0, 1.0, 3.0])
    try:
        probe = cluster.probe(
            image_size=32, in_channels=3, kernel_size=5, num_kernels=80, batch=32
        )
        shares = workload_shares(probe)
        print(f"probe times: {np.round(probe, 4).tolist()}")
        print(f"Eq.1 shares: {np.round(shares, 3).tolist()} "
              f"-> kernels {cluster.shares_for(w.shape[-1]).tolist()}")

        t_balanced = time_forward(cluster, x, w)
        print(f"Eq.1-balanced distributed conv: {t_balanced*1e3:.1f} ms "
              f"(speedup {t_single/t_balanced:.2f}x vs single)")

        cluster.probe_times = [1.0, 1.0, 1.0]  # force the naive equal split
        t_equal = time_forward(cluster, x, w)
        print(f"equal-split distributed conv:   {t_equal*1e3:.1f} ms "
              f"(speedup {t_single/t_equal:.2f}x vs single)")

        gain = t_equal / t_balanced
        print(f"\nEq.1 vs equal split: {gain:.2f}x faster "
              "(the paper's §4.1.1 motivation)")
        print("note: on a single-core host the absolute speedup vs one "
              "device is <1 (threads share the core + protocol overhead); "
              "the Eq.1-vs-equal ratio is the hardware-independent result.")
    finally:
        cluster.shutdown()

    print("\n== async pipeline vs barrier over a finite (50 Mbps) link ==")
    print("   (sim backend: deterministic virtual devices, zeros out)")
    xs = rng.normal(size=(16, 16, 16, 8)).astype(np.float32)
    ws = rng.normal(size=(5, 5, 8, 64)).astype(np.float32)
    times = {}
    for proto, pipelined in (("barrier", False), ("pipelined", True)):
        cluster = HeteroCluster(
            [1.0, 1.5, 3.0], ["sim", "sim", "sim"],
            pipeline=pipelined, microbatches=4, bandwidth_mbps=50.0,
        )
        try:
            cluster.probe_times = [1.0, 1.5, 3.0]  # exact Eq.1 for sim
            times[proto] = time_forward(cluster, xs, ws, reps=2)
            t = cluster.timing
            print(f"{proto:9s}: {times[proto]*1e3:.1f} ms  "
                  f"(overlap {t.overlap_s:.2f}s, blocked {t.gather_wait_s:.2f}s)")
        finally:
            cluster.shutdown()
    print(f"pipeline hides comm behind compute: "
          f"{times['barrier']/times['pipelined']:.2f}x faster")

    print("\n== mixed-backend cluster: numpy master + jitted-XLA slaves ==")
    mixed = HeteroCluster([1.0, 1.0, 2.0], ["numpy", "xla", "xla"])
    try:
        probe = mixed.probe(
            image_size=32, in_channels=3, kernel_size=5, num_kernels=80, batch=32
        )
        print(f"probe times per backend: {np.round(probe, 4).tolist()}")
        print(f"Eq.1 shares follow each device's OWN backend speed: "
              f"{mixed.shares_for(w.shape[-1]).tolist()}")
        t_mixed = time_forward(mixed, x, w)
        print(f"mixed-backend distributed conv: {t_mixed*1e3:.1f} ms")
    finally:
        mixed.shutdown()


if __name__ == "__main__":
    main()
