"""The serving lane: continuous batching vs one-request-at-a-time over
the SAME deterministic sim cluster and finite emulated links.

The cluster cost of serving one batch is (weight broadcast + input
scatter + output gather) on the wire plus the slaves' conv compute.
The sim backend's compute scales with the batch, so the lever dynamic
batching pulls is the FIXED per-batch wire cost: with the versioned
weight-broadcast cache OFF, every ``ServeChain`` push re-broadcasts
the layer kernels, and with weight-heavy layers over a finite link
that broadcast dominates.  Serving N requests one-at-a-time pays it N
times; packing ``max_batch`` slots pays it N/max_batch times — that
ratio (wall-clock, sim compute + emulated wire, deterministic) is
``serve_dynamic_batching_gain``, the acceptance gate's >= 1.5x row.
It is measured with ``weight_cache=False`` so the row stays comparable
with its pre-cache baselines.

The cache itself is the OTHER lever and gets its own gated row:
``weight_cache_serve_gain`` is continuous-batching req/s with the
versioned cache on (pushes ship ~24-byte version tokens after the
first) over req/s with it off (every push re-broadcasts), measured on
a WEIGHT-DOMINATED workload — heavier kernels over a slower link, so
the broadcast is the cost the cache removes — the direct attack on
the serve lane's wire bottleneck.

The throughput and p50/p99 tail-latency rows are the first
requests/s-denominated entries in the BENCH_PR*.json trajectory:
tracked across commits; only the gain ratios are gated.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.master_slave import HeteroCluster
from repro.serve.server import ClusterServer

SLOWDOWNS = [1.0, 1.5, 2.0]  # master + 1.5x slave + 2x-slow slave
BANDWIDTH_MBPS = 200.0       # finite links: the weight broadcast costs
WEIGHT_BW_MBPS = 15.0        # the weight-dominated link for the cache row

# Deterministic rows the CI bench-smoke lane extracts into BENCH_PR*.json.
TRAJECTORY_ROWS = (
    "serve_dynamic_batching_gain",
    "weight_cache_serve_gain",
    "serve_throughput_rps",
    "serve_p50_latency_us",
    "serve_p99_latency_us",
)

# Higher-is-better subset the bench-regression gate guards.  Latency
# rows trend the other way and are tracked, not gated.
GAIN_ROWS = ("serve_dynamic_batching_gain", "weight_cache_serve_gain")


def _serve(requests, weights, *, max_batch: int, sequential: bool,
           weight_cache: bool = False,
           bandwidth_mbps: float = BANDWIDTH_MBPS) -> dict:
    """Serve ``requests`` through a fresh sim cluster; returns wall
    seconds + the server's latency percentiles.  ``sequential`` waits
    for each response before submitting the next (the one-request-at-
    a-time baseline); otherwise everything is submitted upfront and
    the server packs slots.  ``weight_cache`` toggles the versioned
    weight-broadcast cache (off for the pre-cache-comparable rows)."""
    cluster = HeteroCluster(
        SLOWDOWNS, ["sim"] * len(SLOWDOWNS),
        pipeline=True, microbatches=2, bandwidth_mbps=bandwidth_mbps,
        weight_cache=weight_cache,
    )
    try:
        cluster.probe_times = list(SLOWDOWNS)  # exact Eq. 1 for sim
        server = ClusterServer(
            cluster, weights, max_batch=max_batch,
            max_queue=2 * len(requests) + 4,
        )
        t0 = time.perf_counter()
        with server:
            if sequential:
                resps = [server.submit(x).result(timeout=300.0)
                         for x in requests]
            else:
                futs = [server.submit(x) for x in requests]
                resps = [f.result(timeout=300.0) for f in futs]
        wall = time.perf_counter() - t0
        assert all(r.status == "ok" for r in resps), \
            [r.status for r in resps]
        stats = server.stats()
        return {"wall_s": wall, "p50_ms": stats["p50_ms"],
                "p99_ms": stats["p99_ms"]}
    finally:
        cluster.shutdown()


def run(smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    n_req = 12 if smoke else 32
    max_batch = 4 if smoke else 8
    # weight-heavy layers on small images: the per-batch kernel
    # broadcast is the wire cost batching amortizes
    weights = [
        rng.normal(size=(3, 3, 16, 64)).astype(np.float32) * 0.1,
        rng.normal(size=(3, 3, 64, 64)).astype(np.float32) * 0.1,
    ]
    requests = [rng.normal(size=(8, 8, 16)).astype(np.float32)
                for _ in range(n_req)]

    seq = _serve(requests, weights, max_batch=1, sequential=True)
    bat = _serve(requests, weights, max_batch=max_batch, sequential=False)

    gain = seq["wall_s"] / bat["wall_s"]
    rps = n_req / bat["wall_s"]
    rows.append(
        ("serve_dynamic_batching_gain", gain,
         f"sequential={seq['wall_s']:.3f}s batched={bat['wall_s']:.3f}s at "
         f"{n_req} reqs/max_batch={max_batch} (>=1.5 means packing slots "
         f"amortizes the per-batch weight broadcast; ratio, not us)")
    )

    # the versioned weight-broadcast cache, on a weight-dominated serve
    # workload: heavier kernels over a {WEIGHT_BW_MBPS} Mbps link, SAME
    # settings cache-on vs cache-off, continuous batching both sides.
    cw = [
        rng.normal(size=(3, 3, 64, 128)).astype(np.float32) * 0.1,
        rng.normal(size=(3, 3, 128, 128)).astype(np.float32) * 0.1,
    ]
    creq = [rng.normal(size=(8, 8, 64)).astype(np.float32)
            for _ in range(n_req)]
    coff = _serve(creq, cw, max_batch=max_batch, sequential=False,
                  weight_cache=False, bandwidth_mbps=WEIGHT_BW_MBPS)
    con = _serve(creq, cw, max_batch=max_batch, sequential=False,
                 weight_cache=True, bandwidth_mbps=WEIGHT_BW_MBPS)
    cache_gain = coff["wall_s"] / con["wall_s"]
    rows.append(
        ("weight_cache_serve_gain", cache_gain,
         f"cache_off={n_req / coff['wall_s']:.1f}req/s "
         f"cache_on={n_req / con['wall_s']:.1f}req/s at "
         f"{WEIGHT_BW_MBPS:.0f} Mbps (>1 means the versioned cache ships "
         f"~24-byte tokens instead of re-broadcasting static serve "
         f"kernels; ratio, not us)")
    )
    rows.append(
        ("serve_throughput_rps", rps,
         f"{rps:.1f} req/s continuous batching, sim cluster at "
         f"{BANDWIDTH_MBPS:.0f} Mbps (value is req/s, not us)")
    )
    rows.append(
        ("serve_p50_latency_us", bat["p50_ms"] * 1e3,
         f"p50 submit->response under full load (lower is better)")
    )
    rows.append(
        ("serve_p99_latency_us", bat["p99_ms"] * 1e3,
         f"p99 submit->response under full load (lower is better)")
    )
    return rows
