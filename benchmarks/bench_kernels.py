"""Kernel microbenchmarks.

On CPU the Pallas kernels run in interpret mode (Python), so wall-times
are NOT kernel performance — we time the pure-jnp references as the host
baseline and report each kernel's FLOP count + arithmetic intensity +
the v5e roofline-predicted time (the kernel-level §Roofline terms)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.roofline.analysis import HW


def _time(f, *args, reps=3):
    f(*args)
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def run():
    rows = []
    jit = jax.jit

    # conv2d: the paper's C2 layer geometry (16x16x500 -> 1500 kernels)
    x = jax.random.normal(jax.random.key(0), (8, 16, 16, 500), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (5, 5, 500, 1500), jnp.float32)
    dt = _time(jit(ref.conv2d_ref), x, w)
    flops = 2 * 8 * 16 * 16 * 1500 * 5 * 5 * 500
    byts = (x.size + w.size + 8 * 16 * 16 * 1500) * 4
    rows.append((
        "kernel_conv2d_c2layer", dt * 1e6,
        f"gflop={flops/1e9:.1f} AI={flops/byts:.0f} "
        f"v5e_pred={max(flops/HW.peak_flops, byts/HW.hbm_bw)*1e6:.0f}us "
        f"host_gflops={flops/dt/1e9:.1f}",
    ))

    # flash attention: one 32k-context decode-shape head block
    q = jax.random.normal(jax.random.key(2), (1, 8, 128, 128), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(3), (1, 8, 4096, 128), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(4), (1, 8, 4096, 128), jnp.bfloat16)
    dt = _time(jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True)), q, k, v)
    flops = 2 * 2 * 8 * 128 * 4096 * 128
    byts = (q.size + k.size + v.size + q.size) * 2
    rows.append((
        "kernel_flash_attn_4k", dt * 1e6,
        f"gflop={flops/1e9:.2f} AI={flops/byts:.0f} "
        f"v5e_pred={max(flops/HW.peak_flops, byts/HW.hbm_bw)*1e6:.0f}us",
    ))

    # ssd: mamba2-370m one layer at 4k seq
    B, S, H, P, N = 1, 4096, 32, 64, 128
    xs = jax.random.normal(jax.random.key(5), (B, S, H, P), jnp.float32)
    dts = jax.nn.softplus(jax.random.normal(jax.random.key(6), (B, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.key(7), (H,)))
    bm = jax.random.normal(jax.random.key(8), (B, S, H, N), jnp.float32)
    cm = jax.random.normal(jax.random.key(9), (B, S, H, N), jnp.float32)
    from repro.layers.mamba2 import _ssd_chunked

    dt = _time(jit(lambda *t: _ssd_chunked(*t, 256)[0]), xs, dts, a, bm, cm)
    chunk = 256
    flops = B * H * (S // chunk) * (
        2 * chunk * chunk * N + 2 * chunk * chunk * P + 2 * chunk * N * P * 2
    )
    byts = (xs.size + bm.size + cm.size + xs.size) * 4
    rows.append((
        "kernel_ssd_4k", dt * 1e6,
        f"gflop={flops/1e9:.2f} AI={flops/byts:.0f} "
        f"v5e_pred={max(flops/HW.peak_flops, byts/HW.hbm_bw)*1e6:.0f}us",
    ))
    return rows
