"""Kernel microbenchmarks.

On CPU the Pallas kernels run in interpret mode (Python), so wall-times
are NOT kernel performance — we time the pure-jnp references as the host
baseline and report each kernel's FLOP count + arithmetic intensity +
the v5e roofline-predicted time (the kernel-level §Roofline terms)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.roofline.analysis import HW


def _time(f, *args, reps=3):
    f(*args)
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def _bench_backends(rows, smoke: bool):
    """Conv backend comparison through the registry contract — the same
    code the cluster's devices run (core/backends.py)."""
    from repro.core.backends import get_backend

    rng = np.random.default_rng(0)
    b, s, cin, cout = (2, 8, 4, 16) if smoke else (8, 32, 3, 64)
    x = rng.normal(size=(b, s, s, cin)).astype(np.float32)
    w = rng.normal(size=(5, 5, cin, cout)).astype(np.float32)
    g = rng.normal(size=(b, s, s, cout)).astype(np.float32)
    flops = 2 * b * s * s * 25 * cin * cout
    for name in ("numpy", "xla"):
        bk = get_backend(name)
        dt = _time(bk.conv, x, w)
        dtv = _time(lambda *a: bk.conv_vjp(*a), x, w, g)
        rows.append((
            f"backend_conv_{name}", dt * 1e6,
            f"host_gflops={flops / dt / 1e9:.2f} vjp_us={dtv * 1e6:.0f}",
        ))

    # the numpy forward's hot path.  Copy-free formulations of the k>1
    # conv (tensordot/einsum on the strided window view, per-tap shifted
    # GEMMs) all measured SLOWER than the single large im2col GEMM —
    # tensordot materializes the same copy internally — so the copy
    # stays only where the GEMM genuinely needs it, and 1x1 kernels skip
    # the lowering entirely: one GEMM on a free reshape, no pad, no
    # window copy.  This row times that lowering-free path against
    # forcing the same shape through im2col.
    from repro.core.backends import _im2col, numpy_conv

    def _im2col_conv(xx, ww):
        kh, kw, cin_, cout_ = ww.shape
        cols = _im2col(np.asarray(xx, np.float32), kh, kw)
        y = cols.reshape(-1, kh * kw * cin_) @ ww.reshape(kh * kw * cin_, cout_)
        return y.reshape(xx.shape[0], xx.shape[1], xx.shape[2], cout_)

    bm, sm, cm = (2, 8, 16) if smoke else (8, 32, 64)
    xm = rng.normal(size=(bm, sm, sm, cm)).astype(np.float32)
    wm = rng.normal(size=(1, 1, cm, 2 * cm)).astype(np.float32)
    dt_new = min(_time(numpy_conv, xm, wm, reps=5) for _ in range(3))
    dt_old = min(_time(_im2col_conv, xm, wm, reps=5) for _ in range(3))
    rows.append((
        "numpy_fwd_1x1_nocopy", dt_new * 1e6,
        f"im2col_us={dt_old * 1e6:.0f} "
        f"gain={dt_old / dt_new:.2f}x (>1 means the lowering-free 1x1 "
        f"GEMM beats forcing the im2col window copy)",
    ))
    # pallas runs in interpret mode on CPU (Python): tiny shape, parity
    # timing only — kernel perf is only meaningful on a real TPU
    xt = x[:1, :8, :8, :2].copy()
    wt = w[:, :, :2, :8].copy()
    gt = g[:1, :8, :8, :8].copy()
    bk = get_backend("pallas")
    dt = _time(bk.conv, xt, wt)
    dtv = _time(lambda *a: bk.conv_vjp(*a), xt, wt, gt)
    rows.append((
        "backend_conv_pallas_interpret_tiny", dt * 1e6,
        f"vjp_us={dtv * 1e6:.0f} (interpret mode; not kernel perf)",
    ))


def run(smoke: bool = False):
    rows = []
    jit = jax.jit

    _bench_backends(rows, smoke)
    if smoke:
        return rows

    # conv2d: the paper's C2 layer geometry (16x16x500 -> 1500 kernels)
    x = jax.random.normal(jax.random.key(0), (8, 16, 16, 500), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (5, 5, 500, 1500), jnp.float32)
    dt = _time(jit(ref.conv2d_ref), x, w)
    flops = 2 * 8 * 16 * 16 * 1500 * 5 * 5 * 500
    byts = (x.size + w.size + 8 * 16 * 16 * 1500) * 4
    rows.append((
        "kernel_conv2d_c2layer", dt * 1e6,
        f"gflop={flops/1e9:.1f} AI={flops/byts:.0f} "
        f"v5e_pred={max(flops/HW.peak_flops, byts/HW.hbm_bw)*1e6:.0f}us "
        f"host_gflops={flops/dt/1e9:.1f}",
    ))

    # flash attention: one 32k-context decode-shape head block
    q = jax.random.normal(jax.random.key(2), (1, 8, 128, 128), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(3), (1, 8, 4096, 128), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(4), (1, 8, 4096, 128), jnp.bfloat16)
    dt = _time(jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True)), q, k, v)
    flops = 2 * 2 * 8 * 128 * 4096 * 128
    byts = (q.size + k.size + v.size + q.size) * 2
    rows.append((
        "kernel_flash_attn_4k", dt * 1e6,
        f"gflop={flops/1e9:.2f} AI={flops/byts:.0f} "
        f"v5e_pred={max(flops/HW.peak_flops, byts/HW.hbm_bw)*1e6:.0f}us",
    ))

    # ssd: mamba2-370m one layer at 4k seq
    B, S, H, P, N = 1, 4096, 32, 64, 128
    xs = jax.random.normal(jax.random.key(5), (B, S, H, P), jnp.float32)
    dts = jax.nn.softplus(jax.random.normal(jax.random.key(6), (B, S, H)))
    a = -jnp.exp(jax.random.normal(jax.random.key(7), (H,)))
    bm = jax.random.normal(jax.random.key(8), (B, S, H, N), jnp.float32)
    cm = jax.random.normal(jax.random.key(9), (B, S, H, N), jnp.float32)
    from repro.layers.mamba2 import _ssd_chunked

    dt = _time(jit(lambda *t: _ssd_chunked(*t, 256)[0]), xs, dts, a, bm, cm)
    chunk = 256
    flops = B * H * (S // chunk) * (
        2 * chunk * chunk * N + 2 * chunk * chunk * P + 2 * chunk * N * P * 2
    )
    byts = (xs.size + bm.size + cm.size + xs.size) * 4
    rows.append((
        "kernel_ssd_4k", dt * 1e6,
        f"gflop={flops/1e9:.2f} AI={flops/byts:.0f} "
        f"v5e_pred={max(flops/HW.peak_flops, byts/HW.hbm_bw)*1e6:.0f}us",
    ))
    return rows
