"""The two-tier hierarchy vs a flat cluster on a master-ingress-bound
link — the regime the hierarchy exists for.

Both topologies get SEVEN devices and the SAME emulated shared master
NIC (``SharedNIC``: one port, every root link's bytes serialized
through it per direction):

  flat      — 1 master + 6 slaves on the batch axis.  Every step, each
              of the 6 members sends its dX rows AND a FULL dW through
              the shared port: ingress carries 6 copies of the kernel
              gradient.
  two-tier  — 1 root + 2 sub-masters, each sub-master mastering its own
              2 leaves over free in-proc links (group-local traffic
              never touches the root port).  Each group PRE-SUMS its
              members' dW, so root ingress carries 2 copies — same
              exact all-reduce, a third of the gradient bytes.

With parameter-heavy layers (dW >> activation rows) and sim compute
pinned fast, the serialized port is what the step measures, and the
``hierarchy_vs_flat_gain`` row is the wall-clock ratio — the ISSUE
acceptance bar is >= 1.3x.  Deterministic: sleep-for-flops sim devices,
pinned probe times, min across reps and fresh instantiations.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.cluster.hierarchy import GroupSpec, HierarchicalCluster
from repro.core.master_slave import HeteroCluster

# Extracted into BENCH_PR*.json by benchmarks/run.py --trajectory and
# gated (higher-is-better) by --check-against.
TRAJECTORY_ROWS = ("hierarchy_vs_flat_gain",)
GAIN_ROWS = ("hierarchy_vs_flat_gain",)

NIC_MBPS = 200.0  # the shared master port both topologies squeeze through


def _head(z, i):
    return 0.0, np.zeros_like(z)


def _time_steps(make_cluster, x, weights, probe_flops, reps) -> float:
    """Min wall-clock across reps AND across two fresh instantiations
    (the bench_master_slave idiom): emulated-port sleeps are
    deterministic, so the global minimum converges to the schedule's
    true cost while host scheduling spikes are discarded."""
    best = float("inf")
    for _ in range(2):
        cluster = make_cluster()
        try:
            n = 1 + cluster.n_slaves
            cluster.probe_times = [1e9] + [probe_flops / 1e11] * (n - 1)
            cluster.probe_flops = probe_flops
            cluster.conv_train_chain(x, weights, None, _head)  # warm
            for _ in range(max(reps, 3)):
                t0 = time.perf_counter()
                cluster.conv_train_chain(x, weights, None, _head)
                best = min(best, time.perf_counter() - t0)
        finally:
            cluster.shutdown()
    return best


def run(smoke: bool = False):
    """One gain row plus the two absolute step times behind it."""
    rng = np.random.default_rng(0)
    reps = 2 if smoke else 3
    micro = 2
    b, hw = (6, 8) if smoke else (12, 8)
    cin, c1, c2 = (16, 48, 48) if smoke else (16, 64, 64)

    # parameter-heavy: dW bytes (3x3*cin*c1 + 3x3*c1*c2 floats per
    # member per step) dwarf the activation rows through the port
    x = rng.normal(size=(b, hw, hw, cin)).astype(np.float32)
    w1 = rng.normal(size=(3, 3, cin, c1)).astype(np.float32)
    w2 = rng.normal(size=(3, 3, c1, c2)).astype(np.float32)
    probe_flops = 2.0 * b * hw * hw * 9 * cin * c1

    def flat():
        # 6 batch members behind one shared port; the root's own compute
        # is priced out (probe time pinned huge) so every row — and
        # every full dW — crosses the port, 6 copies per step
        return HeteroCluster(
            [1.0] * 7, ["numpy"] + ["sim:1e11"] * 6, partition="batch",
            pipeline=True, microbatches=micro, master_nic_mbps=NIC_MBPS,
        )

    def two_tier():
        # same 7 devices as 2 groups of 3: group-local links are free
        # in-proc queues, the port carries 2 PRE-SUMMED dW per step
        return HierarchicalCluster(
            [GroupSpec(slowdowns=[1.0] * 3, backends=["sim:1e11"] * 3,
                       partition="batch", microbatches=micro)] * 2,
            master_backend="numpy", pipeline=True, microbatches=micro,
            master_nic_mbps=NIC_MBPS,
        )

    t_flat = _time_steps(flat, x, [w1, w2], probe_flops, reps)
    t_tier = _time_steps(two_tier, x, [w1, w2], probe_flops, reps)
    gain = t_flat / t_tier

    rows = [
        ("trainstep_flat6_nic200", t_flat * 1e6,
         f"1 master + 6 batch slaves through one {NIC_MBPS:.0f} Mbps "
         f"port: 6 full dW per step"),
        ("trainstep_hier2x3_nic200", t_tier * 1e6,
         f"2 sub-masters x 3 devices, same port: 2 pre-summed dW per "
         f"step, group traffic stays off the port"),
        ("hierarchy_vs_flat_gain", gain,
         f"gain={gain:.2f}x (>1 means the 2x3 two-tier cluster beats "
         f"the flat 6-slave cluster on a master-ingress-bound "
         f"{NIC_MBPS:.0f} Mbps port; ratio, not us)"),
    ]
    return rows
