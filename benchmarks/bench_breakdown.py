"""Figures 6/8: elapsed-time breakdown (comm / conv / comp) for one
batch of 1024 images across network sizes and node counts."""
from __future__ import annotations

from repro.core.simulator import (
    PAPER_COMP_FRACTION,
    PAPER_TABLE4_CPU,
    PAPER_TABLE5_GPU,
    fit_paper_row,
    predict_speedups,
)
from repro.core.costmodel import paper_network, upload_elements_nodes
from repro.core.simulator import PAPER_CPU_SPEEDS, PAPER_GPU_SPEEDS

import numpy as np


def run():
    rows = []
    for device, table, speeds in (
        ("cpu", PAPER_TABLE4_CPU, PAPER_CPU_SPEEDS),
        ("gpu", PAPER_TABLE5_GPU, PAPER_GPU_SPEEDS),
    ):
        for (c1, c2), reported in table.items():
            fit = fit_paper_row(c1, c2, reported, device=device)
            cf, beta = fit["comp_fraction"], fit["beta"]
            layers = paper_network(c1, c2)
            for n in range(1, len(speeds) + 1):
                t = 1.0 / np.asarray(speeds[:n])
                shares = (1.0 / t) / np.sum(1.0 / t)
                vol = upload_elements_nodes(layers, 1024, shares[1:]) * 8 if n > 1 else 0.0
                comm = vol * beta
                conv = (1 - cf) / np.sum(np.asarray(speeds[:n]))
                total = comm + conv + cf
                rows.append(
                    (
                        f"fig{'6' if device == 'cpu' else '8'}_{device}_{c1}:{c2}_n{n}",
                        0.0,
                        f"comm={comm/total:.0%} conv={conv/total:.0%} comp={cf/total:.0%}",
                    )
                )
    return rows
