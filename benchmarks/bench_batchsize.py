"""Figures 5/7, batch-size axis: the paper varies the batch over
{64, 128, 256, 512, 1024} for every network and finds

* CPU (§5.3.1): batch size barely moves the speedup except for the
  largest network (2.21x-3.28x spread at 4 CPUs);
* GPU (§5.3.2): batch size matters MOST for the smallest network
  (1.45x-2.45x spread at 3 GPUs) and least for the largest.

FINDING (negative result, reported in EXPERIMENTS.md §Repro): the
calibrated Eq. 1/Eq. 2 model does NOT reproduce these spreads — comm and
conv are both linear in batch, so the speedup only shifts through the
batch-independent kernel-scatter term, which moves the CPU spreads the
wrong way and leaves the GPU spreads near zero.  The paper's own §5.3.2
explanation ("for smaller amounts of data the GPU handles these tasks
less efficiently") is a batch-dependent DEVICE-EFFICIENCY effect that its
comm/conv cost model (Eq. 2) cannot express; reproducing the batch axis
would need a utilisation term eta(batch) per device class.
"""
from __future__ import annotations

import numpy as np

from repro.core.simulator import (
    PAPER_CPU_SPEEDS,
    PAPER_GPU_SPEEDS,
    PAPER_TABLE4_CPU,
    PAPER_TABLE5_GPU,
    fit_paper_row,
    predict_speedups,
)

BATCHES = (64, 128, 256, 512, 1024)


def run():
    rows = []
    for device, table, speeds in (
        ("cpu", PAPER_TABLE4_CPU, PAPER_CPU_SPEEDS),
        ("gpu", PAPER_TABLE5_GPU, PAPER_GPU_SPEEDS),
    ):
        n = len(speeds)
        for (c1, c2), reported in table.items():
            fit = fit_paper_row(c1, c2, reported, device=device)
            sp = []
            for batch in BATCHES:
                pred = predict_speedups(
                    c1, c2, batch, speeds=speeds,
                    comp_fraction=fit["comp_fraction"], beta=fit["beta"],
                    n_list=[n],
                )[0]
                sp.append(pred)
                rows.append(
                    (
                        f"fig{'5' if device == 'cpu' else '7'}_{device}_{c1}:{c2}_b{batch}",
                        0.0,
                        f"speedup_at_{n}dev={pred:.2f}x",
                    )
                )
            spread = max(sp) - min(sp)
            rows.append(
                (
                    f"fig{'5' if device == 'cpu' else '7'}_{device}_{c1}:{c2}_batch_spread",
                    0.0,
                    f"spread={spread:.2f}x over batches {BATCHES[0]}-{BATCHES[-1]}",
                )
            )
    return rows
